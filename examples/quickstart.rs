//! Quickstart: mine a small synthetic graph with pattern morphing.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use morphmine::apps;
use morphmine::graph::generators::{Dataset, Scale};
use morphmine::morph::Policy;
use morphmine::pattern::catalog;

fn main() -> anyhow::Result<()> {
    // 1. a data graph (synthetic stand-in for the paper's Mico dataset)
    let graph = Dataset::MicoSim.generate(Scale::Tiny);
    println!(
        "graph {}: |V|={} |E|={} labels={}",
        graph.name(),
        graph.num_vertices(),
        graph.num_edges(),
        graph.num_labels()
    );

    // 2. count all 4-vertex motifs, morphed (cost-based) vs direct
    let direct = apps::count_motifs(&graph, 4, Policy::Off, 4);
    let morphed = apps::count_motifs(&graph, 4, Policy::CostBased, 4);
    println!("\n4-motif counts (direct == morphed):");
    for ((p, a), (_, b)) in direct.counts.iter().zip(&morphed.counts) {
        assert_eq!(a, b, "morphing must be exact");
        println!("  {a:>12}  {p:?}");
    }

    // 3. match a single vertex-induced pattern and show its morph equation
    let query = catalog::cycle(4).vertex_induced();
    let r = apps::match_patterns(&graph, &[query.clone()], Policy::Naive, 4);
    println!("\nvertex-induced 4-cycles: {}", r.counts[0]);
    println!("morphed through: {:?}", r.alt_set);
    println!("equation: {}", r.equations[0]);

    // 4. phase breakdown (matching vs conversion)
    println!("\nphases:");
    for (name, d) in r.profile.entries() {
        println!("  {name:<10} {:.4}s", d.as_secs_f64());
    }
    Ok(())
}
