//! Frequent subgraph mining on a labeled graph, with and without morphing
//! (the paper's 3-FSM experiment, §4.6).

use morphmine::apps::{fsm, FsmConfig};
use morphmine::graph::generators::{Dataset, Scale};
use morphmine::morph::Policy;
use morphmine::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let graph = Dataset::MicoSim.generate(Scale::Tiny);
    let support = (graph.num_vertices() / 25) as u64;
    println!(
        "3-FSM on {} (|V|={}, |E|={}, {} labels, support ≥ {support})",
        graph.name(),
        graph.num_vertices(),
        graph.num_edges(),
        graph.num_labels()
    );

    let mut reference: Option<Vec<(String, u64)>> = None;
    for policy in [Policy::Off, Policy::Naive, Policy::CostBased] {
        let t = Timer::start();
        let r = fsm(
            &graph,
            &FsmConfig {
                max_edges: 3,
                support,
                policy,
                threads: 4,
            },
        );
        let mut freq: Vec<(String, u64)> = r
            .frequent
            .iter()
            .map(|(p, s)| (format!("{p:?}"), *s))
            .collect();
        freq.sort();
        println!(
            "{policy:?}: {:.3}s — {} frequent 3-edge patterns (match={:.3}s)",
            t.secs(),
            freq.len(),
            r.profile.get("match").as_secs_f64(),
        );
        if let Some(prev) = &reference {
            assert_eq!(prev, &freq, "FSM results must be policy-independent");
        } else {
            for (p, s) in freq.iter().take(10) {
                println!("    support={s:<6} {p}");
            }
            if freq.len() > 10 {
                println!("    … and {} more", freq.len() - 10);
            }
            reference = Some(freq);
        }
    }
    println!("all policies agree — FSM morphing is exact");
    Ok(())
}
