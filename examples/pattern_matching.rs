//! Pattern matching with morphing: the paper's p1–p7 queries (§4.5).
//!
//! Shows per-policy timings and the alternative pattern sets the cost-based
//! optimizer chooses per graph (Table 4 behaviour).

use morphmine::apps::match_patterns;
use morphmine::graph::generators::{Dataset, Scale};
use morphmine::morph::Policy;
use morphmine::pattern::catalog;
use morphmine::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    for dataset in [Dataset::MicoSim, Dataset::PatentsSim] {
        let graph = dataset.generate(Scale::Tiny);
        println!(
            "\n== {} (|V|={}, |E|={}) ==",
            graph.name(),
            graph.num_vertices(),
            graph.num_edges()
        );
        for i in 1..=7 {
            let q = catalog::paper_pattern(i).vertex_induced();
            let mut row = format!("p{i}^V ");
            let mut counts = Vec::new();
            for policy in [Policy::Off, Policy::Naive, Policy::CostBased] {
                let t = Timer::start();
                let r = match_patterns(&graph, std::slice::from_ref(&q), policy, 4);
                row.push_str(&format!(" {:?}={:.3}s", policy, t.secs()));
                counts.push(r.counts[0]);
            }
            assert!(counts.windows(2).all(|w| w[0] == w[1]));
            println!("{row}  count={}", counts[0]);
        }
        // show the chosen alternative sets for a pattern group
        let group = vec![catalog::paper_pattern(2), catalog::paper_pattern(3)];
        let r = match_patterns(&graph, &group, Policy::CostBased, 4);
        println!("{{p2^E, p3^E}} cost-based alternative set:");
        for p in &r.alt_set {
            println!("    {}", morphmine::bench::describe_short(p));
        }
        for e in &r.equations {
            println!("  {e}");
        }
    }
    Ok(())
}
