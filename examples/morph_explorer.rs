//! Morph explorer: print the superpattern lattice and morphing equations
//! for any pattern (both directions of the Match Conversion Theorem).
//!
//! ```bash
//! cargo run --release --example morph_explorer -- cycle4
//! cargo run --release --example morph_explorer -- "0-1,1-2,2-3,3-0,0-2;vi"
//! ```

use morphmine::bench::{describe_short, render_unique_equation};
use morphmine::morph::MorphExpr;
use morphmine::pattern::{gen, iso, parse};

fn main() -> anyhow::Result<()> {
    let spec = std::env::args().nth(1).unwrap_or_else(|| "cycle4".into());
    let p = parse::parse(&spec)?;
    println!("pattern: {p:?}");
    println!("  |Aut| = {}", iso::automorphisms(&p).len());
    println!(
        "  kind: {}",
        if p.is_clique() {
            "clique (edge- AND vertex-induced; never morphs)"
        } else if p.is_vertex_induced() {
            "vertex-induced"
        } else if p.is_edge_induced() {
            "edge-induced"
        } else {
            "mixed anti-edges"
        }
    );

    let skeleton = p.edge_induced();
    println!("\nsuperpattern lattice (q ⊃n p over the edge skeleton):");
    for q in gen::superpatterns(&skeleton) {
        let phi = iso::phi_count(&skeleton, &q);
        let reps = iso::phi_coset_reps(&skeleton, &q).len();
        println!(
            "  {:<12} |φ| = {phi:>3}  coset reps = {reps}",
            describe_short(&q)
        );
    }

    if p.is_edge_induced() && !p.is_clique() {
        println!("\nTheorem 3.1 (edge-induced → vertex-induced alternatives):");
        println!("  {}", render_unique_equation(&MorphExpr::theorem_3_1(&p)));
    }
    if p.is_vertex_induced() && !p.is_clique() {
        println!("\nCorollary 3.1 (vertex-induced → signed mix):");
        println!("  {}", render_unique_equation(&MorphExpr::corollary_3_1(&p)));
        let mut full = MorphExpr::corollary_3_1(&p);
        full.expand_to_edge_basis();
        println!("recursively expanded to the edge-induced basis:");
        println!("  {}", render_unique_equation(&full));
    }
    Ok(())
}
