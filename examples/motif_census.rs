//! End-to-end driver (DESIGN.md §4): full-system motif counting on a real
//! (synthetic) workload, exercising every layer:
//!
//! * Layer 3 — coordinator + sparse pattern-aware matcher with morphing,
//!   all three PMR policies;
//! * Layers 1–2 — the AOT-compiled XLA census (Pallas masked-matmul kernel
//!   inside the JAX model), cross-checked against the sparse engine on an
//!   induced subgraph.
//!
//! ```bash
//! make artifacts && cargo run --release --example motif_census
//! ```

use morphmine::coordinator::{Backend, Config, Coordinator};
use morphmine::graph::generators::{Dataset, Scale};
use morphmine::graph::GraphBuilder;
use morphmine::morph::Policy;
use morphmine::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let graph = Dataset::MicoSim.generate(Scale::Small);
    println!(
        "== motif census on {} (|V|={}, |E|={}) ==",
        graph.name(),
        graph.num_vertices(),
        graph.num_edges()
    );

    // --- Layer 3: sparse matcher under the three policies ---------------
    let mut reference = None;
    for policy in [Policy::Off, Policy::Naive, Policy::CostBased] {
        let c = Coordinator::new(
            graph.clone(),
            Config {
                policy,
                artifacts_dir: None,
                ..Config::default()
            },
        )?;
        let t = Timer::start();
        let (m, backend) = c.motifs(4)?;
        let secs = t.secs();
        assert_eq!(backend, Backend::Sparse);
        let counts: Vec<u64> = m.counts.iter().map(|&(_, c)| c).collect();
        println!(
            "{:?}  {:>8.3}s  match={:.3}s convert={:.3}s  total={} matches",
            policy,
            secs,
            m.profile.get("match").as_secs_f64(),
            m.profile.get("convert").as_secs_f64(),
            m.total(),
        );
        if let Some(prev) = &reference {
            assert_eq!(prev, &counts, "policies must agree exactly");
        } else {
            for (p, c) in &m.counts {
                println!("    {c:>14}  {p:?}");
            }
            reference = Some(counts);
        }
    }

    // --- Layers 1–2: dense XLA census cross-check -----------------------
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("census_128.hlo.txt").exists() {
        println!("\n(dense backend skipped: run `make artifacts` first)");
        return Ok(());
    }
    // induced subgraph on the 100 highest-degree vertices (IDs are
    // degree-ordered) — fits the 128-wide artifact
    let block: Vec<u32> = (0..100u32).collect();
    let dense = graph.densify(&block);
    let mut b = GraphBuilder::new().num_vertices(block.len());
    for i in 0..block.len() {
        for j in (i + 1)..block.len() {
            if dense[i * block.len() + j] != 0.0 {
                b = b.edge(i as u32, j as u32);
            }
        }
    }
    let sub = b.build("mico-sim-head");
    let c = Coordinator::new(
        sub.clone(),
        Config {
            policy: Policy::Off,
            artifacts_dir: Some(artifacts),
            ..Config::default()
        },
    )?;
    let t = Timer::start();
    let (dense_counts, backend) = c.motifs(4)?;
    println!(
        "\ndense XLA census on head-100 subgraph ({backend:?}, {:.3}s):",
        t.secs()
    );
    assert_eq!(backend, Backend::DenseXla);
    let sparse = morphmine::apps::count_motifs(&sub, 4, Policy::Off, 4);
    for (p, a) in &dense_counts.counts {
        let b = sparse.get(p).unwrap();
        println!(
            "    {a:>12}  {p:?}  {}",
            if *a == b { "✓ (matches sparse)" } else { "✗" }
        );
        assert_eq!(*a, b, "dense and sparse backends must agree");
    }
    println!("\nall layers agree — end-to-end OK");
    Ok(())
}
