//! End-to-end application tests over the coordinator, on the synthetic
//! dataset stand-ins at tiny scale.

use morphmine::apps;
use morphmine::coordinator::{Config, Coordinator};
use morphmine::graph::generators::{Dataset, Scale};
use morphmine::graph::io;
use morphmine::morph::Policy;
use morphmine::pattern::catalog;

#[test]
fn motif_counting_all_datasets_policies_agree() {
    for d in Dataset::all() {
        let g = d.generate(Scale::Tiny);
        let off = apps::count_motifs(&g, 3, Policy::Off, 2);
        let naive = apps::count_motifs(&g, 3, Policy::Naive, 2);
        let cost = apps::count_motifs(&g, 3, Policy::CostBased, 2);
        for ((p, a), ((_, b), (_, c))) in off
            .counts
            .iter()
            .zip(naive.counts.iter().zip(cost.counts.iter()))
        {
            assert_eq!(a, b, "{} {p:?}", d.name());
            assert_eq!(a, c, "{} {p:?}", d.name());
        }
    }
}

#[test]
fn paper_patterns_on_mico_sim() {
    let g = Dataset::MicoSim.generate(Scale::Tiny);
    let queries: Vec<_> = (1..=4)
        .map(|i| catalog::paper_pattern(i).vertex_induced())
        .collect();
    let off = apps::match_patterns(&g, &queries, Policy::Off, 2);
    let cost = apps::match_patterns(&g, &queries, Policy::CostBased, 2);
    assert_eq!(off.counts, cost.counts);
    // dense co-authorship-like graph must contain all these patterns
    assert!(off.counts.iter().all(|&c| c > 0), "{:?}", off.counts);
}

#[test]
fn fsm_on_labeled_datasets() {
    for d in [Dataset::MicoSim, Dataset::PatentsSim] {
        let g = d.generate(Scale::Tiny);
        let support = (g.num_vertices() / 40) as u64;
        let c = Coordinator::new(
            g,
            Config {
                policy: Policy::CostBased,
                threads: 2,
                artifacts_dir: None,
                ..Config::default()
            },
        )
        .unwrap();
        let r = c.fsm(2, support);
        assert!(
            !r.frequent.is_empty(),
            "{}: no frequent 2-edge patterns at support {support}",
            d.name()
        );
        // every frequent pattern must actually meet the threshold
        for (p, s) in &r.frequent {
            assert!(*s >= support, "{p:?} support {s} < {support}");
            assert_eq!(p.num_edges(), 2);
            assert!(p.is_labeled());
        }
        // level-1 patterns are supersets of level-2 skeletons (antimonotone)
        assert!(r.levels[0].len() >= 1);
    }
}

#[test]
fn clique_counting_across_datasets() {
    for d in Dataset::all() {
        let g = d.generate(Scale::Tiny);
        let k3 = apps::count_cliques(&g, 3, 2);
        let k4 = apps::count_cliques(&g, 4, 2);
        // consistency with the motif counter
        let motifs = apps::count_motifs(&g, 3, Policy::Off, 2);
        assert_eq!(motifs.get(&catalog::triangle()), Some(k3), "{}", d.name());
        let m4 = apps::count_motifs(&g, 4, Policy::Naive, 2);
        assert_eq!(m4.get(&catalog::clique(4)), Some(k4), "{}", d.name());
    }
}

#[test]
fn graph_io_roundtrip_through_mining() {
    let g = Dataset::MicoSim.generate(Scale::Tiny);
    let path = std::env::temp_dir().join("mm_integration_roundtrip.txt");
    io::save_text(&g, &path).unwrap();
    let g2 = io::load_text(&path).unwrap();
    let a = apps::count_motifs(&g, 3, Policy::Off, 2);
    let b = apps::count_motifs(&g2, 3, Policy::Off, 2);
    for ((p, x), (_, y)) in a.counts.iter().zip(&b.counts) {
        assert_eq!(x, y, "{p:?}");
    }
}

#[test]
fn fig2_shape_mc_dominated_by_matching() {
    // the Figure-2 claim: motif counting spends its time matching, not
    // aggregating
    let g = Dataset::MicoSim.generate(Scale::Tiny);
    let r = apps::count_motifs(&g, 4, Policy::Off, 2);
    let match_t = r.profile.get("match").as_secs_f64();
    let conv_t = r.profile.get("convert").as_secs_f64();
    assert!(
        match_t > 10.0 * conv_t,
        "matching {match_t}s should dominate conversion {conv_t}s"
    );
}

#[test]
fn enumeration_equals_counting() {
    let g = Dataset::PatentsSim.generate(Scale::Tiny);
    let q = catalog::diamond().vertex_induced();
    let subs = apps::matching::enumerate_pattern(&g, &q, Policy::Naive, 2);
    let counts = apps::match_patterns(&g, std::slice::from_ref(&q), Policy::Off, 2);
    assert_eq!(subs.len() as u64, counts.counts[0]);
}
