//! Integration tests for the morphing theory — the strongest form of the
//! paper's claims, checked as *match-set* equalities (not just counts) on
//! random graphs, plus aggregation conversion for enumeration and MNI.

use morphmine::agg::{aggregate_pattern, CountAgg, EnumerateAgg, MniAgg};
use morphmine::graph::generators::{assign_labels, barabasi_albert, erdos_renyi};
use morphmine::morph::{self, MorphExpr, Policy};
use morphmine::pattern::{catalog, gen, Pattern};
use morphmine::plan::cost::CostParams;
use morphmine::util::proptest;
use morphmine::util::timer::PhaseProfile;
use std::collections::HashMap;

/// Theorem 3.1 as a SET equality: M(p^E) == M(p^V) ⊎ ⋃ M(q^V)∘φ.
/// Evaluated through the enumeration aggregation, which materializes the
/// (signed) match multisets — so any overlap or multiplicity error fails.
#[test]
fn theorem_3_1_match_set_equality() {
    proptest::check(0x7E0, 12, |rng| {
        let n = 14 + rng.below_usize(12);
        let m = 2 * n + rng.below_usize(2 * n);
        let g = erdos_renyi(n, m, rng.next_u64());
        for q in [
            catalog::cycle(4),
            catalog::tailed_triangle(),
            catalog::path(4),
            catalog::star(4),
        ] {
            let expr = MorphExpr::theorem_3_1(&q);
            let mut values = HashMap::new();
            for b in expr.base_patterns() {
                values.insert(
                    b.canonical_key(),
                    aggregate_pattern(&g, &b, &EnumerateAgg, 1),
                );
            }
            let converted = expr.evaluate(&EnumerateAgg, &values);
            converted.assert_consistent();
            let direct = aggregate_pattern(&g, &q, &EnumerateAgg, 1);
            assert_eq!(
                converted.matches(),
                direct.matches(),
                "match sets differ for {q:?}"
            );
        }
    });
}

/// Corollary 3.1 as a SET equality with exact cancellation.
#[test]
fn corollary_3_1_match_set_equality() {
    proptest::check(0xC0B, 10, |rng| {
        let n = 12 + rng.below_usize(10);
        let m = 2 * n + rng.below_usize(2 * n);
        let g = erdos_renyi(n, m, rng.next_u64());
        for q in [
            catalog::cycle(4).vertex_induced(),
            catalog::tailed_triangle().vertex_induced(),
            catalog::star(4).vertex_induced(),
        ] {
            let mut expr = MorphExpr::corollary_3_1(&q);
            expr.expand_to_edge_basis();
            let mut values = HashMap::new();
            for b in expr.base_patterns() {
                values.insert(
                    b.canonical_key(),
                    aggregate_pattern(&g, &b, &EnumerateAgg, 1),
                );
            }
            let converted = expr.evaluate(&EnumerateAgg, &values);
            converted.assert_consistent(); // no negative residue
            let direct = aggregate_pattern(&g, &q, &EnumerateAgg, 1);
            assert_eq!(converted.matches(), direct.matches(), "{q:?}");
        }
    });
}

/// Theorem 3.2 for the MNI aggregation: morphed MNI tables equal direct
/// ones (domains and support), on labeled graphs.
#[test]
fn aggregation_conversion_mni_tables() {
    proptest::check(0x311A, 8, |rng| {
        let n = 16 + rng.below_usize(12);
        let g = assign_labels(
            erdos_renyi(n, 3 * n, rng.next_u64()),
            2,
            1.2,
            rng.next_u64(),
        );
        // labeled path and triangle queries
        let labels: Vec<u32> = (0..3).map(|_| rng.below(2) as u32).collect();
        for base in [catalog::path(3), catalog::triangle()] {
            let q = base.with_labels(&labels);
            let qv = q.vertex_induced();
            for query in [q, qv] {
                if query.is_clique() && query.num_anti_edges() > 0 {
                    continue;
                }
                let agg = MniAgg {
                    n: query.num_vertices(),
                };
                let direct = aggregate_pattern(&g, &query, &agg, 1);
                let expr = morph::engine::naive_expr(&query);
                let mut values = HashMap::new();
                for b in expr.base_patterns() {
                    values.insert(b.canonical_key(), aggregate_pattern(&g, &b, &agg, 1));
                }
                let converted = expr.evaluate(&agg, &values);
                converted.assert_consistent();
                assert_eq!(converted.support(), direct.support(), "{query:?}");
                for v in 0..query.num_vertices() {
                    assert_eq!(converted.domain(v), direct.domain(v), "{query:?} col {v}");
                }
            }
        }
    });
}

/// All 5-vertex motifs: counting equivalence across policies (heavier
/// lattice: up to 21 superpatterns).
#[test]
fn five_vertex_morphing_counts() {
    let g = erdos_renyi(35, 140, 99);
    let queries: Vec<Pattern> = vec![
        catalog::house().vertex_induced(),
        catalog::gem().vertex_induced(),
        catalog::cycle(5).vertex_induced(),
        catalog::house(),
        catalog::cycle(5),
        catalog::path(5),
    ];
    let off = morph::engine::count_queries(&g, &queries, Policy::Off, 2);
    let naive = morph::engine::count_queries(&g, &queries, Policy::Naive, 2);
    let cost = morph::engine::count_queries(&g, &queries, Policy::CostBased, 2);
    assert_eq!(off, naive);
    assert_eq!(off, cost);
}

/// Morphing on heavy-tailed graphs (the regime where it pays off).
#[test]
fn morphing_on_powerlaw_graphs() {
    let g = barabasi_albert(400, 5, 0xBA);
    let motifs = catalog::motifs_vertex_induced(4);
    let off = morph::engine::count_queries(&g, &motifs, Policy::Off, 2);
    let naive = morph::engine::count_queries(&g, &motifs, Policy::Naive, 2);
    assert_eq!(off, naive);
}

/// A mixed query set (edge- and vertex-induced, shared superpatterns) plans
/// a deduplicated base and converts every query correctly.
#[test]
fn mixed_query_set_shares_bases() {
    let g = erdos_renyi(60, 260, 0x517);
    let queries = vec![
        catalog::cycle(4),
        catalog::cycle(4).vertex_induced(),
        catalog::diamond(),
        catalog::diamond().vertex_induced(),
        catalog::clique(4),
    ];
    let plan = morph::plan_queries(&queries, Policy::Naive, None, &CostParams::counting());
    // naive: C4^E → {C4^V, dia^V, K4}; C4^V → {C4^E, dia^E, K4};
    // dia^E → {dia^V, K4}; dia^V → {dia^E, K4}; K4 → {K4}
    // shared base set must contain K4 exactly once
    let k4 = catalog::clique(4).canonical_key();
    assert_eq!(
        plan.base.iter().filter(|p| p.canonical_key() == k4).count(),
        1
    );
    let mut profile = PhaseProfile::new();
    let values = morph::execute(&g, &plan, &CountAgg, 2, &mut profile);
    let direct = morph::engine::count_queries(&g, &queries, Policy::Off, 2);
    for ((q, &maps), want) in queries.iter().zip(values.iter()).zip(direct) {
        let aut = morphmine::pattern::iso::automorphisms(q).len() as i128;
        assert_eq!((maps / aut) as u64, want, "{q:?}");
    }
}

/// Labeled morphing: superpatterns carry labels; φ respects them.
#[test]
fn labeled_pattern_morphing() {
    proptest::check(0x1AB, 10, |rng| {
        let n = 20 + rng.below_usize(15);
        let g = assign_labels(
            erdos_renyi(n, 3 * n, rng.next_u64()),
            3,
            1.3,
            rng.next_u64(),
        );
        let labels: Vec<u32> = (0..4).map(|_| rng.below(3) as u32).collect();
        let q = catalog::cycle(4).with_labels(&labels);
        for query in [q.clone(), q.vertex_induced()] {
            let off = morph::engine::count_queries(&g, &[query.clone()], Policy::Off, 1);
            let naive = morph::engine::count_queries(&g, &[query.clone()], Policy::Naive, 1);
            assert_eq!(off, naive, "{query:?}");
        }
    });
}

/// The superpattern lattice of every 4-vertex motif is exactly the set of
/// denser 4-vertex motifs it embeds into (cross-validates gen::superpatterns
/// against φ).
#[test]
fn superpattern_lattice_consistency() {
    let motifs = gen::connected_patterns(4);
    for p in &motifs {
        let sups = gen::superpatterns(p);
        for q in &motifs {
            let embeds = morphmine::pattern::iso::phi_count(p, q) > 0;
            let denser = q.num_edges() > p.num_edges();
            let in_lattice = sups
                .iter()
                .any(|s| s.canonical_key() == q.canonical_key());
            assert_eq!(
                in_lattice,
                embeds && denser,
                "p={p:?} q={q:?} (embeds={embeds}, denser={denser})"
            );
        }
    }
}
