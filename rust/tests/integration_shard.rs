//! Integration tests for the distributed first-level sharding layer
//! (`morphmine::shard`): merged shard counts vs single-process execution
//! (property-tested), handshake/fingerprint rejection, shard-local
//! persistence across worker restarts, and protocol behavior on torn or
//! hostile byte streams.

use morphmine::graph::generators::erdos_renyi;
use morphmine::graph::{DataGraph, GraphStats};
use morphmine::morph::Policy;
use morphmine::pattern::catalog;
use morphmine::service::persist::PersistConfig;
use morphmine::service::{QueryPlanner, ResultStore};
use morphmine::shard::proto::{self, ExecRequest, ExecResponse, Msg};
use morphmine::shard::{ShardCoordinator, ShardPool, ShardWorker, WorkerConfig};
use morphmine::util::proptest;
use morphmine::util::timer::PhaseProfile;

fn worker_config() -> WorkerConfig {
    WorkerConfig {
        threads: 2,
        fused: true,
        cache_bytes: 1 << 20,
        persist: None,
        slice_pin: None,
    }
}

fn spawn_workers(g: &DataGraph, k: usize, config: WorkerConfig) -> (Vec<ShardWorker>, Vec<String>) {
    let workers: Vec<ShardWorker> = (0..k)
        .map(|_| ShardWorker::bind(g.clone(), "127.0.0.1:0", config.clone()).unwrap())
        .collect();
    let addrs = workers.iter().map(|w| w.addr().to_string()).collect();
    (workers, addrs)
}

/// The acceptance property: 2-shard merged counts equal single-process
/// counts on ER graphs across motif sizes 3–4 (the distributed mirror of
/// the fused-equals-per-pattern test). Runs the full pipeline both ways —
/// morph, store probe, execute, compose — through the same planner.
#[test]
fn two_shard_merged_counts_equal_single_process() {
    proptest::check(0x54A2, 6, |rng| {
        let n = 12 + rng.below_usize(16);
        let m = n + rng.below_usize(3 * n);
        let g = erdos_renyi(n, m, rng.next_u64());
        let (workers, addrs) = spawn_workers(&g, 2, worker_config());
        let mut pool = ShardPool::connect(&addrs, &g).unwrap();
        let stats = GraphStats::compute(&g, 2000, 0x5E55);
        for size in [3usize, 4] {
            let queries = catalog::motifs_vertex_induced(size);
            for policy in [Policy::Off, Policy::Naive] {
                let planner = QueryPlanner::new(policy, true, 2);
                let mut prof = PhaseProfile::new();
                let mut local_store = ResultStore::new(1 << 20);
                let (local, _) =
                    planner.serve_batch(&g, &queries, &stats, &mut local_store, 0, &mut prof);
                let mut shard_store = ResultStore::new(1 << 20);
                let (sharded, s) = planner
                    .serve_batch_sharded(
                        &queries,
                        &stats,
                        &mut shard_store,
                        0,
                        &mut pool,
                        &mut prof,
                    )
                    .unwrap();
                assert_eq!(
                    local, sharded,
                    "{n}v/{m}e size-{size} {policy:?}: shard sums must be exact"
                );
                assert_eq!(s.remote_bases, s.executed_bases);
                assert_eq!(
                    s.cached_bases + s.executed_bases + s.coalesced_bases,
                    s.total_bases
                );
            }
        }
        drop(pool);
        for w in workers {
            w.shutdown();
        }
    });
}

#[test]
fn coordinator_answers_match_inprocess_service_end_to_end() {
    // the ShardCoordinator front door vs the in-process Service, same
    // query texts — results (pattern, unique count) must be identical
    let g = || erdos_renyi(60, 240, 0x54B1);
    let (workers, addrs) = spawn_workers(&g(), 3, worker_config());
    let planner = QueryPlanner::new(Policy::Naive, true, 2);
    let mut coord = ShardCoordinator::connect(g(), &addrs, planner, 1 << 20).unwrap();
    let svc = morphmine::service::Service::start(
        g(),
        morphmine::service::ServiceConfig {
            workers: 1,
            threads: 2,
            policy: Policy::Naive,
            fused: true,
            cache_bytes: 1 << 20,
            delta_budget: morphmine::service::DEFAULT_DELTA_BUDGET,
            persist: None,
        },
    );
    let batch = ["motifs:4", "match:cycle4,diamond-vi", "cliques:3"];
    let sharded = coord.call(&batch).unwrap();
    let single = svc.call(&batch).unwrap();
    assert_eq!(sharded.results, single.results);
    assert_eq!(sharded.stats.total_bases, single.stats.total_bases);
    // warm repeat: the coordinator's local store answers without any
    // shard traffic at all
    let requests_before = coord.shard_metrics().requests;
    let warm = coord.call(&batch).unwrap();
    assert_eq!(warm.results, single.results);
    assert_eq!(warm.stats.executed_bases, 0);
    assert_eq!(coord.shard_metrics().requests, requests_before, "warm batch sends nothing");
    // FSM is rejected exactly like the in-process service rejects it
    assert!(coord.call(&["fsm:3:10"]).is_err());
    drop(coord);
    for w in workers {
        w.shutdown();
    }
}

#[test]
fn sharded_batch_trace_spans_the_fabric_end_to_end() {
    // one trace id covers the coordinator's batch tree, the per-sub-slice
    // dispatch spans, and the worker spans grafted from proto v5 RESULTs —
    // while the counts stay identical to an untraced single-process run
    // (tracing is passive, it must never change an answer)
    let g = || erdos_renyi(60, 240, 0x54F1);
    let (workers, addrs) = spawn_workers(&g(), 2, worker_config());
    let planner = QueryPlanner::new(Policy::Naive, true, 2);
    let mut coord = ShardCoordinator::connect(g(), &addrs, planner, 1 << 20).unwrap();
    let svc = morphmine::service::Service::start(
        g(),
        morphmine::service::ServiceConfig {
            workers: 1,
            threads: 2,
            policy: Policy::Naive,
            fused: true,
            cache_bytes: 1 << 20,
            delta_budget: morphmine::service::DEFAULT_DELTA_BUDGET,
            persist: None,
        },
    );
    let batch = ["motifs:4"];
    let sharded = coord.call(&batch).unwrap();
    let single = svc.call(&batch).unwrap();
    assert_eq!(sharded.results, single.results, "tracing must not change answers");

    let t = &sharded.trace;
    assert_ne!(t.trace_id, 0, "a served batch always gets a trace id");
    let root = t.root().expect("batch root span");
    assert_eq!(root.name, "batch");
    assert!(root.tag.contains("shards=2"), "{:?}", root.tag);
    assert!(t.stage_us("match") > 0, "the remote match stage is timed");
    let slices: Vec<_> = t.spans.iter().filter(|s| s.name.starts_with("slice ")).collect();
    assert_eq!(
        slices.len(),
        coord.num_sub_slices(),
        "one dispatch span per remote sub-slice"
    );
    for s in &slices {
        assert!(s.tag.contains("worker="), "dispatch spans name their worker: {:?}", s.tag);
        assert!(s.tag.contains("outcome=ok"), "healthy dispatches are tagged ok: {:?}", s.tag);
        assert!(
            t.spans.iter().any(|c| c.parent == s.id && c.name == "probe"),
            "the worker's own spans are grafted under the dispatch span"
        );
    }
    // the rendered tree and the JSON carry the same grep-able trace id
    let id_hex = format!("{:016x}", t.trace_id);
    let tree = t.render_tree();
    assert!(tree.starts_with(&format!("trace {id_hex}")), "{tree}");
    assert!(!tree.contains("orphans"), "every fabric span links into the tree: {tree}");
    assert!(t.to_json().contains(&id_hex));

    // the single-process response carries its own trace from the same
    // span-tree timing source, under a distinct id
    let st = &single.trace;
    assert_ne!(st.trace_id, 0);
    assert_ne!(st.trace_id, t.trace_id, "trace ids are process-unique per batch");
    assert_eq!(st.root().expect("root").name, "batch");

    // a warm repeat still yields a complete trace — no remote dispatches,
    // so no slice spans, but the root and stages remain
    let warm = coord.call(&batch).unwrap();
    assert_eq!(warm.results, single.results);
    let wt = &warm.trace;
    assert_ne!(wt.trace_id, t.trace_id);
    assert!(wt.root().is_some());
    assert!(!wt.spans.iter().any(|s| s.name.starts_with("slice ")));
    drop(coord);
    for w in workers {
        w.shutdown();
    }
}

#[test]
fn wrong_graph_is_rejected_at_connect() {
    let g = erdos_renyi(40, 120, 0x54C1);
    let (workers, addrs) = spawn_workers(&g, 1, worker_config());
    let other = erdos_renyi(40, 120, 0x54C2);
    let err = ShardPool::connect(&addrs, &other).unwrap_err();
    assert!(
        format!("{err:#}").contains("rejected handshake"),
        "wrong graph must be a hard reject: {err:#}"
    );
    // the right graph still connects afterwards
    assert!(ShardPool::connect(&addrs, &g).is_ok());
    for w in workers {
        w.shutdown();
    }
}

#[test]
fn shard_persist_restart_recovers_warm_for_same_slice_only() {
    let dir = std::env::temp_dir().join("mm_shard_persist_it");
    let _ = std::fs::remove_dir_all(&dir);
    let g = || erdos_renyi(50, 180, 0x54D1);
    let persist_config = || WorkerConfig {
        persist: Some(PersistConfig::new(&dir)),
        ..worker_config()
    };
    let planner = QueryPlanner::new(Policy::Naive, true, 2);
    let batch = ["motifs:4"];

    // cold run: one persistent worker serving the whole range
    let w = ShardWorker::bind(g(), "127.0.0.1:0", persist_config()).unwrap();
    let mut coord =
        ShardCoordinator::connect(g(), &[w.addr().to_string()], planner, 1 << 20).unwrap();
    let cold = coord.call(&batch).unwrap();
    assert_eq!(coord.shard_metrics().remote_cached, 0, "fresh dir starts cold");
    drop(coord);
    w.shutdown(); // graceful: compacts the shard's WAL into a snapshot

    // restart, same graph, same pool shape: sub-slice boundaries are a
    // pure function of (graph degrees, pool size), so every per-slice
    // store recovers and every base × sub-slice is served warm
    let w = ShardWorker::bind(g(), "127.0.0.1:0", persist_config()).unwrap();
    let mut coord =
        ShardCoordinator::connect(g(), &[w.addr().to_string()], planner, 1 << 20).unwrap();
    let warm = coord.call(&batch).unwrap();
    assert_eq!(cold.results, warm.results, "recovery must not change answers");
    assert_eq!(
        coord.shard_metrics().remote_cached as usize,
        warm.stats.total_bases * coord.num_sub_slices(),
        "every base × sub-slice served from the restored per-slice stores"
    );
    drop(coord);
    w.shutdown();

    // restart into a DIFFERENT pool shape (2 workers → different
    // sub-slice boundaries): partials are keyed by graph × slice, so
    // stale-slice stores can never serve the new slices wrong — answers
    // stay exact, with whatever subset of slices happens to line up
    // recovering warm
    let w = ShardWorker::bind(g(), "127.0.0.1:0", persist_config()).unwrap();
    let fresh = ShardWorker::bind(g(), "127.0.0.1:0", worker_config()).unwrap();
    let addrs = vec![w.addr().to_string(), fresh.addr().to_string()];
    let mut coord = ShardCoordinator::connect(g(), &addrs, planner, 1 << 20).unwrap();
    let resliced = coord.call(&batch).unwrap();
    assert_eq!(cold.results, resliced.results, "resliced answers still exact");
    drop(coord);
    w.shutdown();
    fresh.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn slice_pin_prewarms_persisted_stores_at_bind() {
    let dir = std::env::temp_dir().join("mm_shard_slice_pin_it");
    let _ = std::fs::remove_dir_all(&dir);
    let g = || erdos_renyi(50, 180, 0x54D7);
    let persist_config = || WorkerConfig {
        persist: Some(PersistConfig::new(&dir)),
        ..worker_config()
    };
    let planner = QueryPlanner::new(Policy::Naive, true, 2);
    let batch = ["motifs:3"];

    // cold run populates the per-slice stores on disk
    let w = ShardWorker::bind(g(), "127.0.0.1:0", persist_config()).unwrap();
    let mut coord =
        ShardCoordinator::connect(g(), &[w.addr().to_string()], planner, 1 << 20).unwrap();
    let cold = coord.call(&batch).unwrap();
    drop(coord);
    w.shutdown();

    // restart with `--slice 0/1` pinning: the stores are re-opened at
    // bind time, before any coordinator has connected or asked anything
    let pinned = WorkerConfig {
        slice_pin: Some((0, 1)),
        ..persist_config()
    };
    let w = ShardWorker::bind(g(), "127.0.0.1:0", pinned).unwrap();
    let m = w.store_metrics();
    assert!(m.restored > 0, "pinning must pre-warm eagerly: {m:?}");
    let mut coord =
        ShardCoordinator::connect(g(), &[w.addr().to_string()], planner, 1 << 20).unwrap();
    let warm = coord.call(&batch).unwrap();
    assert_eq!(cold.results, warm.results, "pre-warm must not change answers");
    assert!(coord.shard_metrics().remote_cached > 0, "pre-warmed stores serve");
    drop(coord);
    w.shutdown();

    // an out-of-range pin is refused loudly at bind
    let bad = WorkerConfig {
        slice_pin: Some((3, 2)),
        ..worker_config()
    };
    assert!(ShardWorker::bind(g(), "127.0.0.1:0", bad).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn protocol_survives_torn_streams_and_hostile_bytes() {
    // a stream of framed messages cut at every byte offset, walked with
    // the same frame walker WAL recovery uses: every complete frame in
    // the prefix decodes, the torn tail is flagged, nothing panics
    use morphmine::service::persist::frame::{write_frame, Frames};
    let fp = erdos_renyi(20, 40, 1).fingerprint();
    let msgs = vec![
        Msg::Hello {
            version: proto::VERSION,
            fingerprint: fp,
            group: 1,
            groups: 2,
            replica: 1,
        },
        Msg::Welcome { fingerprint: fp, threads: 4 },
        Msg::Ping { nonce: 7 },
        Msg::Pong { nonce: 7, inflight: 3 },
        Msg::Exec(ExecRequest {
            id: 1,
            epoch: 0,
            fingerprint: fp,
            lo: 0,
            hi: 20,
            trace_id: 0x1234,
            parent_span: 7,
            patterns: vec![catalog::triangle(), catalog::cycle(4).vertex_induced()],
        }),
        Msg::Result(ExecResponse {
            id: 1,
            epoch: 0,
            served_from_store: 1,
            values: vec![(catalog::triangle().canonical_key(), 99)],
            spans: vec![proto::WireSpan {
                rel_parent: u32::MAX,
                start_us: 3,
                dur_us: 40,
                name: "probe".into(),
                tag: "hits=1".into(),
            }],
        }),
        Msg::Error { id: 2, message: "nope".into() },
    ];
    let mut buf = Vec::new();
    let mut boundaries = vec![0usize];
    for m in &msgs {
        write_frame(&mut buf, &proto::encode(m)).unwrap();
        boundaries.push(buf.len());
    }
    for cut in 0..=buf.len() {
        let mut frames = Frames::new(&buf[..cut]);
        let mut decoded = 0;
        for payload in &mut frames {
            assert!(
                proto::decode(payload).is_some(),
                "cut {cut}: complete frames must decode"
            );
            decoded += 1;
        }
        // exactly the messages whose frames fit the prefix survive
        let expect = boundaries.iter().filter(|&&b| b > 0 && b <= cut).count();
        assert_eq!(decoded, expect, "cut {cut}");
        assert_eq!(
            frames.corrupt(),
            !boundaries.contains(&cut),
            "cut {cut}: torn tails are flagged, clean cuts are not"
        );
        // the stream reader agrees: it yields the same prefix then errors
        // (or cleanly hits EOF on a frame boundary)
        let mut stream = &buf[..cut];
        for _ in 0..expect {
            proto::read_msg(&mut stream).unwrap();
        }
        assert!(proto::read_msg(&mut stream).is_err(), "cut {cut}: tail must error");
    }
}

#[test]
fn workers_coalesce_concurrent_identical_requests() {
    // four coordinators hammering one worker with the same bases: the
    // worker matches each base × sub-slice at most once (sub-slice
    // boundaries are a pure function of graph degrees and pool size, so
    // all four coordinators deal identical slices)
    let g = erdos_renyi(60, 240, 0x54E1);
    let (workers, addrs) = spawn_workers(&g, 1, worker_config());
    let base_queries = ["motifs:4"];
    let results: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let addrs = addrs.clone();
                let g = g.clone();
                s.spawn(move || {
                    let planner = QueryPlanner::new(Policy::Naive, true, 2);
                    let mut coord =
                        ShardCoordinator::connect(g, &addrs, planner, 1 << 20).unwrap();
                    let r = coord.call(&base_queries).unwrap();
                    (r, coord.num_sub_slices())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (r, _) in &results {
        assert_eq!(r.results, results[0].0.results, "all coordinators agree");
    }
    let m = workers[0].store_metrics();
    assert_eq!(
        m.inserts as usize,
        results[0].0.stats.total_bases * results[0].1,
        "each base × sub-slice matched at most once worker-wide: {m:?}"
    );
    for w in workers {
        w.shutdown();
    }
}
