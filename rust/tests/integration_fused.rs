//! End-to-end checks of fused multi-pattern co-execution: the fused trie
//! executor must produce exactly the counts of the per-pattern path for
//! whole morphed base sets, across policies, applications and aggregations.

use morphmine::apps;
use morphmine::exec::count_matches;
use morphmine::exec::fused::fused_count_matches;
use morphmine::graph::generators::{assign_labels, erdos_renyi};
use morphmine::morph::{self, Policy};
use morphmine::pattern::catalog;
use morphmine::plan::cost::CostParams;
use morphmine::plan::fused::FusedPlan;
use morphmine::plan::Plan;

#[test]
fn fused_base_set_counts_equal_individual_plans() {
    let g = erdos_renyi(120, 540, 91);
    for size in [3, 4] {
        let base = morph::plan_queries(
            &catalog::motifs_vertex_induced(size),
            Policy::Naive,
            None,
            &CostParams::counting(),
        )
        .base;
        let fused = FusedPlan::build(&base, None, &CostParams::counting());
        assert!(
            fused.first_level_traversals() < base.len(),
            "{}",
            fused.describe()
        );
        let counts = fused_count_matches(&g, &fused, 2);
        for (i, p) in base.iter().enumerate() {
            assert_eq!(counts[i], count_matches(&g, &Plan::compile(p)), "{p:?}");
        }
    }
}

#[test]
fn motif_counts_invariant_under_fusing() {
    let g = erdos_renyi(70, 300, 92);
    for policy in [Policy::Off, Policy::Naive, Policy::CostBased] {
        let on = apps::count_motifs_opts(&g, 4, policy, morph::ExecOpts::new(2));
        let off =
            apps::count_motifs_opts(&g, 4, policy, morph::ExecOpts::new(2).with_fused(false));
        for ((p, a), (_, b)) in on.counts.iter().zip(off.counts.iter()) {
            assert_eq!(a, b, "{policy:?} {p:?}");
        }
    }
}

#[test]
fn match_patterns_invariant_under_fusing() {
    let g = erdos_renyi(80, 340, 93);
    let queries = vec![
        catalog::cycle(4),
        catalog::diamond().vertex_induced(),
        catalog::tailed_triangle(),
        catalog::house().vertex_induced(),
    ];
    let on = apps::match_patterns_opts(&g, &queries, Policy::Naive, morph::ExecOpts::new(2));
    let off = apps::match_patterns_opts(
        &g,
        &queries,
        Policy::Naive,
        morph::ExecOpts::new(2).with_fused(false),
    );
    assert_eq!(on.counts, off.counts);
}

#[test]
fn fsm_invariant_under_fusing() {
    let g = assign_labels(erdos_renyi(60, 220, 94), 3, 1.3, 95);
    let run = |fused: bool| {
        apps::fsm(
            &g,
            &apps::FsmConfig {
                max_edges: 3,
                support: 3,
                policy: Policy::Naive,
                threads: 2,
                fused,
            },
        )
    };
    let on = run(true);
    let off = run(false);
    let norm = |r: &apps::FsmResult| {
        let mut v: Vec<_> = r
            .frequent
            .iter()
            .map(|(p, s)| (p.canonical_key(), *s))
            .collect();
        v.sort();
        v
    };
    assert_eq!(norm(&on), norm(&off));
}
