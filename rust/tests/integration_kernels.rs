//! End-to-end checks of the hybrid representation + tiered kernels:
//! degree-ordered relabeling must be count-invariant (the relabeled graph
//! is isomorphic to the original), hub bitmap rows must agree with the
//! sorted lists, and the fused path must equal the per-pattern path on the
//! relabeled hybrid representation.

use morphmine::exec::fused::fused_count_matches;
use morphmine::exec::{count_matches, enumerate_matches};
use morphmine::graph::generators::{barabasi_albert, erdos_renyi};
use morphmine::graph::{DataGraph, GraphBuilder, VertexId};
use morphmine::morph::{self, Policy};
use morphmine::pattern::catalog;
use morphmine::plan::cost::CostParams;
use morphmine::plan::fused::FusedPlan;
use morphmine::plan::Plan;
use morphmine::util::proptest;

/// Rebuild `g`'s edge set with degree-ordered relabeling (hybrid index on).
fn relabeled_hybrid(g: &DataGraph) -> DataGraph {
    let mut edges = Vec::with_capacity(g.num_edges());
    for v in 0..g.num_vertices() as VertexId {
        for &u in g.neighbors(v) {
            if v < u {
                edges.push((v, u));
            }
        }
    }
    GraphBuilder::new()
        .edges(&edges)
        .num_vertices(g.num_vertices())
        .degree_ordered(true)
        .build("relabeled")
}

/// Satellite property test: the relabeled graph is isomorphic to the
/// original — 3-/4-motif base-set counts are identical on random ER and
/// power-law graphs, per-pattern and fused.
#[test]
fn relabeled_graph_is_isomorphic_on_random_graphs() {
    proptest::check(0x5E1A, 12, |rng| {
        let n = 30 + rng.below_usize(40);
        let m = 2 * n + rng.below_usize(3 * n);
        let graphs = [
            erdos_renyi(n, m, rng.next_u64()),
            barabasi_albert(n, 2 + rng.below_usize(4), rng.next_u64()),
        ];
        for g in graphs {
            let r = relabeled_hybrid(&g);
            assert!(r.check_invariants());
            for size in [3, 4] {
                let base = morph::plan_queries(
                    &catalog::motifs_vertex_induced(size),
                    Policy::Naive,
                    None,
                    &CostParams::counting(),
                )
                .base;
                // per-pattern counts invariant under relabeling
                for p in &base {
                    let plan = Plan::compile(p);
                    assert_eq!(
                        count_matches(&g, &plan),
                        count_matches(&r, &plan),
                        "{p:?} on {}v/{}e",
                        g.num_vertices(),
                        g.num_edges()
                    );
                }
                // fused == per-pattern on the relabeled hybrid representation
                let fused = FusedPlan::build(&base, None, &CostParams::counting());
                let counts = fused_count_matches(&r, &fused, 2);
                for (i, p) in base.iter().enumerate() {
                    assert_eq!(counts[i], count_matches(&r, &Plan::compile(p)), "{p:?}");
                }
            }
        }
    });
}

/// Hub bitmap rows must not change any count, including patterns with
/// anti-edges (the difference tier) on graphs with genuine hubs.
#[test]
fn hub_bitmaps_are_count_invariant() {
    // BA graphs at this size have vertices above the hub threshold
    let g = barabasi_albert(2000, 8, 0x4B);
    assert!(g.hub_count() > 0, "test needs hub rows to exercise");
    let stripped = g.without_hub_bitmaps();
    for p in [
        catalog::triangle(),
        catalog::clique(4),
        catalog::cycle(4),
        catalog::cycle(4).vertex_induced(),
        catalog::tailed_triangle().vertex_induced(),
        catalog::star(4).vertex_induced(),
    ] {
        let plan = Plan::compile(&p);
        assert_eq!(
            count_matches(&g, &plan),
            count_matches(&stripped, &plan),
            "{p:?}"
        );
    }
}

/// Mining through the apps layer is invariant under the full hybrid stack.
#[test]
fn motif_counts_invariant_under_relabeled_hybrid() {
    let g = erdos_renyi(80, 400, 0x1B);
    let r = relabeled_hybrid(&g);
    for policy in [Policy::Off, Policy::Naive, Policy::CostBased] {
        let a = morphmine::apps::count_motifs(&g, 4, policy, 2);
        let b = morphmine::apps::count_motifs(&r, 4, policy, 2);
        for ((p, x), (_, y)) in a.counts.iter().zip(b.counts.iter()) {
            assert_eq!(x, y, "{policy:?} {p:?}");
        }
    }
}

/// Enumeration reports original vertex IDs after relabeling.
#[test]
fn enumeration_reports_original_ids() {
    // path 7-8-9: vertex 9 is the center and gets relabeled to engine id 0
    let g = GraphBuilder::new()
        .edges(&[(9, 7), (9, 8)])
        .degree_ordered(true)
        .build("p3");
    assert_eq!(g.original_id(0), 9);
    let ms = enumerate_matches(&g, &Plan::compile(&catalog::path(3)));
    assert_eq!(ms.len(), 1);
    assert_eq!(ms[0][1], 9, "pattern center must map to original id 9");
    let mut ends = vec![ms[0][0], ms[0][2]];
    ends.sort_unstable();
    assert_eq!(ends, vec![7, 8]);
}
