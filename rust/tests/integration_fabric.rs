//! Fault-injection tests for the shard fabric (`morphmine::shard`): the
//! merge invariant — per-base totals are exact sums of per-slice partials
//! — must survive severed streams, corrupted bytes, wedged workers, and
//! SIGKILLed worker processes, with the damage visible in the fabric's
//! failure counters instead of in the answers.

mod support;

use morphmine::graph::generators::erdos_renyi;
use morphmine::graph::{DataGraph, DynGraph, GraphFingerprint, GraphStats};
use morphmine::morph::Policy;
use morphmine::pattern::canon::CanonKey;
use morphmine::pattern::catalog;
use morphmine::service::{QueryPlanner, ResultStore, Service, ServiceConfig};
use morphmine::shard::proto::{self, ExecRequest, ExecResponse, Msg};
use morphmine::shard::{PoolConfig, ShardCoordinator, ShardPool, ShardWorker, WorkerConfig};
use morphmine::util::proptest;
use morphmine::util::timer::PhaseProfile;
use std::time::Duration;
use support::ChaosProxy;

fn worker_config() -> WorkerConfig {
    WorkerConfig {
        threads: 2,
        fused: true,
        cache_bytes: 1 << 20,
        persist: None,
        slice_pin: None,
    }
}

/// Wrap a flat address list as the singleton-group topology (PR 6
/// semantics: one shared queue, retry + re-fan).
fn singletons(addrs: &[String]) -> Vec<Vec<String>> {
    addrs.iter().map(|a| vec![a.clone()]).collect()
}

/// Aggressive-but-stable timing for fault tests: fast probes, short
/// wedge deadline, one retry, small backoff.
fn fast_config() -> PoolConfig {
    PoolConfig {
        connect_timeout: Duration::from_millis(500),
        shard_timeout: Duration::from_millis(800),
        probe_interval: Duration::from_millis(50),
        max_retries: 1,
        retry_base: Duration::from_millis(20),
        retry_cap: Duration::from_millis(100),
        ..PoolConfig::default()
    }
}

/// Single-process reference counts for `queries` on `g`.
fn local_counts(g: &DataGraph, stats: &GraphStats) -> Vec<i128> {
    let planner = QueryPlanner::new(Policy::Naive, true, 2);
    let mut store = ResultStore::new(1 << 20);
    let mut prof = PhaseProfile::new();
    let (counts, _) =
        planner.serve_batch(g, &catalog::motifs_vertex_induced(4), stats, &mut store, 0, &mut prof);
    counts
}

/// Sharded counts through `pool`, which must succeed.
fn sharded_counts(g: &DataGraph, stats: &GraphStats, pool: &mut ShardPool) -> Vec<i128> {
    let planner = QueryPlanner::new(Policy::Naive, true, 2);
    let mut store = ResultStore::new(1 << 20);
    let mut prof = PhaseProfile::new();
    let (counts, _) = planner
        .serve_batch_sharded(
            &catalog::motifs_vertex_induced(4),
            stats,
            &mut store,
            0,
            pool,
            &mut prof,
        )
        .unwrap();
    counts
}

#[test]
fn severed_stream_mid_frame_retries_and_stays_exact() {
    let g = erdos_renyi(60, 240, 0xFA01);
    let stats = GraphStats::compute(&g, 2000, 0x5E55);
    let w = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let proxy = ChaosProxy::start(w.addr());
    let addrs = vec![proxy.addr().to_string()];
    let mut pool = ShardPool::connect_with(&singletons(&addrs), &g, fast_config()).unwrap();
    // cut the stream 10 bytes into the first reply — mid-frame, after the
    // coordinator has already committed the request to the wire
    proxy.sever_down_after(10);
    let sharded = sharded_counts(&g, &stats, &mut pool);
    assert_eq!(sharded, local_counts(&g, &stats), "severed stream must not change counts");
    let m = pool.metrics();
    assert!(m.worker_failures >= 1, "the sever is a visible failure: {m:?}");
    assert!(m.refanned >= 1, "in-flight slices were re-dealt: {m:?}");
    assert!(m.retries >= 1, "the worker was reconnected: {m:?}");
    assert_eq!(m.errors, 0, "the batch itself succeeded: {m:?}");
    drop(pool);
    drop(proxy);
    w.shutdown();
}

#[test]
fn corrupt_byte_mid_stream_is_caught_and_refanned() {
    let g = erdos_renyi(60, 240, 0xFA02);
    let stats = GraphStats::compute(&g, 2000, 0x5E55);
    let w = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let proxy = ChaosProxy::start(w.addr());
    let addrs = vec![proxy.addr().to_string()];
    let mut pool = ShardPool::connect_with(&singletons(&addrs), &g, fast_config()).unwrap();
    // flip one bit inside the first reply frame: the CRC (or the frame
    // walk) must catch it — a flipped count silently merged would be the
    // worst possible failure mode
    proxy.corrupt_down_at(10);
    let sharded = sharded_counts(&g, &stats, &mut pool);
    assert_eq!(sharded, local_counts(&g, &stats), "corruption must never reach the sums");
    let m = pool.metrics();
    assert!(m.worker_failures >= 1, "corruption is a visible failure: {m:?}");
    assert!(m.refanned >= 1, "{m:?}");
    drop(pool);
    drop(proxy);
    w.shutdown();
}

#[test]
fn wedged_worker_is_detected_and_refanned_to_survivor() {
    let g = erdos_renyi(60, 240, 0xFA03);
    let stats = GraphStats::compute(&g, 2000, 0x5E55);
    let healthy = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let wedged = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let proxy = ChaosProxy::start(wedged.addr());
    let addrs = vec![healthy.addr().to_string(), proxy.addr().to_string()];
    let mut pool = ShardPool::connect_with(&singletons(&addrs), &g, fast_config()).unwrap();
    // wedge AFTER the handshake: the worker stays connected but all its
    // traffic — requests, replies, probe pongs — is swallowed
    proxy.set_blackhole(true);
    let t = std::time::Instant::now();
    let sharded = sharded_counts(&g, &stats, &mut pool);
    assert_eq!(sharded, local_counts(&g, &stats), "survivor absorbs the wedged slices");
    assert!(
        t.elapsed() < Duration::from_secs(20),
        "wedge detection must be deadline-bound, not a hang ({:?})",
        t.elapsed()
    );
    let m = pool.metrics();
    assert!(m.probes >= 1, "the silent worker was probed: {m:?}");
    assert!(m.worker_failures >= 1, "the wedge is a visible failure: {m:?}");
    assert!(m.refanned >= 1, "wedged slices were re-dealt to the survivor: {m:?}");
    assert_eq!(m.errors, 0, "{m:?}");
    drop(pool);
    drop(proxy);
    healthy.shutdown();
    wedged.shutdown();
}

#[test]
fn no_live_workers_fails_loudly() {
    let g = erdos_renyi(40, 120, 0xFA04);
    let stats = GraphStats::compute(&g, 2000, 0x5E55);
    let w = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let proxy = ChaosProxy::start(w.addr());
    let addrs = vec![proxy.addr().to_string()];
    let mut pool = ShardPool::connect_with(&singletons(&addrs), &g, fast_config()).unwrap();
    // the only worker dies and stays dead: reconnects are refused
    proxy.kill();
    let planner = QueryPlanner::new(Policy::Naive, true, 2);
    let mut store = ResultStore::new(1 << 20);
    let mut prof = PhaseProfile::new();
    let err = planner
        .serve_batch_sharded(
            &catalog::motifs_vertex_induced(3),
            &stats,
            &mut store,
            0,
            &mut pool,
            &mut prof,
        )
        .unwrap_err();
    let text = format!("{err:#}");
    assert!(
        text.contains("no live worker remains"),
        "a dead fleet is a loud, named failure: {text}"
    );
    let m = pool.metrics();
    assert!(m.errors >= 1, "the failed batch is counted: {m:?}");
    assert!(m.worker_failures >= 1, "{m:?}");
    w.shutdown();
}

#[test]
fn killed_worker_process_mid_batch_refans_to_survivors() {
    use std::io::BufRead;
    // three REAL worker processes (the shipped binary), one SIGKILLed
    // after the fabric is connected: the batch must still complete with
    // counts identical to the in-process service
    let spawn = || {
        let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_morphmine"))
            .args([
                "shard-worker",
                "--graph",
                "mico:tiny",
                "--listen",
                "127.0.0.1:0",
                "--threads",
                "2",
            ])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::null())
            .spawn()
            .expect("spawn shard-worker");
        let mut line = String::new();
        std::io::BufReader::new(child.stdout.take().expect("piped stdout"))
            .read_line(&mut line)
            .expect("worker startup line");
        let addr = line
            .split("listening on ")
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .unwrap_or_else(|| panic!("unparseable worker startup line: {line:?}"))
            .to_string();
        (child, addr)
    };
    let (mut a, addr_a) = spawn();
    let (b, addr_b) = spawn();
    let (c, addr_c) = spawn();
    let g = morphmine::graph::io::load_spec("mico:tiny").unwrap();
    let stats = GraphStats::compute(&g, 2000, 0x5E55);
    let addrs = vec![addr_a, addr_b, addr_c];
    let mut pool = ShardPool::connect_with(&singletons(&addrs), &g, fast_config()).unwrap();
    // SIGKILL one connected worker: its established connection dies with
    // it, which the fabric discovers mid-batch on first use
    a.kill().expect("kill worker");
    let _ = a.wait();
    let sharded = sharded_counts(&g, &stats, &mut pool);
    assert_eq!(sharded, local_counts(&g, &stats), "killed worker must not change counts");
    let m = pool.metrics();
    assert!(m.worker_failures >= 1, "the kill is visible: {m:?}");
    assert!(m.refanned >= 1, "the dead worker's slices were re-dealt: {m:?}");
    assert_eq!(m.errors, 0, "the batch completed: {m:?}");
    drop(pool);
    for mut child in [b, c] {
        let _ = child.kill();
        let _ = child.wait();
    }
}

#[test]
fn killed_replica_in_each_group_fails_over_without_refan() {
    // 2 groups × 2 replicas; one replica of EACH group is killed after the
    // fabric connects. Every lost slice must fail over to the surviving
    // sibling — byte-identical counts, zero re-fans (the group still owns
    // its slice cut), and zero counted retries (a failover absorbed by a
    // sibling must not draw on the dead member's budget)
    let g = erdos_renyi(60, 240, 0xFA06);
    let stats = GraphStats::compute(&g, 2000, 0x5E55);
    let workers: Vec<ShardWorker> = (0..4)
        .map(|_| ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap())
        .collect();
    let pa = ChaosProxy::start(workers[1].addr());
    let pb = ChaosProxy::start(workers[3].addr());
    let groups = vec![
        vec![workers[0].addr().to_string(), pa.addr().to_string()],
        vec![workers[2].addr().to_string(), pb.addr().to_string()],
    ];
    let mut pool = ShardPool::connect_with(&groups, &g, fast_config()).unwrap();
    // kill one replica per group: established connections die and
    // reconnects are refused, so the sibling is the only way through
    pa.kill();
    pb.kill();
    let sharded = sharded_counts(&g, &stats, &mut pool);
    assert_eq!(sharded, local_counts(&g, &stats), "failover must not change counts");
    let m = pool.metrics();
    assert!(m.worker_failures >= 1, "the kills are visible failures: {m:?}");
    assert!(m.failovers >= 1, "lost slices moved to the sibling replica: {m:?}");
    assert_eq!(m.refanned, 0, "replicated groups never re-fan across groups: {m:?}");
    assert_eq!(m.retries, 0, "a sibling-absorbed failover is not a counted retry: {m:?}");
    assert_eq!(m.errors, 0, "the batch completed: {m:?}");
    for w in workers {
        w.shutdown();
    }
}

#[test]
fn whole_group_death_fails_loudly_naming_the_group() {
    // one healthy singleton group plus one fully-replicated group whose
    // EVERY replica dies: the dead group's slices are unservable — no
    // other group may adopt them (slice cuts are group property), so the
    // batch must fail fast and name the group, not hang
    let g = erdos_renyi(40, 120, 0xFA07);
    let stats = GraphStats::compute(&g, 2000, 0x5E55);
    let healthy = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let ra = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let rb = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let pa = ChaosProxy::start(ra.addr());
    let pb = ChaosProxy::start(rb.addr());
    let groups = vec![
        vec![healthy.addr().to_string()],
        vec![pa.addr().to_string(), pb.addr().to_string()],
    ];
    let mut pool = ShardPool::connect_with(&groups, &g, fast_config()).unwrap();
    pa.kill();
    pb.kill();
    let planner = QueryPlanner::new(Policy::Naive, true, 2);
    let mut store = ResultStore::new(1 << 20);
    let mut prof = PhaseProfile::new();
    let err = planner
        .serve_batch_sharded(
            &catalog::motifs_vertex_induced(3),
            &stats,
            &mut store,
            0,
            &mut pool,
            &mut prof,
        )
        .unwrap_err();
    let text = format!("{err:#}");
    assert!(
        text.contains("no live replica remaining"),
        "whole-group death is a loud, named failure: {text}"
    );
    assert!(text.contains("shard group 2"), "the dead group is named: {text}");
    let m = pool.metrics();
    assert!(m.errors >= 1, "the failed batch is counted: {m:?}");
    assert!(m.worker_failures >= 1, "{m:?}");
    healthy.shutdown();
    ra.shutdown();
    rb.shutdown();
}

/// A replica that handshakes cleanly and answers every Exec with a
/// perfectly framed, well-formed reply — right id, right key set, right
/// cardinality — whose counts are fabricated. Wire CRCs cannot catch
/// this; only cross-replica verification can.
fn spawn_lying_worker(fingerprint: GraphFingerprint) -> String {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut s) = stream else { return };
            let Ok(Msg::Hello { .. }) = proto::read_msg(&mut s) else { return };
            if proto::write_msg(&mut s, &Msg::Welcome { fingerprint, threads: 2 }).is_err() {
                return;
            }
            loop {
                match proto::read_msg(&mut s) {
                    Ok(Msg::Exec(req)) => {
                        let mut seen = std::collections::HashSet::new();
                        let values: Vec<(CanonKey, i128)> = req
                            .patterns
                            .iter()
                            .map(|p| p.canonical_key())
                            .filter(|k| seen.insert(*k))
                            .map(|k| (k, 1 << 62))
                            .collect();
                        let reply = Msg::Result(ExecResponse {
                            id: req.id,
                            epoch: req.epoch,
                            served_from_store: 0,
                            values,
                            spans: Vec::new(),
                        });
                        if proto::write_msg(&mut s, &reply).is_err() {
                            break;
                        }
                    }
                    Ok(Msg::Ping { nonce }) => {
                        let pong = Msg::Pong { nonce, inflight: 1 };
                        if proto::write_msg(&mut s, &pong).is_err() {
                            break;
                        }
                    }
                    Ok(_) => {}
                    Err(_) => break,
                }
            }
        }
    });
    addr
}

#[test]
fn verified_reads_catch_a_corrupt_replica_naming_the_slice() {
    // one honest replica, one liar, --verify-reads 1.0: every sub-slice
    // is served by both and compared. The fabricated counts must hard-fail
    // the batch with an error naming the slice — never merge silently
    let g = erdos_renyi(60, 240, 0xFA08);
    let stats = GraphStats::compute(&g, 2000, 0x5E55);
    let honest = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let liar = spawn_lying_worker(g.fingerprint());
    let groups = vec![vec![honest.addr().to_string(), liar]];
    let config = PoolConfig {
        verify_reads: 1.0,
        ..fast_config()
    };
    let mut pool = ShardPool::connect_with(&groups, &g, config).unwrap();
    let planner = QueryPlanner::new(Policy::Naive, true, 2);
    let mut store = ResultStore::new(1 << 20);
    let mut prof = PhaseProfile::new();
    let err = planner
        .serve_batch_sharded(
            &catalog::motifs_vertex_induced(4),
            &stats,
            &mut store,
            0,
            &mut pool,
            &mut prof,
        )
        .unwrap_err();
    let text = format!("{err:#}");
    assert!(
        text.contains("verified read mismatch on sub-slice ["),
        "the mismatch error names the slice: {text}"
    );
    let m = pool.metrics();
    assert!(m.verify_mismatches >= 1, "the mismatch is counted: {m:?}");
    assert!(m.errors >= 1, "{m:?}");
    honest.shutdown();
}

#[test]
fn proto_decode_survives_hostile_mutations() {
    // fuzz-lite over every message type: truncations, bit flips, and
    // appended garbage must produce errors (or clean prefix decodes),
    // never panics — and never a silently wrong message on a framed read
    let fp = erdos_renyi(20, 40, 1).fingerprint();
    let msgs = vec![
        Msg::Hello {
            version: proto::VERSION,
            fingerprint: fp,
            group: 1,
            groups: 2,
            replica: 1,
        },
        Msg::Welcome { fingerprint: fp, threads: 4 },
        Msg::Reject { reason: "go away".into() },
        Msg::Exec(ExecRequest {
            id: 3,
            epoch: 1,
            fingerprint: fp,
            lo: 2,
            hi: 17,
            trace_id: u64::MAX,
            parent_span: 42,
            patterns: vec![catalog::triangle(), catalog::cycle(4).vertex_induced()],
        }),
        Msg::Result(ExecResponse {
            id: 3,
            epoch: 1,
            served_from_store: 1,
            values: vec![
                (catalog::triangle().canonical_key(), 99),
                (catalog::path(3).canonical_key(), -4),
            ],
            spans: vec![
                proto::WireSpan {
                    rel_parent: u32::MAX,
                    start_us: 0,
                    dur_us: 120,
                    name: "probe".into(),
                    tag: "hits=0 owned=2 awaited=0".into(),
                },
                proto::WireSpan {
                    rel_parent: 0,
                    start_us: 5,
                    dur_us: 100,
                    name: "match".into(),
                    tag: String::new(),
                },
            ],
        }),
        Msg::Error { id: 9, message: "boom".into() },
        Msg::Ping { nonce: u64::MAX },
        Msg::Pong { nonce: 0, inflight: u32::MAX },
    ];
    proptest::check(0xFAB5, 500, |rng| {
        let m = &msgs[rng.below_usize(msgs.len())];
        let mut framed = Vec::new();
        proto::write_msg(&mut framed, m).unwrap();
        match rng.below_usize(3) {
            0 => {
                // strict-prefix truncation: must error, never panic
                framed.truncate(rng.below_usize(framed.len()));
                assert!(proto::read_msg(&mut &framed[..]).is_err());
            }
            1 => {
                // single-bit flip anywhere: CRC/length/decode must catch
                // it — a flipped frame never yields Ok
                let i = rng.below_usize(framed.len());
                framed[i] ^= 1u8 << rng.below_usize(8);
                assert!(proto::read_msg(&mut &framed[..]).is_err());
            }
            _ => {
                // trailing garbage: the real message reads back intact,
                // the tail errors instead of fabricating a message
                let extra = 1 + rng.below_usize(40);
                for _ in 0..extra {
                    framed.push(rng.below_usize(256) as u8);
                }
                let mut r = &framed[..];
                proto::read_msg(&mut r).unwrap();
                assert!(proto::read_msg(&mut r).is_err());
            }
        }
        // raw decode (payload already unframed) on mutated bytes: any
        // Option outcome is fine, panicking or over-allocating is not
        let mut payload = proto::encode(m);
        if !payload.is_empty() {
            let i = rng.below_usize(payload.len());
            payload[i] ^= 1u8 << rng.below_usize(8);
            let _ = proto::decode(&payload);
            payload.truncate(rng.below_usize(payload.len().max(1)));
            let _ = proto::decode(&payload);
        }
    });
    // an oversized frame header is rejected by the length check BEFORE
    // any payload allocation — the error names the limit
    let mut hostile = Vec::new();
    hostile.extend_from_slice(&((proto::MAX_MSG_LEN as u32) + 1).to_le_bytes());
    hostile.extend_from_slice(&0u32.to_le_bytes());
    hostile.extend_from_slice(&[0u8; 16]);
    let err = proto::read_msg(&mut &hostile[..]).unwrap_err();
    assert!(
        err.to_string().contains("exceeds MAX_MSG_LEN"),
        "oversized frames are refused by name: {err}"
    );
}

/// A cache-less, delta-less, morph-less service over `g` — the oracle the
/// update chaos tests compare the fabric against.
fn cold_service(g: DataGraph) -> Service {
    Service::start(
        g,
        ServiceConfig {
            workers: 1,
            threads: 2,
            policy: Policy::Off,
            fused: true,
            cache_bytes: 1 << 20,
            persist: None,
            delta_budget: 0,
        },
    )
}

/// First non-adjacent vertex pair of `g`, as ((internal), (original)) ids.
fn non_edge(g: &DataGraph) -> ((u32, u32), (u32, u32)) {
    let n = g.num_vertices() as u32;
    let (a, b) = (0..n)
        .flat_map(|a| (0..n).map(move |b| (a, b)))
        .find(|&(a, b)| a != b && !g.has_edge(a, b))
        .expect("sparse test graphs have non-edges");
    ((a, b), (g.original_id(a), g.original_id(b)))
}

#[test]
fn update_racing_an_inflight_batch_pins_to_admission_epoch_or_fails_loudly() {
    // a reader coordinator's batch is in flight (replies stalled by the
    // proxies) when a second coordinator broadcasts an edge insert to the
    // same workers. The raced batch must either complete with the counts
    // of its ADMISSION epoch — requests are pinned to the graph snapshot
    // they were admitted on — or fail loudly naming the divergence; it
    // must never serve a half-updated mix
    let g = erdos_renyi(40, 140, 0xFA10);
    let w0 = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let w1 = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let p0 = ChaosProxy::start(w0.addr());
    let p1 = ChaosProxy::start(w1.addr());
    let mut reader = ShardCoordinator::connect(
        g.clone(),
        &[p0.addr().to_string(), p1.addr().to_string()],
        QueryPlanner::new(Policy::Naive, true, 2),
        1 << 20,
    )
    .unwrap();
    let mut writer = ShardCoordinator::connect(
        g.clone(),
        &[w0.addr().to_string(), w1.addr().to_string()],
        QueryPlanner::new(Policy::Naive, true, 2),
        1 << 20,
    )
    .unwrap();
    let batch = ["motifs:4"];
    let old = cold_service(g.clone()).call(&batch).unwrap();
    let ((au, av), (ou, ov)) = non_edge(&g);
    p0.delay_down(300);
    p1.delay_down(300);
    let raced = std::thread::scope(|s| {
        let h = s.spawn(|| reader.call(&batch));
        std::thread::sleep(Duration::from_millis(80)); // batch admitted, replies stalled
        assert!(writer.insert_edge(ou, ov).unwrap(), "the racing insert applies");
        h.join().unwrap()
    });
    match raced {
        Ok(resp) => assert_eq!(
            resp.results, old.results,
            "a batch that completes under a racing update serves its admission epoch"
        ),
        Err(e) => {
            let t = format!("{e:#}");
            assert!(
                t.contains("fingerprint") || t.contains("epoch") || t.contains("no live worker"),
                "a raced batch may fail, but loudly, naming the divergence: {t}"
            );
        }
    }
    // the dust settles: the writer serves exactly the post-update truth
    let mut updated = DynGraph::from_data_graph(&g);
    assert!(updated.insert_edge(au, av));
    let fresh = cold_service(updated.to_data_graph("updated")).call(&batch).unwrap();
    assert_eq!(
        writer.call(&batch).unwrap().results,
        fresh.results,
        "after the race the fabric serves the post-update counts"
    );
    drop(reader);
    drop(writer);
    drop(p0);
    drop(p1);
    w0.shutdown();
    w1.shutdown();
}

#[test]
fn replica_that_misses_an_update_is_fenced_while_its_sibling_serves() {
    // one 2-replica group; the victim replica goes silent (SIGKILL-style:
    // its traffic vanishes) exactly as an update is broadcast, so it never
    // applies the mutation. The update must succeed on the sibling with
    // the victim's failure counted; when the victim comes back — a cold
    // reload of its original, pre-update graph — the fingerprint handshake
    // must fence it out of the new epoch rather than let stale partials
    // merge
    let g = erdos_renyi(40, 140, 0xFA12);
    let sibling = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let victim = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let proxy = ChaosProxy::start(victim.addr());
    let groups = vec![vec![sibling.addr().to_string(), proxy.addr().to_string()]];
    let mut coord = ShardCoordinator::connect_with(
        g.clone(),
        &groups,
        QueryPlanner::new(Policy::Naive, true, 2),
        1 << 20,
        fast_config(),
    )
    .unwrap();
    let batch = ["motifs:4"];
    coord.call(&batch).unwrap();
    let ((au, av), (ou, ov)) = non_edge(&g);
    proxy.set_blackhole(true); // the UPDATE frame and its ack both vanish
    assert!(coord.insert_edge(ou, ov).unwrap(), "the update lands on the surviving sibling");
    let m = coord.shard_metrics();
    assert!(m.worker_failures >= 1, "the missed update is a visible failure: {m:?}");
    proxy.set_blackhole(false); // the victim is reachable again — and stale
    let mut updated = DynGraph::from_data_graph(&g);
    assert!(updated.insert_edge(au, av));
    let new_g = updated.to_data_graph("updated");
    let fresh = cold_service(new_g.clone()).call(&batch).unwrap();
    assert_eq!(
        coord.call(&batch).unwrap().results,
        fresh.results,
        "the sibling alone serves the post-update truth"
    );
    // fingerprint fencing, proven from the outside: the victim still
    // handshakes for the PRE-update graph and hard-rejects the new one
    assert!(
        ShardPool::connect(&[victim.addr().to_string()], &g).is_ok(),
        "the victim still holds the pre-update graph"
    );
    let err = ShardPool::connect(&[victim.addr().to_string()], &new_g).unwrap_err();
    assert!(
        format!("{err:#}").contains("rejected handshake"),
        "a stale replica is fenced by name: {err:#}"
    );
    drop(coord);
    drop(proxy);
    sibling.shutdown();
    victim.shutdown();
}

#[test]
fn update_with_no_live_workers_fails_loudly_naming_the_scope() {
    // the pool's only worker dies before an update broadcast: accepting
    // the mutation silently would strand every future batch on a graph
    // the fleet does not hold, so the update must error naming the scope
    let g = erdos_renyi(30, 90, 0xFA13);
    let w = ShardWorker::bind(g.clone(), "127.0.0.1:0", worker_config()).unwrap();
    let proxy = ChaosProxy::start(w.addr());
    let addrs = vec![proxy.addr().to_string()];
    let mut coord = ShardCoordinator::connect_with(
        g.clone(),
        &singletons(&addrs),
        QueryPlanner::new(Policy::Naive, true, 2),
        1 << 20,
        fast_config(),
    )
    .unwrap();
    coord.call(&["motifs:3"]).unwrap();
    let (_, (ou, ov)) = non_edge(&g);
    proxy.kill();
    let err = coord.insert_edge(ou, ov).unwrap_err();
    let t = format!("{err:#}");
    assert!(t.contains("edge update left"), "the failure names the update: {t}");
    assert!(t.contains("no live member"), "…and the dead scope: {t}");
    let m = coord.shard_metrics();
    assert!(m.errors >= 1, "the failed update is counted: {m:?}");
    assert!(m.worker_failures >= 1, "{m:?}");
    w.shutdown();
}
