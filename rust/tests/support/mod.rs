//! Test-only fault-injection harness: a TCP chaos proxy that sits between
//! a shard coordinator and a worker and can delay, corrupt, sever, or
//! blackhole the byte stream — including mid-frame — so integration tests
//! can prove the fabric's merge invariant (per-base totals are exact sums
//! of per-slice partials) survives every failure mode the coordinator
//! claims to handle.
//!
//! Faults are one-shot: arming resets the forwarded-byte counter, the
//! fault fires once, and subsequent connections (the coordinator's
//! retries) pass through cleanly — except [`ChaosProxy::set_blackhole`],
//! which holds until cleared, and [`ChaosProxy::kill`], which is
//! permanent. "Down" is the worker→coordinator direction (replies), where
//! corruption exercises the coordinator's CRC check rather than the
//! worker's framing check.
//!
//! Not every test file uses every knob, hence the file-level dead_code
//! allow (each integration test binary compiles this module separately).
#![allow(dead_code)]

pub mod differential;

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Sentinel for a disarmed one-shot fault.
const OFF: u64 = u64::MAX;

#[derive(Default)]
struct Faults {
    /// Sever both directions of the active connection after this many
    /// worker→coordinator bytes have been forwarded.
    sever_down_after: AtomicU64,
    /// XOR one worker→coordinator byte (at this absolute forwarded
    /// offset) with 0x40 — enough to break the frame CRC, not the length.
    corrupt_down_at: AtomicU64,
    /// Sleep this long before forwarding the next worker→coordinator
    /// chunk.
    delay_down_ms: AtomicU64,
    /// Swallow traffic in both directions while set (the connection stays
    /// open: a wedged worker, not a dead one).
    blackhole: AtomicBool,
    /// Worker→coordinator bytes forwarded since the last fault was armed.
    down_forwarded: AtomicU64,
}

impl Faults {
    fn new() -> Faults {
        let f = Faults::default();
        f.sever_down_after.store(OFF, Ordering::SeqCst);
        f.corrupt_down_at.store(OFF, Ordering::SeqCst);
        f.delay_down_ms.store(OFF, Ordering::SeqCst);
        f
    }
}

/// A running proxy: `coordinator → proxy.addr() → target`.
pub struct ChaosProxy {
    addr: SocketAddr,
    target: SocketAddr,
    stop: Arc<AtomicBool>,
    faults: Arc<Faults>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept: Option<JoinHandle<()>>,
}

impl ChaosProxy {
    /// Start proxying an ephemeral local port to `target`.
    pub fn start(target: SocketAddr) -> ChaosProxy {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind chaos proxy");
        let addr = listener.local_addr().expect("proxy addr");
        listener.set_nonblocking(true).expect("nonblocking accept");
        let stop = Arc::new(AtomicBool::new(false));
        let faults = Arc::new(Faults::new());
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let (stop, faults, conns) = (stop.clone(), faults.clone(), conns.clone());
            std::thread::spawn(move || accept_loop(&listener, target, &stop, &faults, &conns))
        };
        ChaosProxy {
            addr,
            target,
            stop,
            faults,
            conns,
            accept: Some(accept),
        }
    }

    /// The address the coordinator should dial instead of the worker's.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Arm: cut the connection after `bytes` more reply bytes.
    pub fn sever_down_after(&self, bytes: u64) {
        self.faults.down_forwarded.store(0, Ordering::SeqCst);
        self.faults.sever_down_after.store(bytes, Ordering::SeqCst);
    }

    /// Arm: flip one reply byte at absolute offset `offset` from now.
    pub fn corrupt_down_at(&self, offset: u64) {
        self.faults.down_forwarded.store(0, Ordering::SeqCst);
        self.faults.corrupt_down_at.store(offset, Ordering::SeqCst);
    }

    /// Arm: stall the next reply chunk by `ms` milliseconds.
    pub fn delay_down(&self, ms: u64) {
        self.faults.delay_down_ms.store(ms, Ordering::SeqCst);
    }

    /// While set, traffic is swallowed in both directions but every
    /// connection stays established — the proxied worker looks wedged.
    pub fn set_blackhole(&self, on: bool) {
        self.faults.blackhole.store(on, Ordering::SeqCst);
    }

    /// Permanently kill the proxy: stop accepting and sever every live
    /// connection, as if the worker process died.
    pub fn kill(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for c in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.kill();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: &TcpListener,
    target: SocketAddr,
    stop: &Arc<AtomicBool>,
    faults: &Arc<Faults>,
    conns: &Arc<Mutex<Vec<TcpStream>>>,
) {
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let client = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
            Err(_) => return,
        };
        let Ok(upstream) = TcpStream::connect(target) else {
            continue; // worker gone: refuse by dropping the client
        };
        {
            let mut cs = conns.lock().unwrap();
            if let (Ok(c), Ok(u)) = (client.try_clone(), upstream.try_clone()) {
                cs.push(c);
                cs.push(u);
            }
        }
        // coordinator → worker: faithful except for stop/blackhole
        {
            let (from, to) = (client.try_clone(), upstream.try_clone());
            let (stop, faults) = (stop.clone(), faults.clone());
            if let (Ok(from), Ok(to)) = (from, to) {
                std::thread::spawn(move || pump(from, to, &stop, &faults, false));
            }
        }
        // worker → coordinator: where the one-shot faults fire
        let (stop2, faults2) = (stop.clone(), faults.clone());
        std::thread::spawn(move || pump(upstream, client, &stop2, &faults2, true));
    }
}

/// Forward `from` → `to` until EOF, error, stop, or an armed sever fires.
/// `down` marks the worker→coordinator direction.
fn pump(mut from: TcpStream, mut to: TcpStream, stop: &AtomicBool, faults: &Faults, down: bool) {
    from.set_read_timeout(Some(Duration::from_millis(25))).ok();
    let mut buf = [0u8; 4096];
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if faults.blackhole.load(Ordering::SeqCst) {
            // swallow without closing: the peer sees an open, silent pipe
            match from.read(&mut buf) {
                Ok(0) => break,
                Ok(_) | Err(_) => continue,
            }
        }
        let n = match from.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        };
        if down {
            let delay = faults.delay_down_ms.swap(OFF, Ordering::SeqCst);
            if delay != OFF {
                std::thread::sleep(Duration::from_millis(delay));
            }
            let start = faults.down_forwarded.fetch_add(n as u64, Ordering::SeqCst);
            let corrupt_at = faults.corrupt_down_at.load(Ordering::SeqCst);
            if corrupt_at != OFF && corrupt_at >= start && corrupt_at < start + n as u64 {
                faults.corrupt_down_at.store(OFF, Ordering::SeqCst);
                buf[(corrupt_at - start) as usize] ^= 0x40;
            }
            let sever_at = faults.sever_down_after.load(Ordering::SeqCst);
            if sever_at != OFF && start + n as u64 >= sever_at {
                // forward the prefix up to the cut so the sever lands
                // mid-frame, then drop both directions
                faults.sever_down_after.store(OFF, Ordering::SeqCst);
                let keep = (sever_at.saturating_sub(start) as usize).min(n);
                let _ = to.write_all(&buf[..keep]);
                break;
            }
        }
        if to.write_all(&buf[..n]).is_err() {
            break;
        }
    }
    let _ = from.shutdown(Shutdown::Both);
    let _ = to.shutdown(Shutdown::Both);
}
