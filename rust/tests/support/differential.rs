//! Differential result-maintenance harness: drive a delta-maintained
//! serving engine and a mirror graph through the same mutation workload,
//! and after EVERY applied update compare the engine's warm answers
//! against a fresh cold engine built from the mirror. The engine under
//! test patches (or purges) cached results in place across epochs; the
//! oracle has no cache, no deltas and no epochs — if they ever disagree,
//! delta maintenance changed an answer.
//!
//! The harness is engine-agnostic ([`UpdatableEngine`]) so the identical
//! workload runs against the single-process [`Service`] and against a
//! [`ShardCoordinator`] fanning out to live worker processes-in-threads
//! ([`ShardedEngine`]) — the tentpole claim is that BOTH stay exact
//! without ever restarting cold.
//!
//! Mutations are addressed in *original* vertex ids (the engines' public
//! surface); the mirror translates through the graph's relabeling exactly
//! like the engines do, so a relabeled serve graph is checked against the
//! same logical edge set.
#![allow(dead_code)]

use morphmine::graph::{DataGraph, DynGraph, GraphFingerprint, Relabeling};
use morphmine::morph::Policy;
use morphmine::service::{BatchResponse, QueryPlanner, Service, ServiceConfig};
use morphmine::shard::{ShardCoordinator, ShardWorker, WorkerConfig};
use morphmine::util::rng::Rng;

/// Anything that serves query batches over a mutable graph: apply an edge
/// update, re-serve, report the graph epoch.
pub trait UpdatableEngine {
    /// Short name for assertion messages ("service", "sharded×2", …).
    fn label(&self) -> String;
    /// Apply `+ (u,v)` / `- (u,v)` in original vertex ids; Ok(changed).
    fn apply(&mut self, insert: bool, u: u32, v: u32) -> anyhow::Result<bool>;
    /// Serve one batch of query texts.
    fn serve(&mut self, batch: &[&str]) -> anyhow::Result<BatchResponse>;
    /// The engine's current graph epoch (mutation version).
    fn epoch(&self) -> u64;
}

impl UpdatableEngine for Service {
    fn label(&self) -> String {
        "service".into()
    }
    fn apply(&mut self, insert: bool, u: u32, v: u32) -> anyhow::Result<bool> {
        if insert {
            self.insert_edge(u, v)
        } else {
            self.remove_edge(u, v)
        }
    }
    fn serve(&mut self, batch: &[&str]) -> anyhow::Result<BatchResponse> {
        self.call(batch)
    }
    fn epoch(&self) -> u64 {
        Service::epoch(self)
    }
}

/// A [`ShardCoordinator`] plus the in-process workers it fans out to,
/// owned together so tests tear the whole fabric down in one place.
pub struct ShardedEngine {
    coord: ShardCoordinator,
    workers: Vec<ShardWorker>,
}

impl ShardedEngine {
    /// Spin up `num_workers` loopback workers over `g` and connect a
    /// coordinator to them.
    pub fn start(g: &DataGraph, num_workers: usize, policy: Policy) -> ShardedEngine {
        let config = WorkerConfig {
            threads: 2,
            fused: true,
            cache_bytes: 1 << 20,
            persist: None,
            slice_pin: None,
        };
        let workers: Vec<ShardWorker> = (0..num_workers)
            .map(|_| ShardWorker::bind(g.clone(), "127.0.0.1:0", config.clone()).unwrap())
            .collect();
        let addrs: Vec<String> = workers.iter().map(|w| w.addr().to_string()).collect();
        let planner = QueryPlanner::new(policy, true, 2);
        let coord = ShardCoordinator::connect(g.clone(), &addrs, planner, 1 << 20).unwrap();
        ShardedEngine { coord, workers }
    }

    pub fn coordinator(&mut self) -> &mut ShardCoordinator {
        &mut self.coord
    }

    pub fn workers(&self) -> &[ShardWorker] {
        &self.workers
    }

    /// Graceful teardown (drop the coordinator first so workers see EOF).
    pub fn shutdown(self) {
        drop(self.coord);
        for w in self.workers {
            w.shutdown();
        }
    }
}

impl UpdatableEngine for ShardedEngine {
    fn label(&self) -> String {
        format!("sharded×{}", self.workers.len())
    }
    fn apply(&mut self, insert: bool, u: u32, v: u32) -> anyhow::Result<bool> {
        if insert {
            self.coord.insert_edge(u, v)
        } else {
            self.coord.remove_edge(u, v)
        }
    }
    fn serve(&mut self, batch: &[&str]) -> anyhow::Result<BatchResponse> {
        self.coord.call(batch)
    }
    fn epoch(&self) -> u64 {
        self.coord.epoch()
    }
}

/// The differential rig: a mirror of the engine's graph plus the batch to
/// re-serve and cross-check after every mutation.
pub struct Differential {
    mirror: DynGraph,
    relabel: Option<Relabeling>,
    batch: Vec<String>,
    /// Mutations attempted through [`Differential::step`].
    pub steps: usize,
    /// Mutations that actually changed the graph.
    pub applied: usize,
}

impl Differential {
    /// Mirror `start` (the exact graph the engine was started on) and
    /// check `batch` after every mutation.
    pub fn new(start: &DataGraph, batch: &[&str]) -> Differential {
        Differential {
            mirror: DynGraph::from_data_graph(start),
            relabel: start.relabeling().cloned(),
            batch: batch.iter().map(|s| s.to_string()).collect(),
            steps: 0,
            applied: 0,
        }
    }

    fn internal(&self, v: u32) -> u32 {
        match &self.relabel {
            Some(r) if (v as usize) < r.len() => r.new_id(v),
            _ => v,
        }
    }

    /// The mirror's current fingerprint — what a correct engine's graph
    /// must hash to after the same mutations.
    pub fn fingerprint(&self) -> GraphFingerprint {
        self.mirror.fingerprint()
    }

    /// Apply one mutation to both the engine and the mirror, assert they
    /// agree on whether anything changed and that the epoch moves iff the
    /// graph did, then cross-check the engine against a cold oracle.
    pub fn step(&mut self, engine: &mut dyn UpdatableEngine, insert: bool, u: u32, v: u32) {
        let sign = if insert { '+' } else { '-' };
        let before = engine.epoch();
        let changed = engine.apply(insert, u, v).unwrap_or_else(|e| {
            panic!("{}: step {} {sign} ({u},{v}) must not fail: {e:#}", engine.label(), self.steps)
        });
        let (iu, iv) = (self.internal(u), self.internal(v));
        let mirrored = if insert {
            self.mirror.insert_edge(iu, iv)
        } else {
            self.mirror.remove_edge(iu, iv)
        };
        assert_eq!(
            changed,
            mirrored,
            "{}: step {} {sign} ({u},{v}): engine and mirror disagree on whether the edge set changed",
            engine.label(),
            self.steps
        );
        if changed {
            assert!(
                engine.epoch() > before,
                "{}: applied {sign} ({u},{v}) must bump the epoch past {before}",
                engine.label()
            );
            self.applied += 1;
        } else {
            assert_eq!(
                engine.epoch(),
                before,
                "{}: rejected {sign} ({u},{v}) must not bump the epoch",
                engine.label()
            );
        }
        self.steps += 1;
        self.check(engine);
    }

    /// The differential check itself: the engine's warm answers vs a
    /// fresh, cache-less, delta-less engine over the mirrored graph. The
    /// oracle runs with morphing OFF so the two sides share as little
    /// machinery as possible.
    pub fn check(&self, engine: &mut dyn UpdatableEngine) {
        let refs: Vec<&str> = self.batch.iter().map(|s| s.as_str()).collect();
        let warm = engine
            .serve(&refs)
            .unwrap_or_else(|e| panic!("{}: warm batch failed: {e:#}", engine.label()));
        let oracle = Service::start(
            self.mirror.to_data_graph("differential-oracle"),
            ServiceConfig {
                workers: 1,
                threads: 2,
                policy: Policy::Off,
                fused: true,
                cache_bytes: 1 << 20,
                persist: None,
                delta_budget: 0,
            },
        );
        let cold = oracle.call(&refs).expect("cold oracle batch");
        assert_eq!(
            warm.results, cold.results,
            "{}: after {} applied mutations ({} attempted) the maintained answers diverged from a cold recount",
            engine.label(),
            self.applied,
            self.steps
        );
    }

    /// Drive `steps` random in-range mutations through the engine (a
    /// ~55/45 insert/remove mix over random vertex pairs, so duplicate
    /// inserts and missing-edge removals occur naturally), checking after
    /// every one.
    pub fn run_random(&mut self, engine: &mut dyn UpdatableEngine, steps: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let n = self.mirror.num_vertices() as u64;
        let mut done = 0;
        while done < steps {
            let u = rng.below(n) as u32;
            let v = rng.below(n) as u32;
            if u == v {
                continue;
            }
            self.step(engine, rng.below(100) < 55, u, v);
            done += 1;
        }
    }
}
