//! Cross-layer validation: the AOT XLA census (Pallas kernel + JAX model,
//! compiled through PJRT) against the sparse Rust matcher — two independent
//! implementations of the same morphing equations.

use morphmine::apps;
use morphmine::graph::generators::{barabasi_albert, erdos_renyi};
use morphmine::morph::Policy;
use morphmine::runtime::{census_motifs3, census_motifs4, CensusBackend};

fn backend() -> Option<CensusBackend> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("census_64.hlo.txt").exists() {
        eprintln!("skipping runtime integration: run `make artifacts`");
        return None;
    }
    Some(CensusBackend::load(&dir).unwrap())
}

#[test]
fn census_cross_check_er_graphs() {
    let Some(be) = backend() else { return };
    for seed in [1u64, 2, 3] {
        let g = erdos_renyi(60, 240, seed);
        let dense = be.census_graph(&g).unwrap();
        let sparse3 = apps::count_motifs(&g, 3, Policy::Off, 2);
        let sparse4 = apps::count_motifs(&g, 4, Policy::Naive, 2);
        let m3 = [dense.get("wedge_vi").unwrap(), dense.get("triangle").unwrap()];
        for (v, p) in m3.iter().zip(census_motifs3().iter()) {
            assert_eq!(v.round() as u64, sparse3.get(p).unwrap(), "seed {seed} {p:?}");
        }
        for (v, p) in dense.motifs4().iter().zip(census_motifs4().iter()) {
            assert_eq!(v.round() as u64, sparse4.get(p).unwrap(), "seed {seed} {p:?}");
        }
    }
}

#[test]
fn census_cross_check_powerlaw() {
    let Some(be) = backend() else { return };
    let g = barabasi_albert(120, 4, 7);
    let dense = be.census_graph(&g).unwrap();
    let sparse = apps::count_motifs(&g, 4, Policy::Off, 2);
    for (v, p) in dense.motifs4().iter().zip(census_motifs4().iter()) {
        assert_eq!(v.round() as u64, sparse.get(p).unwrap(), "{p:?}");
    }
}

#[test]
fn census_cycle5_cross_check() {
    let Some(be) = backend() else { return };
    let g = erdos_renyi(40, 150, 11);
    let dense = be.census_graph(&g).unwrap();
    let sparse = apps::match_patterns(
        &g,
        &[morphmine::pattern::catalog::cycle(5)],
        Policy::Off,
        2,
    );
    assert_eq!(
        dense.get("cycle5_e").unwrap().round() as u64,
        sparse.counts[0]
    );
}

#[test]
fn census_artifact_sizes_consistent() {
    let Some(be) = backend() else { return };
    // same graph through the 64- and 128-wide executables (64-v graph uses
    // the small one; padding it into the large one must agree)
    let g = erdos_renyi(50, 180, 13);
    let r_small = be.census_graph(&g).unwrap();
    let block: Vec<u32> = (0..g.num_vertices() as u32).collect();
    let r_block = be.census_block(&g, &block).unwrap();
    assert_eq!(r_small.values, r_block.values);
}
