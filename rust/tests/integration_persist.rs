//! Crash-recovery integration tests for the durable result store
//! (`rust/src/service/persist/`).
//!
//! The contract under test: a persisted-then-restarted service serves a
//! previously-seen batch with **zero executed bases**, while recovery
//! against a truncated WAL (a kill between records), a bit-flipped
//! record, a corrupted snapshot, or a different/mutated graph silently
//! degrades to a *colder* store — never a panic, and never an answer that
//! differs from a cold engine's on the live graph.

use morphmine::graph::generators::erdos_renyi;
use morphmine::graph::{DataGraph, DynGraph};
use morphmine::morph::{self, Policy};
use morphmine::pattern::Pattern;
use morphmine::service::persist::{self, snapshot, wal, Persistence};
use morphmine::service::{PersistConfig, PersistOpts, Service, ServiceConfig};
use morphmine::util::proptest;
use std::path::{Path, PathBuf};

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mm_itest_persist_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn config(dir: &Path, opts: PersistOpts) -> ServiceConfig {
    ServiceConfig {
        workers: 1,
        threads: 2,
        policy: Policy::Naive,
        fused: true,
        cache_bytes: 8 << 20,
        delta_budget: morphmine::service::DEFAULT_DELTA_BUDGET,
        persist: Some(PersistConfig {
            dir: dir.to_path_buf(),
            opts,
        }),
    }
}

/// WAL-only persistence: never compacts, so the log holds every record.
fn wal_only() -> PersistOpts {
    PersistOpts {
        snapshot_every: usize::MAX,
        compact_on_drop: false,
        fsync_every: None,
    }
}

/// Unique-match counts for `patterns` via the cold (cache-free) engine.
fn cold_counts(g: &DataGraph, patterns: &[Pattern]) -> Vec<u64> {
    morph::engine::count_queries(g, patterns, Policy::Naive, 1)
}

/// Assert a restarted service's batch answers equal the cold engine's on
/// `check`, whatever the store recovered.
fn assert_answers_cold(svc: &Service, check: &DataGraph, batch: &[&str]) {
    let r = svc.call(batch).expect("batch serves");
    for q in &r.results {
        let pats: Vec<Pattern> = q.counts.iter().map(|(p, _)| p.clone()).collect();
        let got: Vec<u64> = q.counts.iter().map(|&(_, c)| c).collect();
        assert_eq!(got, cold_counts(check, &pats), "query {}", q.query);
    }
}

#[test]
fn warm_restart_round_trip_executes_zero_bases() {
    // acceptance criterion: persist → restart → previously-seen batch is
    // served with zero executed bases and identical answers
    let dir = tmp_dir("roundtrip");
    let g = || erdos_renyi(60, 220, 0xD00D);
    let batch = ["motifs:4", "cliques:3"];
    let svc = Service::try_start(g(), config(&dir, PersistOpts::default())).unwrap();
    let cold = svc.call(&batch).unwrap();
    assert!(cold.stats.executed_bases > 0);
    // single-writer guard: a second live service on the same directory is
    // refused instead of interleaving WAL frames with this one
    assert!(Service::try_start(g(), config(&dir, PersistOpts::default())).is_err());
    drop(svc); // graceful shutdown → snapshot compaction
    let svc = Service::try_start(g(), config(&dir, PersistOpts::default())).unwrap();
    let rep = svc.recovery_report().expect("persistence configured");
    assert!(rep.fingerprint_matched);
    assert!(rep.snapshot_entries > 0, "graceful drop must have compacted");
    assert_eq!(rep.wal_records, 0, "compaction resets the log");
    let warm = svc.call(&batch).unwrap();
    assert_eq!(warm.stats.executed_bases, 0, "{:?}", warm.stats);
    assert_eq!(warm.stats.cached_bases, warm.stats.total_bases);
    assert_eq!(cold.results, warm.results);
    assert_eq!(svc.store_metrics().restored as usize, rep.restored);
}

#[test]
fn wal_replay_without_snapshot_restarts_warm() {
    let dir = tmp_dir("replay");
    let g = || erdos_renyi(60, 220, 0x11AB);
    let batch = ["motifs:4"];
    let svc = Service::try_start(g(), config(&dir, wal_only())).unwrap();
    let cold = svc.call(&batch).unwrap();
    svc.call(&["cliques:4"]).unwrap(); // more records in the log
    drop(svc);
    assert!(!dir.join(snapshot::SNAPSHOT_FILE).exists(), "no compaction happened");
    assert!(dir.join(wal::WAL_FILE).exists());
    let svc = Service::try_start(g(), config(&dir, wal_only())).unwrap();
    let rep = svc.recovery_report().unwrap();
    assert_eq!(rep.snapshot_entries, 0);
    assert!(rep.wal_records > 0 && rep.fingerprint_matched);
    let warm = svc.call(&batch).unwrap();
    assert_eq!(warm.stats.executed_bases, 0, "replayed store must serve warm");
    assert_eq!(cold.results, warm.results);
}

#[test]
fn kill_between_wal_records_recovers_a_correct_prefix() {
    // build a WAL-only directory, then simulate a kill at EVERY byte
    // offset of the log: recovery must never panic, and every recovered
    // entry must carry the value the full log holds for that key
    let dir = tmp_dir("kill");
    let graph = erdos_renyi(50, 180, 0x516); // no relabeling on ER graphs
    let fp = graph.fingerprint();
    let svc = Service::try_start(graph.clone(), config(&dir, wal_only())).unwrap();
    svc.call(&["motifs:4", "cliques:3"]).unwrap();
    drop(svc);
    let full_bytes = std::fs::read(dir.join(wal::WAL_FILE)).unwrap();
    let (_, full_entries, full_rep) =
        Persistence::<i128>::open(&dir, fp, wal_only()).expect("full recovery");
    assert!(full_rep.fingerprint_matched && !full_entries.is_empty());
    // the recovery probe itself must not disturb the log
    assert_eq!(std::fs::read(dir.join(wal::WAL_FILE)).unwrap(), full_bytes);

    let cut_dir = tmp_dir("kill_cut");
    for cut in 0..=full_bytes.len() {
        std::fs::write(cut_dir.join(wal::WAL_FILE), &full_bytes[..cut]).unwrap();
        let (_, entries, rep) =
            Persistence::<i128>::open(&cut_dir, fp, wal_only()).expect("truncated recovery");
        assert!(entries.len() <= full_entries.len());
        for (k, v) in &entries {
            let expect = full_entries.iter().find(|(fk, _)| fk == k);
            assert_eq!(expect.map(|(_, fv)| *fv), Some(*v), "cut={cut}");
        }
        assert!(rep.restored == entries.len());
    }

    // a service restart on a mid-log prefix recomputes the missing tail
    // and still answers exactly like the cold engine
    let cut = full_bytes.len() * 2 / 3;
    std::fs::write(cut_dir.join(wal::WAL_FILE), &full_bytes[..cut]).unwrap();
    let _ = std::fs::remove_file(cut_dir.join(snapshot::SNAPSHOT_FILE));
    let svc = Service::try_start(graph.clone(), config(&cut_dir, wal_only())).unwrap();
    assert_answers_cold(&svc, &graph, &["motifs:4", "cliques:3"]);
}

#[test]
fn bit_flipped_wal_record_truncates_never_panics() {
    let dir = tmp_dir("bitflip");
    let graph = erdos_renyi(50, 180, 0xF11);
    let svc = Service::try_start(graph.clone(), config(&dir, wal_only())).unwrap();
    svc.call(&["motifs:3", "cliques:3"]).unwrap();
    drop(svc);
    let bytes = std::fs::read(dir.join(wal::WAL_FILE)).unwrap();
    // flip one bit somewhere after the header frame, in the record region
    let mut flipped = bytes.clone();
    let at = 48.min(flipped.len() - 1);
    flipped[at] ^= 0x20;
    std::fs::write(dir.join(wal::WAL_FILE), &flipped).unwrap();
    let insp = persist::inspect::<i128>(&dir);
    assert!(insp.wal_truncated, "the flip must be detected");
    let svc = Service::try_start(graph.clone(), config(&dir, wal_only())).unwrap();
    let rep = svc.recovery_report().unwrap();
    assert!(rep.wal_truncated);
    // the truncation is physical: before any new record is appended, the
    // log has been cut back to the clean prefix
    assert!(std::fs::metadata(dir.join(wal::WAL_FILE)).unwrap().len() < bytes.len() as u64);
    assert_answers_cold(&svc, &graph, &["motifs:3", "cliques:3"]);
}

#[test]
fn corrupted_snapshot_falls_back_without_panic() {
    let dir = tmp_dir("snapflip");
    let graph = erdos_renyi(50, 180, 0x5A9);
    let svc = Service::try_start(graph.clone(), config(&dir, PersistOpts::default())).unwrap();
    svc.call(&["motifs:3"]).unwrap();
    drop(svc); // compacts: snapshot + empty WAL
    let snap_path = dir.join(snapshot::SNAPSHOT_FILE);
    let mut bytes = std::fs::read(&snap_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x04;
    std::fs::write(&snap_path, &bytes).unwrap();
    let svc = Service::try_start(graph.clone(), config(&dir, PersistOpts::default())).unwrap();
    let rep = svc.recovery_report().unwrap();
    assert_eq!(rep.snapshot_entries, 0, "CRC must reject the whole image");
    assert_eq!(rep.restored, 0, "post-compaction WAL is empty: cold");
    assert_answers_cold(&svc, &graph, &["motifs:3"]);
}

#[test]
fn restart_against_a_different_graph_degrades_to_cold() {
    let dir = tmp_dir("othergraph");
    let a = erdos_renyi(50, 180, 1);
    let b = erdos_renyi(50, 180, 2); // same order, different wiring
    let svc = Service::try_start(a, config(&dir, PersistOpts::default())).unwrap();
    svc.call(&["motifs:4"]).unwrap();
    drop(svc);
    let svc = Service::try_start(b.clone(), config(&dir, PersistOpts::default())).unwrap();
    let rep = svc.recovery_report().unwrap();
    assert!(!rep.fingerprint_matched, "state for graph A is unservable on B");
    assert_eq!(rep.restored, 0);
    let r = svc.call(&["motifs:4"]).unwrap();
    assert_eq!(
        r.stats.executed_bases, r.stats.total_bases,
        "everything recomputes against the new graph"
    );
    assert_answers_cold(&svc, &b, &["motifs:3"]);
    drop(svc);
    // the directory is retargeted to B: a second restart on B is warm
    let svc = Service::try_start(b.clone(), config(&dir, PersistOpts::default())).unwrap();
    assert!(svc.recovery_report().unwrap().fingerprint_matched);
    let warm = svc.call(&["motifs:4"]).unwrap();
    assert_eq!(warm.stats.executed_bases, 0);
}

#[test]
fn restart_against_a_mutated_graph_matches_by_content() {
    // mutate the graph THROUGH the service (epoch bump → WAL invalidation
    // + re-inserts under the post-mutation fingerprint), then restart on
    // graphs of both contents: only the matching one recovers warm
    let dir = tmp_dir("mutated");
    let g0 = erdos_renyi(40, 140, 0xE70);
    let mut mirror = DynGraph::from_data_graph(&g0);
    let svc = Service::try_start(g0.clone(), config(&dir, PersistOpts::default())).unwrap();
    svc.call(&["motifs:3"]).unwrap();
    let (u, v) = (0..40u32)
        .flat_map(|x| (0..40u32).map(move |y| (x, y)))
        .find(|&(x, y)| x < y && !mirror.has_edge(x, y))
        .expect("sparse graph has a non-edge");
    assert!(svc.insert_edge(u, v).unwrap());
    assert!(mirror.insert_edge(u, v));
    let mutated = svc.call(&["motifs:3"]).unwrap(); // persists under the mutated fingerprint
    drop(svc);

    // restart on the ORIGINAL graph: the disk state describes the mutated
    // content, so it must not serve
    let svc = Service::try_start(g0.clone(), config(&dir, PersistOpts::default())).unwrap();
    assert!(!svc.recovery_report().unwrap().fingerprint_matched);
    assert_answers_cold(&svc, &g0, &["motifs:3"]);
    drop(svc);

    // rebuild the mutated dir state (the original-graph restart above
    // retargeted it), then restart on the mutated content: warm
    let dir2 = tmp_dir("mutated2");
    let svc = Service::try_start(g0.clone(), config(&dir2, PersistOpts::default())).unwrap();
    assert!(svc.insert_edge(u, v).unwrap());
    let again = svc.call(&["motifs:3"]).unwrap();
    assert_eq!(again.results, mutated.results);
    drop(svc);
    let snapshot_of_mutated = mirror.to_data_graph("mutated");
    let svc =
        Service::try_start(snapshot_of_mutated.clone(), config(&dir2, PersistOpts::default()))
            .unwrap();
    let rep = svc.recovery_report().unwrap();
    assert!(rep.fingerprint_matched, "content matches the mutated graph");
    assert!(rep.restored > 0);
    let warm = svc.call(&["motifs:3"]).unwrap();
    assert_eq!(warm.stats.executed_bases, 0);
    assert_eq!(warm.results, mutated.results);
    assert_answers_cold(&svc, &snapshot_of_mutated, &["motifs:3"]);
}

#[test]
fn prop_random_corruption_never_panics_and_never_lies() {
    // property: persist a batch, corrupt the directory at random (truncate
    // the WAL at a random offset, flip a random byte in WAL or snapshot,
    // or leave it intact), restart — the service must start, and answers
    // must equal the cold engine's on the live graph
    let dir = tmp_dir("prop");
    proptest::check(0x9E51, 10, |rng| {
        let seed = rng.below(1 << 30);
        let graph = erdos_renyi(36, 120, seed);
        let batches: [&[&str]; 3] =
            [&["motifs:3"], &["motifs:3", "cliques:3"], &["match:wedge,triangle"]];
        let batch = batches[rng.below_usize(batches.len())];
        let opts = if rng.chance(0.5) { wal_only() } else { PersistOpts::default() };
        let _ = std::fs::remove_dir_all(&dir);
        let svc = Service::try_start(graph.clone(), config(&dir, opts)).unwrap();
        svc.call(batch).expect("seed batch");
        drop(svc);
        // random corruption
        for name in [wal::WAL_FILE, snapshot::SNAPSHOT_FILE] {
            let p = dir.join(name);
            let Ok(mut bytes) = std::fs::read(&p) else { continue };
            if bytes.is_empty() {
                continue;
            }
            match rng.below(3) {
                0 => {
                    let cut = rng.below_usize(bytes.len() + 1);
                    bytes.truncate(cut);
                    std::fs::write(&p, &bytes).unwrap();
                }
                1 => {
                    let at = rng.below_usize(bytes.len());
                    bytes[at] ^= 1 << rng.below(8);
                    std::fs::write(&p, &bytes).unwrap();
                }
                _ => {}
            }
        }
        let svc = Service::try_start(graph.clone(), config(&dir, opts)).unwrap();
        assert_answers_cold(&svc, &graph, batch);
    });
}
