//! Differential tests for delta-morphing result maintenance: a
//! delta-patched serving engine (single-process [`Service`] and sharded
//! [`ShardCoordinator`]) is driven through randomized mutation workloads
//! and cross-checked against a fresh cold engine after EVERY update — the
//! tentpole claim that a long-lived serve session never has to restart
//! cold, and never serves a wrong count to avoid it.

mod support;

use morphmine::graph::generators::erdos_renyi;
use morphmine::graph::{DataGraph, GraphBuilder};
use morphmine::morph::Policy;
use morphmine::service::{Service, ServiceConfig, DEFAULT_DELTA_BUDGET};
use morphmine::util::proptest;
use support::differential::{Differential, ShardedEngine, UpdatableEngine};

fn service_over(g: DataGraph, policy: Policy, delta_budget: usize) -> Service {
    Service::start(
        g,
        ServiceConfig {
            workers: 2,
            threads: 2,
            policy,
            fused: true,
            cache_bytes: 1 << 20,
            persist: None,
            delta_budget,
        },
    )
}

/// The headline workload: ≥50 randomized mutations through a warm
/// single-process service, answers checked against a cold recount after
/// every one — and the delta path must actually patch, not quietly purge
/// its way to correctness.
#[test]
fn fifty_mutation_workload_single_process() {
    let g = erdos_renyi(22, 66, 0xD1F1);
    let batch = ["motifs:4", "match:cycle4,diamond-vi"];
    let mut diff = Differential::new(&g, &batch);
    let mut svc = service_over(g, Policy::Naive, DEFAULT_DELTA_BUDGET);
    svc.call(&batch).unwrap(); // warm the store so updates have cached values to maintain
    diff.run_random(&mut svc, 50, 0xD1F2);
    assert!(diff.applied >= 20, "the workload must actually mutate: {} applied", diff.applied);
    assert!(
        svc.store_metrics().patched > 0,
        "the delta path must patch entries in place, not always fall back: {:?}",
        svc.store_metrics()
    );
}

/// The same ≥50-mutation differential through the fabric: a coordinator
/// over two live workers, every update broadcast via proto v6 UPDATE and
/// applied to the workers' own graph copies.
#[test]
fn fifty_mutation_workload_sharded_two_workers() {
    let g = erdos_renyi(20, 60, 0xD1F3);
    let batch = ["motifs:4", "match:cycle4,diamond-vi"];
    let mut diff = Differential::new(&g, &batch);
    let mut eng = ShardedEngine::start(&g, 2, Policy::Naive);
    eng.serve(&batch).unwrap();
    diff.run_random(&mut eng, 50, 0xD1F4);
    assert!(diff.applied >= 20, "the workload must actually mutate: {} applied", diff.applied);
    assert!(
        morphmine::obs::global().counter("mm_worker_updates_total").get() > 0,
        "updates must reach the workers over the wire, not just the coordinator"
    );
    eng.shutdown();
}

/// Satellite property: ER graphs × motif sizes 3–4 × every morph policy,
/// each iteration running a shorter differential workload.
#[test]
fn differential_property_er_by_size_and_policy() {
    proptest::check(0xD1F5, 5, |rng| {
        let n = 12 + rng.below_usize(10);
        let m = n + rng.below_usize(2 * n);
        let g = erdos_renyi(n, m, rng.next_u64());
        let size = 3 + rng.below_usize(2);
        let policy = [Policy::Off, Policy::Naive, Policy::CostBased][rng.below_usize(3)];
        let q = format!("motifs:{size}");
        let batch = [q.as_str()];
        let mut diff = Differential::new(&g, &batch);
        let mut svc = service_over(g, policy, DEFAULT_DELTA_BUDGET);
        svc.call(&batch).unwrap();
        diff.run_random(&mut svc, 10, rng.next_u64());
    });
}

/// Edge cases the delta math must shrug off: re-inserting an existing
/// edge, removing an absent one (both exact no-ops, epoch untouched), and
/// a self-loop (a hard error, loudly, before anything mutates).
#[test]
fn duplicate_inserts_missing_removals_and_self_loops() {
    let g = erdos_renyi(14, 30, 0xD1F6);
    let batch = ["motifs:3"];
    let mut diff = Differential::new(&g, &batch);
    let mut svc = service_over(g.clone(), Policy::Naive, DEFAULT_DELTA_BUDGET);
    svc.call(&batch).unwrap();
    // an edge the graph already has, addressed in original ids
    let iu = 0u32;
    let iv = *g.neighbors(iu).first().expect("vertex 0 has neighbors");
    diff.step(&mut svc, true, g.original_id(iu), g.original_id(iv)); // duplicate insert → no-op
    // a pair the graph does not connect
    let (au, av) = (0..14u32)
        .flat_map(|a| (0..14u32).map(move |b| (a, b)))
        .find(|&(a, b)| a != b && !g.has_edge(a, b))
        .expect("a 14-vertex 30-edge graph has non-edges");
    let (ou, ov) = (g.original_id(au), g.original_id(av));
    diff.step(&mut svc, false, ou, ov); // remove a non-edge → no-op
    diff.step(&mut svc, true, ou, ov); // now insert it for real
    diff.step(&mut svc, false, ou, ov); // …and take it back out
    assert_eq!(diff.applied, 2, "exactly the two real mutations applied");
    // self-loops error before touching anything
    let before = svc.epoch();
    let err = svc.insert_edge(7, 7).unwrap_err();
    assert!(format!("{err:#}").contains("self loop"), "{err:#}");
    assert_eq!(svc.epoch(), before, "a rejected self-loop must not bump the epoch");
}

/// Tearing down a hub one spoke at a time: every removal reshapes the
/// neighborhood of the highest-degree vertex, the hardest case for the
/// delta pass's locality argument.
#[test]
fn disconnecting_a_hub_stays_exact() {
    // hub 0 wired to every ring vertex 1..=11, ring keeps things connected
    let mut edges: Vec<(u32, u32)> = (1..12u32).map(|v| (0, v)).collect();
    edges.extend((1..12u32).map(|v| (v, if v == 11 { 1 } else { v + 1 })));
    let g = GraphBuilder::new().edges(&edges).build("hub");
    let batch = ["motifs:4"];
    let mut diff = Differential::new(&g, &batch);
    let mut svc = service_over(g.clone(), Policy::Naive, DEFAULT_DELTA_BUDGET);
    svc.call(&batch).unwrap();
    for v in 1..12u32 {
        diff.step(&mut svc, false, 0, v);
    }
    assert_eq!(diff.applied, 11, "all hub spokes removed");
}

/// With the delta budget at 0 every update must take the purge fallback —
/// still exact, never patching, and counted out loud.
#[test]
fn purge_fallback_is_counted_never_silent() {
    let g = erdos_renyi(16, 40, 0xD1F7);
    let batch = ["motifs:3"];
    let mut diff = Differential::new(&g, &batch);
    let mut svc = service_over(g, Policy::Naive, 0);
    svc.call(&batch).unwrap();
    let fallback = morphmine::obs::global().counter("mm_delta_fallback_total");
    let before = fallback.get();
    diff.run_random(&mut svc, 6, 0xD1F8);
    assert!(diff.applied > 0, "the workload must mutate");
    assert_eq!(svc.store_metrics().patched, 0, "budget 0 must never patch");
    assert!(fallback.get() > before, "fallbacks are counted, never silent");
}
