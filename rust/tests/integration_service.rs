//! Integration tests for the service layer: cache correctness (warm-cache
//! answers must equal cold-engine answers, including across incremental
//! graph updates), the zero-execution warm-batch guarantee, partial reuse,
//! and cross-batch coalescing.

use morphmine::graph::generators::erdos_renyi;
use morphmine::graph::{DataGraph, DynGraph};
use morphmine::morph::{self, Policy};
use morphmine::pattern::{catalog, Pattern};
use morphmine::service::{Service, ServiceConfig};
use morphmine::util::proptest;

fn naive_service(graph: DataGraph, workers: usize, threads: usize) -> Service {
    Service::start(
        graph,
        ServiceConfig {
            workers,
            threads,
            policy: Policy::Naive,
            fused: true,
            cache_bytes: 8 << 20,
            delta_budget: morphmine::service::DEFAULT_DELTA_BUDGET,
            persist: None,
        },
    )
}

/// Unique-match counts for `patterns` via the cold (cache-free) engine.
fn cold_counts(g: &DataGraph, patterns: &[Pattern]) -> Vec<u64> {
    morph::engine::count_queries(g, patterns, Policy::Naive, 1)
}

#[test]
fn warm_batch_executes_zero_bases() {
    // acceptance criterion: a warm-cache batch over a previously-seen
    // pattern set executes zero base patterns, verified by store metrics
    let g = erdos_renyi(70, 260, 0xCAFE);
    let svc = naive_service(g, 2, 2);
    let cold = svc.call(&["motifs:4"]).unwrap();
    assert!(cold.stats.executed_bases > 0);
    let before = svc.store_metrics();
    let warm = svc.call(&["motifs:4"]).unwrap();
    let after = svc.store_metrics();
    assert_eq!(warm.stats.executed_bases, 0, "{:?}", warm.stats);
    assert_eq!(warm.stats.cached_bases, warm.stats.total_bases);
    assert_eq!(after.inserts, before.inserts, "a fully-warm batch must not insert anything");
    assert!(after.hits >= before.hits + warm.stats.total_bases as u64);
    assert_eq!(cold.results, warm.results);
}

#[test]
fn partial_overlap_executes_only_missing_bases() {
    let g = erdos_renyi(70, 260, 0xBEEF);
    let check = g.clone();
    let svc = naive_service(g, 2, 2);
    let first = svc.call(&["match:cycle4"]).unwrap();
    assert_eq!(first.stats.executed_bases, first.stats.total_bases);
    // the 4-motif set's naive bases overlap C4^E's alternative set via K4
    // (and the overlapping match set re-adds C4's own bases)
    let second = svc.call(&["match:cycle4,tailed", "cliques:4"]).unwrap();
    assert!(second.stats.cached_bases > 0, "{:?}", second.stats);
    assert!(second.stats.executed_bases > 0, "{:?}", second.stats);
    assert!(
        second.stats.executed_bases < second.stats.total_bases,
        "cached bases must drop out of execution: {:?}",
        second.stats
    );
    // answers equal the cold engine's
    let queries = vec![catalog::cycle(4), catalog::tailed_triangle(), catalog::clique(4)];
    let expect = cold_counts(&check, &queries);
    let got: Vec<u64> = second
        .results
        .iter()
        .flat_map(|r| r.counts.iter().map(|&(_, c)| c))
        .collect();
    assert_eq!(got, expect);
}

#[test]
fn epoch_bump_serves_fresh_counts() {
    let g0 = erdos_renyi(40, 140, 0xE70C);
    let mut mirror = DynGraph::from_data_graph(&g0);
    let svc = naive_service(g0, 1, 2);
    let batch = ["motifs:3", "motifs:4"];

    let r0 = svc.call(&batch).unwrap();
    assert_eq!(r0.epoch, 0);

    // apply an insertion through the service, mirror it locally
    let (u, v) = (0..40u32)
        .flat_map(|a| (0..40u32).map(move |b| (a, b)))
        .find(|&(a, b)| a < b && !mirror.has_edge(a, b))
        .expect("sparse graph has a non-edge");
    assert!(svc.insert_edge(u, v).unwrap());
    assert!(mirror.insert_edge(u, v));
    assert_eq!(svc.epoch(), 1);

    let r1 = svc.call(&batch).unwrap();
    assert_eq!(r1.epoch, 1);
    assert_eq!(
        r1.stats.executed_bases, 0,
        "motif bases are delta-patched in place, not recomputed: {:?}",
        r1.stats
    );
    assert!(svc.store_metrics().patched > 0, "the patch must be visible in store metrics");
    let snapshot = mirror.to_data_graph("mirror");
    for q in &r1.results {
        let pats: Vec<Pattern> = q.counts.iter().map(|(p, _)| p.clone()).collect();
        let got: Vec<u64> = q.counts.iter().map(|&(_, c)| c).collect();
        assert_eq!(got, cold_counts(&snapshot, &pats), "{}", q.query);
    }

    // removal restores the original graph — and the original answers
    assert!(svc.remove_edge(u, v).unwrap());
    assert_eq!(svc.epoch(), 2);
    let r2 = svc.call(&batch).unwrap();
    assert_eq!(r0.results, r2.results);
}

#[test]
fn prop_warm_service_equals_cold_engine_across_updates() {
    // satellite: property test over ER graphs and 3/4-motif batches,
    // including insert/remove epoch bumps — the warm service must always
    // agree with a cold execution on the current graph
    proptest::check(0x5E71, 6, |rng| {
        let n = 20 + rng.below_usize(15);
        let m = 2 * n + rng.below_usize(2 * n);
        let g0 = erdos_renyi(n, m, rng.next_u64());
        let mut mirror = DynGraph::from_data_graph(&g0);
        let svc = naive_service(g0, 2, 1);
        let batches: [&[&str]; 3] = [
            &["motifs:3"],
            &["motifs:4", "match:cycle4,tailed-vi"],
            &["motifs:3", "motifs:4"],
        ];
        for round in 0..4 {
            // alternate: query twice (cold-ish then warm), then mutate
            for _ in 0..2 {
                let batch = batches[round % batches.len()];
                let r = svc.call(batch).unwrap();
                let snapshot = mirror.to_data_graph("mirror");
                for q in &r.results {
                    let pats: Vec<Pattern> = q.counts.iter().map(|(p, _)| p.clone()).collect();
                    let got: Vec<u64> = q.counts.iter().map(|&(_, c)| c).collect();
                    assert_eq!(
                        got,
                        cold_counts(&snapshot, &pats),
                        "round {round}, query {}, epoch {}",
                        q.query,
                        r.epoch
                    );
                }
            }
            // random update (insert or remove), mirrored
            let u = rng.below(n as u64) as u32;
            let v = rng.below(n as u64) as u32;
            if u == v {
                continue;
            }
            if rng.chance(0.4) {
                assert_eq!(svc.remove_edge(u, v).unwrap(), mirror.remove_edge(u, v));
            } else {
                assert_eq!(svc.insert_edge(u, v).unwrap(), mirror.insert_edge(u, v));
            }
            assert_eq!(svc.epoch(), mirror.version());
        }
    });
}

#[test]
fn concurrent_mixed_batches_stay_correct() {
    // several workers, overlapping but non-identical batches submitted
    // concurrently: every response must match the cold engine, and each
    // base pattern is computed at most once (coalescing + store)
    let g = erdos_renyi(60, 240, 0xC0A1);
    let check = g.clone();
    let svc = std::sync::Arc::new(naive_service(g, 4, 1));
    let batches: Vec<Vec<&str>> = vec![
        vec!["motifs:4"],
        vec!["motifs:4", "cliques:4"],
        vec!["match:cycle4,diamond-vi"],
        vec!["motifs:4"],
    ];
    let responses: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = batches
            .iter()
            .map(|b| {
                let svc = svc.clone();
                s.spawn(move || svc.call(b).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for r in &responses {
        let s = r.stats;
        assert_eq!(s.cached_bases + s.executed_bases + s.coalesced_bases, s.total_bases);
        for q in &r.results {
            let pats: Vec<Pattern> = q.counts.iter().map(|(p, _)| p.clone()).collect();
            let got: Vec<u64> = q.counts.iter().map(|&(_, c)| c).collect();
            assert_eq!(got, cold_counts(&check, &pats), "{}", q.query);
        }
    }
    // the union of all batches' bases: every one inserted exactly once
    let m = svc.store_metrics();
    let executed: usize = responses.iter().map(|r| r.stats.executed_bases).sum();
    assert_eq!(m.inserts as usize, executed);
    assert_eq!(m.stale_drops, 0);
}
