//! Integration tests for the "other applications" extensions (paper §1):
//! incremental mining, approximate counting with exact morphing
//! conversion, and the end-user surfaces (CLI, pattern parser, IO).

use morphmine::apps::{self, IncrementalMotifCounter};
use morphmine::graph::generators::{barabasi_albert, Dataset, Scale};
use morphmine::graph::DynGraph;
use morphmine::morph::Policy;
use morphmine::pattern::{catalog, parse};
use morphmine::util::rng::Rng;

/// Incremental counting stays exact across a long mixed update stream on a
/// heavy-tailed graph (the regime the paper's streaming application
/// targets).
#[test]
fn incremental_long_stream_on_powerlaw() {
    let g0 = barabasi_albert(120, 3, 0xF00D);
    let mut inc = IncrementalMotifCounter::new(DynGraph::from_data_graph(&g0), 4, 1);
    let mut rng = Rng::new(0xFEED);
    for step in 0..40 {
        let u = rng.below(120) as u32;
        let v = rng.below(120) as u32;
        if u == v {
            continue;
        }
        if step % 4 == 3 {
            inc.remove_edge(u, v);
        } else {
            inc.insert_edge(u, v);
        }
    }
    let snapshot = inc.graph().to_data_graph("stream-end");
    let batch = apps::count_motifs(&snapshot, 4, Policy::Naive, 2);
    for (p, c) in inc.counts() {
        assert_eq!(c, batch.get(&p).unwrap(), "{p:?}");
    }
}

/// The approximate counter's edge-induced conversion is consistent with
/// the exact morphing matrix: converting *exact* vertex-induced counts
/// must give *exact* edge-induced counts.
#[test]
fn approx_conversion_matrix_is_exact_on_exact_inputs() {
    let g = barabasi_albert(150, 4, 0xACE);
    let exact = apps::count_motifs(&g, 4, Policy::Naive, 2);
    // build an ApproxMotifCounts carrying the exact values
    let motifs: Vec<_> = exact.counts.iter().map(|(p, _)| p.clone()).collect();
    let estimates: Vec<f64> = exact.counts.iter().map(|&(_, c)| c as f64).collect();
    let fake = apps::ApproxMotifCounts {
        motifs,
        estimates,
        samples: 0,
    };
    for (pe, est) in fake.edge_induced_estimates() {
        let want = morphmine::exec::count_matches(&g, &morphmine::plan::Plan::compile(&pe));
        assert_eq!(est.round() as u64, want, "{pe:?}");
    }
}

/// Pattern parser round-trips through describe-like specs and catalog
/// names, and the parsed patterns mine identically.
#[test]
fn parser_catalog_equivalence_mines_identically() {
    let g = Dataset::PatentsSim.generate(Scale::Tiny);
    for (name, spec) in [
        ("cycle4", "0-1,1-2,2-3,3-0"),
        ("diamond", "0-1,1-2,2-3,3-0,0-2"),
        ("cycle4-vi", "0-1,1-2,2-3,3-0;vi"),
    ] {
        let a = catalog::by_name(name).unwrap();
        let b = parse::parse(spec).unwrap();
        assert_eq!(a.canonical_key(), b.canonical_key(), "{name}");
        let ra = apps::match_patterns(&g, &[a], Policy::Off, 2);
        let rb = apps::match_patterns(&g, &[b], Policy::CostBased, 2);
        assert_eq!(ra.counts, rb.counts, "{name}");
    }
}

/// CLI end-to-end over a generated file: gen → info → motifs → match.
#[test]
fn cli_pipeline_over_file() {
    let out = std::env::temp_dir().join("mm_ext_cli.txt");
    let argv = |s: &str| s.split_whitespace().map(String::from).collect::<Vec<_>>();
    morphmine::cli::run(argv(&format!(
        "gen --dataset mico:tiny --out {}",
        out.display()
    )))
    .unwrap();
    for cmd in [
        format!("info --graph {}", out.display()),
        format!("motifs --graph {} --size 3 --pmr cost", out.display()),
        format!(
            "match --graph {} --patterns triangle,cycle4-vi --pmr naive --explain",
            out.display()
        ),
        format!("cliques --graph {} --k 4", out.display()),
    ] {
        morphmine::cli::run(argv(&cmd)).unwrap_or_else(|e| panic!("{cmd}: {e:#}"));
    }
}

/// Motif counting at size 5 through the full morph engine: the 21-pattern
/// lattice converts exactly under both rewrite directions.
#[test]
fn motifs5_policies_agree_on_powerlaw() {
    let g = barabasi_albert(60, 3, 0x5A5A);
    let off = apps::count_motifs(&g, 5, Policy::Off, 2);
    let naive = apps::count_motifs(&g, 5, Policy::Naive, 2);
    let cost = apps::count_motifs(&g, 5, Policy::CostBased, 2);
    for ((p, a), ((_, b), (_, c))) in off
        .counts
        .iter()
        .zip(naive.counts.iter().zip(cost.counts.iter()))
    {
        assert_eq!(a, b, "{p:?}");
        assert_eq!(a, c, "{p:?}");
    }
    // the 21 vertex-induced 5-motifs partition the connected 5-subsets:
    // totals agree as well
    assert_eq!(off.total(), naive.total());
}
