//! `cargo bench` entry: regenerates every table and figure of the paper
//! (criterion is unavailable offline; the harness prints markdown reports
//! and records medians through `util::timer::BenchRunner`).
//!
//! Scale via env: `MM_BENCH_SCALE=tiny|small|medium` (default tiny so the
//! full grid completes in minutes), `MM_BENCH_EXP=all|table1|…`.

use morphmine::bench;
use morphmine::graph::generators::Scale;

fn main() -> anyhow::Result<()> {
    // cargo bench passes --bench; ignore unknown flags
    let exp = std::env::var("MM_BENCH_EXP").unwrap_or_else(|_| "all".into());
    let scale = Scale::parse(
        &std::env::var("MM_BENCH_SCALE").unwrap_or_else(|_| "tiny".into()),
    )
    .expect("MM_BENCH_SCALE must be tiny|small|medium");
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    println!(
        "# morphmine paper benches (scale={scale:?}, threads={threads})"
    );
    bench::run_experiment(&exp, scale, threads)?;
    Ok(())
}
