//! Matching-engine executor: runs a compiled [`Plan`] over a [`DataGraph`].
//!
//! Backtracking exploration with per-level candidate buffers; candidates
//! come from the shared level kernel ([`kernel`]): windowed intersections
//! (pattern edges), differences (anti-edges) across the gallop/SIMD/bitmap
//! tiers, plus label filtering and symmetry-breaking ID comparisons — the
//! same exploration style as Peregrine. The parallel driver partitions the
//! first level across threads ([`parallel`]).

pub mod fused;
pub mod intersect;
pub mod kernel;
pub mod parallel;

use crate::graph::{DataGraph, VertexId};
use crate::plan::Plan;

/// Receives every match the executor finds. `m` is indexed by *matching
/// order position*; use [`MatchIter::pattern_order`] to map back to pattern
/// vertices.
pub trait MatchVisitor {
    fn visit(&mut self, m: &[VertexId]);
}

impl<F: FnMut(&[VertexId])> MatchVisitor for F {
    fn visit(&mut self, m: &[VertexId]) {
        self(m)
    }
}

/// Counting visitor (the common fast path).
#[derive(Default)]
pub struct CountVisitor {
    pub count: u64,
}

impl MatchVisitor for CountVisitor {
    #[inline]
    fn visit(&mut self, _m: &[VertexId]) {
        self.count += 1;
    }
}

/// Sequential executor state (one per thread).
pub struct Executor<'g> {
    graph: &'g DataGraph,
    /// candidate buffers, one per level
    bufs: Vec<Vec<VertexId>>,
    /// scratch for intermediate set ops
    scratch: Vec<VertexId>,
    /// current partial match (by order position)
    partial: Vec<VertexId>,
}

impl<'g> Executor<'g> {
    pub fn new(graph: &'g DataGraph, levels: usize) -> Self {
        Executor {
            graph,
            bufs: (0..levels).map(|_| Vec::new()).collect(),
            scratch: Vec::new(),
            partial: vec![0; levels],
        }
    }

    /// Explore all matches rooted at first-level vertex `v0`.
    pub fn run_from(&mut self, plan: &Plan, v0: VertexId, visitor: &mut impl MatchVisitor) {
        let l0 = &plan.levels[0];
        if let Some(lab) = l0.label {
            if self.graph.label(v0) != lab {
                return;
            }
        }
        if self.graph.degree(v0) == 0 && plan.levels.len() > 1 {
            return;
        }
        self.partial[0] = v0;
        self.descend(plan, 1, visitor);
    }

    /// Explore the whole graph sequentially.
    pub fn run(&mut self, plan: &Plan, visitor: &mut impl MatchVisitor) {
        for v in 0..self.graph.num_vertices() as VertexId {
            self.run_from(plan, v, visitor);
        }
    }

    fn descend(&mut self, plan: &Plan, level: usize, visitor: &mut impl MatchVisitor) {
        if level == plan.levels.len() {
            visitor.visit(&self.partial);
            return;
        }
        let graph: &'g DataGraph = self.graph;
        let l = &plan.levels[level];

        // all per-level set operations run in the shared kernel; buffers are
        // taken out so the kernel borrows nothing from `self`
        let mut buf = std::mem::take(&mut self.bufs[level]);
        let mut scratch = std::mem::take(&mut self.scratch);
        let cands = kernel::candidates(graph, l, &self.partial[..level], &mut buf, &mut scratch);
        self.scratch = scratch;
        match cands {
            kernel::Cands::Adj(adj) => {
                self.bufs[level] = buf;
                for &v in adj {
                    if !kernel::accept(graph, l, &self.partial[..level], v) {
                        continue;
                    }
                    self.partial[level] = v;
                    self.descend(plan, level + 1, visitor);
                }
            }
            kernel::Cands::Buffered => {
                // `buf` is a local: deeper levels use their own buffers
                for &v in &buf {
                    if !kernel::accept(graph, l, &self.partial[..level], v) {
                        continue;
                    }
                    self.partial[level] = v;
                    self.descend(plan, level + 1, visitor);
                }
                self.bufs[level] = buf;
            }
        }
    }
}

/// Count canonical (symmetry-broken) matches of `plan`, sequentially.
pub fn count_matches(graph: &DataGraph, plan: &Plan) -> u64 {
    let mut ex = Executor::new(graph, plan.levels.len());
    let mut v = CountVisitor::default();
    ex.run(plan, &mut v);
    v.count
}

/// Enumerate matches in *pattern-vertex order* (not matching order):
/// `out[k]` maps pattern vertex `k` to a data vertex, reported in
/// **original** vertex IDs (the inverse of any degree-ordered relabeling
/// applied at graph build time). Use only on small graphs/tests —
/// materializes everything.
pub fn enumerate_matches(graph: &DataGraph, plan: &Plan) -> Vec<Vec<VertexId>> {
    let mut out = Vec::new();
    let order = plan.order.clone();
    let n = order.len();
    let mut ex = Executor::new(graph, n);
    let mut vis = |m: &[VertexId]| {
        let mut by_pattern = vec![0 as VertexId; n];
        for (pos, &pv) in order.iter().enumerate() {
            by_pattern[pv] = graph.original_id(m[pos]);
        }
        out.push(by_pattern);
    };
    ex.run(plan, &mut vis);
    out
}

/// Reference oracle: brute-force enumeration of subgraph isomorphisms from
/// `pattern` into `graph` by trying all injective vertex maps. Exponential;
/// for tests on tiny graphs only. Returns **canonical** match count (unique
/// subgraph images), i.e. maps / |Aut|.
pub fn brute_force_count(graph: &DataGraph, pattern: &crate::pattern::Pattern) -> u64 {
    let n = pattern.num_vertices();
    let g = graph.num_vertices();
    let mut maps = 0u64;
    let mut m = vec![0 as VertexId; n];
    let mut used = vec![false; g];
    fn rec(
        graph: &DataGraph,
        p: &crate::pattern::Pattern,
        u: usize,
        m: &mut Vec<VertexId>,
        used: &mut Vec<bool>,
        maps: &mut u64,
    ) {
        let n = p.num_vertices();
        if u == n {
            *maps += 1;
            return;
        }
        for v in 0..graph.num_vertices() as VertexId {
            if used[v as usize] {
                continue;
            }
            if p.is_labeled() && graph.label(v) != p.label(u) {
                continue;
            }
            let mut ok = true;
            for w in 0..u {
                if p.has_edge(u, w) && !graph.has_edge(v, m[w]) {
                    ok = false;
                    break;
                }
                if p.has_anti_edge(u, w) && graph.has_edge(v, m[w]) {
                    ok = false;
                    break;
                }
            }
            if ok {
                m[u] = v;
                used[v as usize] = true;
                rec(graph, p, u + 1, m, used, maps);
                used[v as usize] = false;
            }
        }
    }
    rec(graph, pattern, 0, &mut m, &mut used, &mut maps);
    let aut = crate::pattern::iso::automorphisms(pattern).len() as u64;
    debug_assert_eq!(maps % aut, 0, "map count must be divisible by |Aut|");
    maps / aut
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::graph::GraphBuilder;
    use crate::pattern::{catalog, Pattern};
    use crate::plan::Plan;
    use crate::util::proptest;

    fn k4_graph() -> DataGraph {
        GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)])
            .build("k4")
    }

    #[test]
    fn triangle_in_k4() {
        let g = k4_graph();
        let plan = Plan::compile(&catalog::triangle());
        assert_eq!(count_matches(&g, &plan), 4); // C(4,3)
    }

    #[test]
    fn cycle4_in_k4_edge_vs_vertex_induced() {
        let g = k4_graph();
        // edge-induced C4: 3 unique per K4 (paper Fig. 3b)
        assert_eq!(count_matches(&g, &Plan::compile(&catalog::cycle(4))), 3);
        // vertex-induced C4: none (chords exist)
        assert_eq!(
            count_matches(&g, &Plan::compile(&catalog::cycle(4).vertex_induced())),
            0
        );
    }

    #[test]
    fn paper_figure3_example() {
        // Figure 3a data graph: a-b-c-d 4-cycle (a=0,b=1,c=2,d=3),
        // plus d-c-g-f chordal structure and a-d-f-e 4-clique.
        // Edges from the figure: a-b, b-c, c-d, d-a, c-g, g-f, f-d, c-f,
        // a-e, e-f, a-f, d-e... construct exactly the described matches:
        // match a-b-c-d for C4^V, d-c-g-f for chordal-4-cycle^V (one chord
        // c-f... wait chord is d... keep simple: use stated structure)
        let (a, b, c, d, e, f, g_) = (0u32, 1u32, 2u32, 3u32, 4u32, 5u32, 6u32);
        let graph = GraphBuilder::new()
            .edges(&[
                (a, b),
                (b, c),
                (c, d),
                (d, a),
                (c, g_),
                (g_, f),
                (f, d),
                (c, f),
                (a, e),
                (e, f),
                (a, f),
                (d, e),
            ])
            .build("fig3a");
        // a-d-f-e must be a 4-clique: edges ad, af, ae, df, de, ef ✓
        assert!(graph.has_edge(a, d) && graph.has_edge(d, f) && graph.has_edge(e, f));
        // vertex-induced C4 count ≥ 1 (a-b-c-d)
        let c4v = count_matches(&graph, &Plan::compile(&catalog::cycle(4).vertex_induced()));
        assert!(c4v >= 1);
        // 4-clique count = 1 (a-d-f-e)
        let k4 = count_matches(&graph, &Plan::compile(&catalog::clique(4)));
        assert_eq!(k4, 1);
        // morphing identity: EI C4 = VI C4 + VI diamond + 3×K4
        let c4e = count_matches(&graph, &Plan::compile(&catalog::cycle(4)));
        let diav = count_matches(&graph, &Plan::compile(&catalog::diamond().vertex_induced()));
        assert_eq!(c4e, c4v + diav + 3 * k4);
    }

    #[test]
    fn executor_matches_brute_force_on_random_graphs() {
        proptest::check(0xE8EC, 25, |rng| {
            let n = 8 + rng.below_usize(10);
            let m = n + rng.below_usize(2 * n);
            let graph = erdos_renyi(n, m, rng.next_u64());
            for pat in [
                catalog::triangle(),
                catalog::cycle(4),
                catalog::cycle(4).vertex_induced(),
                catalog::tailed_triangle(),
                catalog::tailed_triangle().vertex_induced(),
                catalog::diamond(),
                catalog::star(4).vertex_induced(),
            ] {
                let plan = Plan::compile(&pat);
                assert_eq!(
                    count_matches(&graph, &plan),
                    brute_force_count(&graph, &pat),
                    "pattern {pat:?} on graph {}v/{}e",
                    graph.num_vertices(),
                    graph.num_edges()
                );
            }
        });
    }

    #[test]
    fn labeled_matching() {
        // path a(0)-b(1)-a(0): count in a labeled triangle graph
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (2, 0)])
            .labels(vec![0, 1, 0])
            .build("lt");
        let p = catalog::path(3).with_labels(&[0, 1, 0]);
        let plan = Plan::compile(&p);
        assert_eq!(count_matches(&g, &plan), 1);
        assert_eq!(brute_force_count(&g, &p), 1);
    }

    #[test]
    fn enumerate_positions_are_pattern_indexed() {
        let g = GraphBuilder::new().edges(&[(5, 6), (6, 7)]).num_vertices(8).build("p");
        // pattern path3: vertex 1 is the center
        let p = catalog::path(3);
        let ms = enumerate_matches(&g, &Plan::compile(&p));
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0][1], 6, "pattern center must map to data center");
    }

    #[test]
    fn no_symmetry_counts_maps() {
        let g = k4_graph();
        let p = catalog::triangle();
        let with = count_matches(&g, &Plan::compile(&p));
        let without = count_matches(&g, &Plan::compile_opts(&p, false));
        assert_eq!(without, with * 6, "|Aut(K3)| = 6");
    }

    #[test]
    fn anti_edge_only_neighbors_excluded() {
        // star center 0 with leaves 1,2,3 — count VI star4: leaves must be
        // pairwise non-adjacent
        let star = GraphBuilder::new().edges(&[(0, 1), (0, 2), (0, 3)]).build("s");
        let p = catalog::star(4).vertex_induced();
        assert_eq!(count_matches(&star, &Plan::compile(&p)), 1);
        // close one pair: no more VI star
        let closed = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (0, 3), (1, 2)])
            .build("s2");
        assert_eq!(count_matches(&closed, &Plan::compile(&p)), 0);
    }

    #[test]
    fn five_cycle_count() {
        // C5 graph contains exactly one 5-cycle
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)])
            .build("c5");
        assert_eq!(count_matches(&g, &Plan::compile(&catalog::cycle(5))), 1);
    }

    #[test]
    fn single_vertex_pattern() {
        let g = k4_graph();
        let p = Pattern::empty(1);
        let plan = Plan::compile(&p);
        assert_eq!(count_matches(&g, &plan), 4);
    }
}
