//! Shared per-level exploration kernel — the one place that turns a plan
//! [`Level`] into candidate data vertices.
//!
//! Both executors ([`super::Executor`] per-pattern and
//! [`super::fused::FusedExecutor`] trie-fused) route every per-level set
//! operation through [`candidates`], so kernel improvements land in both
//! paths at once. The executors keep only their own recursion/emit logic.
//!
//! # Tier-dispatch contract
//!
//! For a level `l` with the partial match `partial` (indexed by
//! matching-order position, `partial[..depth]` assigned):
//!
//! 1. **Window first.** Symmetry-breaking bounds are folded into one open
//!    interval `(lo, hi)`; with degree-ordered relabeling these windows
//!    align with adjacency-list *prefixes*, so each operand list is cut to
//!    the window with `partition_point` **before** any merge work.
//! 2. **Fast path.** A single edge constraint with no anti-edges iterates
//!    the windowed adjacency slice directly — zero copies
//!    ([`Cands::Adj`]).
//! 3. **General path.** An intersection whose operands are **all** hubs
//!    (2-way or wider) collapses to one **word-wise AND** sweep over their
//!    bitmap rows, clamped to the window — and subtract operands that are
//!    hubs fold into the same sweep as **ANDNOT** words
//!    ([`bitmap::fold_rows_into`]), so hub-heavy vertex-induced levels
//!    never touch a sorted list at all. Otherwise the candidate buffer
//!    seeds from the windowed smallest-degree operand, and every further
//!    operand applies in one of two tiers: a **hub bitmap row** (O(1)
//!    membership per candidate, [`crate::graph::bitmap`]) when the operand
//!    vertex carries one, or the **sorted-list kernels** of
//!    [`super::intersect`], which themselves dispatch gallop / SIMD /
//!    scalar. Intersections run before differences, mirroring the
//!    candidate-shrinking order the cost model assumes.
//!
//! The contract guaranteed to both executors: the produced candidate set is
//! exactly `⋂ N(partial[j]) \ ⋃ N(partial[k])` restricted to the window,
//! sorted ascending — independent of which tiers served the operands.
//! Label and injectivity filtering stay with the caller ([`accept`]), as
//! they depend on per-executor emit semantics.
//!
//! Because every per-level set operation funnels through here, the two
//! executors can be checked against each other end to end — the fused trie
//! walk and one sweep per pattern must count identically, whatever tiers
//! served the operands on this machine:
//!
//! ```
//! use morphmine::exec::{count_matches, fused::fused_count_matches};
//! use morphmine::graph::generators::erdos_renyi;
//! use morphmine::pattern::catalog;
//! use morphmine::plan::{cost::CostParams, fused::FusedPlan, Plan};
//!
//! let g = erdos_renyi(40, 120, 3);
//! let base = vec![catalog::triangle(), catalog::path(3), catalog::cycle(4)];
//! let fused = FusedPlan::build(&base, None, &CostParams::counting());
//! let fused_counts = fused_count_matches(&g, &fused, 2);
//! for (p, fc) in base.iter().zip(fused_counts) {
//!     assert_eq!(fc, count_matches(&g, &Plan::compile(p)), "{p:?}");
//! }
//! ```

use super::intersect;
use crate::graph::{bitmap, DataGraph, VertexId};
use crate::pattern::MAX_PATTERN_VERTICES;
use crate::plan::Level;

/// Candidate source produced by [`candidates`].
pub enum Cands<'g> {
    /// Fast path: iterate this graph-owned sorted slice directly.
    Adj(&'g [VertexId]),
    /// General path: candidates were materialized into the buffer passed to
    /// [`candidates`].
    Buffered,
}

/// Fold a level's symmetry-breaking constraints into one open interval
/// `(lo, hi)`: candidates must satisfy `lo < v < hi`.
#[inline]
pub fn window(l: &Level, partial: &[VertexId]) -> (Option<VertexId>, Option<VertexId>) {
    let mut lo: Option<VertexId> = None;
    for &j in &l.greater_than {
        lo = Some(lo.map_or(partial[j], |b| b.max(partial[j])));
    }
    let mut hi: Option<VertexId> = None;
    for &j in &l.less_than {
        hi = Some(hi.map_or(partial[j], |b| b.min(partial[j])));
    }
    (lo, hi)
}

/// Cut a sorted slice to the open window `(lo, hi)` with two binary
/// searches — after degree-ordered relabeling this is where most
/// symmetry-breaking pruning happens, before any merge work.
#[inline]
fn window_slice(adj: &[VertexId], lo: Option<VertexId>, hi: Option<VertexId>) -> &[VertexId] {
    let start = lo.map_or(0, |b| adj.partition_point(|&x| x <= b));
    let end = hi.map_or(adj.len(), |b| adj.partition_point(|&x| x < b));
    &adj[start..end.max(start)]
}

/// Compute the candidate set of `l` given `partial`. Returns
/// [`Cands::Adj`] (borrowed from `graph`, nothing written) on the fast
/// path, or fills `buf` (using `scratch` for intermediates) and returns
/// [`Cands::Buffered`].
pub fn candidates<'g>(
    graph: &'g DataGraph,
    l: &Level,
    partial: &[VertexId],
    buf: &mut Vec<VertexId>,
    scratch: &mut Vec<VertexId>,
) -> Cands<'g> {
    debug_assert!(!l.intersect.is_empty());
    let (lo, hi) = window(l, partial);

    // Fast path: a single edge constraint and no anti-edges — iterate the
    // (windowed, sorted) adjacency list directly, no buffer copy. This is
    // the hottest loop for path/star-shaped levels (the last level of most
    // edge-induced plans).
    if l.intersect.len() == 1 && l.subtract.is_empty() {
        crate::obs_counter!("mm_kernel_ops_total{tier=\"adj\"}").inc();
        return Cands::Adj(window_slice(
            graph.neighbors(partial[l.intersect[0]]),
            lo,
            hi,
        ));
    }

    // Word-wise tier: an intersection whose operands are all hubs (2-way
    // or wider) reduces to one AND sweep over the bitmap rows (clamped to
    // the window) — the heaviest merge case in power-law graphs. Subtract
    // operands that are hubs fold into the same sweep as ANDNOT words;
    // non-hub subtractions still run through the list kernels below.
    // (when the word-wise sweep ran, hub subtract operands were already
    // folded into it as ANDNOT words — the subtract loop below skips them)
    let mut word_wise = false;
    if l.intersect.len() >= 2 {
        if let Some(first) = graph.hub_row(partial[l.intersect[0]]) {
            let mut and_rows = [first; MAX_PATTERN_VERTICES];
            let mut n_and = 0usize;
            let all_hubs = l.intersect.iter().all(|&j| match graph.hub_row(partial[j]) {
                Some(r) => {
                    and_rows[n_and] = r;
                    n_and += 1;
                    true
                }
                None => false,
            });
            if all_hubs {
                let mut sub_rows = [first; MAX_PATTERN_VERTICES];
                let mut n_sub = 0usize;
                for &j in &l.subtract {
                    if let Some(r) = graph.hub_row(partial[j]) {
                        sub_rows[n_sub] = r;
                        n_sub += 1;
                    }
                }
                crate::obs_counter!("mm_kernel_ops_total{tier=\"hub\"}").inc();
                bitmap::fold_rows_into(&and_rows[..n_and], &sub_rows[..n_sub], lo, hi, buf);
                word_wise = true;
            }
        }
    }

    if !word_wise {
        // General path: seed from the windowed smallest adjacency list,
        // then per-operand tier dispatch (hub bitmap row vs sorted-list
        // kernels).
        let seed = l
            .intersect
            .iter()
            .copied()
            .min_by_key(|&j| graph.degree(partial[j]))
            .unwrap();
        buf.clear();
        buf.extend_from_slice(window_slice(graph.neighbors(partial[seed]), lo, hi));
        for &j in &l.intersect {
            if j == seed {
                continue;
            }
            if buf.is_empty() {
                break;
            }
            let u = partial[j];
            if let Some(row) = graph.hub_row(u) {
                crate::obs_counter!("mm_kernel_ops_total{tier=\"hub\"}").inc();
                bitmap::intersect_row_into(buf, row, scratch);
            } else {
                intersect::intersect_into(buf, window_slice(graph.neighbors(u), lo, hi), scratch);
            }
            std::mem::swap(buf, scratch);
        }
    }
    for &j in &l.subtract {
        if buf.is_empty() {
            break;
        }
        let u = partial[j];
        if let Some(row) = graph.hub_row(u) {
            if word_wise {
                continue; // already applied word-wise as ANDNOT
            }
            crate::obs_counter!("mm_kernel_ops_total{tier=\"hub\"}").inc();
            bitmap::difference_row_into(buf, row, scratch);
        } else {
            intersect::difference_into(buf, graph.neighbors(u), scratch);
        }
        std::mem::swap(buf, scratch);
    }
    Cands::Buffered
}

/// Per-candidate filter shared by both executors: label match plus
/// injectivity against the already-assigned prefix (levels are small, a
/// linear scan is cheapest).
#[inline]
pub fn accept(graph: &DataGraph, l: &Level, prefix: &[VertexId], v: VertexId) -> bool {
    if let Some(lab) = l.label {
        if graph.label(v) != lab {
            return false;
        }
    }
    !prefix.contains(&v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::pattern::catalog;
    use crate::plan::Plan;

    fn level_of(plan: &Plan, i: usize) -> &Level {
        &plan.levels[i]
    }

    #[test]
    fn fast_path_returns_windowed_slice() {
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (0, 3), (0, 4)])
            .build("star");
        let plan = Plan::compile(&catalog::path(3)); // center then two leaves
        // level 1: single intersect against the center, no subtract
        let mut buf = Vec::new();
        let mut scratch = Vec::new();
        let partial = vec![0u32, 0, 0];
        match candidates(&g, level_of(&plan, 1), &partial, &mut buf, &mut scratch) {
            Cands::Adj(s) => assert_eq!(s, &[1, 2, 3, 4]),
            Cands::Buffered => panic!("single-edge level must take the fast path"),
        }
    }

    #[test]
    fn window_trims_before_merge() {
        // wedge level 2 has a symmetry bound (leaf ids ordered); candidates
        // must already respect it when produced
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (0, 3), (0, 4)])
            .build("star");
        let plan = Plan::compile(&catalog::path(3));
        let l2 = level_of(&plan, 2);
        let has_bound = !l2.greater_than.is_empty() || !l2.less_than.is_empty();
        assert!(has_bound, "wedge endpoints must carry a symmetry bound");
        let mut buf = Vec::new();
        let mut scratch = Vec::new();
        // center at 0, first leaf at 3
        let partial = vec![0u32, 3, 0];
        let cands: Vec<u32> = match candidates(&g, l2, &partial, &mut buf, &mut scratch) {
            Cands::Adj(s) => s.to_vec(),
            Cands::Buffered => buf.clone(),
        };
        for &v in &cands {
            if !l2.greater_than.is_empty() {
                assert!(v > 3, "bound violated: {v}");
            } else {
                assert!(v < 3, "bound violated: {v}");
            }
        }
    }

    #[test]
    fn hub_and_list_paths_agree() {
        // clique level over a graph with two genuine hubs: the kernel must
        // produce identical candidates with and without the bitmap index,
        // covering both the membership tier and the word-wise hub-pair tier
        let mut edges: Vec<(u32, u32)> = (2..=100).flat_map(|v| [(0, v), (1, v)]).collect();
        edges.extend([(0, 1), (2, 3), (3, 4), (4, 5)]);
        let g = GraphBuilder::new().edges(&edges).build("hubby");
        assert!(g.hub_count() >= 2, "test graph must have two hubs");
        let stripped = g.without_hub_bitmaps();
        let plan = Plan::compile(&catalog::triangle());
        let l = &plan.levels[2]; // intersects both earlier positions
        assert!(l.intersect.len() >= 2);
        let mut buf_a = Vec::new();
        let mut buf_b = Vec::new();
        let mut scratch = Vec::new();
        for first in [0u32, 1, 3] {
            for second in [1u32, 2, 3, 4] {
                if first == second {
                    continue;
                }
                let partial = vec![first, second, 0];
                let a = match candidates(&g, l, &partial, &mut buf_a, &mut scratch) {
                    Cands::Adj(s) => s.to_vec(),
                    Cands::Buffered => buf_a.clone(),
                };
                let b = match candidates(&stripped, l, &partial, &mut buf_b, &mut scratch) {
                    Cands::Adj(s) => s.to_vec(),
                    Cands::Buffered => buf_b.clone(),
                };
                assert_eq!(a, b, "hub vs list candidates for ({first},{second})");
            }
        }
    }

    #[test]
    fn word_wise_andnot_agrees_with_list_path() {
        // three hubs with overlapping neighborhoods: 0 and 1 share
        // 10..=100, hub 2 covers 40..=120. A level intersecting the first
        // two and subtracting the third takes the word-wise AND/ANDNOT
        // sweep on the hybrid graph and the sorted-list path on the
        // stripped one — candidates must be identical, windows included.
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for v in 10..=100u32 {
            edges.push((0, v));
            edges.push((1, v));
        }
        for v in 40..=120u32 {
            edges.push((2, v));
        }
        edges.extend([(0, 1), (0, 2), (1, 2)]);
        let g = GraphBuilder::new().edges(&edges).build("three-hubs");
        assert!(g.hub_count() >= 3, "all three centers must carry rows");
        let stripped = g.without_hub_bitmaps();
        let mk = |greater_than: Vec<usize>, less_than: Vec<usize>| Level {
            intersect: vec![0, 1],
            subtract: vec![2],
            label: None,
            greater_than,
            less_than,
        };
        let mut buf_a = Vec::new();
        let mut buf_b = Vec::new();
        let mut scratch = Vec::new();
        let partial = vec![0u32, 1, 2, 0];
        for l in [mk(vec![], vec![]), mk(vec![2], vec![]), mk(vec![], vec![2])] {
            let a = match candidates(&g, &l, &partial, &mut buf_a, &mut scratch) {
                Cands::Adj(s) => s.to_vec(),
                Cands::Buffered => buf_a.clone(),
            };
            let b = match candidates(&stripped, &l, &partial, &mut buf_b, &mut scratch) {
                Cands::Adj(s) => s.to_vec(),
                Cands::Buffered => buf_b.clone(),
            };
            assert_eq!(a, b, "word-wise vs list candidates ({l:?})");
            // sanity: subtraction actually removed the upper overlap
            assert!(a.iter().all(|&v| !(40..=100).contains(&v)), "{a:?}");
        }
        // mixed case: subtract operand is NOT a hub — the fold must leave
        // it to the list kernels, with identical results
        let l = Level {
            intersect: vec![0, 1],
            subtract: vec![3],
            label: None,
            greater_than: vec![],
            less_than: vec![],
        };
        let partial = vec![0u32, 1, 0, 50]; // vertex 50 is a low-degree leaf
        let a = match candidates(&g, &l, &partial, &mut buf_a, &mut scratch) {
            Cands::Adj(s) => s.to_vec(),
            Cands::Buffered => buf_a.clone(),
        };
        let b = match candidates(&stripped, &l, &partial, &mut buf_b, &mut scratch) {
            Cands::Adj(s) => s.to_vec(),
            Cands::Buffered => buf_b.clone(),
        };
        assert_eq!(a, b, "mixed hub/list subtraction");
        assert!(!a.contains(&2), "neighbor of 50 must be subtracted: {a:?}");
    }

    #[test]
    fn accept_filters_labels_and_injectivity() {
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (1, 2)])
            .labels(vec![0, 1, 0])
            .build("lab");
        let p = catalog::path(3).with_labels(&[0, 1, 0]);
        let plan = Plan::compile(&p);
        // find the level requiring label 0
        let l = plan
            .levels
            .iter()
            .find(|l| l.label == Some(0))
            .expect("labeled level");
        assert!(accept(&g, l, &[1], 2));
        assert!(!accept(&g, l, &[1], 1), "injectivity");
        assert!(!accept(&g, l, &[0], 1), "wrong label");
    }
}
