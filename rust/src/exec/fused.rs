//! Fused trie executor: matches a whole base pattern set in **one**
//! data-graph traversal by walking the shared-prefix plan trie built by
//! [`crate::plan::fused::FusedPlan`].
//!
//! Exploration per node runs through the same shared level kernel as
//! [`super::Executor`] ([`super::kernel`]: windowed tiered set ops, the
//! single-edge fast path, label and injectivity filters) — but interior
//! levels are computed once and reused by every pattern routed through
//! them. Complete matches are delivered per pattern through
//! [`FusedVisitor`]. The parallel driver mirrors [`super::parallel`]'s
//! chunked atomic-cursor work stealing.

use super::kernel;
use super::parallel::CHUNK;
use crate::graph::{DataGraph, VertexId};
use crate::plan::fused::FusedPlan;
use std::sync::atomic::{AtomicU32, Ordering};

/// Receives every match the fused executor finds. `pattern` indexes into
/// [`FusedPlan::plans`]; `m` is indexed by that plan's *matching-order
/// position* (use `plans[pattern].order` to map back to pattern vertices).
pub trait FusedVisitor {
    fn visit(&mut self, pattern: usize, m: &[VertexId]);
}

impl<F: FnMut(usize, &[VertexId])> FusedVisitor for F {
    fn visit(&mut self, pattern: usize, m: &[VertexId]) {
        self(pattern, m)
    }
}

/// Sequential fused executor state (one per thread).
pub struct FusedExecutor<'g> {
    graph: &'g DataGraph,
    /// candidate buffers, one per depth
    bufs: Vec<Vec<VertexId>>,
    /// scratch for intermediate set ops
    scratch: Vec<VertexId>,
    /// current partial match (by depth)
    partial: Vec<VertexId>,
    /// trie nodes expanded, accumulated locally (the executor is
    /// per-thread) and flushed to `mm_fused_node_visits_total` on drop so
    /// the hot walk never touches a shared cache line
    node_visits: u64,
}

impl Drop for FusedExecutor<'_> {
    fn drop(&mut self) {
        if self.node_visits > 0 {
            crate::obs_counter!("mm_fused_node_visits_total").add(self.node_visits);
        }
    }
}

impl<'g> FusedExecutor<'g> {
    pub fn new(graph: &'g DataGraph, fused: &FusedPlan) -> Self {
        let depth = fused.max_depth().max(1);
        FusedExecutor {
            graph,
            bufs: (0..depth).map(|_| Vec::new()).collect(),
            scratch: Vec::new(),
            partial: vec![0; depth],
            node_visits: 0,
        }
    }

    /// Explore the whole graph sequentially.
    pub fn run(&mut self, fused: &FusedPlan, visitor: &mut impl FusedVisitor) {
        for v in 0..self.graph.num_vertices() as VertexId {
            self.run_from(fused, v, visitor);
        }
    }

    /// Explore all matches of every fused pattern rooted at `v0`.
    pub fn run_from(&mut self, fused: &FusedPlan, v0: VertexId, visitor: &mut impl FusedVisitor) {
        for &r in &fused.roots {
            let node = &fused.nodes[r];
            if let Some(lab) = node.level.label {
                if self.graph.label(v0) != lab {
                    continue;
                }
            }
            self.partial[0] = v0;
            for &p in &node.emit {
                // single-vertex patterns complete at the root
                visitor.visit(p, &self.partial[..1]);
            }
            if self.graph.degree(v0) == 0 {
                continue; // every child level intersects an adjacency list
            }
            for &c in &node.children {
                self.descend(fused, c, 1, visitor);
            }
        }
    }

    fn descend(
        &mut self,
        fused: &FusedPlan,
        node_idx: usize,
        depth: usize,
        visitor: &mut impl FusedVisitor,
    ) {
        let graph: &'g DataGraph = self.graph;
        let l = &fused.nodes[node_idx].level;
        self.node_visits += 1;

        // per-level set ops in the shared kernel — computed once here and
        // reused by every pattern routed through this trie node
        let mut buf = std::mem::take(&mut self.bufs[depth]);
        let mut scratch = std::mem::take(&mut self.scratch);
        let cands = kernel::candidates(graph, l, &self.partial[..depth], &mut buf, &mut scratch);
        self.scratch = scratch;
        match cands {
            kernel::Cands::Adj(adj) => {
                self.bufs[depth] = buf;
                for &v in adj {
                    if !kernel::accept(graph, l, &self.partial[..depth], v) {
                        continue;
                    }
                    self.partial[depth] = v;
                    self.emit_and_recurse(fused, node_idx, depth, visitor);
                }
            }
            kernel::Cands::Buffered => {
                // `buf` is a local: deeper levels use their own buffers
                for &v in &buf {
                    if !kernel::accept(graph, l, &self.partial[..depth], v) {
                        continue;
                    }
                    self.partial[depth] = v;
                    self.emit_and_recurse(fused, node_idx, depth, visitor);
                }
                self.bufs[depth] = buf;
            }
        }
    }

    /// After assigning `partial[depth]`: report patterns completed at this
    /// node, then explore its children one level deeper.
    fn emit_and_recurse(
        &mut self,
        fused: &FusedPlan,
        node_idx: usize,
        depth: usize,
        visitor: &mut impl FusedVisitor,
    ) {
        let node = &fused.nodes[node_idx];
        for &p in &node.emit {
            visitor.visit(p, &self.partial[..=depth]);
        }
        for &c in &node.children {
            self.descend(fused, c, depth + 1, visitor);
        }
    }
}

/// Run a per-thread fused visitor in parallel and reduce the results —
/// the fused counterpart of [`super::parallel::par_run`], with the same
/// chunked atomic-cursor work stealing over first-level vertices.
pub fn par_fused_run<A, R>(
    graph: &DataGraph,
    fused: &FusedPlan,
    threads: usize,
    make: impl Fn() -> A + Sync,
    visit: impl Fn(&mut A, usize, &[VertexId]) + Sync,
    reduce: R,
) -> A
where
    A: Send,
    R: Fn(A, A) -> A,
{
    par_fused_run_range(graph, fused, threads, 0, graph.num_vertices() as u32, make, visit, reduce)
}

/// [`par_fused_run`] restricted to first-level vertices in `[lo, hi)`. Every
/// fused pattern is still matched in full *within* the slice — each match is
/// rooted at exactly one first-level vertex, so per-pattern results over a
/// disjoint cover of `0..|V|` sum to the full-graph results (the
/// [`crate::shard`] partitioning invariant; symmetry-breaking windows are
/// untouched because they constrain deeper levels relative to the root).
#[allow(clippy::too_many_arguments)]
pub fn par_fused_run_range<A, R>(
    graph: &DataGraph,
    fused: &FusedPlan,
    threads: usize,
    lo: u32,
    hi: u32,
    make: impl Fn() -> A + Sync,
    visit: impl Fn(&mut A, usize, &[VertexId]) + Sync,
    reduce: R,
) -> A
where
    A: Send,
    R: Fn(A, A) -> A,
{
    let n = hi.min(graph.num_vertices() as u32);
    let cursor = AtomicU32::new(lo);
    let threads = threads.max(1);
    let results = std::sync::Mutex::new(Vec::with_capacity(threads));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut acc = make();
                let mut ex = FusedExecutor::new(graph, fused);
                let mut vis = |i: usize, m: &[VertexId]| visit(&mut acc, i, m);
                loop {
                    let start = cursor.fetch_add(CHUNK, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = n.min(start.saturating_add(CHUNK));
                    for v in start..end {
                        ex.run_from(fused, v, &mut vis);
                    }
                }
                results.lock().unwrap().push(acc);
            });
        }
    });
    let accs = results.into_inner().unwrap();
    let mut it = accs.into_iter();
    let first = it.next().expect("at least one worker");
    it.fold(first, reduce)
}

/// Canonical (symmetry-broken) match counts of every fused pattern, in
/// [`FusedPlan::plans`] order — the set-at-once counterpart of running
/// [`super::count_matches`] per pattern, in a single traversal.
pub fn fused_count_matches(graph: &DataGraph, fused: &FusedPlan, threads: usize) -> Vec<u64> {
    par_fused_run(
        graph,
        fused,
        threads,
        || vec![0u64; fused.num_patterns()],
        |acc, i, _m| acc[i] += 1,
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
            a
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::count_matches;
    use crate::graph::generators::erdos_renyi;
    use crate::graph::GraphBuilder;
    use crate::morph::{self, Policy};
    use crate::pattern::{catalog, gen, Pattern};
    use crate::plan::cost::CostParams;
    use crate::plan::Plan;
    use crate::util::proptest;

    fn naive_base(size: usize) -> Vec<Pattern> {
        morph::plan_queries(
            &catalog::motifs_vertex_induced(size),
            Policy::Naive,
            None,
            &CostParams::counting(),
        )
        .base
    }

    fn check_against_per_pattern(g: &crate::graph::DataGraph, base: &[Pattern], threads: usize) {
        let fused = FusedPlan::build(base, None, &CostParams::counting());
        let counts = fused_count_matches(g, &fused, threads);
        for (i, p) in base.iter().enumerate() {
            assert_eq!(
                counts[i],
                count_matches(g, &Plan::compile(p)),
                "{p:?} on {}v/{}e ({})",
                g.num_vertices(),
                g.num_edges(),
                fused.describe()
            );
        }
    }

    #[test]
    fn fused_counts_equal_per_pattern_on_random_graphs() {
        // satellite property test: full 3- and 4-motif base sets (naive-PMR
        // edge-induced bases AND the direct vertex-induced sets, which
        // exercise the subtract ops) against per-pattern `count_matches`
        proptest::check(0xF05D, 20, |rng| {
            let n = 10 + rng.below_usize(14);
            let m = n + rng.below_usize(3 * n);
            let g = erdos_renyi(n, m, rng.next_u64());
            for base in [
                naive_base(3),
                naive_base(4),
                catalog::motifs_vertex_induced(3),
                catalog::motifs_vertex_induced(4),
            ] {
                check_against_per_pattern(&g, &base, 1);
            }
        });
    }

    #[test]
    fn fused_parallel_equals_sequential() {
        let g = erdos_renyi(600, 3000, 17);
        let base = gen::connected_patterns(4);
        let fused = FusedPlan::build(&base, None, &CostParams::counting());
        let mut seq = vec![0u64; base.len()];
        {
            let mut ex = FusedExecutor::new(&g, &fused);
            let mut vis = |i: usize, _m: &[VertexId]| seq[i] += 1;
            ex.run(&fused, &mut vis);
        }
        for threads in [1, 2, 4] {
            assert_eq!(fused_count_matches(&g, &fused, threads), seq, "x{threads}");
        }
    }

    #[test]
    fn fused_range_partitions_sum_to_full_counts() {
        // the shard invariant on the fused path: per-pattern counts over a
        // disjoint first-level cover sum to the full-graph counts
        let g = erdos_renyi(500, 2500, 18);
        let n = g.num_vertices() as u32;
        let base = gen::connected_patterns(4);
        let fused = FusedPlan::build(&base, None, &CostParams::counting());
        let full = fused_count_matches(&g, &fused, 2);
        for k in [2u32, 3, 5] {
            let mut sum = vec![0u64; base.len()];
            for i in 0..k {
                let lo = (n as u64 * i as u64 / k as u64) as u32;
                let hi = (n as u64 * (i + 1) as u64 / k as u64) as u32;
                let part = par_fused_run_range(
                    &g,
                    &fused,
                    2,
                    lo,
                    hi,
                    || vec![0u64; fused.num_patterns()],
                    |acc, i, _m| acc[i] += 1,
                    |mut a, b| {
                        for (x, y) in a.iter_mut().zip(b) {
                            *x += y;
                        }
                        a
                    },
                );
                for (s, p) in sum.iter_mut().zip(part) {
                    *s += p;
                }
            }
            assert_eq!(sum, full, "{k} ranges");
        }
    }

    #[test]
    fn fused_labeled_matching() {
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (1, 2), (2, 0), (2, 3), (3, 4)])
            .labels(vec![0, 1, 0, 1, 0])
            .build("lab");
        let base = vec![
            Pattern::from_edges(2, &[(0, 1)]).with_labels(&[0, 1]),
            catalog::path(3).with_labels(&[0, 1, 0]),
            catalog::triangle().with_labels(&[0, 1, 0]),
        ];
        check_against_per_pattern(&g, &base, 2);
    }

    #[test]
    fn fused_single_vertex_and_mixed_sizes() {
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)])
            .num_vertices(6) // two isolated vertices
            .build("k4+2");
        let base = vec![
            Pattern::empty(1),
            catalog::path(3),
            catalog::triangle(),
            catalog::clique(4),
        ];
        check_against_per_pattern(&g, &base, 1);
    }

    #[test]
    fn fused_match_positions_follow_plan_order() {
        // wedge on a path graph: the center position must map to the data
        // center, exactly as the per-pattern executor reports it
        let g = GraphBuilder::new().edges(&[(5, 6), (6, 7)]).num_vertices(8).build("p");
        let base = vec![catalog::path(3)];
        let fused = FusedPlan::build(&base, None, &CostParams::counting());
        let mut centers = Vec::new();
        let mut ex = FusedExecutor::new(&g, &fused);
        let order = fused.plans[0].order.clone();
        let mut vis = |i: usize, m: &[VertexId]| {
            assert_eq!(i, 0);
            // position of pattern vertex 1 (the wedge center)
            let pos = order.iter().position(|&pv| pv == 1).unwrap();
            centers.push(m[pos]);
        };
        ex.run(&fused, &mut vis);
        assert_eq!(centers, vec![6]);
    }
}
