//! Parallel matching driver: partitions the first exploration level across
//! worker threads (`std::thread::scope`), with dynamic chunked work stealing
//! via a shared atomic cursor — hub vertices make static partitions badly
//! imbalanced in power-law graphs.
//!
//! Every driver also comes in a `_range` form that restricts the **first
//! exploration level** to a contiguous vertex interval `[lo, hi)`. Because
//! each match is rooted at exactly one first-level vertex, partitioning the
//! first level partitions the match set: summing per-range results over a
//! disjoint cover of `0..|V|` reproduces the full-graph result exactly.
//! That property is what the distributed driver ([`crate::shard`]) builds
//! on — a shard is nothing but a `_range` call on another process.

use super::Executor;
use crate::graph::{DataGraph, VertexId};
use crate::plan::Plan;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Number of first-level vertices claimed per cursor fetch (shared with the
/// fused driver in [`super::fused`]).
pub(crate) const CHUNK: u32 = 64;

/// Default worker count: all available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Count canonical matches in parallel.
pub fn par_count_matches(graph: &DataGraph, plan: &Plan, threads: usize) -> u64 {
    par_count_matches_range(graph, plan, threads, 0, graph.num_vertices() as u32)
}

/// [`par_count_matches`] restricted to first-level vertices in `[lo, hi)`.
pub fn par_count_matches_range(
    graph: &DataGraph,
    plan: &Plan,
    threads: usize,
    lo: u32,
    hi: u32,
) -> u64 {
    let hi = hi.min(graph.num_vertices() as u32);
    let cursor = AtomicU32::new(lo);
    let total = AtomicU64::new(0);
    let threads = threads.max(1);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut ex = Executor::new(graph, plan.levels.len());
                let mut local = super::CountVisitor::default();
                loop {
                    let start = cursor.fetch_add(CHUNK, Ordering::Relaxed);
                    if start >= hi {
                        break;
                    }
                    let end = hi.min(start.saturating_add(CHUNK));
                    for v in start..end {
                        ex.run_from(plan, v, &mut local);
                    }
                }
                total.fetch_add(local.count, Ordering::Relaxed);
            });
        }
    });
    total.load(Ordering::Relaxed)
}

/// Run an arbitrary per-thread visitor in parallel and reduce the results.
///
/// `make` constructs each worker's private accumulator; `reduce` folds them.
/// Matches are delivered in *matching-order position* indexing, like
/// [`Executor::run`]; `plan.order` maps positions to pattern vertices.
pub fn par_run<A, R>(
    graph: &DataGraph,
    plan: &Plan,
    threads: usize,
    make: impl Fn() -> A + Sync,
    visit: impl Fn(&mut A, &[VertexId]) + Sync,
    reduce: R,
) -> A
where
    A: Send,
    R: Fn(A, A) -> A,
{
    par_run_range(graph, plan, threads, 0, graph.num_vertices() as u32, make, visit, reduce)
}

/// [`par_run`] restricted to first-level vertices in `[lo, hi)` (an empty
/// interval yields `make()` untouched — the aggregation identity).
#[allow(clippy::too_many_arguments)]
pub fn par_run_range<A, R>(
    graph: &DataGraph,
    plan: &Plan,
    threads: usize,
    lo: u32,
    hi: u32,
    make: impl Fn() -> A + Sync,
    visit: impl Fn(&mut A, &[VertexId]) + Sync,
    reduce: R,
) -> A
where
    A: Send,
    R: Fn(A, A) -> A,
{
    let hi = hi.min(graph.num_vertices() as u32);
    let cursor = AtomicU32::new(lo);
    let threads = threads.max(1);
    let results = std::sync::Mutex::new(Vec::with_capacity(threads));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut acc = make();
                let mut ex = Executor::new(graph, plan.levels.len());
                let mut vis = |m: &[VertexId]| visit(&mut acc, m);
                loop {
                    let start = cursor.fetch_add(CHUNK, Ordering::Relaxed);
                    if start >= hi {
                        break;
                    }
                    let end = hi.min(start.saturating_add(CHUNK));
                    for v in start..end {
                        ex.run_from(plan, v, &mut vis);
                    }
                }
                results.lock().unwrap().push(acc);
            });
        }
    });
    let accs = results.into_inner().unwrap();
    let mut it = accs.into_iter();
    let first = it.next().expect("at least one worker");
    it.fold(first, reduce)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::count_matches;
    use crate::graph::generators::{barabasi_albert, erdos_renyi};
    use crate::pattern::catalog;
    use crate::plan::Plan;

    #[test]
    fn parallel_equals_sequential() {
        let g = erdos_renyi(800, 4000, 11);
        for pat in [
            catalog::triangle(),
            catalog::cycle(4),
            catalog::cycle(4).vertex_induced(),
            catalog::tailed_triangle().vertex_induced(),
        ] {
            let plan = Plan::compile(&pat);
            let seq = count_matches(&g, &plan);
            for threads in [1, 2, 4] {
                assert_eq!(par_count_matches(&g, &plan, threads), seq, "{pat:?} x{threads}");
            }
        }
    }

    #[test]
    fn parallel_on_skewed_graph() {
        let g = barabasi_albert(1500, 6, 12);
        let plan = Plan::compile(&catalog::triangle());
        assert_eq!(
            par_count_matches(&g, &plan, 4),
            count_matches(&g, &plan)
        );
    }

    #[test]
    fn range_partitions_sum_to_full_count() {
        // the shard invariant: any disjoint cover of the first level sums
        // to the full count, because each match roots at one vertex
        let g = barabasi_albert(900, 5, 14);
        let n = g.num_vertices() as u32;
        for pat in [catalog::triangle(), catalog::cycle(4).vertex_induced()] {
            let plan = Plan::compile(&pat);
            let full = par_count_matches(&g, &plan, 2);
            for k in [1u32, 2, 3, 7] {
                let mut sum = 0;
                for i in 0..k {
                    let lo = (n as u64 * i as u64 / k as u64) as u32;
                    let hi = (n as u64 * (i + 1) as u64 / k as u64) as u32;
                    sum += par_count_matches_range(&g, &plan, 2, lo, hi);
                }
                assert_eq!(sum, full, "{pat:?} over {k} ranges");
            }
            // empty and clamped ranges are identities / safe
            assert_eq!(par_count_matches_range(&g, &plan, 2, 5, 5), 0);
            assert_eq!(par_count_matches_range(&g, &plan, 2, 0, u32::MAX), full);
        }
    }

    #[test]
    fn par_run_custom_reduction() {
        let g = erdos_renyi(300, 1200, 13);
        let plan = Plan::compile(&catalog::triangle());
        // accumulate sum of matched vertex ids as a nontrivial reduction
        let sum = par_run(
            &g,
            &plan,
            4,
            || 0u64,
            |acc, m| *acc += m.iter().map(|&v| v as u64).sum::<u64>(),
            |a, b| a + b,
        );
        let mut seq_sum = 0u64;
        let mut ex = crate::exec::Executor::new(&g, plan.levels.len());
        let mut vis = |m: &[u32]| seq_sum += m.iter().map(|&v| v as u64).sum::<u64>();
        ex.run(&plan, &mut vis);
        assert_eq!(sum, seq_sum);
    }
}
