//! Sorted-set kernels: the inner loop of the matching engine.
//!
//! Adjacency lists are sorted `u32` slices. Intersections use galloping when
//! sizes are skewed (hub lists vs. leaf lists differ by orders of magnitude
//! in the power-law graphs the paper mines).

use crate::graph::VertexId;

/// Threshold size ratio above which galloping beats linear merge.
const GALLOP_RATIO: usize = 16;

/// `out = a ∩ b` (clears `out`).
pub fn intersect_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    out.clear();
    let (small, large) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    if small.is_empty() {
        return;
    }
    if large.len() / small.len().max(1) >= GALLOP_RATIO {
        // galloping: binary-search each small element in the large list
        let mut lo = 0;
        for &x in small {
            match large[lo..].binary_search(&x) {
                Ok(i) => {
                    out.push(x);
                    lo += i + 1;
                }
                Err(i) => {
                    lo += i;
                    if lo >= large.len() {
                        break;
                    }
                }
            }
        }
    } else {
        // linear merge
        let (mut i, mut j) = (0, 0);
        while i < small.len() && j < large.len() {
            match small[i].cmp(&large[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    out.push(small[i]);
                    i += 1;
                    j += 1;
                }
            }
        }
    }
}

/// `out = a \ b` (clears `out`).
pub fn difference_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    out.clear();
    if b.is_empty() {
        out.extend_from_slice(a);
        return;
    }
    if b.len() / a.len().max(1) >= GALLOP_RATIO {
        // few candidates vs large subtracted list: binary search each
        for &x in a {
            if b.binary_search(&x).is_err() {
                out.push(x);
            }
        }
    } else {
        let (mut i, mut j) = (0, 0);
        while i < a.len() {
            if j >= b.len() {
                // b exhausted: the rest of a survives — bulk-copy the tail
                out.extend_from_slice(&a[i..]);
                return;
            }
            if a[i] < b[j] {
                out.push(a[i]);
                i += 1;
            } else if a[i] > b[j] {
                j += 1;
            } else {
                i += 1;
                j += 1;
            }
        }
    }
}

/// Retain elements of `v` strictly greater than `bound` (lists are sorted:
/// binary search + drain the prefix). Used for symmetry-breaking filters.
pub fn retain_greater(v: &mut Vec<VertexId>, bound: VertexId) {
    let cut = v.partition_point(|&x| x <= bound);
    v.drain(..cut);
}

/// Retain elements strictly less than `bound`.
pub fn retain_less(v: &mut Vec<VertexId>, bound: VertexId) {
    let cut = v.partition_point(|&x| x < bound);
    v.truncate(cut);
}

/// Remove one element by value if present (injectivity filter).
pub fn remove_value(v: &mut Vec<VertexId>, x: VertexId) {
    if let Ok(i) = v.binary_search(&x) {
        v.remove(i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    fn naive_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
        a.iter().filter(|x| b.contains(x)).copied().collect()
    }

    fn naive_difference(a: &[u32], b: &[u32]) -> Vec<u32> {
        a.iter().filter(|x| !b.contains(x)).copied().collect()
    }

    #[test]
    fn intersect_basics() {
        let mut out = Vec::new();
        intersect_into(&[1, 3, 5, 7], &[2, 3, 4, 7, 9], &mut out);
        assert_eq!(out, vec![3, 7]);
        intersect_into(&[], &[1, 2], &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn intersect_galloping_path() {
        let large: Vec<u32> = (0..10_000).map(|i| i * 3).collect();
        let small = vec![3, 2999 * 3, 5000, 9999 * 3];
        let mut out = Vec::new();
        intersect_into(&small, &large, &mut out);
        assert_eq!(out, naive_intersect(&small, &large));
    }

    #[test]
    fn difference_basics() {
        let mut out = Vec::new();
        difference_into(&[1, 2, 3, 4], &[2, 4, 6], &mut out);
        assert_eq!(out, vec![1, 3]);
        difference_into(&[1, 2], &[], &mut out);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn difference_tail_bulk_copied() {
        // b exhausts midway through a: the tail of a must survive intact
        let mut out = Vec::new();
        difference_into(&[1, 2, 3, 10, 11, 12], &[2, 3], &mut out);
        assert_eq!(out, vec![1, 10, 11, 12]);
    }

    #[test]
    fn retain_filters() {
        let mut v = vec![1, 4, 6, 9, 12];
        retain_greater(&mut v, 6);
        assert_eq!(v, vec![9, 12]);
        let mut v = vec![1, 4, 6, 9, 12];
        retain_less(&mut v, 6);
        assert_eq!(v, vec![1, 4]);
    }

    #[test]
    fn remove_value_works() {
        let mut v = vec![1, 4, 6];
        remove_value(&mut v, 4);
        assert_eq!(v, vec![1, 6]);
        remove_value(&mut v, 5);
        assert_eq!(v, vec![1, 6]);
    }

    #[test]
    fn prop_against_naive() {
        proptest::check(0x1A7, 200, |rng| {
            let mut a: Vec<u32> = (0..rng.below(60)).map(|_| rng.below(100) as u32).collect();
            let mut b: Vec<u32> = (0..rng.below(1500)).map(|_| rng.below(2000) as u32).collect();
            a.sort_unstable();
            a.dedup();
            b.sort_unstable();
            b.dedup();
            let mut out = Vec::new();
            intersect_into(&a, &b, &mut out);
            assert_eq!(out, naive_intersect(&a, &b));
            intersect_into(&b, &a, &mut out);
            assert_eq!(out, naive_intersect(&a, &b), "commutativity");
            difference_into(&a, &b, &mut out);
            assert_eq!(out, naive_difference(&a, &b));
        });
    }
}
