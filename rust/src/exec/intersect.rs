//! Sorted-set kernels: the inner loop of the matching engine.
//!
//! Adjacency lists are sorted, strictly increasing `u32` slices. Every
//! public entry point dispatches across three tiers:
//!
//! 1. **galloping** — when operand sizes are skewed (hub lists vs. leaf
//!    lists differ by orders of magnitude in the power-law graphs the paper
//!    mines), binary-search the small list into the large one;
//! 2. **SIMD** — in the merge regime, wide-compare + compress blocks
//!    (AVX2 8×8, else SSSE3 4×4 on `x86_64`; NEON 4×4 on `aarch64`),
//!    selected by runtime feature detection; the scalar path is always
//!    compiled and the property tests assert tier-for-tier equality;
//! 3. **scalar** — branch-reduced two-pointer merge, the portable baseline
//!    and the only tier on targets without a vector unit.
//!
//! Hub *bitmap* operands are a fourth tier living one level up: the shared
//! exploration kernel ([`super::kernel`]) routes set ops whose operand is a
//! hub adjacency list through the O(1)-membership rows of
//! [`crate::graph::bitmap`] instead of these list kernels.
//!
//! Dispatch control: `MORPHMINE_NO_SIMD=1` (read once) disables tier 2 for
//! the whole process — CI runs the test suite both ways; [`force_tier`]
//! narrows dispatch at runtime for benchmarks ([`Tier::Scalar`] pins the
//! portable merge, [`Tier::Simd`] re-enables auto detection).
//!
//! Every dispatch decision is counted into the observability registry
//! (`mm_kernel_ops_total{tier="scalar|gallop|ssse3|avx2|neon"}`,
//! [`crate::obs`]), so a scrape shows which tiers actually served a
//! workload — the counter evidence behind the A7 kernels ablation.

use crate::graph::VertexId;
use std::sync::atomic::{AtomicU8, Ordering};

/// Threshold size ratio above which galloping beats merging.
const GALLOP_RATIO: usize = 16;

/// Minimum small-operand length for the SIMD tier to pay for itself.
const SIMD_MIN: usize = 16;

/// Kernel tier override for benchmarks and tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Pin the portable scalar merge (galloping still applies to skewed
    /// operands — it is a strategy, not an instruction set).
    Scalar,
    /// Allow the SIMD tier wherever the CPU supports it (the default).
    Simd,
}

/// `0` = auto, `1` = forced scalar, `2` = forced simd (== auto on capable
/// CPUs, scalar elsewhere).
static FORCED: AtomicU8 = AtomicU8::new(0);

/// Force the dispatch tier process-wide (`None` restores auto detection).
/// Every tier computes identical results; this only steers performance.
pub fn force_tier(t: Option<Tier>) {
    let v = match t {
        None => 0,
        Some(Tier::Scalar) => 1,
        Some(Tier::Simd) => 2,
    };
    FORCED.store(v, Ordering::Relaxed);
}

/// SIMD capability actually available to this process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SimdLevel {
    None,
    #[cfg(target_arch = "x86_64")]
    Ssse3,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

/// Runtime-detected SIMD level, honoring `MORPHMINE_NO_SIMD` (read once).
fn detected_level() -> SimdLevel {
    static LEVEL: std::sync::OnceLock<SimdLevel> = std::sync::OnceLock::new();
    *LEVEL.get_or_init(|| {
        if std::env::var_os("MORPHMINE_NO_SIMD").is_some_and(|v| v != "0" && !v.is_empty()) {
            return SimdLevel::None;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return SimdLevel::Avx2;
            }
            if std::arch::is_x86_feature_detected!("ssse3") {
                return SimdLevel::Ssse3;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            // ASIMD is architecturally mandatory on AArch64, but keep the
            // detection honest (and overridable via MORPHMINE_NO_SIMD)
            if std::arch::is_aarch64_feature_detected!("neon") {
                return SimdLevel::Neon;
            }
        }
        SimdLevel::None
    })
}

/// The level dispatch will use right now (forced tier applied).
fn active_level() -> SimdLevel {
    if FORCED.load(Ordering::Relaxed) == 1 {
        SimdLevel::None
    } else {
        detected_level()
    }
}

/// Whether the SIMD tier is live (reported by the kernels ablation).
pub fn simd_active() -> bool {
    active_level() != SimdLevel::None
}

/// `out = a ∩ b` (clears `out`).
pub fn intersect_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    out.clear();
    let (small, large) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    if small.is_empty() {
        return;
    }
    if large.len() / small.len() >= GALLOP_RATIO {
        crate::obs_counter!("mm_kernel_ops_total{tier=\"gallop\"}").inc();
        gallop_intersect(small, large, out);
        return;
    }
    #[cfg(target_arch = "x86_64")]
    if small.len() >= SIMD_MIN {
        match active_level() {
            SimdLevel::Avx2 => {
                crate::obs_counter!("mm_kernel_ops_total{tier=\"avx2\"}").inc();
                // SAFETY: avx2 presence checked by `detected_level`
                unsafe { x86::intersect_avx2(small, large, out) };
                return;
            }
            SimdLevel::Ssse3 => {
                crate::obs_counter!("mm_kernel_ops_total{tier=\"ssse3\"}").inc();
                // SAFETY: ssse3 presence checked by `detected_level`
                unsafe { x86::intersect_ssse3(small, large, out) };
                return;
            }
            SimdLevel::None => {}
        }
    }
    #[cfg(target_arch = "aarch64")]
    if small.len() >= SIMD_MIN && active_level() == SimdLevel::Neon {
        crate::obs_counter!("mm_kernel_ops_total{tier=\"neon\"}").inc();
        // SAFETY: neon presence checked by `detected_level`
        unsafe { neon::intersect_neon(small, large, out) };
        return;
    }
    crate::obs_counter!("mm_kernel_ops_total{tier=\"scalar\"}").inc();
    merge_intersect(small, large, 0, 0, out);
}

/// `out = a \ b` (clears `out`).
pub fn difference_into(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    out.clear();
    if b.is_empty() {
        out.extend_from_slice(a);
        return;
    }
    if b.len() / a.len().max(1) >= GALLOP_RATIO {
        crate::obs_counter!("mm_kernel_ops_total{tier=\"gallop\"}").inc();
        // few candidates vs large subtracted list: binary search each
        for &x in a {
            if b.binary_search(&x).is_err() {
                out.push(x);
            }
        }
        return;
    }
    // When a dwarfs b, the scalar merge wins: it exhausts b quickly and
    // bulk-copies the surviving tail of a in one memcpy, where the SIMD
    // membership loop would still push every element of a individually.
    #[cfg(target_arch = "x86_64")]
    if b.len() >= SIMD_MIN && a.len() / b.len() < GALLOP_RATIO {
        match active_level() {
            SimdLevel::Avx2 => {
                crate::obs_counter!("mm_kernel_ops_total{tier=\"avx2\"}").inc();
                // SAFETY: avx2 presence checked by `detected_level`
                unsafe { x86::difference_avx2(a, b, out) };
                return;
            }
            SimdLevel::Ssse3 => {
                crate::obs_counter!("mm_kernel_ops_total{tier=\"ssse3\"}").inc();
                // SAFETY: ssse3 (⊇ sse2) presence checked by `detected_level`
                unsafe { x86::difference_sse2(a, b, out) };
                return;
            }
            SimdLevel::None => {}
        }
    }
    #[cfg(target_arch = "aarch64")]
    if b.len() >= SIMD_MIN
        && a.len() / b.len() < GALLOP_RATIO
        && active_level() == SimdLevel::Neon
    {
        crate::obs_counter!("mm_kernel_ops_total{tier=\"neon\"}").inc();
        // SAFETY: neon presence checked by `detected_level`
        unsafe { neon::difference_neon(a, b, out) };
        return;
    }
    crate::obs_counter!("mm_kernel_ops_total{tier=\"scalar\"}").inc();
    merge_difference(a, b, out);
}

/// Galloping intersection: binary-search each small element in the large
/// list, restarting past the previous hit.
fn gallop_intersect(small: &[VertexId], large: &[VertexId], out: &mut Vec<VertexId>) {
    let mut lo = 0;
    for &x in small {
        match large[lo..].binary_search(&x) {
            Ok(i) => {
                out.push(x);
                lo += i + 1;
            }
            Err(i) => {
                lo += i;
                if lo >= large.len() {
                    break;
                }
            }
        }
    }
}

/// Branch-reduced scalar merge intersection from positions `(i, j)` — also
/// the tail finisher for the SIMD block loops.
fn merge_intersect(
    a: &[VertexId],
    b: &[VertexId],
    mut i: usize,
    mut j: usize,
    out: &mut Vec<VertexId>,
) {
    while i < a.len() && j < b.len() {
        let (x, y) = (a[i], b[j]);
        if x == y {
            out.push(x);
        }
        // strictly sorted inputs: advance whichever side is not ahead
        i += (x <= y) as usize;
        j += (y <= x) as usize;
    }
}

/// Scalar merge difference with bulk tail copy once `b` is exhausted.
fn merge_difference(a: &[VertexId], b: &[VertexId], out: &mut Vec<VertexId>) {
    let (mut i, mut j) = (0, 0);
    while i < a.len() {
        if j >= b.len() {
            // b exhausted: the rest of a survives — bulk-copy the tail
            out.extend_from_slice(&a[i..]);
            return;
        }
        let (x, y) = (a[i], b[j]);
        if x < y {
            out.push(x);
            i += 1;
        } else if x > y {
            j += 1;
        } else {
            i += 1;
            j += 1;
        }
    }
}

/// Byte-shuffle masks compacting the matched 32-bit lanes of a 128-bit
/// vector: entry `m` moves lane `k` (for each set bit `k` of `m`, in
/// ascending order) to the front. Unused bytes are `0x80` (out of range:
/// zeroed by x86 `pshufb` and aarch64 `vqtbl1q_u8` alike, then ignored —
/// only the first `popcount(m)` lanes are copied out). Shared by the
/// SSSE3 and NEON 4×4 kernels.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
const fn compress4_table() -> [[u8; 16]; 16] {
    let mut t = [[0x80u8; 16]; 16];
    let mut m = 0;
    while m < 16 {
        let mut out_byte = 0;
        let mut lane = 0;
        while lane < 4 {
            if m & (1 << lane) != 0 {
                let mut b = 0;
                while b < 4 {
                    t[m][out_byte] = (lane * 4 + b) as u8;
                    out_byte += 1;
                    b += 1;
                }
            }
            lane += 1;
        }
        m += 1;
    }
    t
}

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
static COMPRESS4: [[u8; 16]; 16] = compress4_table();

/// x86 wide-compare + compress kernels. All functions require the inputs to
/// be strictly increasing (no duplicates) — guaranteed by the CSR
/// invariants — and produce exactly the scalar tiers' output.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// Lane-index vectors compacting the matched 32-bit lanes of a 256-bit
    /// vector via `vpermd`: entry `m` lists the set bits of `m` ascending.
    const fn avx_compress_table() -> [[u32; 8]; 256] {
        let mut t = [[0u32; 8]; 256];
        let mut m = 0;
        while m < 256 {
            let mut o = 0;
            let mut lane = 0;
            while lane < 8 {
                if m & (1 << lane) != 0 {
                    t[m][o] = lane as u32;
                    o += 1;
                }
                lane += 1;
            }
            m += 1;
        }
        t
    }

    static AVX_COMPRESS: [[u32; 8]; 256] = avx_compress_table();

    /// `vpermd` index vectors rotating the 8 lanes left by `r + 1`:
    /// `ROTATE[r][k] = (k + r + 1) % 8`.
    const fn avx_rotate_table() -> [[u32; 8]; 7] {
        let mut t = [[0u32; 8]; 7];
        let mut r = 0;
        while r < 7 {
            let mut k = 0;
            while k < 8 {
                t[r][k] = ((k + r + 1) % 8) as u32;
                k += 1;
            }
            r += 1;
        }
        t
    }

    static AVX_ROTATE: [[u32; 8]; 7] = avx_rotate_table();

    /// SSSE3 4×4 block intersection: compare each block of `a` against all
    /// four rotations of a block of `b`, compress the matched `a` lanes.
    ///
    /// # Safety
    /// Requires SSSE3 (and baseline SSE2) at runtime.
    #[target_feature(enable = "ssse3")]
    pub unsafe fn intersect_ssse3(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
        let mut i = 0usize;
        let mut j = 0usize;
        let na = a.len() / 4 * 4;
        let nb = b.len() / 4 * 4;
        while i < na && j < nb {
            let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(j) as *const __m128i);
            let a_max = *a.get_unchecked(i + 3);
            let b_max = *b.get_unchecked(j + 3);
            // all-pairs equality via the 4 rotations of vb
            let rot1 = _mm_shuffle_epi32::<0b00_11_10_01>(vb); // [1,2,3,0]
            let rot2 = _mm_shuffle_epi32::<0b01_00_11_10>(vb); // [2,3,0,1]
            let rot3 = _mm_shuffle_epi32::<0b10_01_00_11>(vb); // [3,0,1,2]
            let hit = _mm_or_si128(
                _mm_or_si128(_mm_cmpeq_epi32(va, vb), _mm_cmpeq_epi32(va, rot1)),
                _mm_or_si128(_mm_cmpeq_epi32(va, rot2), _mm_cmpeq_epi32(va, rot3)),
            );
            let mask = _mm_movemask_ps(_mm_castsi128_ps(hit)) as usize;
            if mask != 0 {
                let shuf = _mm_loadu_si128(
                    super::COMPRESS4.get_unchecked(mask).as_ptr() as *const __m128i,
                );
                let packed = _mm_shuffle_epi8(va, shuf);
                let mut tmp = [0u32; 4];
                _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, packed);
                out.extend_from_slice(&tmp[..mask.count_ones() as usize]);
            }
            // advance the block(s) whose max cannot match anything ahead
            i += ((a_max <= b_max) as usize) * 4;
            j += ((b_max <= a_max) as usize) * 4;
        }
        super::merge_intersect(a, b, i, j, out);
    }

    /// AVX2 8×8 block intersection: compare each block of `a` against all
    /// eight rotations of a block of `b`, compress the matched `a` lanes
    /// with `vpermd`.
    ///
    /// # Safety
    /// Requires AVX2 at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn intersect_avx2(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
        let mut i = 0usize;
        let mut j = 0usize;
        let na = a.len() / 8 * 8;
        let nb = b.len() / 8 * 8;
        while i < na && j < nb {
            let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(j) as *const __m256i);
            let a_max = *a.get_unchecked(i + 7);
            let b_max = *b.get_unchecked(j + 7);
            let mut hit = _mm256_cmpeq_epi32(va, vb);
            for rot in &AVX_ROTATE {
                let idx = _mm256_loadu_si256(rot.as_ptr() as *const __m256i);
                let rb = _mm256_permutevar8x32_epi32(vb, idx);
                hit = _mm256_or_si256(hit, _mm256_cmpeq_epi32(va, rb));
            }
            let mask = _mm256_movemask_ps(_mm256_castsi256_ps(hit)) as usize;
            if mask != 0 {
                let idx_ptr = AVX_COMPRESS.get_unchecked(mask).as_ptr() as *const __m256i;
                let packed = _mm256_permutevar8x32_epi32(va, _mm256_loadu_si256(idx_ptr));
                let mut tmp = [0u32; 8];
                _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, packed);
                out.extend_from_slice(&tmp[..mask.count_ones() as usize]);
            }
            i += ((a_max <= b_max) as usize) * 8;
            j += ((b_max <= a_max) as usize) * 8;
        }
        super::merge_intersect(a, b, i, j, out);
    }

    /// SSE2 blocked membership difference: skip 4-wide blocks of `b` below
    /// each candidate, then one wide compare decides membership.
    ///
    /// # Safety
    /// Requires SSE2 at runtime (implied by ssse3 detection).
    #[target_feature(enable = "sse2")]
    pub unsafe fn difference_sse2(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
        let mut j = 0usize;
        let nb = b.len() / 4 * 4;
        for &x in a {
            while j < nb && *b.get_unchecked(j + 3) < x {
                j += 4;
            }
            let found = if j < nb {
                // block max ≥ x and all earlier blocks < x: any match is here
                let vb = _mm_loadu_si128(b.as_ptr().add(j) as *const __m128i);
                let eq = _mm_cmpeq_epi32(_mm_set1_epi32(x as i32), vb);
                _mm_movemask_ps(_mm_castsi128_ps(eq)) != 0
            } else {
                b.get_unchecked(j..).binary_search(&x).is_ok()
            };
            if !found {
                out.push(x);
            }
        }
    }

    /// AVX2 blocked membership difference (8-wide blocks).
    ///
    /// # Safety
    /// Requires AVX2 at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn difference_avx2(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
        let mut j = 0usize;
        let nb = b.len() / 8 * 8;
        for &x in a {
            while j < nb && *b.get_unchecked(j + 7) < x {
                j += 8;
            }
            let found = if j < nb {
                let vb = _mm256_loadu_si256(b.as_ptr().add(j) as *const __m256i);
                let eq = _mm256_cmpeq_epi32(_mm256_set1_epi32(x as i32), vb);
                _mm256_movemask_ps(_mm256_castsi256_ps(eq)) != 0
            } else {
                b.get_unchecked(j..).binary_search(&x).is_ok()
            };
            if !found {
                out.push(x);
            }
        }
    }
}

/// AArch64 NEON wide-compare + compress kernels — the 4×4 blocked shapes
/// of the SSSE3/SSE2 tier on the other ISA. All-pairs equality uses the
/// four `vext`-rotations of a block of `b`; lane compaction goes through
/// [`COMPRESS4`] via `vqtbl1q_u8` (NEON's byte table lookup plays the role
/// of `pshufb`, zeroing out-of-range `0x80` indices the same way); the
/// 4-bit movemask NEON lacks is rebuilt by AND-ing the compare mask with
/// per-lane bit weights and summing across lanes (`vaddvq_u32`). Same
/// contracts as [`x86`]: strictly increasing inputs, output identical to
/// the scalar tiers.
#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// Per-lane weights turning a `vceqq` all-ones/zeros mask into the
    /// 4-bit movemask the compress table is indexed by.
    static LANE_BITS: [u32; 4] = [1, 2, 4, 8];

    /// NEON 4×4 block intersection: compare each block of `a` against all
    /// four rotations of a block of `b`, compress the matched `a` lanes.
    ///
    /// # Safety
    /// Requires NEON (ASIMD) at runtime.
    #[target_feature(enable = "neon")]
    pub unsafe fn intersect_neon(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
        let mut i = 0usize;
        let mut j = 0usize;
        let na = a.len() / 4 * 4;
        let nb = b.len() / 4 * 4;
        let lane_bits = vld1q_u32(LANE_BITS.as_ptr());
        while i < na && j < nb {
            let va = vld1q_u32(a.as_ptr().add(i));
            let vb = vld1q_u32(b.as_ptr().add(j));
            let a_max = *a.get_unchecked(i + 3);
            let b_max = *b.get_unchecked(j + 3);
            // all-pairs equality via the 4 rotations of vb
            let rot1 = vextq_u32::<1>(vb, vb);
            let rot2 = vextq_u32::<2>(vb, vb);
            let rot3 = vextq_u32::<3>(vb, vb);
            let hit = vorrq_u32(
                vorrq_u32(vceqq_u32(va, vb), vceqq_u32(va, rot1)),
                vorrq_u32(vceqq_u32(va, rot2), vceqq_u32(va, rot3)),
            );
            let mask = vaddvq_u32(vandq_u32(hit, lane_bits)) as usize;
            if mask != 0 {
                let shuf = vld1q_u8(super::COMPRESS4.get_unchecked(mask).as_ptr());
                let packed = vqtbl1q_u8(vreinterpretq_u8_u32(va), shuf);
                let mut tmp = [0u32; 4];
                vst1q_u8(tmp.as_mut_ptr() as *mut u8, packed);
                out.extend_from_slice(&tmp[..mask.count_ones() as usize]);
            }
            // advance the block(s) whose max cannot match anything ahead
            i += ((a_max <= b_max) as usize) * 4;
            j += ((b_max <= a_max) as usize) * 4;
        }
        super::merge_intersect(a, b, i, j, out);
    }

    /// NEON blocked membership difference: skip 4-wide blocks of `b`
    /// below each candidate, then one wide compare decides membership
    /// (`vmaxvq_u32` reads "any lane hit").
    ///
    /// # Safety
    /// Requires NEON (ASIMD) at runtime.
    #[target_feature(enable = "neon")]
    pub unsafe fn difference_neon(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
        let mut j = 0usize;
        let nb = b.len() / 4 * 4;
        for &x in a {
            while j < nb && *b.get_unchecked(j + 3) < x {
                j += 4;
            }
            let found = if j < nb {
                // block max ≥ x and all earlier blocks < x: any match is here
                let vb = vld1q_u32(b.as_ptr().add(j));
                let eq = vceqq_u32(vdupq_n_u32(x), vb);
                vmaxvq_u32(eq) != 0
            } else {
                b.get_unchecked(j..).binary_search(&x).is_ok()
            };
            if !found {
                out.push(x);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    fn naive_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
        a.iter().filter(|x| b.contains(x)).copied().collect()
    }

    fn naive_difference(a: &[u32], b: &[u32]) -> Vec<u32> {
        a.iter().filter(|x| !b.contains(x)).copied().collect()
    }

    /// Strictly-sorted random list with adversarial shapes: dense runs of
    /// consecutive values (exercise every block lane), strided gaps, and
    /// values colliding at block boundaries.
    fn adversarial_list(rng: &mut Rng, max_len: usize, universe: u64) -> Vec<u32> {
        let mut v: Vec<u32> = Vec::new();
        while v.len() < max_len {
            match rng.below(4) {
                0 => {
                    // dense run
                    let start = rng.below(universe) as u32;
                    let run = rng.below(20) as u32 + 1;
                    v.extend(start..start.saturating_add(run));
                }
                1 => {
                    // strided
                    let start = rng.below(universe) as u32;
                    let stride = rng.below(7) as u32 + 1;
                    for k in 0..rng.below(16) as u32 {
                        v.push(start.saturating_add(k * stride));
                    }
                }
                _ => v.push(rng.below(universe) as u32),
            }
        }
        v.truncate(max_len);
        v.sort_unstable();
        v.dedup();
        v
    }

    #[test]
    fn intersect_basics() {
        let mut out = Vec::new();
        intersect_into(&[1, 3, 5, 7], &[2, 3, 4, 7, 9], &mut out);
        assert_eq!(out, vec![3, 7]);
        intersect_into(&[], &[1, 2], &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn intersect_galloping_path() {
        let large: Vec<u32> = (0..10_000).map(|i| i * 3).collect();
        let small = vec![3, 2999 * 3, 5000, 9999 * 3];
        let mut out = Vec::new();
        intersect_into(&small, &large, &mut out);
        assert_eq!(out, naive_intersect(&small, &large));
    }

    #[test]
    fn difference_basics() {
        let mut out = Vec::new();
        difference_into(&[1, 2, 3, 4], &[2, 4, 6], &mut out);
        assert_eq!(out, vec![1, 3]);
        difference_into(&[1, 2], &[], &mut out);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn difference_tail_bulk_copied() {
        // b exhausts midway through a: the tail of a must survive intact
        let mut out = Vec::new();
        difference_into(&[1, 2, 3, 10, 11, 12], &[2, 3], &mut out);
        assert_eq!(out, vec![1, 10, 11, 12]);
    }

    #[test]
    fn prop_dispatch_against_naive() {
        proptest::check(0x1A7, 200, |rng| {
            let mut a: Vec<u32> = (0..rng.below(60)).map(|_| rng.below(100) as u32).collect();
            let mut b: Vec<u32> = (0..rng.below(1500)).map(|_| rng.below(2000) as u32).collect();
            a.sort_unstable();
            a.dedup();
            b.sort_unstable();
            b.dedup();
            let mut out = Vec::new();
            intersect_into(&a, &b, &mut out);
            assert_eq!(out, naive_intersect(&a, &b));
            intersect_into(&b, &a, &mut out);
            assert_eq!(out, naive_intersect(&a, &b), "commutativity");
            difference_into(&a, &b, &mut out);
            assert_eq!(out, naive_difference(&a, &b));
        });
    }

    /// Satellite property test: every kernel tier agrees with the naive set
    /// ops on adversarial skewed inputs (dense runs, strides, block-boundary
    /// collisions, heavily unequal lengths).
    #[test]
    fn prop_all_tiers_agree_on_adversarial_inputs() {
        proptest::check(0x7153, 150, |rng| {
            let la = 1 + rng.below_usize(400);
            let lb = 1 + rng.below_usize(400);
            let universe = 1 + rng.below(3000);
            let a = adversarial_list(rng, la, universe);
            let b = adversarial_list(rng, lb, universe);
            let want_i = naive_intersect(&a, &b);
            let want_d = naive_difference(&a, &b);

            // scalar tier, both argument orders
            let mut out = Vec::new();
            merge_intersect(&a, &b, 0, 0, &mut out);
            assert_eq!(out, want_i, "scalar merge");
            out.clear();
            merge_difference(&a, &b, &mut out);
            assert_eq!(out, want_d, "scalar difference");

            // galloping tier
            out.clear();
            gallop_intersect(&a, &b, &mut out);
            assert_eq!(out, want_i, "gallop");

            // SIMD tiers (when the CPU has them)
            #[cfg(target_arch = "x86_64")]
            {
                if std::arch::is_x86_feature_detected!("ssse3") {
                    out.clear();
                    unsafe { x86::intersect_ssse3(&a, &b, &mut out) };
                    assert_eq!(out, want_i, "ssse3 intersect\na={a:?}\nb={b:?}");
                    out.clear();
                    unsafe { x86::difference_sse2(&a, &b, &mut out) };
                    assert_eq!(out, want_d, "sse2 difference\na={a:?}\nb={b:?}");
                }
                if std::arch::is_x86_feature_detected!("avx2") {
                    out.clear();
                    unsafe { x86::intersect_avx2(&a, &b, &mut out) };
                    assert_eq!(out, want_i, "avx2 intersect\na={a:?}\nb={b:?}");
                    out.clear();
                    unsafe { x86::difference_avx2(&a, &b, &mut out) };
                    assert_eq!(out, want_d, "avx2 difference\na={a:?}\nb={b:?}");
                }
            }
            #[cfg(target_arch = "aarch64")]
            {
                if std::arch::is_aarch64_feature_detected!("neon") {
                    out.clear();
                    unsafe { neon::intersect_neon(&a, &b, &mut out) };
                    assert_eq!(out, want_i, "neon intersect\na={a:?}\nb={b:?}");
                    out.clear();
                    unsafe { neon::difference_neon(&a, &b, &mut out) };
                    assert_eq!(out, want_d, "neon difference\na={a:?}\nb={b:?}");
                }
            }

            // dispatch under both forced tiers (restored afterwards). Other
            // tests may observe the temporary override, but every tier
            // computes identical results, so nothing else can fail from it
            // (which is also why no test asserts on `simd_active`).
            for tier in [Some(Tier::Scalar), Some(Tier::Simd), None] {
                force_tier(tier);
                intersect_into(&a, &b, &mut out);
                assert_eq!(out, want_i, "dispatch {tier:?}");
                difference_into(&a, &b, &mut out);
                assert_eq!(out, want_d, "dispatch {tier:?}");
            }
            force_tier(None);
        });
        force_tier(None);
    }

    #[test]
    fn simd_blocks_with_equal_maxes_advance_both() {
        // a and b share block maxima exactly at block boundaries — the
        // advance-both case of the block loop
        let a: Vec<u32> = (0..64).collect();
        let b: Vec<u32> = (0..64).collect();
        let mut out = Vec::new();
        intersect_into(&a, &b, &mut out);
        assert_eq!(out, a);
        difference_into(&a, &b, &mut out);
        assert!(out.is_empty());
    }
}
