//! The structure-aware pattern algebra: morph *expressions* implementing the
//! Match Conversion Theorem (3.1), its inverse (Corollary 3.1) and the
//! Aggregation Conversion Theorem (3.2).
//!
//! A [`MorphExpr`] represents, for a query pattern `p`:
//!
//! ```text
//! a(M(p)) = ⨁_{terms (q, F)} ⨁_{(f, c) ∈ F} c · ( a(M(q)) ∘* f )
//! ```
//!
//! where each term pattern `q` is stored as its canonical representative and
//! `F` is a signed multiset of vertex maps `f : V(p) → V(q)`. Expressions
//! can be substituted into each other (composition of maps), which is how
//! the recursive expansion of Corollary 3.1 reaches an edge-induced basis.

use crate::agg::Aggregation;
use crate::pattern::canon::{canonical_form_with_iso, CanonKey};
use crate::pattern::gen::superpatterns;
use crate::pattern::iso::{phi_coset_reps, VertexMap};
use crate::pattern::Pattern;
use std::collections::{BTreeMap, HashMap};

/// One term of a morph expression: a (canonical) pattern plus a signed
/// multiset of maps from the query into it.
#[derive(Clone, Debug)]
pub struct Term {
    pub pattern: Pattern,
    /// `f → signed multiplicity`
    pub maps: HashMap<VertexMap, i64>,
}

impl Term {
    /// Total signed coefficient (`Σ c` over maps) — for counting
    /// aggregations this is the coefficient shown in the paper's Fig. 4.
    pub fn coefficient(&self) -> i64 {
        self.maps.values().sum()
    }
}

/// A morph expression for a query pattern.
#[derive(Clone, Debug)]
pub struct MorphExpr {
    pub query: Pattern,
    pub terms: BTreeMap<CanonKey, Term>,
}

impl MorphExpr {
    /// The trivial expression `a(M(p)) = a(M(p))` (no morphing).
    pub fn direct(query: &Pattern) -> MorphExpr {
        let mut e = MorphExpr {
            query: query.clone(),
            terms: BTreeMap::new(),
        };
        let n = query.num_vertices();
        let (canon, sigma) = canonical_form_with_iso(query);
        debug_assert_eq!(sigma.len(), n);
        e.add_map(canon, sigma, 1);
        e
    }

    /// Theorem 3.1: for an edge-induced query `p^E`,
    /// `M(p^E) = M(p^V) ∪ ⋃_{q^E ⊃n p^E} M(q^V) ∘ φ(p^E, q^E)`.
    ///
    /// All right-hand patterns are vertex-induced (cliques included).
    pub fn theorem_3_1(query: &Pattern) -> MorphExpr {
        assert!(
            query.is_edge_induced(),
            "Theorem 3.1 morphs edge-induced patterns, got {query:?}"
        );
        let mut e = MorphExpr {
            query: query.clone(),
            terms: BTreeMap::new(),
        };
        // M(p^V) term, identity map
        let pv = query.vertex_induced();
        let (canon, sigma) = canonical_form_with_iso(&pv);
        e.add_map(canon, sigma, 1);
        // superpattern terms
        for q in superpatterns(query) {
            let qv = q.vertex_induced();
            let (canon, sigma) = canonical_form_with_iso(&qv);
            for f in phi_coset_reps(query, &qv) {
                // f : V(p) → V(q); compose with σ : V(q) → V(canon)
                let composed: VertexMap = f.iter().map(|&x| sigma[x]).collect();
                e.add_map(canon.clone(), composed, 1);
            }
        }
        e
    }

    /// Corollary 3.1: for a vertex-induced query `p^V`,
    /// `M(p^V) = M(p^E) \ ⋃_{q^E ⊃n p^E} M(q^V) ∘ φ(p^E, q^E)` —
    /// expressed with signed terms (the union is disjoint, so subtraction
    /// is exact for additive aggregation values).
    pub fn corollary_3_1(query: &Pattern) -> MorphExpr {
        assert!(
            query.is_vertex_induced(),
            "Corollary 3.1 morphs vertex-induced patterns, got {query:?}"
        );
        let pe = query.edge_induced();
        let mut e = MorphExpr {
            query: query.clone(),
            terms: BTreeMap::new(),
        };
        let (canon, sigma) = canonical_form_with_iso(&pe);
        e.add_map(canon, sigma, 1);
        for q in superpatterns(&pe) {
            let qv = q.vertex_induced();
            let (canon, sigma) = canonical_form_with_iso(&qv);
            for f in phi_coset_reps(&pe, &qv) {
                let composed: VertexMap = f.iter().map(|&x| sigma[x]).collect();
                e.add_map(canon.clone(), composed, -1);
            }
        }
        e
    }

    /// Add a signed map to the term for `pattern` (which must already be in
    /// canonical form). Cancelling entries are removed.
    pub fn add_map(&mut self, pattern: Pattern, f: VertexMap, c: i64) {
        let key = pattern.canonical_key();
        let term = self.terms.entry(key).or_insert_with(|| Term {
            pattern,
            maps: HashMap::new(),
        });
        let e = term.maps.entry(f).or_insert(0);
        *e += c;
        if *e == 0 {
            let dead: Vec<_> = term
                .maps
                .iter()
                .filter(|(_, &c)| c == 0)
                .map(|(k, _)| k.clone())
                .collect();
            for k in dead {
                term.maps.remove(&k);
            }
        }
        if self.terms.get(&key).is_some_and(|t| t.maps.is_empty()) {
            self.terms.remove(&key);
        }
    }

    /// Substitute `sub` (an expression for the pattern keyed `key` in this
    /// expression) into this expression: the term is removed and replaced by
    /// the composition of its maps with `sub`'s terms.
    ///
    /// `sub.query` must be isomorphic to this expression's term pattern —
    /// and, because terms store canonical representatives, `sub.query` must
    /// *be* that canonical representative for the maps to compose correctly.
    pub fn substitute(&mut self, key: CanonKey, sub: &MorphExpr) {
        let Some(term) = self.terms.remove(&key) else {
            return;
        };
        debug_assert_eq!(
            sub.query.canonical_key(),
            key,
            "substituted expression must be for the term's pattern"
        );
        for (f, c) in &term.maps {
            for sterm in sub.terms.values() {
                for (g, c2) in &sterm.maps {
                    // f : V(p) → V(q); g : V(q) → V(r); g∘f : V(p) → V(r)
                    let composed: VertexMap = f.iter().map(|&x| g[x]).collect();
                    self.add_map(sterm.pattern.clone(), composed, c * c2);
                }
            }
        }
    }

    /// Fully expand to an **edge-induced basis**: every non-clique
    /// vertex-induced term is recursively replaced via Corollary 3.1.
    /// (Cliques are simultaneously edge-induced; they stay.)
    pub fn expand_to_edge_basis(&mut self) {
        loop {
            let next = self.terms.iter().find_map(|(k, t)| {
                (t.pattern.is_vertex_induced() && !t.pattern.is_clique()).then_some(*k)
            });
            let Some(key) = next else { break };
            let pat = self.terms[&key].pattern.clone();
            let sub = MorphExpr::corollary_3_1(&pat);
            // re-canonicalize sub.query == pat (already canonical rep)
            self.substitute(key, &sub);
        }
    }

    /// The distinct patterns that must be matched to evaluate this
    /// expression.
    pub fn base_patterns(&self) -> Vec<Pattern> {
        self.terms.values().map(|t| t.pattern.clone()).collect()
    }

    /// Evaluate under aggregation `agg`, given full-match-set values for
    /// every base pattern (keyed by canonical key).
    pub fn evaluate<A: Aggregation>(
        &self,
        agg: &A,
        values: &HashMap<CanonKey, A::Value>,
    ) -> A::Value {
        let mut acc = agg.identity();
        for (key, term) in &self.terms {
            let v = values
                .get(key)
                .unwrap_or_else(|| panic!("missing base value for {:?}", term.pattern));
            for (f, &c) in &term.maps {
                let permuted = agg.permute(v, f);
                acc = agg.combine(acc, agg.scale(&permuted, c));
            }
        }
        acc
    }

    /// Counting-only shortcut: evaluate with per-pattern *map* counts.
    pub fn evaluate_counts(&self, counts: &HashMap<CanonKey, i128>) -> i128 {
        let mut total = 0i128;
        for (key, term) in &self.terms {
            let v = counts
                .get(key)
                .unwrap_or_else(|| panic!("missing count for {:?}", term.pattern));
            total += v * term.coefficient() as i128;
        }
        total
    }

    /// Pretty-print as an equation over pattern descriptions (Fig. 4 style).
    pub fn describe(&self) -> String {
        let mut s = format!("a({:?}) =", self.query);
        let mut first = true;
        for term in self.terms.values() {
            let c = term.coefficient();
            if first {
                s.push(' ');
                first = false;
            } else {
                s.push_str(if c >= 0 { " + " } else { " " });
            }
            if c >= 0 && c != 1 {
                s.push_str(&format!("{c}·"));
            } else if c < 0 {
                s.push_str(&format!("- {}·", -c));
            }
            s.push_str(&format!("a({:?})", term.pattern));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;

    #[test]
    fn theorem_terms_for_cycle4() {
        // PR-E2 (Fig. 4): EI C4 = VI C4 + VI diamond + 3·K4 (unique-match
        // coefficients). In *map space* the coefficients are left-coset
        // counts |φ| / |Aut(q)|: diamond 8/4 = 2, K4 24/24 = 1.
        let e = MorphExpr::theorem_3_1(&catalog::cycle(4));
        assert_eq!(e.terms.len(), 3);
        let pv_key = catalog::cycle(4).vertex_induced().canonical_key();
        assert_eq!(e.terms[&pv_key].coefficient(), 1);
        let k4_key = catalog::clique(4).canonical_key();
        assert_eq!(e.terms[&k4_key].coefficient(), 1);
        let d_key = catalog::diamond().vertex_induced().canonical_key();
        assert_eq!(e.terms[&d_key].coefficient(), 2);
    }

    #[test]
    fn unique_match_coefficients_match_figure4() {
        // Converting map-space coefficients to unique-match space
        // (multiply by |Aut(q)| / |Aut(p)|) recovers the paper's Fig. 4:
        // K4 coefficient 3, diamond coefficient 1.
        let e = MorphExpr::theorem_3_1(&catalog::cycle(4));
        let aut_p = crate::pattern::iso::automorphisms(&catalog::cycle(4)).len() as i64;
        let k4 = catalog::clique(4);
        let aut_k4 = crate::pattern::iso::automorphisms(&k4).len() as i64;
        assert_eq!(
            e.terms[&k4.canonical_key()].coefficient() * aut_k4 / aut_p,
            3
        );
        let dia = catalog::diamond().vertex_induced();
        let aut_d = crate::pattern::iso::automorphisms(&dia).len() as i64;
        assert_eq!(
            e.terms[&dia.canonical_key()].coefficient() * aut_d / aut_p,
            1
        );
    }

    #[test]
    fn corollary_negates() {
        let e = MorphExpr::corollary_3_1(&catalog::cycle(4).vertex_induced());
        let pe_key = catalog::cycle(4).canonical_key();
        assert_eq!(e.terms[&pe_key].coefficient(), 1);
        let k4_key = catalog::clique(4).canonical_key();
        assert_eq!(e.terms[&k4_key].coefficient(), -1);
        let d_key = catalog::diamond().vertex_induced().canonical_key();
        assert_eq!(e.terms[&d_key].coefficient(), -2);
    }

    #[test]
    fn edge_basis_expansion_terminates_and_is_edge_induced() {
        for i in 1..=7 {
            let p = catalog::paper_pattern(i).vertex_induced();
            let mut e = MorphExpr::corollary_3_1(&p);
            e.expand_to_edge_basis();
            for t in e.terms.values() {
                assert!(
                    t.pattern.is_edge_induced(),
                    "p{i}: non-edge-induced term {:?}",
                    t.pattern
                );
            }
        }
    }

    #[test]
    fn clique_query_direct_only() {
        let e = MorphExpr::theorem_3_1(&catalog::clique(4));
        assert_eq!(e.terms.len(), 1);
        assert_eq!(e.terms.values().next().unwrap().coefficient(), 1);
    }

    #[test]
    fn describe_is_readable() {
        let e = MorphExpr::theorem_3_1(&catalog::cycle(4));
        let s = e.describe();
        assert!(s.contains('+'), "{s}");
    }
}
