//! Cost-based PMR optimizer (§4.1): constructs the best alternative pattern
//! set for a query set by minimizing estimated pattern-set cost.
//!
//! The cost of an alternative set captures the paper's three factors:
//! 1. **exploration cost** of each base pattern — [`crate::plan::cost`]
//!    simulates the compiled plan level-by-level against graph statistics
//!    (set-op work, symmetry breaking, anti-edge differences);
//! 2. **aggregation/conversion cost** — per-match aggregation work
//!    ([`CostParams`]) plus a per-map conversion term (`|φ|` permutes,
//!    Corollary 3.2);
//! 3. **data-graph details** — degree moments, density, clustering and
//!    label frequencies inside [`GraphStats`].
//!
//! Search: per query we enumerate candidate expressions (direct; the naïve
//! full rewrite; and *partial* rewrites where each vertex-induced
//! superpattern term independently chooses direct-vs-expand, decided
//! bottom-up over the superpattern lattice). A final greedy pass accounts
//! for base-pattern sharing across the query set — the effect the paper
//! observes for `{p5^V, p6^V}`, where morphing pays only when the extra
//! superpatterns are amortized.

use super::algebra::MorphExpr;
use crate::graph::GraphStats;
use crate::pattern::canon::CanonKey;
use crate::pattern::Pattern;
use crate::plan::cost::{estimate, estimate_matches, CostParams};
use crate::plan::Plan;
use std::collections::{HashMap, HashSet};

/// Conversion overhead per map in an expression (cheap: pattern-level
/// permutes, Corollary 3.2's `O(|φ|)` term).
const CONVERT_UNIT: f64 = 50.0;

/// Memoized per-pattern matching-cost estimator.
pub struct CostOracle<'a> {
    stats: &'a GraphStats,
    params: &'a CostParams,
    cache: HashMap<CanonKey, f64>,
    match_count_cache: HashMap<CanonKey, f64>,
    expand_decision: HashMap<CanonKey, bool>,
    expansion_cache: HashMap<CanonKey, MorphExpr>,
}

impl<'a> CostOracle<'a> {
    pub fn new(stats: &'a GraphStats, params: &'a CostParams) -> Self {
        CostOracle {
            stats,
            params,
            cache: HashMap::new(),
            match_count_cache: HashMap::new(),
            expand_decision: HashMap::new(),
            expansion_cache: HashMap::new(),
        }
    }

    /// Estimated cost of matching `p` once.
    pub fn match_cost(&mut self, p: &Pattern) -> f64 {
        let key = p.canonical_key();
        if let Some(&c) = self.cache.get(&key) {
            return c;
        }
        let plan = Plan::compile(p);
        let c = estimate(&plan, self.stats, self.params);
        self.cache.insert(key, c);
        c
    }

    /// Estimated number of matches of `p` (for conversion-cost estimates).
    pub fn match_count(&mut self, p: &Pattern) -> f64 {
        let key = p.canonical_key();
        if let Some(&c) = self.match_count_cache.get(&key) {
            return c;
        }
        let plan = Plan::compile(p);
        let c = estimate_matches(&plan, self.stats);
        self.match_count_cache.insert(key, c);
        c
    }

    /// Cost of evaluating an expression assuming no sharing: sum of base
    /// match costs plus conversion overhead. Each map permutes the term's
    /// aggregation value: O(1) for counting, but proportional to the term's
    /// match count for value-carrying aggregations (MNI tables,
    /// enumeration) — the §4.1 factor-2 effect that makes Cost-Based PMR
    /// decline to morph FSM on some graphs.
    pub fn expr_cost(&mut self, e: &MorphExpr) -> f64 {
        let mut c = 0.0;
        for key in e.terms.keys().copied().collect::<Vec<_>>() {
            let t = &e.terms[&key];
            let pattern = t.pattern.clone();
            let maps = t.maps.len() as f64;
            c += self.match_cost(&pattern);
            c += (CONVERT_UNIT + self.params.agg_per_match * self.match_count(&pattern)) * maps;
        }
        c
    }

    /// Memoized decision: is the fully-expanded Corollary 3.1 basis of a
    /// vertex-induced pattern estimated cheaper than matching it directly?
    fn should_expand(&mut self, p: &Pattern) -> bool {
        let key = p.canonical_key();
        if let Some(&d) = self.expand_decision.get(&key) {
            return d;
        }
        let direct_cost = self.match_cost(p);
        let mut expanded = MorphExpr::corollary_3_1(p);
        expanded.expand_to_edge_basis();
        let exp_cost = self.expr_cost(&expanded);
        let d = exp_cost < direct_cost;
        self.expand_decision.insert(key, d);
        self.expansion_cache.insert(key, expanded);
        d
    }

    /// The memoized expansion computed by [`Self::should_expand`].
    fn expansion_of(&mut self, p: &Pattern) -> MorphExpr {
        let key = p.canonical_key();
        if !self.expansion_cache.contains_key(&key) {
            let mut e = MorphExpr::corollary_3_1(p);
            e.expand_to_edge_basis();
            self.expansion_cache.insert(key, e);
        }
        self.expansion_cache[&key].clone()
    }
}

/// Queries whose direct plan is estimated cheaper than this many units of
/// work skip alternative generation entirely: morphing cannot recoup its
/// own planning cost on them. This is the fast path that keeps cost-based
/// PMR viable for FSM, whose levels produce thousands of highly
/// label-selective candidates (and where the paper's optimizer likewise
/// "ends up choosing not to morph the input pattern set", §4.6).
fn direct_fast_path_threshold(stats: &GraphStats) -> f64 {
    4.0 * stats.num_edges as f64
}

/// Candidate expressions for one query.
fn candidates(q: &Pattern, oracle: &mut CostOracle) -> Vec<MorphExpr> {
    let mut cands = vec![MorphExpr::direct(q)];
    if q.is_clique() {
        return cands;
    }
    if oracle.match_cost(q) < direct_fast_path_threshold(oracle.stats) {
        return cands;
    }
    if q.is_edge_induced() {
        // Theorem 3.1, with each vertex-induced superpattern term optionally
        // expanded further (bottom-up local decisions).
        let mut e = MorphExpr::theorem_3_1(q);
        refine_vertex_terms(&mut e, oracle, /* keep_query_term = */ Some(q));
        cands.push(MorphExpr::theorem_3_1(q)); // pure naive
        cands.push(e);
    } else if q.is_vertex_induced() {
        // Corollary 3.1 one-step…
        let one = MorphExpr::corollary_3_1(q);
        cands.push(one.clone());
        // …fully expanded (naive)…
        let mut full = one.clone();
        full.expand_to_edge_basis();
        cands.push(full);
        // …and locally optimized per superpattern term
        let mut local = one;
        refine_vertex_terms(&mut local, oracle, None);
        cands.push(local);
    }
    cands
}

/// For every vertex-induced non-clique term, decide bottom-up whether to
/// expand it via Corollary 3.1 (if its expanded basis is estimated cheaper
/// than matching it directly). `skip` protects the `p^V` term of a Theorem
/// 3.1 expansion from re-expansion (which would reintroduce the query).
fn refine_vertex_terms(e: &mut MorphExpr, oracle: &mut CostOracle, skip: Option<&Pattern>) {
    let skip_key = skip.map(|p| p.vertex_induced().canonical_key());
    loop {
        let mut target: Option<(CanonKey, Pattern)> = None;
        for (k, t) in &e.terms {
            if Some(*k) == skip_key {
                continue;
            }
            if !t.pattern.is_vertex_induced() || t.pattern.is_clique() {
                continue;
            }
            let pat = t.pattern.clone();
            if oracle.should_expand(&pat) {
                target = Some((*k, pat));
                break;
            }
        }
        let Some((key, pat)) = target else { break };
        let sub = oracle.expansion_of(&pat);
        e.substitute(key, &sub);
    }
}

/// Optimize a query set: returns one expression per query minimizing the
/// estimated total cost, with base patterns shared across queries counted
/// once.
pub fn optimize(
    queries: &[Pattern],
    stats: &GraphStats,
    params: &CostParams,
) -> Vec<MorphExpr> {
    let mut oracle = CostOracle::new(stats, params);
    let cands: Vec<Vec<MorphExpr>> = queries
        .iter()
        .map(|q| candidates(q, &mut oracle))
        .collect();

    // Precompute per-candidate summaries so the descent below does no
    // pattern-level work: base keys + match costs, and the total conversion
    // overhead of the candidate.
    struct Summary {
        bases: Vec<(CanonKey, f64)>,
        convert: f64,
    }
    let summaries: Vec<Vec<Summary>> = cands
        .iter()
        .map(|cs| {
            cs.iter()
                .map(|e| {
                    let mut bases = Vec::with_capacity(e.terms.len());
                    let mut convert = 0.0;
                    for t in e.terms.values() {
                        let pat = t.pattern.clone();
                        bases.push((pat.canonical_key(), oracle.match_cost(&pat)));
                        convert += (CONVERT_UNIT
                            + oracle.params.agg_per_match * oracle.match_count(&pat))
                            * t.maps.len() as f64;
                    }
                    Summary { bases, convert }
                })
                .collect()
        })
        .collect();

    // start: per-query locally-cheapest candidate
    let mut choice: Vec<usize> = summaries
        .iter()
        .map(|ss| {
            (0..ss.len())
                .min_by(|&a, &b| {
                    let ca: f64 = ss[a].bases.iter().map(|&(_, c)| c).sum::<f64>() + ss[a].convert;
                    let cb: f64 = ss[b].bases.iter().map(|&(_, c)| c).sum::<f64>() + ss[b].convert;
                    ca.partial_cmp(&cb).unwrap()
                })
                .unwrap()
        })
        .collect();

    // greedy coordinate descent on the *global* cost (shared bases counted
    // once), bounded sweeps
    let global_cost = |choice: &[usize]| -> f64 {
        let mut bases: HashSet<CanonKey> = HashSet::new();
        let mut cost = 0.0;
        for (qi, &ci) in choice.iter().enumerate() {
            let s = &summaries[qi][ci];
            for &(key, mc) in &s.bases {
                if bases.insert(key) {
                    cost += mc;
                }
            }
            cost += s.convert;
        }
        cost
    };

    let mut best = global_cost(&choice);
    for _sweep in 0..4 {
        let mut improved = false;
        for qi in 0..queries.len() {
            let current = choice[qi];
            for ci in 0..cands[qi].len() {
                if ci == current {
                    continue;
                }
                choice[qi] = ci;
                let c = global_cost(&choice);
                if c + 1e-9 < best {
                    best = c;
                    improved = true;
                } else {
                    choice[qi] = current;
                }
            }
        }
        if !improved {
            break;
        }
    }

    choice
        .into_iter()
        .enumerate()
        .map(|(qi, ci)| cands[qi][ci].clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{barabasi_albert, erdos_renyi};
    use crate::pattern::catalog;

    fn stats_of(g: &crate::graph::DataGraph) -> GraphStats {
        GraphStats::compute(g, 2000, 7)
    }

    #[test]
    fn clique_never_morphs() {
        let g = erdos_renyi(500, 3000, 31);
        let s = stats_of(&g);
        let exprs = optimize(&[catalog::clique(4)], &s, &CostParams::counting());
        assert_eq!(exprs[0].terms.len(), 1);
    }

    #[test]
    fn optimizer_never_worse_than_both_fixed_policies() {
        // cost-model-internal check: chosen expr cost ≤ direct, and ≤ naive
        // up to the direct fast-path threshold (queries cheaper than the
        // threshold skip alternative generation entirely — see
        // `direct_fast_path_threshold`).
        let g = barabasi_albert(2000, 8, 32);
        let s = stats_of(&g);
        let params = CostParams::counting();
        let slack = direct_fast_path_threshold(&s);
        for i in 1..=7 {
            for q in [
                catalog::paper_pattern(i),
                catalog::paper_pattern(i).vertex_induced(),
            ] {
                let mut oracle = CostOracle::new(&s, &params);
                let chosen = optimize(std::slice::from_ref(&q), &s, &params);
                let c_chosen = oracle.expr_cost(&chosen[0]);
                let c_direct = oracle.expr_cost(&MorphExpr::direct(&q));
                let c_naive = oracle.expr_cost(&crate::morph::engine::naive_expr(&q));
                assert!(
                    c_chosen <= c_direct + 1e-6 && c_chosen <= c_naive + slack,
                    "p{i} {q:?}: chosen {c_chosen} direct {c_direct} naive {c_naive}"
                );
            }
        }
    }

    #[test]
    fn sharing_encourages_morphing_groups() {
        // Global cost with shared bases must be ≤ sum of independent costs.
        let g = barabasi_albert(2000, 8, 33);
        let s = stats_of(&g);
        let params = CostParams::counting();
        let q1 = catalog::house().vertex_induced();
        let q2 = catalog::gem().vertex_induced();
        let both = optimize(&[q1.clone(), q2.clone()], &s, &params);
        let mut oracle = CostOracle::new(&s, &params);
        // recompute global cost of the pair
        let mut bases = std::collections::HashSet::new();
        let mut pair_cost = 0.0;
        for e in &both {
            for t in e.terms.values() {
                if bases.insert(t.pattern.canonical_key()) {
                    pair_cost += oracle.match_cost(&t.pattern.clone());
                }
            }
        }
        let solo: f64 = [q1, q2]
            .iter()
            .map(|q| {
                let e = optimize(std::slice::from_ref(q), &s, &params);
                oracle.expr_cost(&e[0])
            })
            .sum();
        assert!(pair_cost <= solo + 1e-6, "pair {pair_cost} vs solo {solo}");
    }

    #[test]
    fn mni_params_discourage_heavy_conversions_sometimes() {
        // with expensive aggregation the optimizer can still return
        // *something* valid — structural smoke test
        let g = erdos_renyi(1000, 5000, 34);
        let s = stats_of(&g);
        let q = catalog::path(3).with_labels(&[1, 2, 1]).vertex_induced();
        let exprs = optimize(&[q], &s, &CostParams::mni(3));
        assert!(!exprs[0].terms.is_empty());
    }
}
