//! PATTERN MORPHING — the paper's contribution.
//!
//! [`algebra`] implements the structure-aware algebra over patterns
//! (Theorem 3.1, Corollary 3.1, Theorem 3.2); [`engine`] turns query sets
//! into morph plans and executes them against a data graph; [`optimizer`]
//! is the cost-based PMR optimizer of §4.1 that picks the cheapest
//! alternative pattern set per query and data graph.

pub mod algebra;
pub mod engine;
pub mod optimizer;

pub use algebra::{MorphExpr, Term};
pub use engine::{execute, execute_opts, plan_queries, ExecOpts, MorphPlan, Policy};
