//! Morphing engine: rewrites a query pattern set into an alternative
//! pattern set (per policy), matches the alternative set, and converts the
//! aggregation results back — the "external module" of §4.1.

use super::algebra::MorphExpr;
use super::optimizer;
use crate::agg::Aggregation;
use crate::graph::{DataGraph, GraphStats};
use crate::pattern::canon::CanonKey;
use crate::pattern::Pattern;
use crate::plan::cost::CostParams;
use crate::plan::fused::FusedPlan;
use crate::util::timer::PhaseProfile;
use std::collections::HashMap;

/// Morphing policy (the three variants of the paper's evaluation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// No PMR: match the query patterns directly.
    Off,
    /// Naïve PMR: edge-induced queries are morphed to vertex-induced
    /// alternatives (Theorem 3.1), vertex-induced queries to edge-induced
    /// alternatives (Corollary 3.1, fully expanded).
    Naive,
    /// Cost-based PMR: the optimizer picks the cheapest alternative per
    /// query given graph statistics and aggregation cost.
    CostBased,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "off" | "none" => Some(Policy::Off),
            "naive" => Some(Policy::Naive),
            "cost" | "cost-based" => Some(Policy::CostBased),
            _ => None,
        }
    }
}

/// A planned (possibly morphed) query set.
pub struct MorphPlan {
    /// One expression per input query, in input order.
    pub exprs: Vec<MorphExpr>,
    /// Distinct base patterns to match (canonical forms).
    pub base: Vec<Pattern>,
}

impl MorphPlan {
    pub fn from_exprs(exprs: Vec<MorphExpr>) -> MorphPlan {
        let mut base: HashMap<CanonKey, Pattern> = HashMap::new();
        for e in &exprs {
            for t in e.terms.values() {
                base.entry(t.pattern.canonical_key())
                    .or_insert_with(|| t.pattern.clone());
            }
        }
        let mut base: Vec<Pattern> = base.into_values().collect();
        base.sort_by_key(|p| p.canonical_key());
        MorphPlan { exprs, base }
    }

    /// Human-readable description of the alternative pattern sets
    /// (Table 4 of the paper).
    pub fn describe(&self) -> String {
        let mut s = String::new();
        for e in &self.exprs {
            s.push_str(&format!(
                "{:?}  ⇒  {{{}}}\n",
                e.query,
                e.terms
                    .values()
                    .map(|t| format!("{:?}", t.pattern))
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
        }
        s
    }
}

/// Build the morph plan for a query set under `policy`.
///
/// `stats` + `params` are required for [`Policy::CostBased`] (they describe
/// the data graph and the aggregation cost, §4.1's factors 2–3).
pub fn plan_queries(
    queries: &[Pattern],
    policy: Policy,
    stats: Option<&GraphStats>,
    params: &CostParams,
) -> MorphPlan {
    let exprs: Vec<MorphExpr> = match policy {
        Policy::Off => queries.iter().map(MorphExpr::direct).collect(),
        Policy::Naive => queries.iter().map(naive_expr).collect(),
        Policy::CostBased => {
            let stats = stats.expect("cost-based PMR needs graph stats");
            optimizer::optimize(queries, stats, params)
        }
    };
    MorphPlan::from_exprs(exprs)
}

/// The Naïve-PMR rewrite of a single query.
pub fn naive_expr(q: &Pattern) -> MorphExpr {
    if q.is_clique() {
        // cliques are both edge- and vertex-induced; nothing to morph
        MorphExpr::direct(q)
    } else if q.is_edge_induced() {
        MorphExpr::theorem_3_1(q)
    } else if q.is_vertex_induced() {
        let mut e = MorphExpr::corollary_3_1(q);
        e.expand_to_edge_basis();
        e
    } else {
        // mixed anti-edge patterns: theory covers pE/pV; leave direct
        MorphExpr::direct(q)
    }
}

/// How a morph plan's base set is matched — see [`execute_opts`].
#[derive(Clone, Debug)]
pub struct ExecOpts {
    /// Worker threads for the matcher.
    pub threads: usize,
    /// Fuse the base pattern set into one shared-prefix trie traversal
    /// ([`FusedPlan`]) instead of one full sweep per base pattern. Ignored
    /// (per-pattern path) when the base set has fewer than two patterns.
    pub fused: bool,
    /// Real data-graph statistics steering fused matching-order selection.
    /// Callers that already computed stats for cost-based PMR pass the same
    /// instance so both decisions share one cost model; `None` means
    /// [`execute_opts`] computes them from the graph on the fused path.
    pub stats: Option<GraphStats>,
    /// Restrict the **first exploration level** to `[lo, hi)`. `None`
    /// explores the whole graph. Matches are rooted at exactly one
    /// first-level vertex, so values computed over a disjoint cover of
    /// `0..|V|` combine to the full-graph values — this is the seam the
    /// distributed driver ([`crate::shard`]) partitions along.
    pub first_level: Option<(crate::graph::VertexId, crate::graph::VertexId)>,
}

impl Default for ExecOpts {
    fn default() -> ExecOpts {
        ExecOpts::new(crate::exec::parallel::default_threads())
    }
}

impl ExecOpts {
    /// Default options: fused co-execution on.
    pub fn new(threads: usize) -> ExecOpts {
        ExecOpts {
            threads,
            fused: true,
            stats: None,
            first_level: None,
        }
    }

    /// Toggle fused co-execution.
    pub fn with_fused(mut self, fused: bool) -> ExecOpts {
        self.fused = fused;
        self
    }

    /// Attach graph statistics (shared with the PMR cost model).
    pub fn with_stats(mut self, stats: GraphStats) -> ExecOpts {
        self.stats = Some(stats);
        self
    }

    /// Restrict the first exploration level to `[lo, hi)` (shard slice).
    pub fn with_first_level(
        mut self,
        lo: crate::graph::VertexId,
        hi: crate::graph::VertexId,
    ) -> ExecOpts {
        self.first_level = Some((lo, hi));
        self
    }
}

/// Execute a morph plan: match every base pattern (full-match-set
/// aggregation), then convert per query via Theorem 3.2. Matching defaults
/// to fused co-execution — see [`execute_opts`].
///
/// Phase timings are accumulated into `profile` under `"match"` and
/// `"convert"` (the Figure-2 breakdown), plus `"fuse"` for set-plan
/// construction on the fused path.
pub fn execute<A: Aggregation>(
    graph: &DataGraph,
    plan: &MorphPlan,
    agg: &A,
    threads: usize,
    profile: &mut PhaseProfile,
) -> Vec<A::Value> {
    execute_opts(graph, plan, agg, ExecOpts::new(threads), profile)
}

/// [`execute`] with explicit execution options.
///
/// With `opts.fused` and a multi-pattern base set, the base patterns are
/// compiled into one prefix-sharing plan trie and matched in a **single
/// traversal** of the data graph (the fused path is policy-independent:
/// it applies to whatever base set the morph plan produced). Otherwise
/// each base pattern is matched with its own sweep.
///
/// Fused matching-order selection is scored against **real** graph
/// statistics: `opts.stats` when the caller already computed them (e.g.
/// for cost-based PMR — both decisions then share one cost model), or a
/// fresh [`GraphStats::compute`] otherwise (timed under `"stats"`).
pub fn execute_opts<A: Aggregation>(
    graph: &DataGraph,
    plan: &MorphPlan,
    agg: &A,
    opts: ExecOpts,
    profile: &mut PhaseProfile,
) -> Vec<A::Value> {
    let values = match_bases(graph, &plan.base, agg, &opts, profile);
    plan.exprs
        .iter()
        .map(|e| profile.time("convert", || e.evaluate(agg, &values)))
        .collect()
}

/// Match every pattern of `base` over the full match set and return the
/// aggregation values keyed by canonical key — the matching half of
/// [`execute_opts`], delegating to [`match_base_subset`] with the full
/// index range so the fused-vs-per-pattern dispatch lives in one place.
fn match_bases<A: Aggregation>(
    graph: &DataGraph,
    base: &[Pattern],
    agg: &A,
    opts: &ExecOpts,
    profile: &mut PhaseProfile,
) -> HashMap<CanonKey, A::Value> {
    let all: Vec<usize> = (0..base.len()).collect();
    match_base_subset(graph, base, &all, agg, opts, profile)
        .into_iter()
        .collect()
}

/// Match the subset of `base` selected by `indices` over full match sets,
/// returning `(canonical key, value)` pairs — **the** dispatch point for
/// fused-vs-per-pattern matching (fused threshold, stats fallback,
/// counting cost params). The fused path plans the trie over only the
/// subset ([`FusedPlan::build_for_subset`]), so excluded patterns — e.g.
/// bases the service's result cache already holds
/// ([`crate::service::QueryPlanner::execute_bases`]) — never enter it.
pub(crate) fn match_base_subset<A: Aggregation>(
    graph: &DataGraph,
    base: &[Pattern],
    indices: &[usize],
    agg: &A,
    opts: &ExecOpts,
    profile: &mut PhaseProfile,
) -> Vec<(CanonKey, A::Value)> {
    if indices.is_empty() {
        return Vec::new();
    }
    let (lo, hi) = opts.first_level.unwrap_or((0, graph.num_vertices() as u32));
    if opts.fused && indices.len() > 1 {
        let computed;
        let stats = match opts.stats.as_ref() {
            Some(s) => s,
            None => {
                computed = profile.time("stats", || GraphStats::compute(graph, 2000, 0xF0D5));
                &computed
            }
        };
        let mut keep = vec![false; base.len()];
        for &i in indices {
            keep[i] = true;
        }
        let (fused, selected) = profile.time("fuse", || {
            FusedPlan::build_for_subset(base, &keep, Some(stats), &CostParams::counting())
        });
        let vals = profile.time("match", || {
            crate::agg::aggregate_patterns_fused_range(graph, &fused, agg, opts.threads, lo, hi)
        });
        selected
            .into_iter()
            .zip(vals)
            .map(|(i, v)| (base[i].canonical_key(), v))
            .collect()
    } else {
        indices
            .iter()
            .map(|&i| {
                let v = profile.time("match", || {
                    crate::agg::aggregate_pattern_range(graph, &base[i], agg, opts.threads, lo, hi)
                });
                (base[i].canonical_key(), v)
            })
            .collect()
    }
}

/// Counting convenience: run a query set under a policy and return
/// **unique-match counts** (map counts divided by `|Aut(query)|`, the number
/// reported by pattern-aware systems like Peregrine).
pub fn count_queries(
    graph: &DataGraph,
    queries: &[Pattern],
    policy: Policy,
    threads: usize,
) -> Vec<u64> {
    let stats;
    let stats_ref = if policy == Policy::CostBased {
        stats = GraphStats::compute(graph, 2000, 0xC057);
        Some(&stats)
    } else {
        None
    };
    let plan = plan_queries(queries, policy, stats_ref, &CostParams::counting());
    let mut profile = PhaseProfile::new();
    let mut opts = ExecOpts::new(threads);
    if let Some(s) = stats_ref {
        // PMR and fused order selection share the one cost model
        opts = opts.with_stats(s.clone());
    }
    let vals = execute_opts(graph, &plan, &crate::agg::CountAgg, opts, &mut profile);
    vals.iter()
        .zip(queries)
        .map(|(&maps, q)| {
            let aut = crate::pattern::iso::automorphisms(q).len() as i128;
            assert!(maps >= 0, "negative match count for {q:?}: {maps}");
            assert_eq!(maps % aut, 0, "map count {maps} not divisible by |Aut|={aut}");
            (maps / aut) as u64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{brute_force_count, count_matches};
    use crate::graph::generators::erdos_renyi;
    use crate::pattern::catalog;
    use crate::util::proptest;

    #[test]
    fn naive_morph_counts_match_direct_edge_induced() {
        let g = erdos_renyi(60, 180, 21);
        for i in 1..=7 {
            let q = catalog::paper_pattern(i);
            let direct = count_queries(&g, &[q.clone()], Policy::Off, 2);
            let naive = count_queries(&g, &[q.clone()], Policy::Naive, 2);
            assert_eq!(direct, naive, "p{i} edge-induced");
            assert_eq!(direct[0], brute_force_count(&g, &q), "p{i} vs oracle");
        }
    }

    #[test]
    fn naive_morph_counts_match_direct_vertex_induced() {
        let g = erdos_renyi(60, 200, 22);
        for i in 1..=7 {
            let q = catalog::paper_pattern(i).vertex_induced();
            let direct = count_queries(&g, &[q.clone()], Policy::Off, 2);
            let naive = count_queries(&g, &[q.clone()], Policy::Naive, 2);
            assert_eq!(direct, naive, "p{i} vertex-induced");
        }
    }

    #[test]
    fn cost_based_morph_counts_match_direct() {
        let g = erdos_renyi(80, 320, 23);
        let queries: Vec<_> = (1..=7)
            .flat_map(|i| {
                [
                    catalog::paper_pattern(i),
                    catalog::paper_pattern(i).vertex_induced(),
                ]
            })
            .collect();
        let direct = count_queries(&g, &queries, Policy::Off, 2);
        let cost = count_queries(&g, &queries, Policy::CostBased, 2);
        assert_eq!(direct, cost);
    }

    #[test]
    fn morphed_4motifs_sum_rule() {
        // Σ over vertex-induced 4-motifs of (count · 1) must equal the
        // number of connected 4-vertex induced subgraphs — independent check
        // that morphing preserves totals.
        let g = erdos_renyi(50, 150, 24);
        let motifs = catalog::motifs_vertex_induced(4);
        let morphed = count_queries(&g, &motifs, Policy::Naive, 2);
        let direct = count_queries(&g, &motifs, Policy::Off, 2);
        assert_eq!(morphed, direct);
    }

    #[test]
    fn base_patterns_deduplicated_across_queries() {
        // morphing both C4^E and tailed^E shares the K4 base
        let plan = plan_queries(
            &[catalog::cycle(4), catalog::tailed_triangle()],
            Policy::Naive,
            None,
            &CostParams::counting(),
        );
        let k4 = catalog::clique(4).canonical_key();
        let count = plan
            .base
            .iter()
            .filter(|p| p.canonical_key() == k4)
            .count();
        assert_eq!(count, 1);
    }

    #[test]
    fn profile_records_phases() {
        let g = erdos_renyi(40, 100, 25);
        let plan = plan_queries(
            &[catalog::cycle(4)],
            Policy::Naive,
            None,
            &CostParams::counting(),
        );
        let mut prof = PhaseProfile::new();
        let _ = execute(&g, &plan, &crate::agg::CountAgg, 1, &mut prof);
        assert!(prof.get("match") > std::time::Duration::ZERO);
        assert!(prof.get("convert") > std::time::Duration::ZERO);
    }

    #[test]
    fn fused_execute_matches_per_pattern_path() {
        let g = erdos_renyi(60, 240, 26);
        let plan = plan_queries(
            &catalog::motifs_vertex_induced(4),
            Policy::Naive,
            None,
            &CostParams::counting(),
        );
        assert!(plan.base.len() > 1);
        let mut prof_fused = PhaseProfile::new();
        let mut prof_per = PhaseProfile::new();
        let agg = crate::agg::CountAgg;
        let fused = execute_opts(&g, &plan, &agg, ExecOpts::new(2), &mut prof_fused);
        let per = execute_opts(
            &g,
            &plan,
            &agg,
            ExecOpts::new(2).with_fused(false),
            &mut prof_per,
        );
        assert_eq!(fused, per);
        assert!(prof_fused.get("fuse") > std::time::Duration::ZERO);
        assert_eq!(prof_per.get("fuse"), std::time::Duration::ZERO);
    }

    #[test]
    fn prop_morph_equivalence_random_graphs() {
        proptest::check(0x3015, 15, |rng| {
            let n = 20 + rng.below_usize(30);
            let m = 2 * n + rng.below_usize(3 * n);
            let g = erdos_renyi(n, m, rng.next_u64());
            let qs = [
                catalog::cycle(4),
                catalog::cycle(4).vertex_induced(),
                catalog::tailed_triangle().vertex_induced(),
                catalog::star(4).vertex_induced(),
                catalog::diamond(),
            ];
            for q in qs {
                let direct = count_queries(&g, std::slice::from_ref(&q), Policy::Off, 1);
                let naive = count_queries(&g, std::slice::from_ref(&q), Policy::Naive, 1);
                assert_eq!(direct, naive, "{q:?}");
                // cross-check the matcher itself
                let plan = crate::plan::Plan::compile(&q);
                assert_eq!(direct[0], count_matches(&g, &plan));
            }
        });
    }
}
