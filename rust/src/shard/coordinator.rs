//! Coordinator side of the shard fan-out: one framed TCP connection per
//! worker ([`ShardClient`]) and the pool that partitions a batch's missing
//! bases across all of them ([`ShardPool`]).
//!
//! The pool's one operation, [`ShardPool::execute_bases`], is a drop-in
//! replacement for local execution: it splits the first-level vertex range
//! into one contiguous slice per worker ([`super::shard_ranges`]), sends
//! every worker the *same* base pattern set with *its* slice, and sums the
//! per-shard partial map counts per canonical key. Each match is rooted at
//! exactly one first-level vertex, so the sums are exactly the full-graph
//! values — no reconciliation, no double counting, and the morph-algebra
//! composition downstream is untouched.
//!
//! Failure handling is fail-fast: a worker that rejects the handshake
//! (wrong graph), drops the connection, or answers with an error fails the
//! whole batch with a descriptive error. Partial answers are never merged
//! — a missing slice would silently undercount.

use super::proto::{self, ExecRequest, ExecResponse, Msg};
use super::shard_ranges;
use crate::graph::{DataGraph, GraphFingerprint};
use crate::pattern::canon::CanonKey;
use crate::pattern::Pattern;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::net::TcpStream;

/// Coordinator-side counters for the shard fan-out.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardMetrics {
    /// Exec requests sent (one per worker per batch with missing bases).
    pub requests: u64,
    /// Base patterns fanned out, summed over workers.
    pub bases_sent: u64,
    /// Per-shard partial values merged into totals.
    pub partials_merged: u64,
    /// Bases workers reported serving from their local stores instead of
    /// matching (shard-level cache reuse, summed over workers).
    pub remote_cached: u64,
    /// Batches failed by a worker error or lost connection.
    pub errors: u64,
}

/// One connected shard worker.
pub struct ShardClient {
    addr: String,
    stream: TcpStream,
    threads: u32,
}

/// How long a worker gets to answer the handshake. A worker that accepts
/// the TCP connection but never replies (wedged, SIGSTOPped, black-holed)
/// must fail the pool loudly at connect time, not hang it. Exec replies
/// are deliberately *not* deadlined — matching a big slice legitimately
/// takes as long as it takes; liveness probing for in-flight requests is
/// a recorded ROADMAP follow-up.
pub const HANDSHAKE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

impl ShardClient {
    /// Connect and handshake: the worker must hold a graph with exactly
    /// `fingerprint` — anything else is a hard reject on its side, which
    /// surfaces here as a connection error. The handshake reply is
    /// deadlined by [`HANDSHAKE_TIMEOUT`] so a wedged worker fails the
    /// pool instead of hanging it.
    pub fn connect(addr: &str, fingerprint: GraphFingerprint) -> Result<ShardClient> {
        let mut stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to shard worker {addr}"))?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
            .context("setting handshake timeout")?;
        proto::write_msg(&mut stream, &Msg::Hello { fingerprint })
            .with_context(|| format!("greeting shard worker {addr}"))?;
        let reply = proto::read_msg(&mut stream)
            .with_context(|| format!("reading handshake reply from {addr}"))?;
        // exec replies wait on real matching work: no deadline (see above)
        stream
            .set_read_timeout(None)
            .context("clearing handshake timeout")?;
        match reply {
            Msg::Welcome { fingerprint: fp, threads } => {
                ensure!(
                    fp == fingerprint,
                    "shard worker {addr} answered with fingerprint {fp}, expected {fingerprint}"
                );
                Ok(ShardClient {
                    addr: addr.to_string(),
                    stream,
                    threads,
                })
            }
            Msg::Reject { reason } => bail!("shard worker {addr} rejected handshake: {reason}"),
            other => bail!("shard worker {addr} sent unexpected handshake reply {other:?}"),
        }
    }

    /// The worker's address, as given to [`ShardClient::connect`].
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Matcher threads the worker reported at handshake.
    pub fn threads(&self) -> u32 {
        self.threads
    }

    fn execute(&mut self, req: ExecRequest) -> Result<ExecResponse> {
        let id = req.id;
        proto::write_msg(&mut self.stream, &Msg::Exec(req))
            .with_context(|| format!("sending request to shard worker {}", self.addr))?;
        match proto::read_msg(&mut self.stream)
            .with_context(|| format!("reading reply from shard worker {}", self.addr))?
        {
            Msg::Result(resp) if resp.id == id => Ok(resp),
            Msg::Result(resp) => bail!(
                "shard worker {} answered request {} while {} was pending",
                self.addr,
                resp.id,
                id
            ),
            Msg::Error { id: eid, message } if eid == id => {
                bail!("shard worker {} failed the request: {message}", self.addr)
            }
            other => bail!("shard worker {} sent unexpected reply {other:?}", self.addr),
        }
    }
}

/// A fixed set of connected shard workers sharing one graph identity.
pub struct ShardPool {
    clients: Vec<ShardClient>,
    fingerprint: GraphFingerprint,
    num_vertices: u32,
    next_id: u64,
    metrics: ShardMetrics,
}

impl ShardPool {
    /// Connect to every address, handshaking each against `graph`'s
    /// fingerprint. Any unreachable or mismatched worker fails the pool —
    /// a partial pool would silently undercount.
    pub fn connect(addrs: &[String], graph: &DataGraph) -> Result<ShardPool> {
        ensure!(!addrs.is_empty(), "a shard pool needs at least one worker address");
        let fingerprint = graph.fingerprint();
        let clients = addrs
            .iter()
            .map(|a| ShardClient::connect(a, fingerprint))
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardPool {
            clients,
            fingerprint,
            num_vertices: graph.num_vertices() as u32,
            next_id: 0,
            metrics: ShardMetrics::default(),
        })
    }

    /// Number of workers (= number of first-level slices).
    pub fn num_shards(&self) -> usize {
        self.clients.len()
    }

    /// The contiguous first-level slices, one per worker in pool order.
    pub fn ranges(&self) -> Vec<(u32, u32)> {
        shard_ranges(self.num_vertices, self.clients.len())
    }

    /// Coordinator-side fan-out counters.
    pub fn metrics(&self) -> ShardMetrics {
        self.metrics
    }

    /// Match the subset of `base` selected by `indices` across the pool
    /// and return **full-graph** map counts per canonical key: every
    /// worker runs the same base set over its own first-level slice, and
    /// the per-shard partials are summed here. `epoch` is the
    /// coordinator's cache epoch, echoed through for bookkeeping.
    pub fn execute_bases(
        &mut self,
        base: &[Pattern],
        indices: &[usize],
        epoch: u64,
    ) -> Result<Vec<(CanonKey, i128)>> {
        if indices.is_empty() {
            return Ok(Vec::new());
        }
        let patterns: Vec<Pattern> = indices.iter().map(|&i| base[i].clone()).collect();
        let keys: Vec<CanonKey> = patterns.iter().map(|p| p.canonical_key()).collect();
        let ranges = shard_ranges(self.num_vertices, self.clients.len());
        let base_id = self.next_id;
        self.next_id += self.clients.len() as u64;
        let fingerprint = self.fingerprint;

        // fan out: blocking IO, one thread per worker so slices overlap
        let replies: Vec<Result<ExecResponse>> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .clients
                .iter_mut()
                .zip(ranges.iter().copied())
                .enumerate()
                .map(|(i, (client, (lo, hi)))| {
                    let patterns = patterns.clone();
                    s.spawn(move || {
                        client.execute(ExecRequest {
                            id: base_id + i as u64,
                            epoch,
                            fingerprint,
                            lo,
                            hi,
                            patterns,
                        })
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard client thread"))
                .collect()
        });

        // merge: exact sums per canonical key, all slices or nothing
        let mut sums: HashMap<CanonKey, i128> = keys.iter().map(|k| (*k, 0)).collect();
        let distinct = sums.len();
        for reply in replies {
            let resp = match reply {
                Ok(r) => r,
                Err(e) => {
                    self.metrics.errors += 1;
                    return Err(e);
                }
            };
            ensure!(
                resp.values.len() == distinct,
                "shard worker answered {} bases, expected {distinct}",
                resp.values.len()
            );
            self.metrics.remote_cached += resp.served_from_store as u64;
            for (k, v) in resp.values {
                match sums.get_mut(&k) {
                    Some(total) => {
                        *total += v;
                        self.metrics.partials_merged += 1;
                    }
                    None => bail!("shard worker answered an unrequested base pattern {k:?}"),
                }
            }
        }
        self.metrics.requests += self.clients.len() as u64;
        self.metrics.bases_sent += (distinct * self.clients.len()) as u64;
        let mut out = Vec::with_capacity(distinct);
        let mut emitted = std::collections::HashSet::new();
        for k in keys {
            if emitted.insert(k) {
                out.push((k, sums[&k]));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::pattern::catalog;
    use crate::shard::worker::{ShardWorker, WorkerConfig};

    fn spawn_workers(seed: u64, k: usize) -> (Vec<ShardWorker>, Vec<String>) {
        let workers: Vec<ShardWorker> = (0..k)
            .map(|_| {
                ShardWorker::bind(
                    erdos_renyi(70, 260, seed),
                    "127.0.0.1:0",
                    WorkerConfig {
                        threads: 2,
                        fused: true,
                        cache_bytes: 1 << 20,
                        persist: None,
                    },
                )
                .unwrap()
            })
            .collect();
        let addrs = workers.iter().map(|w| w.addr().to_string()).collect();
        (workers, addrs)
    }

    #[test]
    fn pool_sums_equal_local_execution() {
        let seed = 0x7001;
        let (workers, addrs) = spawn_workers(seed, 2);
        let g = erdos_renyi(70, 260, seed);
        let mut pool = ShardPool::connect(&addrs, &g).unwrap();
        assert_eq!(pool.num_shards(), 2);
        let ranges = pool.ranges();
        assert_eq!(ranges[0].0, 0);
        assert_eq!(ranges[1].1, 70);
        assert_eq!(ranges[0].1, ranges[1].0, "slices tile the vertex range");
        let base = vec![
            catalog::triangle(),
            catalog::path(3),
            catalog::cycle(4).vertex_induced(),
        ];
        let indices: Vec<usize> = (0..base.len()).collect();
        let merged = pool.execute_bases(&base, &indices, 0).unwrap();
        assert_eq!(merged.len(), base.len());
        for ((k, v), p) in merged.iter().zip(&base) {
            assert_eq!(*k, p.canonical_key());
            let direct = crate::agg::aggregate_pattern(&g, p, &crate::agg::CountAgg, 1);
            assert_eq!(*v, direct, "{p:?}: shard sums must equal local counts");
        }
        let m = pool.metrics();
        assert_eq!(m.requests, 2);
        assert_eq!(m.bases_sent, 6);
        assert_eq!(m.partials_merged, 6);
        assert_eq!(m.errors, 0);
        // a resend is served from the worker-local stores
        let again = pool.execute_bases(&base, &indices, 0).unwrap();
        assert_eq!(again, merged);
        assert_eq!(pool.metrics().remote_cached, 6);
        drop(pool);
        for w in workers {
            w.shutdown();
        }
    }

    #[test]
    fn pool_rejects_mismatched_graph() {
        let (workers, addrs) = spawn_workers(0x7002, 1);
        let other = erdos_renyi(70, 260, 0x7003); // different content
        let err = ShardPool::connect(&addrs, &other).unwrap_err();
        assert!(format!("{err:#}").contains("rejected handshake"), "{err:#}");
        drop(workers);
        // a dead worker fails the pool, not just a request
        assert!(ShardPool::connect(&addrs, &erdos_renyi(70, 260, 0x7002)).is_err());
    }

    #[test]
    fn empty_subset_is_free() {
        let (workers, addrs) = spawn_workers(0x7004, 1);
        let g = erdos_renyi(70, 260, 0x7004);
        let mut pool = ShardPool::connect(&addrs, &g).unwrap();
        let base = vec![catalog::triangle()];
        assert!(pool.execute_bases(&base, &[], 0).unwrap().is_empty());
        assert_eq!(pool.metrics().requests, 0);
        drop(pool);
        drop(workers);
    }
}
