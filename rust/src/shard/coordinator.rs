//! Coordinator side of the shard fabric: one framed TCP connection per
//! worker ([`ShardClient`]) and the pool that deals a batch's missing
//! bases across all of them ([`ShardPool`]).
//!
//! The pool's one operation, [`ShardPool::execute_bases`], is a drop-in
//! replacement for local execution, built as a small fault-tolerant
//! fabric rather than a fixed fan-out:
//!
//! * **Sub-slice dealing** — the first-level vertex range is cut into
//!   degree-weighted sub-slices ([`super::weighted_ranges`], several per
//!   worker) held in a shared work queue. Each worker thread keeps a small
//!   pipeline of requests in flight and pulls the next sub-slice as
//!   replies land, so a fast worker steals the sub-slices a straggler
//!   never got to — no barrier on the slowest fixed slice.
//! * **Liveness** — while replies are outstanding, the client probes the
//!   worker with [`Msg::Ping`] every `probe_interval`; any traffic
//!   (including pongs) counts as liveness, and a connection silent for
//!   `shard_timeout` is declared wedged. A pong reporting zero in-flight
//!   requests while we still await replies means the worker lost them —
//!   caught immediately instead of waiting out the deadline.
//! * **Replica groups** — the topology is a list of groups
//!   ([`super::parse_topology`]): each group owns a contiguous cut of the
//!   first-level range ([`super::weighted_cuts`]) and every member holds
//!   the same graph, so any member can serve any of the group's
//!   sub-slices. Group queues are disjoint; members of one group steal
//!   from each other, never across groups. The unreplicated topology (all
//!   groups singleton) collapses to one shared queue — PR 6's fabric,
//!   byte-for-byte.
//! * **Failover before re-fan** — in a replicated group a failed member
//!   (refused connect, broken pipe, CRC error, wedge, error reply) has
//!   its unserved sub-slices handed to a live sibling (`failovers`), its
//!   reconnect attempts are opportunistic (they draw on no retry budget —
//!   the sibling already holds the fort), and the batch fails loudly the
//!   moment a whole group is dead with slices unserved (its declared
//!   redundancy is exhausted; silently shifting its load across groups
//!   would mask the outage). Only the unreplicated topology re-fans
//!   across workers (`refanned`) with counted, capped-backoff reconnects
//!   — the last resort, reached when there is no sibling to fail over to.
//! * **Hedged reads** — an idle member whose group queue is dry duplicates
//!   the group's oldest straggling sub-slice (in flight elsewhere longer
//!   than `hedge_timeout`) onto its own connection (`hedges`); the first
//!   reply is merged, the loser is dropped by the completion bookkeeping.
//! * **Verified reads** — opt-in (`verify_reads` fraction): a sampled,
//!   deterministically chosen subset of sub-slices is executed by **two
//!   distinct** replicas and the partials compared byte-for-byte.
//!   Deterministic slices make equality exact, so any divergence is
//!   corruption or a bug — the batch hard-fails naming the slice
//!   (`verify_mismatches`). If a group loses its redundancy mid-batch,
//!   affected slices degrade to ordinary unverified reads instead of
//!   deadlocking.
//!
//! The merge stays exact under every re-assignment — failover, hedge
//! duplicate, verify duplicate, or re-fan: sub-slices tile the
//! first-level range, every match roots at exactly one first-level vertex,
//! and per-key sums commute — so which replica serves a sub-slice is
//! irrelevant as long as each one is merged exactly once, which the
//! per-slice `done` flag and the completion count (`remaining`) enforce.
//! Partial answers are never merged into results: a missing sub-slice
//! fails the batch loudly.

use super::proto::{self, ExecRequest, ExecResponse, Msg, UpdateRequest};
use crate::graph::{DataGraph, GraphFingerprint};
use crate::obs::{Counter, Registry, SpanRecord};
use crate::pattern::canon::CanonKey;
use crate::pattern::Pattern;
use crate::util::rng::splitmix64;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{ErrorKind, Read};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Fabric tuning: connection deadlines, liveness probing, retry budget,
/// and sub-slice dealing. The defaults suit LAN pools; tests and the CLI
/// (`--connect-timeout`, `--shard-timeout`, `--probe-interval`) override.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Deadline for TCP connect + handshake reply, per attempt. A worker
    /// that accepts the connection but never answers the handshake
    /// (wedged, SIGSTOPped, black-holed) fails the attempt loudly.
    pub connect_timeout: Duration,
    /// Declare a connection wedged when it produces no traffic (replies
    /// *or* pongs) for this long while requests are in flight. This is a
    /// soft per-request deadline: a live worker deep in a heavy slice
    /// keeps answering probes and is left alone.
    pub shard_timeout: Duration,
    /// How often to send a liveness probe while waiting for replies.
    pub probe_interval: Duration,
    /// Reconnect attempts per worker failure; also bounds how many times
    /// a flaky worker may fail per batch before it is dropped for good.
    pub max_retries: u32,
    /// First reconnect backoff; doubles per attempt up to `retry_cap`,
    /// then jittered by ×[0.5, 1.5).
    pub retry_base: Duration,
    /// Backoff ceiling.
    pub retry_cap: Duration,
    /// Degree-weighted sub-slices dealt per connected worker (a group's
    /// queue holds `members × this` sub-slices, minus empties).
    pub sub_slices_per_worker: usize,
    /// Requests kept in flight per worker connection, so the worker can
    /// start the next sub-slice while a reply is on the wire.
    pub pipeline: usize,
    /// How long a sub-slice may sit in flight on one replica before an
    /// idle sibling hedges it — sends a duplicate request and lets the
    /// first reply win. Only replicated groups hedge; set it high to
    /// effectively disable hedging.
    pub hedge_timeout: Duration,
    /// Fraction of sub-slices (0.0–1.0) dispatched to **two** distinct
    /// replicas and compared byte-for-byte; any disagreement hard-fails
    /// the batch. Deterministic slices make the comparison exact, so this
    /// is a built-in corruption/heisenbug detector. Requires a replicated
    /// topology; 0.0 (the default) disables it.
    pub verify_reads: f64,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig {
            connect_timeout: Duration::from_secs(30),
            shard_timeout: Duration::from_secs(30),
            probe_interval: Duration::from_secs(2),
            max_retries: 2,
            retry_base: Duration::from_millis(100),
            retry_cap: Duration::from_secs(2),
            sub_slices_per_worker: 4,
            pipeline: 2,
            hedge_timeout: Duration::from_secs(5),
            verify_reads: 0.0,
        }
    }
}

/// Point-in-time view of the coordinator-side fabric counters, rendered
/// from the live [`crate::obs`] atomics a pool owns (see [`PoolCounters`])
/// — the struct is the *view*, the atomics are the one implementation.
/// Per-batch deltas are still accumulated as a plain struct under the
/// batch mutex and absorbed into the atomics once per batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardMetrics {
    /// Exec requests sent (one per dealt sub-slice, retries included).
    pub requests: u64,
    /// Base patterns fanned out, summed over requests.
    pub bases_sent: u64,
    /// Per-sub-slice partial values merged into totals.
    pub partials_merged: u64,
    /// Bases workers reported serving from their local stores instead of
    /// matching (shard-level cache reuse, summed over requests).
    pub remote_cached: u64,
    /// Batches failed because sub-slices remained with no live worker.
    pub errors: u64,
    /// Worker failures observed mid-batch (disconnect, wedge, error
    /// reply, malformed reply) — each one triggers failover (replicated
    /// groups) or retry + re-fan (unreplicated topologies).
    pub worker_failures: u64,
    /// Budgeted reconnect attempts made after worker failures. A failover
    /// absorbed by a live sibling does **not** count here: the dead
    /// member's reconnects are then opportunistic, outside any budget.
    pub retries: u64,
    /// Sub-slices re-queued from a failed worker for the survivors
    /// (unreplicated topologies only — the last resort).
    pub refanned: u64,
    /// Liveness probes sent while replies were outstanding.
    pub probes: u64,
    /// Sub-slices handed from a failed replica to a live sibling in its
    /// group — the failover path that replaces re-fan in replicated
    /// topologies.
    pub failovers: u64,
    /// Duplicate requests sent for straggling sub-slices to an idle
    /// sibling replica (first reply wins).
    pub hedges: u64,
    /// Verified reads whose two replicas disagreed. Each one is a hard
    /// batch failure — deterministic slices mean a disagreement is
    /// corruption or a bug, never noise.
    pub verify_mismatches: u64,
}

/// The pool's live counters: one `Arc`ed atomic per [`ShardMetrics`]
/// field. Registered under `mm_shard_*` in the process registry so a
/// `--metrics` scrape and [`ShardPool::metrics`] read the very same
/// atomics.
#[derive(Default)]
struct PoolCounters {
    requests: Arc<Counter>,
    bases_sent: Arc<Counter>,
    partials_merged: Arc<Counter>,
    remote_cached: Arc<Counter>,
    errors: Arc<Counter>,
    worker_failures: Arc<Counter>,
    retries: Arc<Counter>,
    refanned: Arc<Counter>,
    probes: Arc<Counter>,
    failovers: Arc<Counter>,
    hedges: Arc<Counter>,
    verify_mismatches: Arc<Counter>,
}

impl PoolCounters {
    fn register(&self, reg: &Registry) {
        reg.register_counter("mm_shard_requests_total", self.requests.clone());
        reg.register_counter("mm_shard_bases_sent_total", self.bases_sent.clone());
        reg.register_counter("mm_shard_partials_merged_total", self.partials_merged.clone());
        reg.register_counter("mm_shard_remote_cached_total", self.remote_cached.clone());
        reg.register_counter("mm_shard_errors_total", self.errors.clone());
        reg.register_counter("mm_shard_worker_failures_total", self.worker_failures.clone());
        reg.register_counter("mm_shard_retries_total", self.retries.clone());
        reg.register_counter("mm_shard_refanned_total", self.refanned.clone());
        reg.register_counter("mm_shard_probes_total", self.probes.clone());
        reg.register_counter("mm_shard_failovers_total", self.failovers.clone());
        reg.register_counter("mm_shard_hedges_total", self.hedges.clone());
        reg.register_counter("mm_shard_verify_mismatches_total", self.verify_mismatches.clone());
    }

    fn absorb(&self, d: &ShardMetrics) {
        self.requests.add(d.requests);
        self.bases_sent.add(d.bases_sent);
        self.partials_merged.add(d.partials_merged);
        self.remote_cached.add(d.remote_cached);
        self.errors.add(d.errors);
        self.worker_failures.add(d.worker_failures);
        self.retries.add(d.retries);
        self.refanned.add(d.refanned);
        self.probes.add(d.probes);
        self.failovers.add(d.failovers);
        self.hedges.add(d.hedges);
        self.verify_mismatches.add(d.verify_mismatches);
    }

    fn render(&self) -> ShardMetrics {
        ShardMetrics {
            requests: self.requests.get(),
            bases_sent: self.bases_sent.get(),
            partials_merged: self.partials_merged.get(),
            remote_cached: self.remote_cached.get(),
            errors: self.errors.get(),
            worker_failures: self.worker_failures.get(),
            retries: self.retries.get(),
            refanned: self.refanned.get(),
            probes: self.probes.get(),
            failovers: self.failovers.get(),
            hedges: self.hedges.get(),
            verify_mismatches: self.verify_mismatches.get(),
        }
    }
}

/// What one [`ShardPool::broadcast_update`] achieved across the pool:
/// how many members applied the mutation, how many were dropped for
/// refusing (or dying mid-update), and the summed per-slice store
/// bookkeeping the applying workers reported.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct UpdateOutcome {
    /// Members that applied the mutation and landed on the new
    /// fingerprint.
    pub updated: usize,
    /// Members dropped from the pool: refused the update, answered with
    /// the wrong fingerprint, or died mid-broadcast. Their seats remain
    /// (reconnects handshake against the *new* fingerprint), but until a
    /// restarted worker holds the mutated graph they stay dead.
    pub failed: usize,
    /// Per-slice store entries carried warm across the epoch, summed over
    /// the applying workers.
    pub carried: u64,
    /// Per-slice store entries purged to recompute-on-demand, summed over
    /// the applying workers.
    pub purged: u64,
}

/// One connected shard worker: the framed stream plus an incremental
/// receive buffer (a probe-interval read timeout can fire mid-frame, and
/// `read_exact` would lose the partial bytes — the buffer keeps them).
pub struct ShardClient {
    addr: String,
    stream: TcpStream,
    threads: u32,
    recv: Vec<u8>,
    /// Nonce of the last liveness probe sent.
    next_nonce: u64,
    /// Nonce watermark at the last Exec send: pongs with a nonce above
    /// this were probed *after* the newest request, so the worker has
    /// necessarily read every request we still await (TCP ordering) and
    /// its in-flight count is trustworthy.
    exec_nonce_mark: u64,
}

impl ShardClient {
    /// Connect and handshake with the default 30s deadline: the worker
    /// must speak this protocol version and hold a graph with exactly
    /// `fingerprint` — anything else is a hard reject on its side, which
    /// surfaces here as a connection error. The connection identifies
    /// itself as the sole member of a single-group topology; pools pass
    /// their real topology coordinates via
    /// [`ShardClient::connect_deadline`].
    pub fn connect(addr: &str, fingerprint: GraphFingerprint) -> Result<ShardClient> {
        Self::connect_deadline(
            addr,
            fingerprint,
            PoolConfig::default().connect_timeout,
            (0, 1, 0),
        )
    }

    /// [`ShardClient::connect`] with an explicit deadline covering both
    /// the TCP connect and the handshake reply (so a worker that accepts
    /// the socket but never answers fails the attempt instead of hanging
    /// it), and the connection's topology identity `(group, total groups,
    /// replica within group)` — carried in the `Hello` so the worker can
    /// pre-warm its group's persisted slices and log which seat it holds.
    pub fn connect_deadline(
        addr: &str,
        fingerprint: GraphFingerprint,
        timeout: Duration,
        identity: (u32, u32, u32),
    ) -> Result<ShardClient> {
        let timeout = timeout.max(Duration::from_millis(1));
        let mut last_err: Option<std::io::Error> = None;
        let mut connected: Option<TcpStream> = None;
        for sa in addr
            .to_socket_addrs()
            .with_context(|| format!("resolving shard worker address {addr}"))?
        {
            match TcpStream::connect_timeout(&sa, timeout) {
                Ok(s) => {
                    connected = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let mut stream = connected.ok_or_else(|| match last_err {
            Some(e) => anyhow!(e).context(format!("connecting to shard worker {addr}")),
            None => anyhow!("shard worker address {addr} resolved to nothing"),
        })?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(timeout))
            .context("setting handshake deadline")?;
        proto::write_msg(
            &mut stream,
            &Msg::Hello {
                version: proto::VERSION,
                fingerprint,
                group: identity.0,
                groups: identity.1,
                replica: identity.2,
            },
        )
        .with_context(|| format!("greeting shard worker {addr}"))?;
        let reply = proto::read_msg(&mut stream)
            .with_context(|| format!("reading handshake reply from {addr}"))?;
        match reply {
            Msg::Welcome { fingerprint: fp, threads } => {
                ensure!(
                    fp == fingerprint,
                    "shard worker {addr} answered with fingerprint {fp}, expected {fingerprint}"
                );
                Ok(ShardClient {
                    addr: addr.to_string(),
                    stream,
                    threads,
                    recv: Vec::new(),
                    next_nonce: 0,
                    exec_nonce_mark: 0,
                })
            }
            Msg::Reject { reason } => bail!("shard worker {addr} rejected handshake: {reason}"),
            other => bail!("shard worker {addr} sent unexpected handshake reply {other:?}"),
        }
    }

    /// The worker's address, as given to [`ShardClient::connect`].
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Matcher threads the worker reported at handshake.
    pub fn threads(&self) -> u32 {
        self.threads
    }

    fn send(&mut self, msg: &Msg) -> Result<()> {
        if matches!(msg, Msg::Exec(_)) {
            self.exec_nonce_mark = self.next_nonce;
        }
        proto::write_msg(&mut self.stream, msg)
            .with_context(|| format!("sending to shard worker {}", self.addr))
    }

    /// Pop one complete frame off the receive buffer, if any. Framing
    /// violations (oversized length, CRC mismatch, unreadable body) are
    /// errors — the connection is done.
    fn pop_frame(&mut self) -> Result<Option<Msg>> {
        use crate::service::persist::frame::{self, FRAME_HEADER};
        if self.recv.len() < FRAME_HEADER {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.recv[..4].try_into().expect("4 bytes")) as usize;
        let crc = u32::from_le_bytes(self.recv[4..FRAME_HEADER].try_into().expect("4 bytes"));
        ensure!(
            len <= proto::MAX_MSG_LEN,
            "shard worker {} sent a {len}-byte frame (cap {})",
            self.addr,
            proto::MAX_MSG_LEN
        );
        if self.recv.len() < FRAME_HEADER + len {
            return Ok(None);
        }
        let payload = &self.recv[FRAME_HEADER..FRAME_HEADER + len];
        ensure!(
            frame::crc32(payload) == crc,
            "shard worker {} sent a corrupt frame (CRC mismatch)",
            self.addr
        );
        let msg = proto::decode(payload)
            .ok_or_else(|| anyhow!("shard worker {} sent an unreadable message", self.addr))?;
        self.recv.drain(..FRAME_HEADER + len);
        Ok(Some(msg))
    }

    /// Wait for the next substantive reply (Result/Error), probing the
    /// worker with pings every `probe_interval` and failing after
    /// `shard_timeout` of total silence. Pongs are consumed here: they
    /// count as liveness, and a trustworthy pong reporting zero in-flight
    /// requests while we wait means the requests were lost.
    fn recv_reply(
        &mut self,
        probe_interval: Duration,
        shard_timeout: Duration,
        probes: &mut u64,
    ) -> Result<Msg> {
        self.stream
            .set_read_timeout(Some(probe_interval.max(Duration::from_millis(1))))
            .context("setting probe interval")?;
        let mut last_traffic = Instant::now();
        let mut chunk = [0u8; 16 << 10];
        loop {
            match self.pop_frame()? {
                Some(Msg::Pong { nonce, inflight }) => {
                    last_traffic = Instant::now();
                    if inflight == 0 && nonce > self.exec_nonce_mark {
                        // the probe was sent after our newest request, so
                        // the worker read every request we await before
                        // answering it — zero in-flight means they were
                        // dropped without a reply
                        bail!(
                            "shard worker {} answered a probe but reports no in-flight \
                             work — requests were lost",
                            self.addr
                        );
                    }
                    continue;
                }
                Some(msg) => return Ok(msg),
                None => {}
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => bail!("shard worker {} closed the connection", self.addr),
                Ok(n) => {
                    self.recv.extend_from_slice(&chunk[..n]);
                    last_traffic = Instant::now();
                }
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    if last_traffic.elapsed() >= shard_timeout {
                        bail!(
                            "shard worker {} wedged: no traffic for {:.1?} \
                             (deadline {:.1?})",
                            self.addr,
                            last_traffic.elapsed(),
                            shard_timeout
                        );
                    }
                    self.next_nonce += 1;
                    *probes += 1;
                    let ping = Msg::Ping { nonce: self.next_nonce };
                    self.send(&ping)
                        .with_context(|| format!("probing shard worker {}", self.addr))?;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("reading from shard worker {}", self.addr))
                }
            }
        }
    }
}

/// One pool seat: the address and topology coordinates are permanent, the
/// connection comes and goes with failures and reconnects.
struct WorkerSlot {
    addr: String,
    /// Group index in the topology (0-based), sent in the handshake.
    group: u32,
    /// Total groups in the topology, sent in the handshake.
    groups_total: u32,
    /// Replica index within the group (0-based), sent in the handshake.
    replica: u32,
    /// Work queue this member serves: its group's queue in a replicated
    /// topology, the single shared queue (0) otherwise.
    queue: usize,
    client: Option<ShardClient>,
}

impl WorkerSlot {
    fn reconnect(&self, cfg: &PoolConfig, fingerprint: GraphFingerprint) -> Result<ShardClient> {
        ShardClient::connect_deadline(
            &self.addr,
            fingerprint,
            cfg.connect_timeout,
            (self.group, self.groups_total, self.replica),
        )
    }
}

/// Trace context armed for one batch (see [`ShardPool::set_trace`]):
/// the wire context every EXEC carries down, plus the id range and time
/// base the batch's coordinator-side spans are built against.
#[derive(Clone, Copy, Debug)]
struct TraceCtx {
    trace_id: u64,
    parent_span: u64,
    id_base: u64,
    epoch: Instant,
}

/// What one replica answered for a verified read, parked until a sibling
/// answers the duplicate and the two can be compared.
struct PendingRead {
    slot: usize,
    addr: String,
    served: u32,
    values: Vec<(CanonKey, i128)>,
}

/// Per-sub-slice batch bookkeeping. A slice may be dealt more than once —
/// failover re-deal, hedge duplicate, verify duplicate — but `done`
/// guarantees it merges exactly once.
struct SliceEntry {
    lo: u32,
    hi: u32,
    /// Queue (= group, in replicated topologies) that owns this slice.
    queue: usize,
    /// Verified read: needs replies from two distinct members.
    verify: bool,
    done: bool,
    /// Members currently running this slice, with dispatch times (the
    /// hedging clock).
    inflight: Vec<(usize, Instant)>,
    /// Members that have taken (or completed) a copy — a verify duplicate
    /// must go to a member *not* listed here.
    assigned: Vec<usize>,
    /// First reply of a verified read, awaiting the sibling's.
    pending: Option<PendingRead>,
}

/// Shared state of one in-flight batch: per-group work queues, per-slice
/// bookkeeping, the completion count, and the partial sums.
struct WorkState {
    /// One queue per group (replicated) or a single shared queue
    /// (unreplicated). Queues hold indices into `slices`; a verified
    /// slice is enqueued twice.
    queues: Vec<VecDeque<usize>>,
    slices: Vec<SliceEntry>,
    /// Live member count per queue — failover needs to know whether a
    /// sibling can absorb a dead member's slices.
    live: Vec<usize>,
    /// Members per queue currently inside a reconnect loop; group death
    /// is declared only when `live` and `retrying` are both zero, so a
    /// member racing back from a transient blip isn't written off.
    retrying: Vec<usize>,
    /// Sub-slices not yet merged. The batch is complete exactly when this
    /// hits zero — each sub-slice is merged once, no matter how many
    /// times it was re-dealt.
    remaining: usize,
    sums: HashMap<CanonKey, i128>,
    delta: ShardMetrics,
    failures: Vec<String>,
    /// Unrecoverable batch failure (dead group, verify mismatch): every
    /// member thread drains out as soon as it observes this.
    fatal: Option<String>,
    /// Coordinator-side spans of the batch's distributed trace: one per
    /// served sub-slice copy (the worker's phase spans grafted beneath),
    /// plus failover / re-fan / retry event spans. Appended under the
    /// batch mutex; drained by [`ShardPool::take_spans`].
    trace_spans: Vec<SpanRecord>,
    /// Next span id, allocated upward from the embedder's reserved base.
    trace_next: u64,
    /// Parent span id every top-level pool span hangs under.
    trace_parent: u64,
    /// The trace's birth instant — all span clocks are relative to it.
    trace_epoch: Instant,
}

struct Batch {
    work: Mutex<WorkState>,
    /// Signalled on completion, on failover/re-fan, and on fatal errors,
    /// so an idle member reacts promptly.
    changed: Condvar,
}

/// A set of connected shard workers sharing one graph identity, organised
/// into replica groups: each group owns a contiguous cut of the
/// first-level range and deals its degree-weighted sub-slices from a
/// group queue with failover, hedging, and optional verified reads. The
/// unreplicated topology (all groups singleton) shares one queue with PR
/// 6's retry + re-fan semantics.
pub struct ShardPool {
    workers: Vec<WorkerSlot>,
    fingerprint: GraphFingerprint,
    /// All sub-slices in vertex order (concatenation of the group cuts).
    sub_slices: Vec<(u32, u32)>,
    /// Owning queue per sub-slice, parallel to `sub_slices`.
    slice_queue: Vec<usize>,
    /// Member count per queue.
    queue_members: Vec<usize>,
    num_queues: usize,
    num_groups: usize,
    replicated: bool,
    config: PoolConfig,
    next_id: u64,
    counters: PoolCounters,
    /// Trace context armed for the next batch (consumed by
    /// [`ShardPool::execute_bases`]).
    trace_ctx: Option<TraceCtx>,
    /// Spans collected by the most recent batch, drained by
    /// [`ShardPool::take_spans`].
    last_spans: Vec<SpanRecord>,
}

impl ShardPool {
    /// Connect to every address as a singleton group (the unreplicated
    /// topology) with default [`PoolConfig`], handshaking each against
    /// `graph`'s fingerprint.
    pub fn connect(addrs: &[String], graph: &DataGraph) -> Result<ShardPool> {
        let groups: Vec<Vec<String>> = addrs.iter().map(|a| vec![a.clone()]).collect();
        Self::connect_with(&groups, graph, PoolConfig::default())
    }

    /// Connect to every member of every replica group, handshaking each
    /// against `graph`'s fingerprint. Every unusable worker — unreachable,
    /// wedged, wrong graph, wrong protocol — is collected and reported in
    /// **one** error, so an operator fixes the whole pool in one pass
    /// instead of replaying connect once per broken address. A partial
    /// pool is still refused: batches tolerate workers dying, but a pool
    /// that *starts* degraded usually means a typo'd address list.
    pub fn connect_with(
        groups: &[Vec<String>],
        graph: &DataGraph,
        config: PoolConfig,
    ) -> Result<ShardPool> {
        ensure!(
            !groups.is_empty() && groups.iter().all(|g| !g.is_empty()),
            "a shard pool needs at least one worker address"
        );
        ensure!(
            config.verify_reads.is_finite() && (0.0..=1.0).contains(&config.verify_reads),
            "verify_reads must be a fraction in [0, 1], got {}",
            config.verify_reads
        );
        let replicated = groups.iter().any(|g| g.len() > 1);
        ensure!(
            config.verify_reads == 0.0 || replicated,
            "verified reads need a replicated topology (a group with two \
             replicas, e.g. `a1|a2`): there is no second replica to compare \
             against"
        );
        let fingerprint = graph.fingerprint();
        let total: usize = groups.iter().map(|g| g.len()).sum();
        let mut workers = Vec::with_capacity(total);
        let mut unusable: Vec<String> = Vec::new();
        for (g, members) in groups.iter().enumerate() {
            for (r, addr) in members.iter().enumerate() {
                let mut slot = WorkerSlot {
                    addr: addr.clone(),
                    group: g as u32,
                    groups_total: groups.len() as u32,
                    replica: r as u32,
                    queue: if replicated { g } else { 0 },
                    client: None,
                };
                match slot.reconnect(&config, fingerprint) {
                    Ok(c) => {
                        slot.client = Some(c);
                        workers.push(slot);
                    }
                    Err(e) => unusable.push(format!("{addr}: {e:#}")),
                }
            }
        }
        if !unusable.is_empty() {
            bail!(
                "{} of {} shard workers unusable:\n  {}",
                unusable.len(),
                total,
                unusable.join("\n  ")
            );
        }
        let weights: Vec<u64> = (0..graph.num_vertices() as u32)
            .map(|v| graph.degree(v) as u64 + 1)
            .collect();
        let per = config.sub_slices_per_worker.max(1);
        let mut sub_slices = Vec::new();
        let mut slice_queue = Vec::new();
        let (num_queues, queue_members);
        if replicated {
            // each group owns a contiguous weight-quantile cut of the
            // range, sub-sliced for dealing among its members; the cut is
            // index-stable (weighted_cuts) so `--slice g/G` pinned workers
            // agree on the boundaries
            num_queues = groups.len();
            queue_members = groups.iter().map(|g| g.len()).collect::<Vec<_>>();
            let cuts = super::weighted_cuts(&weights, groups.len());
            for (g, &(glo, ghi)) in cuts.iter().enumerate() {
                if glo >= ghi {
                    continue;
                }
                let within =
                    super::weighted_ranges(&weights[glo as usize..ghi as usize], groups[g].len() * per);
                for (lo, hi) in within {
                    sub_slices.push((glo + lo, glo + hi));
                    slice_queue.push(g);
                }
            }
        } else {
            // the unreplicated topology: one shared queue over the whole
            // range — PR 6's layout, unchanged
            num_queues = 1;
            queue_members = vec![workers.len()];
            sub_slices = super::weighted_ranges(&weights, workers.len() * per);
            slice_queue = vec![0; sub_slices.len()];
        }
        let counters = PoolCounters::default();
        counters.register(crate::obs::global());
        Ok(ShardPool {
            workers,
            fingerprint,
            sub_slices,
            slice_queue,
            queue_members,
            num_queues,
            num_groups: groups.len(),
            replicated,
            config,
            next_id: 0,
            counters,
            trace_ctx: None,
            last_spans: Vec::new(),
        })
    }

    /// Number of pool seats (connected workers at start; a seat whose
    /// worker died stays counted — the address is still part of the pool).
    /// Replicas count individually.
    pub fn num_shards(&self) -> usize {
        self.workers.len()
    }

    /// Number of replica groups in the topology.
    pub fn num_groups(&self) -> usize {
        self.num_groups
    }

    /// Whether any group has more than one replica (group-ownership
    /// semantics: failover before re-fan, loud death of a whole group).
    pub fn replicated(&self) -> bool {
        self.replicated
    }

    /// The degree-weighted sub-slices dealt per batch, in vertex order.
    /// Deterministic for a given graph and pool size — sub-slice identity
    /// keys worker-side stores and durable state.
    pub fn sub_slices(&self) -> &[(u32, u32)] {
        &self.sub_slices
    }

    /// Number of dealt sub-slices (≤ workers × `sub_slices_per_worker`).
    pub fn num_sub_slices(&self) -> usize {
        self.sub_slices.len()
    }

    /// Coordinator-side fabric counters (a point-in-time render of the
    /// pool's live `mm_shard_*` atomics).
    pub fn metrics(&self) -> ShardMetrics {
        self.counters.render()
    }

    /// Ask every connected worker for a snapshot of its metric registry
    /// (proto v4 `STATS`, answered inline from the worker's read loop) and
    /// return `(address, flat series)` per worker that answered. Workers
    /// that fail to answer are skipped — a stats sweep is diagnostics,
    /// never a correctness gate. Aggregate the serieses with
    /// [`crate::obs::aggregate`] for the cluster view.
    pub fn collect_stats(&mut self) -> Vec<(String, Vec<(String, u64)>)> {
        let cfg = self.config;
        let mut out = Vec::new();
        let mut probes = 0u64;
        for slot in &mut self.workers {
            let Some(client) = slot.client.as_mut() else {
                continue;
            };
            let id = self.next_id;
            self.next_id += 1;
            if client.send(&Msg::Stats { id }).is_err() {
                continue;
            }
            match client.recv_reply(cfg.probe_interval, cfg.shard_timeout, &mut probes) {
                Ok(Msg::StatsReply { id: rid, series }) if rid == id => {
                    out.push((slot.addr.clone(), series));
                }
                _ => {}
            }
        }
        self.counters.probes.add(probes);
        out
    }

    /// The fabric tuning this pool runs with.
    pub fn config(&self) -> PoolConfig {
        self.config
    }

    /// Broadcast one applied edge mutation to every pool member (proto v6
    /// `UPDATE`): each worker verifies the `old → new` fingerprint
    /// transition against its own copy, mutates it, rebases its per-slice
    /// stores, and acks. A member that refuses (diverged copy), answers
    /// with the wrong fingerprint, or dies mid-broadcast is dropped from
    /// the pool exactly like a mid-batch failure — its seat remains, and
    /// any reconnect now handshakes against the **new** fingerprint, so a
    /// stale restart can never rejoin with pre-update content. The
    /// broadcast fails loudly (never silently serving a partial pool) when
    /// it leaves a replica group — or, unreplicated, the whole pool — with
    /// no live member.
    ///
    /// `u`/`v` are **internal** vertex ids (the coordinator translates
    /// original ids before calling). The pool's own expected fingerprint
    /// advances to `new_fingerprint` whether or not every member applied:
    /// the coordinator's graph has already moved, and the only workers
    /// worth talking to are the ones that moved with it.
    pub fn broadcast_update(
        &mut self,
        insert: bool,
        u: u32,
        v: u32,
        old_fingerprint: GraphFingerprint,
        new_fingerprint: GraphFingerprint,
        new_version: u64,
    ) -> Result<UpdateOutcome> {
        ensure!(
            old_fingerprint == self.fingerprint,
            "update broadcast starts from fingerprint {old_fingerprint}, but the pool \
             expects {} — the coordinator and pool have diverged",
            self.fingerprint
        );
        let cfg = self.config;
        let mut probes = 0u64;
        let mut outcome = UpdateOutcome::default();
        let mut failures: Vec<String> = Vec::new();
        for slot in &mut self.workers {
            let Some(client) = slot.client.as_mut() else {
                outcome.failed += 1;
                failures.push(format!("{}: not connected", slot.addr));
                continue;
            };
            let id = self.next_id;
            self.next_id += 1;
            let req = UpdateRequest {
                id,
                insert,
                u,
                v,
                old_fingerprint,
                new_fingerprint,
                new_version,
            };
            let reply = client
                .send(&Msg::Update(req))
                .and_then(|()| client.recv_reply(cfg.probe_interval, cfg.shard_timeout, &mut probes));
            let reason = match reply {
                Ok(Msg::UpdateAck(ack)) if ack.id == id => {
                    if ack.applied && ack.fingerprint == new_fingerprint {
                        outcome.updated += 1;
                        outcome.carried += ack.carried;
                        outcome.purged += ack.purged;
                        None
                    } else {
                        Some(format!(
                            "update refused: {} (worker now holds {})",
                            ack.error, ack.fingerprint
                        ))
                    }
                }
                Ok(other) => Some(format!("unexpected update reply {other:?}")),
                Err(e) => Some(format!("{e:#}")),
            };
            if let Some(reason) = reason {
                slot.client = None;
                self.counters.worker_failures.inc();
                outcome.failed += 1;
                failures.push(format!("{}: {reason}", slot.addr));
            }
        }
        self.counters.probes.add(probes);
        self.fingerprint = new_fingerprint;
        // a queue with no live member left can never serve its slices:
        // that redundancy (or, unreplicated, the whole pool) is gone, and
        // the next batch would only discover it the slow way
        let mut live = vec![0usize; self.num_queues];
        for s in &self.workers {
            if s.client.is_some() {
                live[s.queue] += 1;
            }
        }
        if let Some(q) = live.iter().position(|&n| n == 0) {
            self.counters.errors.inc();
            let scope = if self.replicated {
                format!("shard group {}", q + 1)
            } else {
                "the pool".to_string()
            };
            bail!(
                "edge update left {scope} with no live member ({} of {} workers \
                 updated); failures:\n  {}",
                outcome.updated,
                self.workers.len(),
                failures.join("\n  ")
            );
        }
        Ok(outcome)
    }

    /// Arm the distributed-trace context for the **next**
    /// [`ShardPool::execute_bases`] call: every EXEC of that batch
    /// carries `(trace_id, parent_span)` down the wire (proto v5), and
    /// the spans the batch collects — one per served sub-slice copy with
    /// the worker's phase spans grafted beneath, plus failover / re-fan /
    /// retry event spans — are parented under `parent_span`. Span ids are
    /// allocated upward from `id_base` (reserve a generous range with
    /// [`crate::obs::TraceBuilder::reserve`] so they never collide with
    /// the embedder's own ids) and clocks are measured from `epoch`, the
    /// trace's birth instant. Tracing is passive: it never changes what a
    /// batch computes, only what it reports.
    pub fn set_trace(&mut self, trace_id: u64, parent_span: u64, id_base: u64, epoch: Instant) {
        self.trace_ctx = Some(TraceCtx {
            trace_id,
            parent_span,
            id_base,
            epoch,
        });
    }

    /// Drain the spans collected by the most recent batch (empty if none
    /// ran since the last drain). Spans survive batch failure on purpose
    /// — the trace of a batch that died is exactly the one worth reading.
    pub fn take_spans(&mut self) -> Vec<SpanRecord> {
        std::mem::take(&mut self.last_spans)
    }

    /// Match the subset of `base` selected by `indices` across the pool
    /// and return **full-graph** map counts per canonical key: sub-slices
    /// are dealt to workers from a shared queue, each worker runs the same
    /// base set over the sub-slices it pulls, and the partials are summed
    /// here — exactly once per sub-slice, whichever worker served it.
    /// `epoch` is the coordinator's cache epoch, echoed through for
    /// bookkeeping.
    pub fn execute_bases(
        &mut self,
        base: &[Pattern],
        indices: &[usize],
        epoch: u64,
    ) -> Result<Vec<(CanonKey, i128)>> {
        if indices.is_empty() {
            return Ok(Vec::new());
        }
        let patterns: Vec<Pattern> = indices.iter().map(|&i| base[i].clone()).collect();
        let keys: Vec<CanonKey> = patterns.iter().map(|p| p.canonical_key()).collect();
        let sums: HashMap<CanonKey, i128> = keys.iter().map(|k| (*k, 0)).collect();
        let distinct = sums.len();
        let fraction = self.config.verify_reads;
        let mut queues: Vec<VecDeque<usize>> = vec![VecDeque::new(); self.num_queues];
        let mut slices = Vec::with_capacity(self.sub_slices.len());
        for (idx, (&(lo, hi), &q)) in
            self.sub_slices.iter().zip(&self.slice_queue).enumerate()
        {
            // verified reads need two distinct replicas, so only groups
            // with siblings sample; the choice is deterministic in
            // (fingerprint, epoch, slice) — re-runs verify the same slices
            let verify = self.replicated
                && self.queue_members[q] >= 2
                && verify_selected(fraction, self.fingerprint, epoch, idx);
            queues[q].push_back(idx);
            if verify {
                queues[q].push_back(idx);
            }
            slices.push(SliceEntry {
                lo,
                hi,
                queue: q,
                verify,
                done: false,
                inflight: Vec::new(),
                assigned: Vec::new(),
                pending: None,
            });
        }
        let remaining = slices.len();
        // tracing is always on: spans are byproducts of instants the
        // fabric reads anyway, and an unarmed batch just gets the
        // untraced wire context (trace_id 0) with ids from 1
        let trace = self.trace_ctx.take().unwrap_or(TraceCtx {
            trace_id: 0,
            parent_span: 0,
            id_base: 1,
            epoch: Instant::now(),
        });
        let batch = Batch {
            work: Mutex::new(WorkState {
                queues,
                slices,
                live: self.queue_members.clone(),
                retrying: vec![0; self.num_queues],
                remaining,
                sums,
                delta: ShardMetrics::default(),
                failures: Vec::new(),
                fatal: None,
                trace_spans: Vec::new(),
                trace_next: trace.id_base,
                trace_parent: trace.parent_span,
                trace_epoch: trace.epoch,
            }),
            changed: Condvar::new(),
        };
        if remaining == 0 {
            // zero-vertex graph: every count is the aggregation identity
        } else {
            let ids = AtomicU64::new(self.next_id);
            let (cfg, fingerprint, replicated) = (self.config, self.fingerprint, self.replicated);
            let hedge_flags: Vec<bool> = self
                .workers
                .iter()
                .map(|s| replicated && self.queue_members[s.queue] > 1)
                .collect();
            std::thread::scope(|sc| {
                for (slot_id, slot) in self.workers.iter_mut().enumerate() {
                    let hedge = hedge_flags[slot_id];
                    let (batch, patterns, ids) = (&batch, &patterns, &ids);
                    sc.spawn(move || {
                        let ctx = MemberCtx {
                            batch,
                            cfg,
                            patterns,
                            distinct,
                            fingerprint,
                            epoch,
                            ids,
                            replicated,
                            hedge,
                            slot_id,
                            trace_id: trace.trace_id,
                            trace_parent: trace.parent_span,
                        };
                        run_member(slot, &ctx)
                    });
                }
            });
            self.next_id = ids.into_inner();
        }
        let state = batch.work.into_inner().expect("batch threads joined");
        self.counters.absorb(&state.delta);
        self.last_spans = state.trace_spans;
        if let Some(fatal) = state.fatal {
            self.counters.errors.inc();
            let detail = if state.failures.is_empty() {
                String::new()
            } else {
                format!("; worker failures:\n  {}", state.failures.join("\n  "))
            };
            bail!("sharded batch failed: {fatal}{detail}");
        }
        if state.remaining > 0 {
            self.counters.errors.inc();
            bail!(
                "sharded batch failed: {} of {} sub-slices unserved and no live worker \
                 remains; worker failures:\n  {}",
                state.remaining,
                self.sub_slices.len(),
                state.failures.join("\n  ")
            );
        }
        let mut out = Vec::with_capacity(distinct);
        let mut emitted = HashSet::new();
        for k in keys {
            if emitted.insert(k) {
                out.push((k, state.sums[&k]));
            }
        }
        Ok(out)
    }
}

/// Deterministic verified-read sampling: a pure function of
/// `(fingerprint, epoch, slice index)`, so the sampled set is stable
/// across members, re-deals, and re-runs — a flaky slice can't dodge
/// verification by being retried.
fn verify_selected(fraction: f64, fingerprint: GraphFingerprint, epoch: u64, idx: usize) -> bool {
    if fraction <= 0.0 {
        return false;
    }
    if fraction >= 1.0 {
        return true;
    }
    let mut seed = fingerprint
        .hash
        .wrapping_add(epoch.rotate_left(17))
        .wrapping_add((idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let unit = (splitmix64(&mut seed) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    unit < fraction
}

/// Everything a member thread needs besides its own slot: the shared
/// batch, the fabric tuning, and the member's place in the topology.
struct MemberCtx<'a> {
    batch: &'a Batch,
    cfg: PoolConfig,
    patterns: &'a [Pattern],
    distinct: usize,
    fingerprint: GraphFingerprint,
    epoch: u64,
    ids: &'a AtomicU64,
    /// Replica-group semantics (slice ownership, failover, loud group
    /// death) vs the unreplicated shared-queue semantics of PR 6.
    replicated: bool,
    /// Whether this member may hedge stragglers (its group has siblings).
    hedge: bool,
    slot_id: usize,
    /// Trace context stamped into every EXEC this batch sends (proto v5).
    trace_id: u64,
    trace_parent: u64,
}

/// Append one coordinator-side span under the batch's trace parent and
/// return its id. Called under the batch mutex.
fn push_span(w: &mut WorkState, name: String, start_us: u64, dur_us: u64, tag: String) -> u64 {
    let id = w.trace_next;
    w.trace_next += 1;
    let parent = w.trace_parent;
    w.trace_spans.push(SpanRecord {
        id,
        parent,
        name,
        start_us,
        dur_us,
        tag,
    });
    id
}

/// Record the span for one served sub-slice copy — coordinator-side wall
/// clock from dispatch to reply, tagged with the serving worker and the
/// race outcome — and graft the worker's own phase spans beneath it
/// (reply-relative parent indices renumbered into the batch's id range,
/// remote clocks shifted by the dispatch offset onto the trace's
/// timeline). Late hedge losers are recorded too, tagged as such: the
/// worker really did spend that time.
fn record_slice_span(
    w: &mut WorkState,
    addr: &str,
    idx: usize,
    sent: Instant,
    el: Duration,
    outcome: &str,
    remote: &[proto::WireSpan],
) {
    let (lo, hi) = (w.slices[idx].lo, w.slices[idx].hi);
    let start_us = sent.saturating_duration_since(w.trace_epoch).as_micros() as u64;
    let slice_span = push_span(
        w,
        format!("slice {lo}-{hi}"),
        start_us,
        el.as_micros() as u64,
        format!("worker={addr} outcome={outcome}"),
    );
    let first = w.trace_next;
    w.trace_next += remote.len() as u64;
    for (i, rs) in remote.iter().enumerate() {
        let rel = rs.rel_parent as usize;
        let parent = if rel < remote.len() && rel != i {
            first + rel as u64
        } else {
            slice_span
        };
        w.trace_spans.push(SpanRecord {
            id: first + i as u64,
            parent,
            name: rs.name.clone(),
            start_us: start_us.saturating_add(rs.start_us),
            dur_us: rs.dur_us,
            tag: rs.tag.clone(),
        });
    }
}

/// One member's batch loop: deal admissible sub-slices into the pipeline
/// (hedging stragglers when idle), await replies (probing for liveness),
/// merge, and on failure fail over / re-fan per the topology's semantics.
/// Returns when the batch is complete, fatally failed, or this member is
/// out of lives.
fn run_member(slot: &mut WorkerSlot, ctx: &MemberCtx<'_>) {
    // deterministic backoff jitter, decorrelated per worker address
    let mut jitter = {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in slot.addr.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    };
    // budgeted failures tolerated before this member is dropped from the
    // batch; failovers absorbed by a sibling don't draw on it
    let mut lives = ctx.cfg.max_retries as i64 + 1;
    let mut inflight: HashMap<u64, usize> = HashMap::new();
    let mut probes = 0u64;
    'outer: loop {
        if slot.client.is_none() {
            break;
        }
        // deal sub-slices into the pipeline
        let mut send_failure: Option<String> = None;
        while inflight.len() < ctx.cfg.pipeline.max(1) {
            let dealt = {
                let mut w = ctx.batch.work.lock().unwrap();
                if w.fatal.is_some() {
                    break 'outer;
                }
                let picked = match pop_slice(&mut w, ctx.batch, ctx.slot_id, slot.queue, ctx.distinct)
                {
                    Some(i) => Some(i),
                    None if ctx.hedge => {
                        try_hedge(&mut w, ctx.slot_id, slot.queue, ctx.cfg.hedge_timeout)
                    }
                    None => None,
                };
                picked.map(|i| {
                    w.delta.requests += 1;
                    w.delta.bases_sent += ctx.distinct as u64;
                    (i, w.slices[i].lo, w.slices[i].hi)
                })
            };
            let Some((idx, lo, hi)) = dealt else { break };
            let id = ctx.ids.fetch_add(1, Ordering::SeqCst);
            inflight.insert(id, idx);
            let req = ExecRequest {
                id,
                epoch: ctx.epoch,
                fingerprint: ctx.fingerprint,
                lo,
                hi,
                trace_id: ctx.trace_id,
                parent_span: ctx.trace_parent,
                patterns: ctx.patterns.to_vec(),
            };
            let client = slot.client.as_mut().expect("checked live above");
            if let Err(e) = client.send(&Msg::Exec(req)) {
                send_failure = Some(format!("{e:#}"));
                break;
            }
        }
        if let Some(reason) = send_failure {
            fail_member(slot, ctx, &mut inflight, &mut lives, &mut jitter, &reason);
            continue;
        }
        if inflight.is_empty() {
            // the queue holds nothing admissible; linger — a failover or
            // re-fan may queue work back, a straggler may become
            // hedge-eligible — until remaining hits zero or the batch dies
            let w = ctx.batch.work.lock().unwrap();
            if w.remaining == 0 || w.fatal.is_some() {
                break;
            }
            let _unused = ctx
                .batch
                .changed
                .wait_timeout(w, Duration::from_millis(25))
                .unwrap();
            continue;
        }
        // await one reply, probing for liveness while we wait
        let outcome = slot
            .client
            .as_mut()
            .expect("checked live above")
            .recv_reply(ctx.cfg.probe_interval, ctx.cfg.shard_timeout, &mut probes);
        let reason = match outcome {
            Ok(Msg::Result(resp)) => merge_reply(ctx, &slot.addr, &mut inflight, &resp),
            Ok(Msg::Error { id: _, message }) => Some(format!("worker error reply: {message}")),
            Ok(other) => Some(format!("unexpected reply {other:?}")),
            Err(e) => Some(format!("{e:#}")),
        };
        if let Some(reason) = reason {
            fail_member(slot, ctx, &mut inflight, &mut lives, &mut jitter, &reason);
        }
    }
    ctx.batch.work.lock().unwrap().delta.probes += probes;
}

/// Pop the next sub-slice member `m` may run from queue `q`. Entries the
/// member already took a copy of are rotated to the back — a verified
/// read needs two *distinct* replicas — and stale copies of merged slices
/// are dropped. If the member is its group's last live replica and meets
/// a duplicate it can't serve, the verified read degrades to an ordinary
/// one (finishing from the parked first reply when present) rather than
/// deadlocking the batch.
fn pop_slice(
    w: &mut WorkState,
    batch: &Batch,
    m: usize,
    q: usize,
    distinct: usize,
) -> Option<usize> {
    for _ in 0..w.queues[q].len() {
        let idx = w.queues[q].pop_front()?;
        if w.slices[idx].done {
            continue; // stale copy of an already-merged slice
        }
        if w.slices[idx].assigned.contains(&m) {
            if w.live[q] <= 1 {
                // redundancy is gone: a distinct second read can never
                // happen, so the verified read degrades to an unverified
                // (still exact) one
                if let Some(p) = w.slices[idx].pending.take() {
                    let PendingRead { served, values, .. } = p;
                    finish_slice(w, batch, idx, served, &values, distinct);
                }
                // with no parked reply, our own in-flight copy finishes
                // the slice unverified when it lands (see merge_reply)
                continue;
            }
            w.queues[q].push_back(idx); // a sibling must take this copy
            continue;
        }
        w.slices[idx].assigned.push(m);
        w.slices[idx].inflight.push((m, Instant::now()));
        return Some(idx);
    }
    None
}

/// Find the group's oldest straggling sub-slice — in flight on exactly
/// one sibling for longer than `hedge_timeout` — and duplicate it onto
/// member `m`. First reply wins; the loser is dropped by the `done`
/// check. Called only when the member is otherwise idle, so hedging never
/// competes with fresh work.
fn try_hedge(w: &mut WorkState, m: usize, q: usize, hedge_timeout: Duration) -> Option<usize> {
    let now = Instant::now();
    let mut pick: Option<(usize, Instant)> = None;
    for (idx, e) in w.slices.iter().enumerate() {
        if e.queue != q || e.done || e.inflight.len() != 1 || e.assigned.contains(&m) {
            continue;
        }
        let (holder, sent) = e.inflight[0];
        if holder == m || now.duration_since(sent) < hedge_timeout {
            continue;
        }
        let older = match pick {
            None => true,
            Some((_, t)) => sent < t,
        };
        if older {
            pick = Some((idx, sent));
        }
    }
    let (idx, _) = pick?;
    w.slices[idx].assigned.push(m);
    w.slices[idx].inflight.push((m, now));
    w.delta.hedges += 1;
    Some(idx)
}

/// Merge one sub-slice's partials into the batch sums and retire it.
fn finish_slice(
    w: &mut WorkState,
    batch: &Batch,
    idx: usize,
    served: u32,
    values: &[(CanonKey, i128)],
    distinct: usize,
) {
    for (k, v) in values {
        *w.sums.get_mut(k).expect("validated against requested keys") += *v;
    }
    w.delta.partials_merged += distinct as u64;
    w.delta.remote_cached += served as u64;
    w.slices[idx].done = true;
    w.remaining -= 1;
    if w.remaining == 0 {
        batch.changed.notify_all();
    }
}

/// Validate and dispose of one reply: merge it, park it as the first half
/// of a verified read, compare it against the parked half (hard-failing
/// the batch on mismatch), or drop it as the late loser of a hedge.
/// Returns a failure reason if the reply is malformed (wrong id, wrong
/// cardinality, duplicate or unrequested keys) — nothing is merged in
/// that case, so the sub-slice can be re-dealt without double counting.
fn merge_reply(
    ctx: &MemberCtx<'_>,
    addr: &str,
    inflight: &mut HashMap<u64, usize>,
    resp: &ExecResponse,
) -> Option<String> {
    let Some(&idx) = inflight.get(&resp.id) else {
        return Some(format!("reply for unknown request id {}", resp.id));
    };
    let m = ctx.slot_id;
    let mut w = ctx.batch.work.lock().unwrap();
    // Service time from dispatch to reply, even for late hedge losers —
    // the worker really did spend that long. Labels stay bounded: one
    // series per worker address, one per fixed sub-slice boundary.
    let dispatched = w.slices[idx]
        .inflight
        .iter()
        .find(|&&(s, _)| s == m)
        .map(|&(_, sent)| sent);
    if let Some(sent) = dispatched {
        let el = sent.elapsed();
        let (lo, hi) = (w.slices[idx].lo, w.slices[idx].hi);
        let reg = crate::obs::global();
        reg.histogram(&format!("mm_shard_worker_service_us{{worker=\"{addr}\"}}"))
            .record_duration(el);
        reg.histogram(&format!("mm_shard_slice_service_us{{slice=\"{lo}-{hi}\"}}"))
            .record_duration(el);
    }
    if w.slices[idx].done {
        // the late loser of a hedge or a degraded verify: the slice is
        // already merged exactly once — drop the duplicate
        inflight.remove(&resp.id);
        w.slices[idx].inflight.retain(|&(s, _)| s != m);
        if let Some(sent) = dispatched {
            record_slice_span(&mut w, addr, idx, sent, sent.elapsed(), "hedge-loser", &resp.spans);
        }
        return None;
    }
    let mut seen: HashSet<CanonKey> = HashSet::with_capacity(resp.values.len());
    let well_formed = resp.values.len() == ctx.distinct
        && resp
            .values
            .iter()
            .all(|(k, _)| seen.insert(*k) && w.sums.contains_key(k));
    if !well_formed {
        return Some(format!(
            "malformed reply for request {}: {} values for {} requested bases",
            resp.id,
            resp.values.len(),
            ctx.distinct
        ));
    }
    inflight.remove(&resp.id);
    w.slices[idx].inflight.retain(|&(s, _)| s != m);
    if let Some(sent) = dispatched {
        // a duplicate still running a non-verify slice means we just won
        // a hedge race; verify duplicates are expected pairs, not races
        let outcome = if !w.slices[idx].verify && !w.slices[idx].inflight.is_empty() {
            "hedge-winner"
        } else {
            "ok"
        };
        record_slice_span(&mut w, addr, idx, sent, sent.elapsed(), outcome, &resp.spans);
    }
    if !w.slices[idx].verify {
        finish_slice(&mut w, ctx.batch, idx, resp.served_from_store, &resp.values, ctx.distinct);
        return None;
    }
    match w.slices[idx].pending.take() {
        Some(p) if p.slot != m => {
            if p.values == resp.values {
                let PendingRead { served, values, .. } = p;
                finish_slice(&mut w, ctx.batch, idx, served, &values, ctx.distinct);
            } else {
                // deterministic slices: two honest replicas are
                // byte-identical, so this is corruption or a bug — refuse
                // the whole batch, loudly, naming the slice
                w.delta.verify_mismatches += 1;
                let (lo, hi) = (w.slices[idx].lo, w.slices[idx].hi);
                w.fatal = Some(format!(
                    "verified read mismatch on sub-slice [{lo}, {hi}): replica {} and \
                     replica {addr} returned different partials for the same \
                     deterministic slice — corruption or a bug, refusing the batch",
                    p.addr
                ));
                ctx.batch.changed.notify_all();
            }
        }
        Some(p) => {
            // the same member answered twice (a reconnect re-deal): one
            // process re-reading itself proves nothing — keep the parked
            // reply and wait for a sibling's
            w.slices[idx].pending = Some(p);
        }
        None => {
            if w.live[w.slices[idx].queue] >= 2 {
                w.slices[idx].pending = Some(PendingRead {
                    slot: m,
                    addr: addr.to_string(),
                    served: resp.served_from_store,
                    values: resp.values.clone(),
                });
            } else {
                // the group lost its redundancy mid-batch: a second,
                // distinct replica can never answer — degrade to an
                // unverified (still exact) read rather than deadlock
                finish_slice(&mut w, ctx.batch, idx, resp.served_from_store, &resp.values, ctx.distinct);
            }
        }
    }
    None
}

/// Handle one member failure per the topology's semantics. Replicated
/// group with a live sibling: hand the lost sub-slices over (`failovers`)
/// and reconnect opportunistically — no retry budget spent, no `retries`
/// counted (the satellite accounting fix: a failover absorbed by a
/// sibling is not a retry against the dead member). Last live member of a
/// replicated group: budgeted, counted reconnects; if none succeeds and
/// no sibling is concurrently retrying its way back, the group is dead
/// and the batch fails loudly. Unreplicated topology: PR 6 unchanged —
/// re-fan to the survivors (`refanned`) plus budgeted, counted
/// reconnects.
fn fail_member(
    slot: &mut WorkerSlot,
    ctx: &MemberCtx<'_>,
    inflight: &mut HashMap<u64, usize>,
    lives: &mut i64,
    jitter: &mut u64,
    reason: &str,
) {
    slot.client = None;
    let m = ctx.slot_id;
    let q = slot.queue;
    let cfg = &ctx.cfg;
    // whether reconnects below draw on the budget and count as retries
    let counted;
    {
        let mut w = ctx.batch.work.lock().unwrap();
        w.delta.worker_failures += 1;
        w.failures.push(format!("{}: {reason}", slot.addr));
        w.live[q] -= 1;
        let sibling_alive = w.live[q] > 0;
        let mut lost = 0u64;
        let idxs: Vec<usize> = inflight.drain().map(|(_, i)| i).collect();
        for idx in idxs {
            // release our claim so the slice can be re-dealt — but a
            // parked verified read stays on the books (it is data we
            // already hold, not a claim on future work)
            let keep_assigned =
                matches!(&w.slices[idx].pending, Some(p) if p.slot == m);
            w.slices[idx].inflight.retain(|&(s, _)| s != m);
            if !keep_assigned {
                w.slices[idx].assigned.retain(|&s| s != m);
            }
            if w.slices[idx].done || !w.slices[idx].inflight.is_empty() {
                continue; // merged already, or a duplicate still runs it
            }
            w.queues[q].push_back(idx);
            lost += 1;
        }
        // the failure shows up in the batch's trace as an event span
        // (zero duration, timestamped at detection) named after the
        // recovery path taken — failover to a sibling vs re-fan to the
        // surviving unreplicated workers
        let at_us = Instant::now()
            .saturating_duration_since(w.trace_epoch)
            .as_micros() as u64;
        if ctx.replicated {
            if sibling_alive {
                w.delta.failovers += lost;
                push_span(
                    &mut w,
                    "failover".into(),
                    at_us,
                    0,
                    format!("worker={} slices={lost}", slot.addr),
                );
            }
            counted = !sibling_alive;
            w.retrying[q] += 1;
        } else {
            w.delta.refanned += lost;
            push_span(
                &mut w,
                "refan".into(),
                at_us,
                0,
                format!("worker={} slices={lost}", slot.addr),
            );
            counted = true;
        }
        ctx.batch.changed.notify_all();
    }
    if counted {
        *lives -= 1;
    }
    let mut reconnected = false;
    if !(counted && *lives <= 0) {
        for attempt in 0..cfg.max_retries {
            let base = cfg
                .retry_base
                .saturating_mul(1u32 << attempt.min(16))
                .min(cfg.retry_cap);
            // deterministic jitter in [0.5, 1.5): decorrelates reconnect
            // storms without nondeterministic tests
            let frac = (splitmix64(jitter) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            std::thread::sleep(base.mul_f64(0.5 + frac));
            if counted {
                let mut w = ctx.batch.work.lock().unwrap();
                w.delta.retries += 1;
                let at_us = Instant::now()
                    .saturating_duration_since(w.trace_epoch)
                    .as_micros() as u64;
                push_span(
                    &mut w,
                    "retry".into(),
                    at_us,
                    0,
                    format!("worker={} attempt={}", slot.addr, attempt + 1),
                );
            }
            if let Ok(c) = slot.reconnect(cfg, ctx.fingerprint) {
                slot.client = Some(c);
                reconnected = true;
                break;
            }
        }
    }
    if ctx.replicated {
        let mut w = ctx.batch.work.lock().unwrap();
        w.retrying[q] -= 1;
        if reconnected {
            w.live[q] += 1;
        } else if w.live[q] == 0 && w.retrying[q] == 0 && w.fatal.is_none() {
            // no live replica and none on the way back: the group's
            // slices can never be served — fail the batch loudly now
            // instead of letting every other group wait forever
            let unserved = w
                .slices
                .iter()
                .filter(|e| e.queue == q && !e.done)
                .count();
            if unserved > 0 {
                w.fatal = Some(format!(
                    "shard group {} has no live replica remaining and {unserved} \
                     sub-slice(s) unserved (replication exhausted; last failure: \
                     {}: {reason})",
                    q + 1,
                    slot.addr
                ));
            }
            ctx.batch.changed.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::pattern::catalog;
    use crate::shard::worker::{ShardWorker, WorkerConfig};

    fn singletons(addrs: &[String]) -> Vec<Vec<String>> {
        addrs.iter().map(|a| vec![a.clone()]).collect()
    }

    fn spawn_workers(seed: u64, k: usize) -> (Vec<ShardWorker>, Vec<String>) {
        let workers: Vec<ShardWorker> = (0..k)
            .map(|_| {
                ShardWorker::bind(
                    erdos_renyi(70, 260, seed),
                    "127.0.0.1:0",
                    WorkerConfig {
                        threads: 2,
                        fused: true,
                        cache_bytes: 1 << 20,
                        persist: None,
                        slice_pin: None,
                    },
                )
                .unwrap()
            })
            .collect();
        let addrs = workers.iter().map(|w| w.addr().to_string()).collect();
        (workers, addrs)
    }

    #[test]
    fn pool_sums_equal_local_execution() {
        let seed = 0x7001;
        let (workers, addrs) = spawn_workers(seed, 2);
        let g = erdos_renyi(70, 260, seed);
        let mut pool = ShardPool::connect(&addrs, &g).unwrap();
        assert_eq!(pool.num_shards(), 2);
        let slices = pool.sub_slices().to_vec();
        let deal = PoolConfig::default().sub_slices_per_worker * 2;
        assert!(!slices.is_empty() && slices.len() <= deal, "{slices:?}");
        assert_eq!(slices[0].0, 0);
        assert_eq!(slices[slices.len() - 1].1, 70);
        for w in slices.windows(2) {
            assert_eq!(w[0].1, w[1].0, "sub-slices tile the vertex range");
        }
        let base = vec![
            catalog::triangle(),
            catalog::path(3),
            catalog::cycle(4).vertex_induced(),
        ];
        let indices: Vec<usize> = (0..base.len()).collect();
        let merged = pool.execute_bases(&base, &indices, 0).unwrap();
        assert_eq!(merged.len(), base.len());
        for ((k, v), p) in merged.iter().zip(&base) {
            assert_eq!(*k, p.canonical_key());
            let direct = crate::agg::aggregate_pattern(&g, p, &crate::agg::CountAgg, 1);
            assert_eq!(*v, direct, "{p:?}: shard sums must equal local counts");
        }
        let ns = slices.len() as u64;
        let m = pool.metrics();
        assert_eq!(m.requests, ns, "one request per dealt sub-slice");
        assert_eq!(m.bases_sent, 3 * ns);
        assert_eq!(m.partials_merged, 3 * ns);
        assert_eq!(m.errors, 0);
        assert_eq!(m.worker_failures, 0);
        assert_eq!(m.refanned, 0);
        // a resend stays exact; how much of it the per-slice worker
        // stores serve depends on which worker stole which sub-slice, so
        // only the bound is deterministic here (see
        // resend_served_from_worker_store for the exact single-worker case)
        let again = pool.execute_bases(&base, &indices, 0).unwrap();
        assert_eq!(again, merged);
        assert!(pool.metrics().remote_cached <= 3 * ns);
        drop(pool);
        for w in workers {
            w.shutdown();
        }
    }

    #[test]
    fn resend_served_from_worker_store() {
        // one worker serves every sub-slice, so the warm resend is exact:
        // every base × sub-slice comes from its store
        let (workers, addrs) = spawn_workers(0x7006, 1);
        let g = erdos_renyi(70, 260, 0x7006);
        let mut pool = ShardPool::connect(&addrs, &g).unwrap();
        let base = vec![catalog::triangle(), catalog::path(3)];
        let indices: Vec<usize> = (0..base.len()).collect();
        let merged = pool.execute_bases(&base, &indices, 0).unwrap();
        assert_eq!(pool.metrics().remote_cached, 0, "first run matches everything");
        let again = pool.execute_bases(&base, &indices, 0).unwrap();
        assert_eq!(again, merged);
        let ns = pool.num_sub_slices() as u64;
        assert_eq!(pool.metrics().remote_cached, 2 * ns);
        drop(pool);
        for w in workers {
            w.shutdown();
        }
    }

    #[test]
    fn pool_rejects_mismatched_graph() {
        let (workers, addrs) = spawn_workers(0x7002, 1);
        let other = erdos_renyi(70, 260, 0x7003); // different content
        let err = ShardPool::connect(&addrs, &other).unwrap_err();
        assert!(format!("{err:#}").contains("rejected handshake"), "{err:#}");
        drop(workers);
        // a dead worker fails the pool, not just a request
        let cfg = PoolConfig {
            connect_timeout: Duration::from_millis(500),
            ..PoolConfig::default()
        };
        assert!(
            ShardPool::connect_with(&singletons(&addrs), &erdos_renyi(70, 260, 0x7002), cfg)
                .is_err()
        );
    }

    #[test]
    fn connect_reports_every_unusable_worker_at_once() {
        // two dead addresses (bind ephemeral ports, then free them) plus
        // one live worker: the error must name both dead ones
        let dead: Vec<String> = (0..2)
            .map(|_| {
                let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
                l.local_addr().unwrap().to_string()
            })
            .collect();
        let (workers, live) = spawn_workers(0x7005, 1);
        let g = erdos_renyi(70, 260, 0x7005);
        let addrs = vec![dead[0].clone(), live[0].clone(), dead[1].clone()];
        let cfg = PoolConfig {
            connect_timeout: Duration::from_millis(500),
            ..PoolConfig::default()
        };
        let err = format!(
            "{:#}",
            ShardPool::connect_with(&singletons(&addrs), &g, cfg).unwrap_err()
        );
        assert!(err.contains("2 of 3"), "{err}");
        assert!(
            err.contains(&dead[0]) && err.contains(&dead[1]),
            "both dead addresses reported in one pass: {err}"
        );
        assert!(!err.contains(&format!("{}:", live[0])), "live worker not blamed: {err}");
        for w in workers {
            w.shutdown();
        }
    }

    #[test]
    fn replicated_pool_sums_equal_local_execution() {
        // 2 groups × 2 replicas, all healthy: group queues are disjoint,
        // every sub-slice is served exactly once, and neither failover
        // nor hedging nor re-fan fires
        let seed = 0x7007;
        let (workers, addrs) = spawn_workers(seed, 4);
        let g = erdos_renyi(70, 260, seed);
        let groups = vec![
            vec![addrs[0].clone(), addrs[1].clone()],
            vec![addrs[2].clone(), addrs[3].clone()],
        ];
        let mut pool = ShardPool::connect_with(&groups, &g, PoolConfig::default()).unwrap();
        assert_eq!(pool.num_shards(), 4);
        assert_eq!(pool.num_groups(), 2);
        assert!(pool.replicated());
        let slices = pool.sub_slices().to_vec();
        assert!(!slices.is_empty());
        assert_eq!(slices[0].0, 0);
        assert_eq!(slices[slices.len() - 1].1, 70);
        for w in slices.windows(2) {
            assert_eq!(w[0].1, w[1].0, "group cuts + sub-slices tile the range");
        }
        let base = vec![catalog::triangle(), catalog::path(3)];
        let indices: Vec<usize> = (0..base.len()).collect();
        let merged = pool.execute_bases(&base, &indices, 0).unwrap();
        for ((k, v), p) in merged.iter().zip(&base) {
            assert_eq!(*k, p.canonical_key());
            let direct = crate::agg::aggregate_pattern(&g, p, &crate::agg::CountAgg, 1);
            assert_eq!(*v, direct, "{p:?}: replicated sums must equal local counts");
        }
        let ns = slices.len() as u64;
        let m = pool.metrics();
        assert_eq!(m.requests, ns, "healthy groups deal each sub-slice once");
        assert_eq!(m.partials_merged, 2 * ns);
        assert_eq!(m.worker_failures, 0);
        assert_eq!(m.failovers, 0);
        assert_eq!(m.refanned, 0);
        assert_eq!(m.verify_mismatches, 0);
        drop(pool);
        for w in workers {
            w.shutdown();
        }
    }

    #[test]
    fn verified_reads_pass_on_honest_replicas() {
        // verify_reads = 1.0 over one group of two honest replicas: every
        // sub-slice is executed twice (once per replica), compared, and
        // merged exactly once
        let seed = 0x7008;
        let (workers, addrs) = spawn_workers(seed, 2);
        let g = erdos_renyi(70, 260, seed);
        let groups = vec![vec![addrs[0].clone(), addrs[1].clone()]];
        let cfg = PoolConfig {
            verify_reads: 1.0,
            ..PoolConfig::default()
        };
        let mut pool = ShardPool::connect_with(&groups, &g, cfg).unwrap();
        let base = vec![catalog::triangle(), catalog::path(3)];
        let indices: Vec<usize> = (0..base.len()).collect();
        let merged = pool.execute_bases(&base, &indices, 0).unwrap();
        for ((k, v), p) in merged.iter().zip(&base) {
            assert_eq!(*k, p.canonical_key());
            let direct = crate::agg::aggregate_pattern(&g, p, &crate::agg::CountAgg, 1);
            assert_eq!(*v, direct, "{p:?}: verified sums must equal local counts");
        }
        let ns = pool.num_sub_slices() as u64;
        let m = pool.metrics();
        assert_eq!(m.requests, 2 * ns, "every sub-slice read twice under verify 1.0");
        assert_eq!(m.partials_merged, 2 * ns, "but merged exactly once");
        assert_eq!(m.verify_mismatches, 0);
        assert_eq!(m.worker_failures, 0);
        assert_eq!(m.refanned, 0);
        // both replicas ran every slice, so a warm rerun is fully served
        // from their per-slice stores on both sides
        let again = pool.execute_bases(&base, &indices, 0).unwrap();
        assert_eq!(again, merged);
        assert_eq!(pool.metrics().remote_cached, 2 * 2 * ns);
        drop(pool);
        for w in workers {
            w.shutdown();
        }
    }

    #[test]
    fn verify_reads_rejected_without_replicas() {
        let (workers, addrs) = spawn_workers(0x7009, 2);
        let g = erdos_renyi(70, 260, 0x7009);
        let cfg = PoolConfig {
            verify_reads: 0.5,
            ..PoolConfig::default()
        };
        let err = ShardPool::connect_with(&singletons(&addrs), &g, cfg).unwrap_err();
        assert!(
            format!("{err:#}").contains("replicated topology"),
            "{err:#}"
        );
        for w in workers {
            w.shutdown();
        }
    }

    #[test]
    fn batch_spans_cover_every_sub_slice() {
        let (workers, addrs) = spawn_workers(0x700A, 2);
        let g = erdos_renyi(70, 260, 0x700A);
        let mut pool = ShardPool::connect(&addrs, &g).unwrap();
        let base = vec![catalog::triangle(), catalog::path(3)];
        let indices: Vec<usize> = (0..base.len()).collect();
        pool.set_trace(0xDEAD_BEEF, 42, 1000, Instant::now());
        pool.execute_bases(&base, &indices, 0).unwrap();
        let spans = pool.take_spans();
        let slices: Vec<&SpanRecord> =
            spans.iter().filter(|s| s.name.starts_with("slice ")).collect();
        assert_eq!(
            slices.len(),
            pool.num_sub_slices(),
            "one span per served sub-slice: {spans:?}"
        );
        for s in &slices {
            assert_eq!(s.parent, 42, "slice spans hang under the armed parent");
            assert!(s.id >= 1000, "ids come from the reserved range: {}", s.id);
            assert!(s.tag.contains("outcome=ok"), "{}", s.tag);
            assert!(
                spans.iter().any(|c| c.parent == s.id && c.name == "probe"),
                "slice span {} has the worker's probe grafted beneath it",
                s.id
            );
        }
        // the drain is a drain, and an unarmed batch collects afresh
        assert!(pool.take_spans().is_empty());
        pool.execute_bases(&base, &indices, 0).unwrap();
        assert!(!pool.take_spans().is_empty(), "tracing is always on");
        drop(pool);
        for w in workers {
            w.shutdown();
        }
    }

    #[test]
    fn empty_subset_is_free() {
        let (workers, addrs) = spawn_workers(0x7004, 1);
        let g = erdos_renyi(70, 260, 0x7004);
        let mut pool = ShardPool::connect(&addrs, &g).unwrap();
        let base = vec![catalog::triangle()];
        assert!(pool.execute_bases(&base, &[], 0).unwrap().is_empty());
        assert_eq!(pool.metrics().requests, 0);
        drop(pool);
        drop(workers);
    }
}
