//! Coordinator side of the shard fabric: one framed TCP connection per
//! worker ([`ShardClient`]) and the pool that deals a batch's missing
//! bases across all of them ([`ShardPool`]).
//!
//! The pool's one operation, [`ShardPool::execute_bases`], is a drop-in
//! replacement for local execution, built as a small fault-tolerant
//! fabric rather than a fixed fan-out:
//!
//! * **Sub-slice dealing** — the first-level vertex range is cut into
//!   degree-weighted sub-slices ([`super::weighted_ranges`], several per
//!   worker) held in a shared work queue. Each worker thread keeps a small
//!   pipeline of requests in flight and pulls the next sub-slice as
//!   replies land, so a fast worker steals the sub-slices a straggler
//!   never got to — no barrier on the slowest fixed slice.
//! * **Liveness** — while replies are outstanding, the client probes the
//!   worker with [`Msg::Ping`] every `probe_interval`; any traffic
//!   (including pongs) counts as liveness, and a connection silent for
//!   `shard_timeout` is declared wedged. A pong reporting zero in-flight
//!   requests while we still await replies means the worker lost them —
//!   caught immediately instead of waiting out the deadline.
//! * **Retry and re-fan** — a failed worker (refused connect, broken
//!   pipe, CRC error, wedge, error reply) has its in-flight sub-slices
//!   pushed back on the queue for the survivors, then gets reconnect
//!   attempts with capped exponential backoff + deterministic jitter.
//!   All-slices-or-nothing becomes all-slices-*eventually*: the batch
//!   fails only when sub-slices remain and no live worker is left.
//!
//! The merge stays exact under every re-assignment: sub-slices tile the
//! first-level range, every match roots at exactly one first-level vertex,
//! and per-key sums commute — so which worker serves a sub-slice is
//! irrelevant as long as each one is merged exactly once, which the
//! completion count (`remaining`) enforces. Partial answers are never
//! merged into results: a missing sub-slice fails the batch loudly.

use super::proto::{self, ExecRequest, ExecResponse, Msg};
use crate::graph::{DataGraph, GraphFingerprint};
use crate::pattern::canon::CanonKey;
use crate::pattern::Pattern;
use crate::util::rng::splitmix64;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{ErrorKind, Read};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Fabric tuning: connection deadlines, liveness probing, retry budget,
/// and sub-slice dealing. The defaults suit LAN pools; tests and the CLI
/// (`--connect-timeout`, `--shard-timeout`, `--probe-interval`) override.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Deadline for TCP connect + handshake reply, per attempt. A worker
    /// that accepts the connection but never answers the handshake
    /// (wedged, SIGSTOPped, black-holed) fails the attempt loudly.
    pub connect_timeout: Duration,
    /// Declare a connection wedged when it produces no traffic (replies
    /// *or* pongs) for this long while requests are in flight. This is a
    /// soft per-request deadline: a live worker deep in a heavy slice
    /// keeps answering probes and is left alone.
    pub shard_timeout: Duration,
    /// How often to send a liveness probe while waiting for replies.
    pub probe_interval: Duration,
    /// Reconnect attempts per worker failure; also bounds how many times
    /// a flaky worker may fail per batch before it is dropped for good.
    pub max_retries: u32,
    /// First reconnect backoff; doubles per attempt up to `retry_cap`,
    /// then jittered by ×[0.5, 1.5).
    pub retry_base: Duration,
    /// Backoff ceiling.
    pub retry_cap: Duration,
    /// Degree-weighted sub-slices dealt per connected worker (the work
    /// queue holds `workers × this` sub-slices, minus empties).
    pub sub_slices_per_worker: usize,
    /// Requests kept in flight per worker connection, so the worker can
    /// start the next sub-slice while a reply is on the wire.
    pub pipeline: usize,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig {
            connect_timeout: Duration::from_secs(30),
            shard_timeout: Duration::from_secs(30),
            probe_interval: Duration::from_secs(2),
            max_retries: 2,
            retry_base: Duration::from_millis(100),
            retry_cap: Duration::from_secs(2),
            sub_slices_per_worker: 4,
            pipeline: 2,
        }
    }
}

/// Coordinator-side counters for the shard fabric.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardMetrics {
    /// Exec requests sent (one per dealt sub-slice, retries included).
    pub requests: u64,
    /// Base patterns fanned out, summed over requests.
    pub bases_sent: u64,
    /// Per-sub-slice partial values merged into totals.
    pub partials_merged: u64,
    /// Bases workers reported serving from their local stores instead of
    /// matching (shard-level cache reuse, summed over requests).
    pub remote_cached: u64,
    /// Batches failed because sub-slices remained with no live worker.
    pub errors: u64,
    /// Worker failures observed mid-batch (disconnect, wedge, error
    /// reply, malformed reply) — each one triggers retry + re-fan.
    pub worker_failures: u64,
    /// Reconnect attempts made after worker failures.
    pub retries: u64,
    /// Sub-slices re-queued from a failed worker for the survivors.
    pub refanned: u64,
    /// Liveness probes sent while replies were outstanding.
    pub probes: u64,
}

impl ShardMetrics {
    fn absorb(&mut self, d: ShardMetrics) {
        self.requests += d.requests;
        self.bases_sent += d.bases_sent;
        self.partials_merged += d.partials_merged;
        self.remote_cached += d.remote_cached;
        self.errors += d.errors;
        self.worker_failures += d.worker_failures;
        self.retries += d.retries;
        self.refanned += d.refanned;
        self.probes += d.probes;
    }
}

/// One connected shard worker: the framed stream plus an incremental
/// receive buffer (a probe-interval read timeout can fire mid-frame, and
/// `read_exact` would lose the partial bytes — the buffer keeps them).
pub struct ShardClient {
    addr: String,
    stream: TcpStream,
    threads: u32,
    recv: Vec<u8>,
    /// Nonce of the last liveness probe sent.
    next_nonce: u64,
    /// Nonce watermark at the last Exec send: pongs with a nonce above
    /// this were probed *after* the newest request, so the worker has
    /// necessarily read every request we still await (TCP ordering) and
    /// its in-flight count is trustworthy.
    exec_nonce_mark: u64,
}

impl ShardClient {
    /// Connect and handshake with the default 30s deadline: the worker
    /// must speak this protocol version and hold a graph with exactly
    /// `fingerprint` — anything else is a hard reject on its side, which
    /// surfaces here as a connection error.
    pub fn connect(addr: &str, fingerprint: GraphFingerprint) -> Result<ShardClient> {
        Self::connect_deadline(addr, fingerprint, PoolConfig::default().connect_timeout)
    }

    /// [`ShardClient::connect`] with an explicit deadline covering both
    /// the TCP connect and the handshake reply, so a worker that accepts
    /// the socket but never answers fails the attempt instead of hanging
    /// it.
    pub fn connect_deadline(
        addr: &str,
        fingerprint: GraphFingerprint,
        timeout: Duration,
    ) -> Result<ShardClient> {
        let timeout = timeout.max(Duration::from_millis(1));
        let mut last_err: Option<std::io::Error> = None;
        let mut connected: Option<TcpStream> = None;
        for sa in addr
            .to_socket_addrs()
            .with_context(|| format!("resolving shard worker address {addr}"))?
        {
            match TcpStream::connect_timeout(&sa, timeout) {
                Ok(s) => {
                    connected = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let mut stream = connected.ok_or_else(|| match last_err {
            Some(e) => anyhow!(e).context(format!("connecting to shard worker {addr}")),
            None => anyhow!("shard worker address {addr} resolved to nothing"),
        })?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(timeout))
            .context("setting handshake deadline")?;
        proto::write_msg(
            &mut stream,
            &Msg::Hello {
                version: proto::VERSION,
                fingerprint,
            },
        )
        .with_context(|| format!("greeting shard worker {addr}"))?;
        let reply = proto::read_msg(&mut stream)
            .with_context(|| format!("reading handshake reply from {addr}"))?;
        match reply {
            Msg::Welcome { fingerprint: fp, threads } => {
                ensure!(
                    fp == fingerprint,
                    "shard worker {addr} answered with fingerprint {fp}, expected {fingerprint}"
                );
                Ok(ShardClient {
                    addr: addr.to_string(),
                    stream,
                    threads,
                    recv: Vec::new(),
                    next_nonce: 0,
                    exec_nonce_mark: 0,
                })
            }
            Msg::Reject { reason } => bail!("shard worker {addr} rejected handshake: {reason}"),
            other => bail!("shard worker {addr} sent unexpected handshake reply {other:?}"),
        }
    }

    /// The worker's address, as given to [`ShardClient::connect`].
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Matcher threads the worker reported at handshake.
    pub fn threads(&self) -> u32 {
        self.threads
    }

    fn send(&mut self, msg: &Msg) -> Result<()> {
        if matches!(msg, Msg::Exec(_)) {
            self.exec_nonce_mark = self.next_nonce;
        }
        proto::write_msg(&mut self.stream, msg)
            .with_context(|| format!("sending to shard worker {}", self.addr))
    }

    /// Pop one complete frame off the receive buffer, if any. Framing
    /// violations (oversized length, CRC mismatch, unreadable body) are
    /// errors — the connection is done.
    fn pop_frame(&mut self) -> Result<Option<Msg>> {
        use crate::service::persist::frame::{self, FRAME_HEADER};
        if self.recv.len() < FRAME_HEADER {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.recv[..4].try_into().expect("4 bytes")) as usize;
        let crc = u32::from_le_bytes(self.recv[4..FRAME_HEADER].try_into().expect("4 bytes"));
        ensure!(
            len <= proto::MAX_MSG_LEN,
            "shard worker {} sent a {len}-byte frame (cap {})",
            self.addr,
            proto::MAX_MSG_LEN
        );
        if self.recv.len() < FRAME_HEADER + len {
            return Ok(None);
        }
        let payload = &self.recv[FRAME_HEADER..FRAME_HEADER + len];
        ensure!(
            frame::crc32(payload) == crc,
            "shard worker {} sent a corrupt frame (CRC mismatch)",
            self.addr
        );
        let msg = proto::decode(payload)
            .ok_or_else(|| anyhow!("shard worker {} sent an unreadable message", self.addr))?;
        self.recv.drain(..FRAME_HEADER + len);
        Ok(Some(msg))
    }

    /// Wait for the next substantive reply (Result/Error), probing the
    /// worker with pings every `probe_interval` and failing after
    /// `shard_timeout` of total silence. Pongs are consumed here: they
    /// count as liveness, and a trustworthy pong reporting zero in-flight
    /// requests while we wait means the requests were lost.
    fn recv_reply(
        &mut self,
        probe_interval: Duration,
        shard_timeout: Duration,
        probes: &mut u64,
    ) -> Result<Msg> {
        self.stream
            .set_read_timeout(Some(probe_interval.max(Duration::from_millis(1))))
            .context("setting probe interval")?;
        let mut last_traffic = Instant::now();
        let mut chunk = [0u8; 16 << 10];
        loop {
            match self.pop_frame()? {
                Some(Msg::Pong { nonce, inflight }) => {
                    last_traffic = Instant::now();
                    if inflight == 0 && nonce > self.exec_nonce_mark {
                        // the probe was sent after our newest request, so
                        // the worker read every request we await before
                        // answering it — zero in-flight means they were
                        // dropped without a reply
                        bail!(
                            "shard worker {} answered a probe but reports no in-flight \
                             work — requests were lost",
                            self.addr
                        );
                    }
                    continue;
                }
                Some(msg) => return Ok(msg),
                None => {}
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => bail!("shard worker {} closed the connection", self.addr),
                Ok(n) => {
                    self.recv.extend_from_slice(&chunk[..n]);
                    last_traffic = Instant::now();
                }
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    if last_traffic.elapsed() >= shard_timeout {
                        bail!(
                            "shard worker {} wedged: no traffic for {:.1?} \
                             (deadline {:.1?})",
                            self.addr,
                            last_traffic.elapsed(),
                            shard_timeout
                        );
                    }
                    self.next_nonce += 1;
                    *probes += 1;
                    let ping = Msg::Ping { nonce: self.next_nonce };
                    self.send(&ping)
                        .with_context(|| format!("probing shard worker {}", self.addr))?;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    return Err(e)
                        .with_context(|| format!("reading from shard worker {}", self.addr))
                }
            }
        }
    }
}

/// One pool seat: the address is permanent, the connection comes and goes
/// with failures and reconnects.
struct WorkerSlot {
    addr: String,
    client: Option<ShardClient>,
}

/// Shared state of one in-flight batch: the sub-slice work queue, the
/// completion count, and the partial sums.
struct WorkState {
    queue: VecDeque<(u32, u32)>,
    /// Sub-slices not yet merged. The batch is complete exactly when this
    /// hits zero — each sub-slice is merged once, no matter how many
    /// times it was re-dealt.
    remaining: usize,
    sums: HashMap<CanonKey, i128>,
    delta: ShardMetrics,
    failures: Vec<String>,
}

struct Batch {
    work: Mutex<WorkState>,
    /// Signalled on completion and on re-fan, so an idle survivor picks
    /// up a failed worker's slices promptly.
    changed: Condvar,
}

/// A set of connected shard workers sharing one graph identity, dealing
/// degree-weighted sub-slices from a shared queue with retry and re-fan
/// on failure.
pub struct ShardPool {
    workers: Vec<WorkerSlot>,
    fingerprint: GraphFingerprint,
    sub_slices: Vec<(u32, u32)>,
    config: PoolConfig,
    next_id: u64,
    metrics: ShardMetrics,
}

impl ShardPool {
    /// Connect to every address with default [`PoolConfig`], handshaking
    /// each against `graph`'s fingerprint.
    pub fn connect(addrs: &[String], graph: &DataGraph) -> Result<ShardPool> {
        Self::connect_with(addrs, graph, PoolConfig::default())
    }

    /// Connect to every address, handshaking each against `graph`'s
    /// fingerprint. Every unusable worker — unreachable, wedged, wrong
    /// graph, wrong protocol — is collected and reported in **one** error,
    /// so an operator fixes the whole pool in one pass instead of
    /// replaying connect once per broken address. A partial pool is still
    /// refused: batches tolerate workers dying, but a pool that *starts*
    /// degraded usually means a typo'd address list.
    pub fn connect_with(
        addrs: &[String],
        graph: &DataGraph,
        config: PoolConfig,
    ) -> Result<ShardPool> {
        ensure!(!addrs.is_empty(), "a shard pool needs at least one worker address");
        let fingerprint = graph.fingerprint();
        let mut workers = Vec::with_capacity(addrs.len());
        let mut unusable: Vec<String> = Vec::new();
        for addr in addrs {
            match ShardClient::connect_deadline(addr, fingerprint, config.connect_timeout) {
                Ok(c) => workers.push(WorkerSlot {
                    addr: addr.clone(),
                    client: Some(c),
                }),
                Err(e) => unusable.push(format!("{addr}: {e:#}")),
            }
        }
        if !unusable.is_empty() {
            bail!(
                "{} of {} shard workers unusable:\n  {}",
                unusable.len(),
                addrs.len(),
                unusable.join("\n  ")
            );
        }
        let weights: Vec<u64> = (0..graph.num_vertices() as u32)
            .map(|v| graph.degree(v) as u64 + 1)
            .collect();
        let sub_slices = super::weighted_ranges(
            &weights,
            workers.len() * config.sub_slices_per_worker.max(1),
        );
        Ok(ShardPool {
            workers,
            fingerprint,
            sub_slices,
            config,
            next_id: 0,
            metrics: ShardMetrics::default(),
        })
    }

    /// Number of pool seats (connected workers at start; a seat whose
    /// worker died stays counted — the address is still part of the pool).
    pub fn num_shards(&self) -> usize {
        self.workers.len()
    }

    /// The degree-weighted sub-slices dealt per batch, in vertex order.
    /// Deterministic for a given graph and pool size — sub-slice identity
    /// keys worker-side stores and durable state.
    pub fn sub_slices(&self) -> &[(u32, u32)] {
        &self.sub_slices
    }

    /// Number of dealt sub-slices (≤ workers × `sub_slices_per_worker`).
    pub fn num_sub_slices(&self) -> usize {
        self.sub_slices.len()
    }

    /// Coordinator-side fabric counters.
    pub fn metrics(&self) -> ShardMetrics {
        self.metrics
    }

    /// The fabric tuning this pool runs with.
    pub fn config(&self) -> PoolConfig {
        self.config
    }

    /// Match the subset of `base` selected by `indices` across the pool
    /// and return **full-graph** map counts per canonical key: sub-slices
    /// are dealt to workers from a shared queue, each worker runs the same
    /// base set over the sub-slices it pulls, and the partials are summed
    /// here — exactly once per sub-slice, whichever worker served it.
    /// `epoch` is the coordinator's cache epoch, echoed through for
    /// bookkeeping.
    pub fn execute_bases(
        &mut self,
        base: &[Pattern],
        indices: &[usize],
        epoch: u64,
    ) -> Result<Vec<(CanonKey, i128)>> {
        if indices.is_empty() {
            return Ok(Vec::new());
        }
        let patterns: Vec<Pattern> = indices.iter().map(|&i| base[i].clone()).collect();
        let keys: Vec<CanonKey> = patterns.iter().map(|p| p.canonical_key()).collect();
        let sums: HashMap<CanonKey, i128> = keys.iter().map(|k| (*k, 0)).collect();
        let distinct = sums.len();
        let batch = Batch {
            work: Mutex::new(WorkState {
                queue: self.sub_slices.iter().copied().collect(),
                remaining: self.sub_slices.len(),
                sums,
                delta: ShardMetrics::default(),
                failures: Vec::new(),
            }),
            changed: Condvar::new(),
        };
        if self.sub_slices.is_empty() {
            // zero-vertex graph: every count is the aggregation identity
        } else {
            let ids = AtomicU64::new(self.next_id);
            let (cfg, fingerprint) = (self.config, self.fingerprint);
            std::thread::scope(|s| {
                for slot in self.workers.iter_mut() {
                    let (batch, patterns, ids) = (&batch, &patterns, &ids);
                    s.spawn(move || {
                        run_worker(slot, batch, &cfg, patterns, distinct, fingerprint, epoch, ids)
                    });
                }
            });
            self.next_id = ids.into_inner();
        }
        let state = batch.work.into_inner().expect("batch threads joined");
        self.metrics.absorb(state.delta);
        if state.remaining > 0 {
            self.metrics.errors += 1;
            bail!(
                "sharded batch failed: {} of {} sub-slices unserved and no live worker \
                 remains; worker failures:\n  {}",
                state.remaining,
                self.sub_slices.len(),
                state.failures.join("\n  ")
            );
        }
        let mut out = Vec::with_capacity(distinct);
        let mut emitted = HashSet::new();
        for k in keys {
            if emitted.insert(k) {
                out.push((k, state.sums[&k]));
            }
        }
        Ok(out)
    }
}

/// One worker's batch loop: deal sub-slices into the pipeline, await
/// replies (probing for liveness), merge, and on failure re-fan + retry.
/// Returns when the batch is complete or this worker is out of lives.
#[allow(clippy::too_many_arguments)]
fn run_worker(
    slot: &mut WorkerSlot,
    batch: &Batch,
    cfg: &PoolConfig,
    patterns: &[Pattern],
    distinct: usize,
    fingerprint: GraphFingerprint,
    epoch: u64,
    ids: &AtomicU64,
) {
    // deterministic backoff jitter, decorrelated per worker address
    let mut jitter = {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in slot.addr.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    };
    // failures tolerated before this worker is dropped from the batch
    let mut lives = cfg.max_retries as i64 + 1;
    let mut inflight: HashMap<u64, (u32, u32)> = HashMap::new();
    let mut probes = 0u64;
    loop {
        if slot.client.is_none() {
            break;
        }
        // deal sub-slices into the pipeline
        let mut send_failure: Option<String> = None;
        while inflight.len() < cfg.pipeline.max(1) {
            let slice = {
                let mut w = batch.work.lock().unwrap();
                match w.queue.pop_front() {
                    Some(s) => {
                        w.delta.requests += 1;
                        w.delta.bases_sent += distinct as u64;
                        s
                    }
                    None => break,
                }
            };
            let id = ids.fetch_add(1, Ordering::SeqCst);
            inflight.insert(id, slice);
            let req = ExecRequest {
                id,
                epoch,
                fingerprint,
                lo: slice.0,
                hi: slice.1,
                patterns: patterns.to_vec(),
            };
            let client = slot.client.as_mut().expect("checked live above");
            if let Err(e) = client.send(&Msg::Exec(req)) {
                send_failure = Some(format!("{e:#}"));
                break;
            }
        }
        if let Some(reason) = send_failure {
            fail_and_refan(slot, batch, cfg, fingerprint, &mut inflight, &mut lives, &mut jitter, &reason);
            continue;
        }
        if inflight.is_empty() {
            // the queue is dry; linger in case a failing worker re-fans
            // its slices back — the batch is over only at remaining == 0
            let w = batch.work.lock().unwrap();
            if w.remaining == 0 {
                break;
            }
            if w.queue.is_empty() {
                let _unused = batch
                    .changed
                    .wait_timeout(w, Duration::from_millis(25))
                    .unwrap();
            }
            continue;
        }
        // await one reply, probing for liveness while we wait
        let outcome = slot
            .client
            .as_mut()
            .expect("checked live above")
            .recv_reply(cfg.probe_interval, cfg.shard_timeout, &mut probes);
        let reason = match outcome {
            Ok(Msg::Result(resp)) => merge_reply(batch, &mut inflight, &resp, distinct),
            Ok(Msg::Error { id: _, message }) => Some(format!("worker error reply: {message}")),
            Ok(other) => Some(format!("unexpected reply {other:?}")),
            Err(e) => Some(format!("{e:#}")),
        };
        if let Some(reason) = reason {
            fail_and_refan(slot, batch, cfg, fingerprint, &mut inflight, &mut lives, &mut jitter, &reason);
        }
    }
    batch.work.lock().unwrap().delta.probes += probes;
}

/// Validate and merge one reply. Returns a failure reason if the reply is
/// malformed (wrong id, wrong cardinality, duplicate or unrequested keys)
/// — nothing is merged in that case, so the sub-slice can be re-dealt
/// without double counting.
fn merge_reply(
    batch: &Batch,
    inflight: &mut HashMap<u64, (u32, u32)>,
    resp: &ExecResponse,
    distinct: usize,
) -> Option<String> {
    if !inflight.contains_key(&resp.id) {
        return Some(format!("reply for unknown request id {}", resp.id));
    }
    let mut w = batch.work.lock().unwrap();
    let mut seen: HashSet<CanonKey> = HashSet::with_capacity(resp.values.len());
    let well_formed = resp.values.len() == distinct
        && resp
            .values
            .iter()
            .all(|(k, _)| seen.insert(*k) && w.sums.contains_key(k));
    if !well_formed {
        return Some(format!(
            "malformed reply for request {}: {} values for {distinct} requested bases",
            resp.id,
            resp.values.len()
        ));
    }
    for (k, v) in &resp.values {
        *w.sums.get_mut(k).expect("validated above") += *v;
    }
    w.delta.partials_merged += distinct as u64;
    w.delta.remote_cached += resp.served_from_store as u64;
    inflight.remove(&resp.id);
    w.remaining -= 1;
    if w.remaining == 0 {
        batch.changed.notify_all();
    }
    None
}

/// Handle one worker failure: push its in-flight sub-slices back on the
/// queue (the survivors pick them up immediately), then try to reconnect
/// with capped exponential backoff + jitter. On reconnect the worker
/// rejoins the dealing loop; otherwise its seat goes dark for the batch.
#[allow(clippy::too_many_arguments)]
fn fail_and_refan(
    slot: &mut WorkerSlot,
    batch: &Batch,
    cfg: &PoolConfig,
    fingerprint: GraphFingerprint,
    inflight: &mut HashMap<u64, (u32, u32)>,
    lives: &mut i64,
    jitter: &mut u64,
    reason: &str,
) {
    slot.client = None;
    {
        let mut w = batch.work.lock().unwrap();
        w.delta.worker_failures += 1;
        w.delta.refanned += inflight.len() as u64;
        for (_, slice) in inflight.drain() {
            w.queue.push_back(slice);
        }
        w.failures.push(format!("{}: {reason}", slot.addr));
        batch.changed.notify_all();
    }
    *lives -= 1;
    if *lives <= 0 {
        return;
    }
    for attempt in 0..cfg.max_retries {
        let base = cfg
            .retry_base
            .saturating_mul(1u32 << attempt.min(16))
            .min(cfg.retry_cap);
        // deterministic jitter in [0.5, 1.5): decorrelates reconnect
        // storms without nondeterministic tests
        let frac = (splitmix64(jitter) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        std::thread::sleep(base.mul_f64(0.5 + frac));
        batch.work.lock().unwrap().delta.retries += 1;
        if let Ok(c) = ShardClient::connect_deadline(&slot.addr, fingerprint, cfg.connect_timeout)
        {
            slot.client = Some(c);
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::pattern::catalog;
    use crate::shard::worker::{ShardWorker, WorkerConfig};

    fn spawn_workers(seed: u64, k: usize) -> (Vec<ShardWorker>, Vec<String>) {
        let workers: Vec<ShardWorker> = (0..k)
            .map(|_| {
                ShardWorker::bind(
                    erdos_renyi(70, 260, seed),
                    "127.0.0.1:0",
                    WorkerConfig {
                        threads: 2,
                        fused: true,
                        cache_bytes: 1 << 20,
                        persist: None,
                    },
                )
                .unwrap()
            })
            .collect();
        let addrs = workers.iter().map(|w| w.addr().to_string()).collect();
        (workers, addrs)
    }

    #[test]
    fn pool_sums_equal_local_execution() {
        let seed = 0x7001;
        let (workers, addrs) = spawn_workers(seed, 2);
        let g = erdos_renyi(70, 260, seed);
        let mut pool = ShardPool::connect(&addrs, &g).unwrap();
        assert_eq!(pool.num_shards(), 2);
        let slices = pool.sub_slices().to_vec();
        let deal = PoolConfig::default().sub_slices_per_worker * 2;
        assert!(!slices.is_empty() && slices.len() <= deal, "{slices:?}");
        assert_eq!(slices[0].0, 0);
        assert_eq!(slices[slices.len() - 1].1, 70);
        for w in slices.windows(2) {
            assert_eq!(w[0].1, w[1].0, "sub-slices tile the vertex range");
        }
        let base = vec![
            catalog::triangle(),
            catalog::path(3),
            catalog::cycle(4).vertex_induced(),
        ];
        let indices: Vec<usize> = (0..base.len()).collect();
        let merged = pool.execute_bases(&base, &indices, 0).unwrap();
        assert_eq!(merged.len(), base.len());
        for ((k, v), p) in merged.iter().zip(&base) {
            assert_eq!(*k, p.canonical_key());
            let direct = crate::agg::aggregate_pattern(&g, p, &crate::agg::CountAgg, 1);
            assert_eq!(*v, direct, "{p:?}: shard sums must equal local counts");
        }
        let ns = slices.len() as u64;
        let m = pool.metrics();
        assert_eq!(m.requests, ns, "one request per dealt sub-slice");
        assert_eq!(m.bases_sent, 3 * ns);
        assert_eq!(m.partials_merged, 3 * ns);
        assert_eq!(m.errors, 0);
        assert_eq!(m.worker_failures, 0);
        assert_eq!(m.refanned, 0);
        // a resend stays exact; how much of it the per-slice worker
        // stores serve depends on which worker stole which sub-slice, so
        // only the bound is deterministic here (see
        // resend_served_from_worker_store for the exact single-worker case)
        let again = pool.execute_bases(&base, &indices, 0).unwrap();
        assert_eq!(again, merged);
        assert!(pool.metrics().remote_cached <= 3 * ns);
        drop(pool);
        for w in workers {
            w.shutdown();
        }
    }

    #[test]
    fn resend_served_from_worker_store() {
        // one worker serves every sub-slice, so the warm resend is exact:
        // every base × sub-slice comes from its store
        let (workers, addrs) = spawn_workers(0x7006, 1);
        let g = erdos_renyi(70, 260, 0x7006);
        let mut pool = ShardPool::connect(&addrs, &g).unwrap();
        let base = vec![catalog::triangle(), catalog::path(3)];
        let indices: Vec<usize> = (0..base.len()).collect();
        let merged = pool.execute_bases(&base, &indices, 0).unwrap();
        assert_eq!(pool.metrics().remote_cached, 0, "first run matches everything");
        let again = pool.execute_bases(&base, &indices, 0).unwrap();
        assert_eq!(again, merged);
        let ns = pool.num_sub_slices() as u64;
        assert_eq!(pool.metrics().remote_cached, 2 * ns);
        drop(pool);
        for w in workers {
            w.shutdown();
        }
    }

    #[test]
    fn pool_rejects_mismatched_graph() {
        let (workers, addrs) = spawn_workers(0x7002, 1);
        let other = erdos_renyi(70, 260, 0x7003); // different content
        let err = ShardPool::connect(&addrs, &other).unwrap_err();
        assert!(format!("{err:#}").contains("rejected handshake"), "{err:#}");
        drop(workers);
        // a dead worker fails the pool, not just a request
        let cfg = PoolConfig {
            connect_timeout: Duration::from_millis(500),
            ..PoolConfig::default()
        };
        assert!(ShardPool::connect_with(&addrs, &erdos_renyi(70, 260, 0x7002), cfg).is_err());
    }

    #[test]
    fn connect_reports_every_unusable_worker_at_once() {
        // two dead addresses (bind ephemeral ports, then free them) plus
        // one live worker: the error must name both dead ones
        let dead: Vec<String> = (0..2)
            .map(|_| {
                let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
                l.local_addr().unwrap().to_string()
            })
            .collect();
        let (workers, live) = spawn_workers(0x7005, 1);
        let g = erdos_renyi(70, 260, 0x7005);
        let addrs = vec![dead[0].clone(), live[0].clone(), dead[1].clone()];
        let cfg = PoolConfig {
            connect_timeout: Duration::from_millis(500),
            ..PoolConfig::default()
        };
        let err = format!("{:#}", ShardPool::connect_with(&addrs, &g, cfg).unwrap_err());
        assert!(err.contains("2 of 3"), "{err}");
        assert!(
            err.contains(&dead[0]) && err.contains(&dead[1]),
            "both dead addresses reported in one pass: {err}"
        );
        assert!(!err.contains(&format!("{}:", live[0])), "live worker not blamed: {err}");
        for w in workers {
            w.shutdown();
        }
    }

    #[test]
    fn empty_subset_is_free() {
        let (workers, addrs) = spawn_workers(0x7004, 1);
        let g = erdos_renyi(70, 260, 0x7004);
        let mut pool = ShardPool::connect(&addrs, &g).unwrap();
        let base = vec![catalog::triangle()];
        assert!(pool.execute_bases(&base, &[], 0).unwrap().is_empty());
        assert_eq!(pool.metrics().requests, 0);
        drop(pool);
        drop(workers);
    }
}
