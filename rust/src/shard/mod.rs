//! DISTRIBUTED FIRST-LEVEL SHARDING — scale the fused sweep out across
//! processes.
//!
//! Everything below this module makes one process fast; this layer makes
//! *several* processes one system. The seam is the same one the
//! thread-parallel driver already exploits ([`crate::exec::parallel`]):
//! every match is rooted at exactly one first-level vertex, so
//! partitioning the first-level vertex range partitions the match set, and
//! **per-base totals are exact sums of per-slice partials**. A shard
//! worker is nothing more than a remote `_range` call — it runs the full
//! fused plan ([`crate::plan::fused`]) restricted to its contiguous slice
//! of the degree-ordered CSR, symmetry windows and all, so sharded
//! execution can never drift from single-process semantics.
//!
//! The split:
//!
//! * [`worker`] — `morphmine shard-worker --listen <addr>`: owns an
//!   immutable copy of the graph, answers slice requests over a framed TCP
//!   protocol (pipelined: several requests in flight per connection,
//!   replies matched by id), caches partials in per-slice
//!   [`ResultStore`](crate::service::ResultStore)s (a re-sent
//!   base × slice is served without matching), coalesces concurrent
//!   requests for the same base × slice, answers liveness probes inline
//!   from its read loop, and optionally persists its partials keyed by
//!   [`shard_fingerprint`] — graph × slice — so a shard restart recovers
//!   warm.
//! * [`proto`] — the wire protocol, reusing the persistence layer's
//!   CRC32 framing ([`crate::service::persist::frame`]). Handshakes carry
//!   the protocol version and graph fingerprint; a worker holding
//!   different content (or speaking a different revision) hard-rejects.
//! * [`coordinator`] — [`ShardPool`]: the fault-tolerant fan-out fabric.
//!   The first-level range is cut into degree-weighted **sub-slices**
//!   ([`weighted_ranges`] — the degree-ordered CSR makes low slices far
//!   heavier than high ones) dealt from a shared work queue, so fast
//!   workers steal remaining sub-slices from stragglers. A worker failure
//!   (refused connect, broken pipe, probe timeout, error reply) triggers
//!   capped-backoff reconnects and then **re-fans** its unserved
//!   sub-slices across the survivors; the batch fails only when no live
//!   worker remains. [`ShardCoordinator`]: the batch front door used by
//!   `morphmine batch|serve --shards <addr,…>`, composing the summed
//!   totals through the same morph algebra and result store as the
//!   single-process service
//!   ([`QueryPlanner::serve_batch_sharded`](crate::service::QueryPlanner::serve_batch_sharded)).
//!
//! Re-fanning is trivially correct for the same reason sharding is exact:
//! sub-slices tile the first-level range, every match roots at exactly one
//! first-level vertex, and the per-key sums are commutative — so it never
//! matters *which* worker serves a sub-slice, only that each one is merged
//! exactly once, which the work queue's completion count enforces.
//!
//! End to end:
//!
//! ```
//! use morphmine::graph::generators::erdos_renyi;
//! use morphmine::morph::Policy;
//! use morphmine::service::QueryPlanner;
//! use morphmine::shard::{ShardCoordinator, ShardWorker, WorkerConfig};
//!
//! // two "processes", each holding its own copy of the same graph
//! let graph = || erdos_renyi(60, 220, 7);
//! let a = ShardWorker::bind(graph(), "127.0.0.1:0", WorkerConfig::default()).unwrap();
//! let b = ShardWorker::bind(graph(), "127.0.0.1:0", WorkerConfig::default()).unwrap();
//! let addrs = vec![a.addr().to_string(), b.addr().to_string()];
//!
//! // the coordinator morphs, probes its cache, fans missing bases out,
//! // and composes the summed partials — exact, not approximate
//! let planner = QueryPlanner::new(Policy::Naive, true, 2);
//! let mut coord = ShardCoordinator::connect(graph(), &addrs, planner, 1 << 20).unwrap();
//! let r = coord.call(&["motifs:3"]).unwrap();
//! assert_eq!(r.results[0].counts.len(), 2, "wedge + triangle");
//! assert_eq!(r.stats.remote_bases, r.stats.executed_bases);
//! # drop(coord); a.shutdown(); b.shutdown();
//! ```

pub mod coordinator;
pub mod proto;
pub mod worker;

pub use coordinator::{PoolConfig, ShardClient, ShardMetrics, ShardPool};
pub use worker::{ShardWorker, WorkerConfig};

use crate::graph::{DataGraph, GraphFingerprint};
use crate::service::serve::{to_query_results, BatchResponse, ServiceQuery};
use crate::service::{QueryPlanner, ResultStore, StoreMetrics};
use crate::util::timer::PhaseProfile;
use anyhow::Result;

/// Split `0..n` into `k` contiguous slices, one per shard in pool order.
/// Slices tile the range exactly (first starts at 0, last ends at `n`,
/// neighbours meet); with `k > n` the surplus slices are empty — an empty
/// slice contributes the aggregation identity, so correctness never
/// depends on `k` dividing `n`.
pub fn shard_ranges(n: u32, k: usize) -> Vec<(u32, u32)> {
    let k = k.max(1) as u64;
    (0..k)
        .map(|i| ((n as u64 * i / k) as u32, (n as u64 * (i + 1) / k) as u32))
        .collect()
}

/// Split `0..weights.len()` into at most `k` contiguous slices of roughly
/// equal **total weight** (quantile cuts of the prefix-sum). The work of
/// matching rooted at vertex `v` scales with its degree, and the CSR is
/// degree-ordered, so [`shard_ranges`]' equal-*width* slices make slice 0
/// a straggler by construction; weighting by `degree + 1` instead yields
/// sub-slices that cost about the same — tiny ranges over the hubs, wide
/// ranges over the low-degree tail.
///
/// Empty slices are dropped (a heavy single vertex can consume several
/// quantiles), so the result tiles `[0, n)` with between 1 and `k`
/// nonempty slices — and is a pure function of `(weights, k)`, which keeps
/// sub-slice boundaries stable across coordinators and restarts (worker
/// stores and durable state are keyed per slice).
pub fn weighted_ranges(weights: &[u64], k: usize) -> Vec<(u32, u32)> {
    let n = weights.len() as u32;
    let k = k.max(1);
    if n == 0 {
        return Vec::new();
    }
    let total: u128 = weights.iter().map(|&w| w as u128).sum::<u128>().max(1);
    let mut out = Vec::with_capacity(k);
    let (mut lo, mut acc, mut cut) = (0u32, 0u128, 1usize);
    for (v, &w) in weights.iter().enumerate() {
        acc += w as u128;
        // emit a boundary for every quantile the running sum has crossed
        while cut < k && acc * (k as u128) >= total * (cut as u128) {
            let hi = v as u32 + 1;
            if hi > lo {
                out.push((lo, hi));
                lo = hi;
            }
            cut += 1;
        }
    }
    if lo < n {
        out.push((lo, n));
    }
    out
}

/// Durable identity of one shard's partial counts: the graph fingerprint
/// folded with the slice bounds (same FNV-1a stream as the fingerprint
/// itself). A shard's persisted partials are valid only for the exact
/// `(graph content, first-level slice)` pair they were computed over —
/// restarting a worker against a different graph *or* a resized pool must
/// recover cold, never wrong, and this key makes both structurally
/// unservable (the same invariant the persistence layer already enforces
/// for whole-graph state).
pub fn shard_fingerprint(fp: GraphFingerprint, lo: u32, hi: u32) -> GraphFingerprint {
    let mut h = fp.hash;
    for b in lo.to_le_bytes().into_iter().chain(hi.to_le_bytes()) {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    GraphFingerprint {
        order: fp.order,
        size: fp.size,
        hash: h,
    }
}

/// The sharded batch front door: one coordinator process holding the
/// morph planner, a local result store for composed totals, and a
/// [`ShardPool`] that matches the missing bases. Answers are
/// [`BatchResponse`]s — byte-identical in content to what the
/// single-process service produces for the same graph and queries.
///
/// The coordinator's graph is immutable (epoch pinned at 0): edge updates
/// would desynchronize it from the workers' copies, so the sharded CLI
/// rejects them. Mutable sharded serving would need update broadcast —
/// recorded as a ROADMAP follow-up.
pub struct ShardCoordinator {
    stats: crate::graph::GraphStats,
    planner: QueryPlanner,
    store: ResultStore<i128>,
    pool: ShardPool,
}

impl ShardCoordinator {
    /// Connect to every worker (handshaking each against `graph`'s
    /// fingerprint) and set up the coordinator-side planner and store.
    pub fn connect(
        graph: DataGraph,
        addrs: &[String],
        planner: QueryPlanner,
        cache_bytes: usize,
    ) -> Result<ShardCoordinator> {
        Self::connect_with(graph, addrs, planner, cache_bytes, PoolConfig::default())
    }

    /// [`ShardCoordinator::connect`] with explicit fabric tuning
    /// (timeouts, probe cadence, retry budget, sub-slicing).
    pub fn connect_with(
        graph: DataGraph,
        addrs: &[String],
        planner: QueryPlanner,
        cache_bytes: usize,
        config: PoolConfig,
    ) -> Result<ShardCoordinator> {
        // same stats seed as the service layer: the coordinator's morph
        // plan (and the equality of its answers to single-process runs)
        // must not depend on which path computed the statistics
        let stats = crate::graph::GraphStats::compute(&graph, 2000, 0x5E55);
        let pool = ShardPool::connect_with(addrs, &graph, config)?;
        Ok(ShardCoordinator {
            stats,
            planner,
            store: ResultStore::new(cache_bytes),
            pool,
        })
    }

    /// Number of connected shard workers.
    pub fn num_shards(&self) -> usize {
        self.pool.num_shards()
    }

    /// Number of degree-weighted sub-slices the pool deals per batch.
    pub fn num_sub_slices(&self) -> usize {
        self.pool.num_sub_slices()
    }

    /// Coordinator-side fan-out counters.
    pub fn shard_metrics(&self) -> ShardMetrics {
        self.pool.metrics()
    }

    /// Counters of the coordinator-local store of composed totals.
    pub fn store_metrics(&self) -> StoreMetrics {
        self.store.metrics()
    }

    /// Parse and serve one batch of query texts (`motifs:4`,
    /// `match:cycle4,p3`, `cliques:4`; FSM is rejected exactly as the
    /// in-process service rejects it).
    pub fn call(&mut self, queries: &[&str]) -> Result<BatchResponse> {
        let parsed = queries
            .iter()
            .map(|q| ServiceQuery::parse(q))
            .collect::<Result<Vec<_>>>()?;
        self.call_parsed(&parsed)
    }

    /// Serve one pre-parsed batch.
    pub fn call_parsed(&mut self, queries: &[ServiceQuery]) -> Result<BatchResponse> {
        let mut flat = Vec::new();
        let mut spans = Vec::with_capacity(queries.len());
        for q in queries {
            let start = flat.len();
            flat.extend(q.patterns.iter().cloned());
            spans.push((start, flat.len()));
        }
        let mut profile = PhaseProfile::new();
        let (vals, stats) = self.planner.serve_batch_sharded(
            &flat,
            &self.stats,
            &mut self.store,
            0,
            &mut self.pool,
            &mut profile,
        )?;
        Ok(BatchResponse {
            results: to_query_results(queries, &spans, &vals),
            stats,
            epoch: 0,
            profile,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_tile_exactly() {
        for (n, k) in [(100u32, 1usize), (100, 3), (7, 7), (5, 9), (0, 2), (1, 1)] {
            let rs = shard_ranges(n, k);
            assert_eq!(rs.len(), k.max(1));
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs[rs.len() - 1].1, n);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0, "n={n} k={k}: slices must meet");
            }
            for &(lo, hi) in &rs {
                assert!(lo <= hi);
            }
            let covered: u64 = rs.iter().map(|&(lo, hi)| (hi - lo) as u64).sum();
            assert_eq!(covered, n as u64);
        }
    }

    #[test]
    fn weighted_ranges_tile_and_balance() {
        // uniform weights reduce to (at most) equal-width slices
        let uniform = vec![1u64; 12];
        let rs = weighted_ranges(&uniform, 4);
        assert_eq!(rs, vec![(0, 3), (3, 6), (6, 9), (9, 12)]);
        // a degree-ordered profile: the hub head gets narrow slices, the
        // tail gets wide ones, every slice is nonempty, and they tile
        let degrees: Vec<u64> = (0..100u64).map(|v| 200 - 2 * v + 1).collect();
        for k in [1usize, 2, 3, 7, 16, 100, 1000] {
            let rs = weighted_ranges(&degrees, k);
            assert!(!rs.is_empty() && rs.len() <= k, "k={k}: {} slices", rs.len());
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs[rs.len() - 1].1, 100);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0, "k={k}: slices must meet");
            }
            for &(lo, hi) in &rs {
                assert!(lo < hi, "k={k}: no empty slices");
            }
            // no slice exceeds twice the ideal share (plus one vertex of
            // rounding slack) — the balance property the work queue needs
            let total: u64 = degrees.iter().sum();
            for &(lo, hi) in &rs {
                let w: u64 = degrees[lo as usize..hi as usize].iter().sum();
                let max_one = degrees[lo as usize]; // heaviest vertex in the slice
                assert!(
                    w <= 2 * total / k as u64 + max_one,
                    "k={k}: slice [{lo},{hi}) weighs {w} of {total}"
                );
            }
        }
        // a single monster vertex consumes several quantiles without
        // producing empty slices
        let spiked = vec![1_000_000u64, 1, 1, 1];
        let rs = weighted_ranges(&spiked, 4);
        assert_eq!(rs[0], (0, 1));
        assert_eq!(rs[rs.len() - 1].1, 4);
        for &(lo, hi) in &rs {
            assert!(lo < hi);
        }
        // degenerate shapes
        assert!(weighted_ranges(&[], 3).is_empty());
        // all-zero weights collapse to one slice covering everything
        assert_eq!(weighted_ranges(&[0, 0], 2), vec![(0, 2)]);
        assert_eq!(weighted_ranges(&[5], 8), vec![(0, 1)]);
        // determinism: sub-slice boundaries key durable worker state
        assert_eq!(weighted_ranges(&degrees, 7), weighted_ranges(&degrees, 7));
    }

    #[test]
    fn shard_fingerprints_separate_slices_and_graphs() {
        let fp = GraphFingerprint {
            order: 10,
            size: 20,
            hash: 0xABCD,
        };
        let a = shard_fingerprint(fp, 0, 5);
        let b = shard_fingerprint(fp, 5, 10);
        let c = shard_fingerprint(fp, 0, 10);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
        // deterministic (it keys durable state across restarts)
        assert_eq!(a, shard_fingerprint(fp, 0, 5));
        // a different graph separates even with equal slices
        let other = GraphFingerprint { hash: 0xABCE, ..fp };
        assert_ne!(shard_fingerprint(other, 0, 5), a);
    }
}
