//! DISTRIBUTED FIRST-LEVEL SHARDING — scale the fused sweep out across
//! processes.
//!
//! Everything below this module makes one process fast; this layer makes
//! *several* processes one system. The seam is the same one the
//! thread-parallel driver already exploits ([`crate::exec::parallel`]):
//! every match is rooted at exactly one first-level vertex, so
//! partitioning the first-level vertex range partitions the match set, and
//! **per-base totals are exact sums of per-slice partials**. A shard
//! worker is nothing more than a remote `_range` call — it runs the full
//! fused plan ([`crate::plan::fused`]) restricted to its contiguous slice
//! of the degree-ordered CSR, symmetry windows and all, so sharded
//! execution can never drift from single-process semantics.
//!
//! The split:
//!
//! * [`worker`] — `morphmine shard-worker --listen <addr>`: owns a
//!   **mutable** copy of the graph, answers slice requests over a framed
//!   TCP protocol (pipelined: several requests in flight per connection,
//!   replies matched by id), caches partials in per-slice
//!   [`ResultStore`](crate::service::ResultStore)s (a re-sent
//!   base × slice is served without matching), coalesces concurrent
//!   requests for the same base × slice, answers liveness probes inline
//!   from its read loop, applies coordinator-broadcast edge updates
//!   (proto v6 `UPDATE`: fingerprint-verified transitions, per-slice
//!   stores rebased — provably-unchanged bases carried warm, the rest
//!   purged to recompute-on-demand), and optionally persists its partials
//!   keyed by [`shard_fingerprint`] — graph × slice — so a shard restart
//!   recovers warm.
//! * [`proto`] — the wire protocol, reusing the persistence layer's
//!   CRC32 framing ([`crate::service::persist::frame`]). Handshakes carry
//!   the protocol version and graph fingerprint; a worker holding
//!   different content (or speaking a different revision) hard-rejects.
//! * [`coordinator`] — [`ShardPool`]: the fault-tolerant fan-out fabric.
//!   The topology is a list of **replica groups** ([`parse_topology`]:
//!   `a1|a2,b1|b2` — commas separate groups, pipes separate replicas).
//!   Each group owns a contiguous slice of the first-level range
//!   ([`weighted_cuts`]), cut further into degree-weighted **sub-slices**
//!   ([`weighted_ranges`] — the degree-ordered CSR makes low slices far
//!   heavier than high ones) dealt from a per-group work queue, so fast
//!   replicas steal remaining sub-slices from stragglers. In a replicated
//!   group a member failure (refused connect, broken pipe, probe timeout,
//!   error reply) **fails over** its unserved sub-slices to a live
//!   sibling, and a straggling sub-slice is **hedged** — duplicated onto
//!   an idle sibling, first reply wins; the batch fails loudly when a
//!   whole group is dead (its redundancy contract is exhausted). In the
//!   unreplicated topology (no `|` anywhere) all workers share one queue
//!   and PR 6's semantics are unchanged: capped-backoff reconnects, then
//!   **re-fanning** the dead worker's sub-slices across the survivors —
//!   the last resort, reached only when there is no sibling to fail over
//!   to. Opt-in verified reads double-dispatch a sampled fraction of
//!   sub-slices to two distinct replicas and hard-fail the batch on any
//!   mismatch. [`ShardCoordinator`]: the batch front door used by
//!   `morphmine batch|serve --shards <topology>`, composing the summed
//!   totals through the same morph algebra and result store as the
//!   single-process service
//!   ([`QueryPlanner::serve_batch_sharded`](crate::service::QueryPlanner::serve_batch_sharded)),
//!   and accepting live edge updates — delta-patching its own composed
//!   totals and broadcasting the mutation across the pool, so a
//!   long-lived sharded serve session never restarts cold.
//!
//! Failover, hedging, and re-fanning are trivially correct for the same
//! reason sharding is exact: sub-slices tile the first-level range, every
//! match roots at exactly one first-level vertex, and the per-key sums are
//! commutative — so it never matters *which* replica serves a sub-slice,
//! only that each one is merged exactly once, which the work queue's
//! completion count enforces. Determinism buys more than exactness:
//! identical slice ⇒ byte-identical partials on every replica, so a
//! verified read is a plain equality check and any divergence is a bug or
//! corruption, never noise.
//!
//! End to end:
//!
//! ```
//! use morphmine::graph::generators::erdos_renyi;
//! use morphmine::morph::Policy;
//! use morphmine::service::QueryPlanner;
//! use morphmine::shard::{ShardCoordinator, ShardWorker, WorkerConfig};
//!
//! // two "processes", each holding its own copy of the same graph
//! let graph = || erdos_renyi(60, 220, 7);
//! let a = ShardWorker::bind(graph(), "127.0.0.1:0", WorkerConfig::default()).unwrap();
//! let b = ShardWorker::bind(graph(), "127.0.0.1:0", WorkerConfig::default()).unwrap();
//! let addrs = vec![a.addr().to_string(), b.addr().to_string()];
//!
//! // the coordinator morphs, probes its cache, fans missing bases out,
//! // and composes the summed partials — exact, not approximate
//! let planner = QueryPlanner::new(Policy::Naive, true, 2);
//! let mut coord = ShardCoordinator::connect(graph(), &addrs, planner, 1 << 20).unwrap();
//! let r = coord.call(&["motifs:3"]).unwrap();
//! assert_eq!(r.results[0].counts.len(), 2, "wedge + triangle");
//! assert_eq!(r.stats.remote_bases, r.stats.executed_bases);
//! # drop(coord); a.shutdown(); b.shutdown();
//! ```

pub mod coordinator;
pub mod proto;
pub mod worker;

pub use coordinator::{PoolConfig, ShardClient, ShardMetrics, ShardPool, UpdateOutcome};
pub use worker::{ShardWorker, WorkerConfig};

use crate::graph::{DataGraph, DynGraph, GraphFingerprint, Relabeling, VertexId};
use crate::pattern::canon::CanonKey;
use crate::pattern::Pattern;
use crate::service::delta::{self, DeltaOutcome};
use crate::service::serve::{to_query_results, BatchResponse, ServiceQuery};
use crate::service::{QueryPlanner, ResultStore, StoreMetrics};
use crate::util::timer::PhaseProfile;
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

/// Parse a shard topology spec: comma-separated replica groups, each a
/// pipe-separated list of worker addresses — `a1|a2,b1|b2` is two groups
/// of two replicas; `a,b,c` is the unreplicated topology (three singleton
/// groups sharing one work queue, PR 6's semantics). Whitespace around
/// addresses is trimmed; empty groups, empty addresses, and duplicate
/// addresses (the same process serving twice would silently halve the
/// redundancy the spec promises) are errors.
pub fn parse_topology(spec: &str) -> Result<Vec<Vec<String>>> {
    let mut groups = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for (gi, group) in spec.split(',').enumerate() {
        if group.trim().is_empty() {
            // tolerate stray commas, exactly like the flat parser did
            continue;
        }
        let members: Vec<String> = group
            .split('|')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        if members.is_empty() {
            bail!("--shards group {} is empty in {spec:?}", gi + 1);
        }
        for m in &members {
            if !seen.insert(m.clone()) {
                bail!("--shards lists {m:?} twice: a replica set needs distinct processes");
            }
        }
        groups.push(members);
    }
    if groups.is_empty() {
        bail!("--shards needs at least one worker address");
    }
    Ok(groups)
}

/// Split `0..n` into `k` contiguous slices, one per shard in pool order.
/// Slices tile the range exactly (first starts at 0, last ends at `n`,
/// neighbours meet); with `k > n` the surplus slices are empty — an empty
/// slice contributes the aggregation identity, so correctness never
/// depends on `k` dividing `n`.
pub fn shard_ranges(n: u32, k: usize) -> Vec<(u32, u32)> {
    let k = k.max(1) as u64;
    (0..k)
        .map(|i| ((n as u64 * i / k) as u32, (n as u64 * (i + 1) / k) as u32))
        .collect()
}

/// Split `0..weights.len()` into at most `k` contiguous slices of roughly
/// equal **total weight** (quantile cuts of the prefix-sum). The work of
/// matching rooted at vertex `v` scales with its degree, and the CSR is
/// degree-ordered, so [`shard_ranges`]' equal-*width* slices make slice 0
/// a straggler by construction; weighting by `degree + 1` instead yields
/// sub-slices that cost about the same — tiny ranges over the hubs, wide
/// ranges over the low-degree tail.
///
/// Empty slices are dropped (a heavy single vertex can consume several
/// quantiles), so the result tiles `[0, n)` with between 1 and `k`
/// nonempty slices — and is a pure function of `(weights, k)`, which keeps
/// sub-slice boundaries stable across coordinators and restarts (worker
/// stores and durable state are keyed per slice).
pub fn weighted_ranges(weights: &[u64], k: usize) -> Vec<(u32, u32)> {
    let n = weights.len() as u32;
    let k = k.max(1);
    if n == 0 {
        return Vec::new();
    }
    let total: u128 = weights.iter().map(|&w| w as u128).sum::<u128>().max(1);
    let mut out = Vec::with_capacity(k);
    let (mut lo, mut acc, mut cut) = (0u32, 0u128, 1usize);
    for (v, &w) in weights.iter().enumerate() {
        acc += w as u128;
        // emit a boundary for every quantile the running sum has crossed
        while cut < k && acc * (k as u128) >= total * (cut as u128) {
            let hi = v as u32 + 1;
            if hi > lo {
                out.push((lo, hi));
                lo = hi;
            }
            cut += 1;
        }
    }
    if lo < n {
        out.push((lo, n));
    }
    out
}

/// Cut `0..weights.len()` into **exactly** `k` contiguous ranges at the
/// same weight quantiles as [`weighted_ranges`], keeping empty ranges so
/// that index `i` is stable — this is the group-level cut of a replicated
/// topology: group `i` of `k` owns `weighted_cuts(weights, k)[i]` and
/// every replica of that group serves (and persists) the same slices. The
/// index stability is what lets `shard-worker --slice i/k` compute its
/// group's range independently of the coordinator and pre-warm the right
/// persisted slices before the first request arrives.
pub fn weighted_cuts(weights: &[u64], k: usize) -> Vec<(u32, u32)> {
    let n = weights.len() as u32;
    let k = k.max(1);
    let total: u128 = weights.iter().map(|&w| w as u128).sum::<u128>().max(1);
    let mut bounds = Vec::with_capacity(k + 1);
    bounds.push(0u32);
    let (mut acc, mut cut) = (0u128, 1usize);
    for (v, &w) in weights.iter().enumerate() {
        acc += w as u128;
        while cut < k && acc * (k as u128) >= total * (cut as u128) {
            bounds.push(v as u32 + 1);
            cut += 1;
        }
    }
    // quantiles never crossed (all-zero weights, or fewer vertices than
    // cuts): the remaining boundaries all land at the end
    while bounds.len() < k {
        bounds.push(n);
    }
    bounds.push(n);
    bounds.windows(2).map(|w| (w[0], w[1])).collect()
}

/// Durable identity of one shard's partial counts: the graph fingerprint
/// folded with the slice bounds (same FNV-1a stream as the fingerprint
/// itself). A shard's persisted partials are valid only for the exact
/// `(graph content, first-level slice)` pair they were computed over —
/// restarting a worker against a different graph *or* a resized pool must
/// recover cold, never wrong, and this key makes both structurally
/// unservable (the same invariant the persistence layer already enforces
/// for whole-graph state).
pub fn shard_fingerprint(fp: GraphFingerprint, lo: u32, hi: u32) -> GraphFingerprint {
    let mut h = fp.hash;
    for b in lo.to_le_bytes().into_iter().chain(hi.to_le_bytes()) {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    GraphFingerprint {
        order: fp.order,
        size: fp.size,
        hash: h,
    }
}

/// The sharded batch front door: one coordinator process holding the
/// morph planner, a local result store for composed totals, and a
/// [`ShardPool`] that matches the missing bases. Answers are
/// [`BatchResponse`]s — byte-identical in content to what the
/// single-process service produces for the same graph and queries.
///
/// The coordinator's graph is **mutable**: [`ShardCoordinator::insert_edge`]
/// / [`ShardCoordinator::remove_edge`] apply the mutation to the
/// coordinator's own [`DynGraph`] copy, delta-patch the composed-totals
/// store across the epoch bump (the same
/// [`crate::service::delta`] pass the single-process service runs — the
/// coordinator's totals are full-graph counts, so a proven nonzero delta
/// patches exactly), and broadcast the mutation across the pool (proto v6
/// `UPDATE`), where each worker verifies the fingerprint transition
/// against its own copy and rebases its per-slice stores. Subsequent
/// batches carry the new graph version as their epoch. Sharded updates
/// never grow the vertex set: workers hold fixed copies whose slice
/// boundaries are keyed by the original vertex range, so an id outside it
/// is an error (the single-process service's
/// [`crate::service::serve::MAX_UPDATE_GROWTH`] slack does not apply
/// here).
///
/// The coordinator's [`crate::graph::GraphStats`] are pinned at connect
/// time and never recomputed: fused plan orders are a function of the
/// stats, and the workers pin theirs the same way, so recomputing on one
/// side would silently re-key cached partials.
pub struct ShardCoordinator {
    graph: DynGraph,
    /// Original→internal id translation from the initial degree-ordered
    /// build (`None` when the graph was not relabeled).
    relabel: Option<Relabeling>,
    stats: crate::graph::GraphStats,
    planner: QueryPlanner,
    store: ResultStore<i128>,
    pool: ShardPool,
    /// Every base pattern any batch has planned, keyed canonically — the
    /// delta pass needs patterns, the store only knows keys.
    patterns: HashMap<CanonKey, Pattern>,
    delta_budget: usize,
}

impl ShardCoordinator {
    /// Connect to every worker (handshaking each against `graph`'s
    /// fingerprint) and set up the coordinator-side planner and store.
    /// Each address forms its own singleton group — the unreplicated
    /// topology; use [`ShardCoordinator::connect_with`] for replica
    /// groups.
    pub fn connect(
        graph: DataGraph,
        addrs: &[String],
        planner: QueryPlanner,
        cache_bytes: usize,
    ) -> Result<ShardCoordinator> {
        let groups: Vec<Vec<String>> = addrs.iter().map(|a| vec![a.clone()]).collect();
        Self::connect_with(graph, &groups, planner, cache_bytes, PoolConfig::default())
    }

    /// [`ShardCoordinator::connect`] with an explicit replica-group
    /// topology (see [`parse_topology`]) and fabric tuning (timeouts,
    /// probe cadence, retry budget, sub-slicing, hedging, verified reads).
    pub fn connect_with(
        graph: DataGraph,
        groups: &[Vec<String>],
        planner: QueryPlanner,
        cache_bytes: usize,
        config: PoolConfig,
    ) -> Result<ShardCoordinator> {
        // same stats seed as the service layer: the coordinator's morph
        // plan (and the equality of its answers to single-process runs)
        // must not depend on which path computed the statistics
        let stats = crate::graph::GraphStats::compute(&graph, 2000, 0x5E55);
        let pool = ShardPool::connect_with(groups, &graph, config)?;
        let store = ResultStore::new(cache_bytes);
        // expose the composed-totals store on the coordinator's own
        // `--metrics` scrape (last coordinator built in-process wins)
        store.register_metrics(crate::obs::global(), "mm_store_");
        let relabel = graph.relabeling().cloned();
        Ok(ShardCoordinator {
            graph: DynGraph::from_data_graph(&graph),
            relabel,
            stats,
            planner,
            store,
            pool,
            patterns: HashMap::new(),
            delta_budget: delta::DEFAULT_DELTA_BUDGET,
        })
    }

    /// Cap the connected `(k)`-set neighborhood the delta pass may examine
    /// per update before falling back to a purge (see
    /// [`crate::service::delta::DEFAULT_DELTA_BUDGET`]); `0` disables
    /// delta-patching entirely — every update purges the composed-totals
    /// store and recomputes cold.
    pub fn set_delta_budget(&mut self, budget: usize) {
        self.delta_budget = budget;
    }

    /// Number of connected shard workers (replicas count individually).
    pub fn num_shards(&self) -> usize {
        self.pool.num_shards()
    }

    /// Number of replica groups in the topology.
    pub fn num_groups(&self) -> usize {
        self.pool.num_groups()
    }

    /// Number of degree-weighted sub-slices the pool deals per batch.
    pub fn num_sub_slices(&self) -> usize {
        self.pool.num_sub_slices()
    }

    /// Coordinator-side fan-out counters.
    pub fn shard_metrics(&self) -> ShardMetrics {
        self.pool.metrics()
    }

    /// Counters of the coordinator-local store of composed totals.
    pub fn store_metrics(&self) -> StoreMetrics {
        self.store.metrics()
    }

    /// Current graph epoch (count of applied mutations across the fabric).
    pub fn epoch(&self) -> u64 {
        self.graph.version()
    }

    /// Map an original (input) vertex id to the internal id the workers'
    /// degree-ordered CSRs use. Identity when the graph was never
    /// relabeled, or for ids past the relabeling's range.
    fn internal(&self, v: VertexId) -> VertexId {
        match &self.relabel {
            Some(r) if (v as usize) < r.len() => r.new_id(v),
            _ => v,
        }
    }

    /// Apply an edge insertion across the fabric: mutate the coordinator's
    /// copy, delta-patch the composed-totals store, and broadcast the
    /// mutation to every worker (see the struct docs). `Ok(true)` means
    /// applied everywhere that survived; `Ok(false)` is a duplicate insert
    /// (no-op, nothing broadcast). Self-loops and ids outside the vertex
    /// set are errors — sharded updates never grow the graph. Vertex ids
    /// are **original** (input) ids, exactly like the single-process
    /// service.
    pub fn insert_edge(&mut self, u: VertexId, v: VertexId) -> Result<bool> {
        ensure!(u != v, "self loop ({u},{u}) not allowed");
        let (u, v) = (self.internal(u), self.internal(v));
        ensure!(
            (u.max(v) as usize) < self.graph.num_vertices(),
            "vertex {} is outside the {}-vertex sharded graph: workers hold fixed \
             copies keyed by the original vertex range, so sharded updates cannot \
             grow the graph",
            u.max(v),
            self.graph.num_vertices()
        );
        let old_fp = self.graph.fingerprint();
        if !self.graph.insert_edge(u, v) {
            return Ok(false);
        }
        // the graph now contains the edge — the state the delta pass walks
        self.rebase_and_broadcast(u, v, true, old_fp)?;
        Ok(true)
    }

    /// Apply an edge removal across the fabric (see
    /// [`ShardCoordinator::insert_edge`]). Ids that name no edge return
    /// `Ok(false)`.
    pub fn remove_edge(&mut self, u: VertexId, v: VertexId) -> Result<bool> {
        let (u, v) = (self.internal(u), self.internal(v));
        if u == v || u.max(v) as usize >= self.graph.num_vertices() {
            return Ok(false);
        }
        if !self.graph.has_edge(u, v) {
            return Ok(false);
        }
        let old_fp = self.graph.fingerprint();
        // removal deltas are computed on the pre-removal graph — the one
        // that still contains the edge — then the removal is applied and
        // the store rebased to the post-removal epoch
        self.rebase_and_broadcast(u, v, false, old_fp)?;
        Ok(true)
    }

    /// Delta-rebase the composed-totals store across one applied edge
    /// update and broadcast the mutation to the pool. Called with the edge
    /// `(u,v)` **present** in `self.graph` (insertions already applied;
    /// removals applied here, after the delta pass). The coordinator's
    /// totals are order-independent full-graph counts, so every proven
    /// delta — zero or not — patches exactly; only fallbacks (and keys the
    /// registry can't resolve) purge to recompute-on-demand. The broadcast
    /// errors when it leaves a replica group with no live member; the
    /// coordinator's own state is already rebased by then, so a later
    /// batch against a repaired pool serves the patched values.
    fn rebase_and_broadcast(
        &mut self,
        u: VertexId,
        v: VertexId,
        inserted: bool,
        old_fp: GraphFingerprint,
    ) -> Result<()> {
        debug_assert!(self.graph.has_edge(u, v), "delta pass needs the edge present");
        let bases: Vec<(CanonKey, Pattern)> = self
            .store
            .entries()
            .iter()
            .filter_map(|(k, _)| self.patterns.get(k).map(|p| (*k, p.clone())))
            .collect();
        let report =
            delta::edge_update_deltas(&self.graph, u, v, inserted, &bases, self.delta_budget);
        if !inserted {
            let removed = self.graph.remove_edge(u, v);
            debug_assert!(removed, "caller checked the edge exists");
        }
        let epoch = self.graph.version();
        let new_fp = self.graph.fingerprint();
        crate::obs_counter!("mm_delta_updates_total").inc();
        let (patched, _dropped) = self.store.rebase_epoch(epoch, |k, old| {
            match report.deltas.get(k) {
                Some(DeltaOutcome::Patch(d)) => {
                    let next = old + d;
                    // a negative full-map count means a broken delta;
                    // purge defensively rather than ever serving it
                    (next >= 0).then_some(next)
                }
                _ => None,
            }
        });
        crate::obs_counter!("mm_delta_patched_total").add(patched);
        self.pool
            .broadcast_update(inserted, u, v, old_fp, new_fp, epoch)?;
        Ok(())
    }

    /// Proto v4 `STATS` sweep: every connected worker's metric registry as
    /// `(address, flat series)`, for the coordinator's aggregated cluster
    /// view (`--cluster-stats`). Unresponsive workers are skipped.
    pub fn collect_stats(&mut self) -> Vec<(String, Vec<(String, u64)>)> {
        self.pool.collect_stats()
    }

    /// Parse and serve one batch of query texts (`motifs:4`,
    /// `match:cycle4,p3`, `cliques:4`; FSM is rejected exactly as the
    /// in-process service rejects it).
    pub fn call(&mut self, queries: &[&str]) -> Result<BatchResponse> {
        let parsed = queries
            .iter()
            .map(|q| ServiceQuery::parse(q))
            .collect::<Result<Vec<_>>>()?;
        self.call_parsed(&parsed)
    }

    /// Serve one pre-parsed batch. The batch gets a fresh trace id and a
    /// span tree that spans the whole fabric: a root `batch` span, one
    /// child per pipeline stage, and under the `match` stage one span per
    /// remote sub-slice (the worker's own phase spans grafted beneath,
    /// carried back in the proto v5 RESULT) plus failover / hedge / retry
    /// event spans with outcome tags.
    pub fn call_parsed(&mut self, queries: &[ServiceQuery]) -> Result<BatchResponse> {
        let mut flat = Vec::new();
        let mut spans = Vec::with_capacity(queries.len());
        for q in queries {
            let start = flat.len();
            flat.extend(q.patterns.iter().cloned());
            spans.push((start, flat.len()));
        }
        // fixed span-id layout for the batch's trace: 1 is the root batch
        // span, 2 is the match stage (the parent every fabric span hangs
        // under — it must be known before the batch runs, so the pool can
        // parent its spans while replies land), the other stages follow,
        // and the pool allocates upward from TRACE_POOL_BASE, comfortably
        // past the handful of stage spans
        const TRACE_ROOT: u64 = 1;
        const TRACE_MATCH: u64 = 2;
        const TRACE_POOL_BASE: u64 = 64;
        let trace_id = crate::obs::trace::next_trace_id();
        let started = std::time::Instant::now();
        self.pool.set_trace(trace_id, TRACE_MATCH, TRACE_POOL_BASE, started);
        // record the batch's base patterns before serving: a later edge
        // update must be able to resolve every stored key back to its
        // pattern for the delta pass (the morph plan is recomputed inside
        // serve_batch_sharded; planning is pure rewriting, cheap next to
        // one remote fan-out)
        for p in self.planner.plan_bases(&flat, &self.stats) {
            self.patterns.entry(p.canonical_key()).or_insert(p);
        }
        let epoch = self.graph.version();
        let mut profile = PhaseProfile::new();
        let (vals, stats) = self.planner.serve_batch_sharded(
            &flat,
            &self.stats,
            &mut self.store,
            epoch,
            &mut self.pool,
            &mut profile,
        )?;
        let mut records = vec![crate::obs::SpanRecord {
            id: TRACE_ROOT,
            parent: 0,
            name: "batch".into(),
            start_us: 0,
            dur_us: started.elapsed().as_micros() as u64,
            tag: format!(
                "queries={} epoch={epoch} shards={}",
                queries.len(),
                self.pool.num_shards()
            ),
        }];
        let mut next_id = TRACE_MATCH + 1;
        let mut clock_us = 0u64;
        for (name, d) in profile.entries() {
            let dur_us = d.as_micros() as u64;
            let id = if name == "match" {
                TRACE_MATCH
            } else {
                next_id += 1;
                next_id - 1
            };
            records.push(crate::obs::SpanRecord {
                id,
                parent: TRACE_ROOT,
                name: name.clone(),
                start_us: clock_us,
                dur_us,
                tag: String::new(),
            });
            clock_us += dur_us;
        }
        records.extend(self.pool.take_spans());
        Ok(BatchResponse {
            results: to_query_results(queries, &spans, &vals),
            stats,
            epoch,
            profile,
            trace: crate::obs::Trace {
                trace_id,
                spans: records,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_tile_exactly() {
        for (n, k) in [(100u32, 1usize), (100, 3), (7, 7), (5, 9), (0, 2), (1, 1)] {
            let rs = shard_ranges(n, k);
            assert_eq!(rs.len(), k.max(1));
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs[rs.len() - 1].1, n);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0, "n={n} k={k}: slices must meet");
            }
            for &(lo, hi) in &rs {
                assert!(lo <= hi);
            }
            let covered: u64 = rs.iter().map(|&(lo, hi)| (hi - lo) as u64).sum();
            assert_eq!(covered, n as u64);
        }
    }

    #[test]
    fn weighted_ranges_tile_and_balance() {
        // uniform weights reduce to (at most) equal-width slices
        let uniform = vec![1u64; 12];
        let rs = weighted_ranges(&uniform, 4);
        assert_eq!(rs, vec![(0, 3), (3, 6), (6, 9), (9, 12)]);
        // a degree-ordered profile: the hub head gets narrow slices, the
        // tail gets wide ones, every slice is nonempty, and they tile
        let degrees: Vec<u64> = (0..100u64).map(|v| 200 - 2 * v + 1).collect();
        for k in [1usize, 2, 3, 7, 16, 100, 1000] {
            let rs = weighted_ranges(&degrees, k);
            assert!(!rs.is_empty() && rs.len() <= k, "k={k}: {} slices", rs.len());
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs[rs.len() - 1].1, 100);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0, "k={k}: slices must meet");
            }
            for &(lo, hi) in &rs {
                assert!(lo < hi, "k={k}: no empty slices");
            }
            // no slice exceeds twice the ideal share (plus one vertex of
            // rounding slack) — the balance property the work queue needs
            let total: u64 = degrees.iter().sum();
            for &(lo, hi) in &rs {
                let w: u64 = degrees[lo as usize..hi as usize].iter().sum();
                let max_one = degrees[lo as usize]; // heaviest vertex in the slice
                assert!(
                    w <= 2 * total / k as u64 + max_one,
                    "k={k}: slice [{lo},{hi}) weighs {w} of {total}"
                );
            }
        }
        // a single monster vertex consumes several quantiles without
        // producing empty slices
        let spiked = vec![1_000_000u64, 1, 1, 1];
        let rs = weighted_ranges(&spiked, 4);
        assert_eq!(rs[0], (0, 1));
        assert_eq!(rs[rs.len() - 1].1, 4);
        for &(lo, hi) in &rs {
            assert!(lo < hi);
        }
        // degenerate shapes
        assert!(weighted_ranges(&[], 3).is_empty());
        // all-zero weights collapse to one slice covering everything
        assert_eq!(weighted_ranges(&[0, 0], 2), vec![(0, 2)]);
        assert_eq!(weighted_ranges(&[5], 8), vec![(0, 1)]);
        // determinism: sub-slice boundaries key durable worker state
        assert_eq!(weighted_ranges(&degrees, 7), weighted_ranges(&degrees, 7));
    }

    #[test]
    fn topology_parses_groups_and_rejects_abuse() {
        // flat list: singleton groups, trailing comma tolerated
        let flat = parse_topology("a:1,b:2,").unwrap();
        assert_eq!(flat, vec![vec!["a:1".to_string()], vec!["b:2".to_string()]]);
        // replica groups with whitespace slack
        let groups = parse_topology(" a1:1 | a2:2 , b1:3|b2:4 ").unwrap();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0], vec!["a1:1".to_string(), "a2:2".to_string()]);
        assert_eq!(groups[1], vec!["b1:3".to_string(), "b2:4".to_string()]);
        // abuse: empty spec, pipe-only group, duplicate address
        assert!(parse_topology("").is_err());
        assert!(parse_topology(",,").is_err());
        assert!(parse_topology("a:1,|").is_err());
        let dup = parse_topology("a:1|a:1").unwrap_err().to_string();
        assert!(dup.contains("twice"), "{dup}");
        assert!(parse_topology("a:1,a:1").is_err());
    }

    #[test]
    fn weighted_cuts_are_stable_and_consistent_with_ranges() {
        let degrees: Vec<u64> = (0..100u64).map(|v| 200 - 2 * v + 1).collect();
        for k in [1usize, 2, 3, 7, 16] {
            let cuts = weighted_cuts(&degrees, k);
            // exactly k ranges, tiling [0, n)
            assert_eq!(cuts.len(), k);
            assert_eq!(cuts[0].0, 0);
            assert_eq!(cuts[k - 1].1, 100);
            for w in cuts.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            // the nonempty cuts are exactly weighted_ranges' slices: a
            // worker pinning --slice i/k and a coordinator cutting group
            // ranges agree on the boundaries
            let nonempty: Vec<(u32, u32)> =
                cuts.iter().copied().filter(|&(lo, hi)| lo < hi).collect();
            assert_eq!(nonempty, weighted_ranges(&degrees, k));
        }
        // all-zero weights: group 0 owns everything, the rest are empty
        // (index stability even in the degenerate case)
        assert_eq!(weighted_cuts(&[0, 0], 3), vec![(0, 2), (2, 2), (2, 2)]);
        assert!(weighted_cuts(&[], 2).iter().all(|&(lo, hi)| lo == hi));
    }

    #[test]
    fn shard_fingerprints_separate_slices_and_graphs() {
        let fp = GraphFingerprint {
            order: 10,
            size: 20,
            hash: 0xABCD,
        };
        let a = shard_fingerprint(fp, 0, 5);
        let b = shard_fingerprint(fp, 5, 10);
        let c = shard_fingerprint(fp, 0, 10);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
        // deterministic (it keys durable state across restarts)
        assert_eq!(a, shard_fingerprint(fp, 0, 5));
        // a different graph separates even with equal slices
        let other = GraphFingerprint { hash: 0xABCE, ..fp };
        assert_ne!(shard_fingerprint(other, 0, 5), a);
    }
}
