//! Wire protocol of the shard fan-out: length-prefixed, CRC32-framed
//! messages over a byte stream, reusing the persistence layer's frame
//! format ([`crate::service::persist::frame`]) so both subsystems share
//! one hardened codec.
//!
//! Every message is one frame whose payload starts with a tag byte:
//!
//! ```text
//! [payload len: u32 LE][crc32(payload): u32 LE][tag: u8][body…]
//! ```
//!
//! Handshake: the coordinator opens with [`Msg::Hello`] (magic + protocol
//! version + its graph's [`GraphFingerprint`]); the worker answers
//! [`Msg::Welcome`] when the version and fingerprint match what it speaks
//! and loaded, and [`Msg::Reject`] otherwise — a shard serving partial
//! counts for a *different* graph would merge into silent garbage, so a
//! mismatch is a hard reject, never a degraded mode. The version rides in
//! the `Hello` body and is decoded *tolerantly* (an unknown version still
//! yields a `Hello` carrying it), so a revision skew surfaces as a
//! descriptive reject naming both versions instead of an opaque framing
//! error. After the handshake the coordinator sends [`Msg::Exec`] requests
//! (each carrying a request id and the fingerprint again, so a coordinator
//! whose graph mutated mid-session is caught per-request) and the worker
//! answers [`Msg::Result`] or [`Msg::Error`]. Requests are pipelined:
//! several may be in flight on one connection, and replies are matched by
//! id, not order. While a request is being matched, the coordinator may
//! interleave [`Msg::Ping`] liveness probes; the worker answers
//! [`Msg::Pong`] inline from its read loop (echoing the nonce plus its
//! count of in-flight requests on that connection), which is what lets a
//! wedged-but-connected worker be told apart from one that is legitimately
//! deep in a heavy slice.
//!
//! Graph mutation rides on [`Msg::Update`] (proto v6): the coordinator
//! broadcasts one applied edge insert/removal, naming the fingerprint it
//! mutated *from* and the fingerprint and version it arrived *at*, and the
//! worker answers [`Msg::UpdateAck`] after mutating its own copy and
//! delta-patching its per-slice stores. The double fingerprint makes the
//! transition itself verifiable end-to-end: a worker whose copy diverged
//! (missed update, torn restart) fails the `old` check, and a worker whose
//! mutation somehow landed elsewhere fails the `new` check — both surface
//! as a refused ack, never as silently wrong partials.
//!
//! Decoding is total on hostile bytes, exactly like WAL replay: a short
//! header, an oversized length, a CRC mismatch or an unreadable body all
//! surface as an [`io::Error`] from [`read_msg`] (which closes the
//! connection) — never a panic. Unlike a WAL tail, a live stream has no
//! "truncate and continue" recovery: any framing violation ends the
//! conversation.

use crate::graph::GraphFingerprint;
use crate::pattern::canon::CanonKey;
use crate::pattern::{Pattern, MAX_PATTERN_VERTICES};
use crate::service::persist::frame::{self, ByteReader, FRAME_HEADER};
use std::io::{self, Read, Write};

/// Cap on one message's payload — far above any honest request or response
/// (a million-base response is ~33 MB), but low enough that a corrupt
/// length field is rejected before the reader allocates for it.
pub const MAX_MSG_LEN: usize = 64 << 20;

/// Protocol magic, first bytes of every handshake payload.
pub const MAGIC: &[u8; 8] = b"MMSHARD1";

/// Protocol version; bumped on any wire-format change. v2 added PING/PONG
/// liveness probes, pipelined request ids, and the version field in the
/// `Hello` body (decoded tolerantly so skew rejects descriptively). v3
/// added replica-group identity to the `Hello` body: the coordinator tells
/// each worker which slice group it serves (`group` of `groups`) and which
/// replica it is within that group, so a worker can pre-warm the persisted
/// slices its group owns and siblings of one group share a persistence
/// story (per-slice keys are fingerprint × slice, identical across
/// replicas). v4 added STATS/STATS_REPLY: the coordinator asks a worker
/// for a snapshot of its metric registry (flat `(series name, value)`
/// pairs, see [`crate::obs::flatten`]) and aggregates the replies into one
/// cluster view. Like PING, a STATS request is answered inline from the
/// worker's read loop, never queued behind matching work. v5 added trace
/// context: EXEC carries `(trace_id, parent_span)` of the coordinator's
/// batch trace and RESULT carries the worker's child spans back
/// ([`WireSpan`] — store probe, match, with reply-relative parent
/// indices), so a sharded batch assembles one span tree across the whole
/// fabric (see [`crate::obs::trace`]). v6 added UPDATE/UPDATE_ACK: the
/// coordinator broadcasts applied edge mutations (with the old and new
/// graph fingerprints and the new version) so workers mutate their graph
/// copies in place and delta-patch their per-slice stores instead of
/// being restarted cold.
pub const VERSION: u32 = 6;

const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_REJECT: u8 = 3;
const TAG_EXEC: u8 = 4;
const TAG_RESULT: u8 = 5;
const TAG_ERROR: u8 = 6;
const TAG_PING: u8 = 7;
const TAG_PONG: u8 = 8;
const TAG_STATS: u8 = 9;
const TAG_STATS_REPLY: u8 = 10;
const TAG_UPDATE: u8 = 11;
const TAG_UPDATE_ACK: u8 = 12;

/// One shard-execution request: match `patterns` (base patterns of a morph
/// plan) with the first exploration level restricted to `[lo, hi)`.
#[derive(Clone, Debug)]
pub struct ExecRequest {
    /// Request id, echoed in the response.
    pub id: u64,
    /// Coordinator's cache epoch — echoed back so the coordinator can tag
    /// the partials; the worker's own store identity rides on the
    /// fingerprint (its graph is immutable).
    pub epoch: u64,
    /// Fingerprint of the graph the coordinator is mining **now**. The
    /// worker re-checks it on every request: a coordinator whose graph
    /// mutated after the handshake must not receive partials computed on
    /// the worker's (unmutated) copy.
    pub fingerprint: GraphFingerprint,
    /// First-level slice, inclusive-exclusive.
    pub lo: u32,
    /// First-level slice end.
    pub hi: u32,
    /// Trace id of the coordinator's batch trace (0 = untraced). Pure
    /// observability: the worker echoes it into nothing and decides
    /// nothing by it — it only labels the spans riding back in the
    /// response.
    pub trace_id: u64,
    /// Span id of the coordinator's dispatch span for this sub-slice —
    /// the parent the worker's spans conceptually attach to
    /// (informational; the response's spans use reply-relative indices,
    /// see [`WireSpan::rel_parent`]).
    pub parent_span: u64,
    /// Base patterns to match (distinct canonical forms).
    pub patterns: Vec<Pattern>,
}

/// One worker-side trace span riding back in a proto v5 RESULT.
/// Timings are microseconds relative to the worker's handling of the
/// request (the coordinator offsets them by the sub-slice dispatch time
/// when grafting); `rel_parent` is an index into the same reply's span
/// list, or [`crate::obs::trace::WIRE_PARENT_ROOT`] to attach to the
/// coordinator's dispatch span — reply-relative links mean span ids
/// never need cross-process coordination.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireSpan {
    pub rel_parent: u32,
    pub start_us: u64,
    pub dur_us: u64,
    pub name: String,
    pub tag: String,
}

/// A shard's answer: per-base **partial map counts** over its slice.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExecResponse {
    /// Echoed request id.
    pub id: u64,
    /// Echoed coordinator epoch.
    pub epoch: u64,
    /// How many of the requested bases the worker served from its local
    /// result store instead of matching (shard-level cache reuse).
    pub served_from_store: u32,
    /// `(canonical key, partial map count)` — one entry per distinct
    /// requested base.
    pub values: Vec<(CanonKey, i128)>,
    /// The worker's trace spans for this request (store probe, match
    /// stages), reply-relative (proto v5). Observability only — the
    /// coordinator's merge logic never reads them; an empty vector is a
    /// complete, valid response.
    pub spans: Vec<WireSpan>,
}

/// One broadcast edge mutation (proto v6). Vertex ids are **internal**
/// (post-relabeling) ids — the coordinator translates before it
/// broadcasts, so a worker applies the update to the identical graph copy
/// it loaded at bind time without knowing about original ids at all.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UpdateRequest {
    /// Request id, echoed in the ack.
    pub id: u64,
    /// `true` for an insertion, `false` for a removal.
    pub insert: bool,
    /// Internal endpoint ids of the mutated edge.
    pub u: u32,
    /// See `u`.
    pub v: u32,
    /// Fingerprint of the graph the coordinator mutated *from*. A worker
    /// whose copy doesn't carry this fingerprint has diverged (missed an
    /// update, restarted against other content) and must refuse.
    pub old_fingerprint: GraphFingerprint,
    /// Fingerprint the coordinator's graph arrived *at*. The worker
    /// verifies its own copy lands on the same fingerprint after applying
    /// the mutation — the transition is checked on both ends.
    pub new_fingerprint: GraphFingerprint,
    /// The coordinator's graph version after the mutation; becomes the
    /// epoch of the worker's rebased per-slice stores.
    pub new_version: u64,
}

/// A worker's answer to an [`UpdateRequest`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UpdateAck {
    /// Echoed request id.
    pub id: u64,
    /// Whether the worker applied the mutation and landed on the
    /// requested fingerprint. `false` always comes with a descriptive
    /// `error` naming what diverged.
    pub applied: bool,
    /// The worker's graph fingerprint after handling the request —
    /// `new_fingerprint` on success, whatever it actually holds on
    /// failure, so the coordinator's error can name both sides.
    pub fingerprint: GraphFingerprint,
    /// Per-slice store entries carried across the epoch (delta-patched in
    /// place — for a worker these are exactly the provably-unchanged
    /// bases, see the worker docs for why partials are never arithmetic-
    /// patched).
    pub carried: u64,
    /// Per-slice store entries purged to recompute-on-demand.
    pub purged: u64,
    /// Human-readable failure description; empty on success.
    pub error: String,
}

/// A protocol message.
#[derive(Clone, Debug)]
pub enum Msg {
    /// Coordinator → worker greeting (magic, version, graph fingerprint,
    /// replica-group identity). `version` is what the *peer* speaks: an
    /// unknown version decodes to a `Hello` carrying it (with the rest of
    /// the body zeroed, since that tail is the other revision's layout), so
    /// the worker can reject by name instead of dropping the connection on
    /// a framing error. `group`/`groups`/`replica` tell the worker which
    /// slice group of the topology this connection serves and which replica
    /// within the group it is — informational for logging, load-bearing for
    /// replica-aware warm-up (the worker can pre-warm exactly the persisted
    /// slices its group owns).
    Hello {
        version: u32,
        fingerprint: GraphFingerprint,
        /// Index of the slice group this worker serves (0-based).
        group: u32,
        /// Total number of slice groups in the coordinator's topology.
        groups: u32,
        /// Index of this worker within its replica group (0-based).
        replica: u32,
    },
    /// Worker → coordinator: fingerprints match, ready for requests.
    Welcome {
        fingerprint: GraphFingerprint,
        /// Matcher threads the worker runs per request (informational).
        threads: u32,
    },
    /// Worker → coordinator: handshake refused (wrong graph or version,
    /// bad magic).
    Reject { reason: String },
    /// Coordinator → worker: execute a first-level slice.
    Exec(ExecRequest),
    /// Worker → coordinator: partial counts.
    Result(ExecResponse),
    /// Worker → coordinator: the request failed (echoes the request id).
    Error { id: u64, message: String },
    /// Coordinator → worker: liveness probe, sent while replies are
    /// outstanding. The nonce is echoed in the matching [`Msg::Pong`].
    Ping { nonce: u64 },
    /// Worker → coordinator: probe answer, written inline from the read
    /// loop (never queued behind matching work). `inflight` is the
    /// worker's count of requests still being matched on this connection —
    /// a pong proves the socket and the read loop; `inflight > 0` proves
    /// the probed requests are actually registered and being worked.
    Pong { nonce: u64, inflight: u32 },
    /// Coordinator → worker: snapshot your metric registry. Answered
    /// inline from the read loop, like [`Msg::Ping`].
    Stats { id: u64 },
    /// Worker → coordinator: flat `(series name, value)` pairs in the
    /// summable form of [`crate::obs::flatten`] — histograms ride as
    /// `_count`/`_sum`/cumulative `_bucket{le="…"}` series, so the
    /// coordinator can sum same-named series across workers and re-derive
    /// cluster percentiles exactly (percentiles themselves never cross the
    /// wire: averaging them would be meaningless).
    StatsReply { id: u64, series: Vec<(String, u64)> },
    /// Coordinator → worker: apply one edge mutation to your graph copy
    /// and rebase your per-slice stores (proto v6).
    Update(UpdateRequest),
    /// Worker → coordinator: mutation outcome.
    UpdateAck(UpdateAck),
}

fn put_fingerprint(out: &mut Vec<u8>, fp: GraphFingerprint) {
    out.extend_from_slice(&fp.to_bytes());
}

fn put_pattern(out: &mut Vec<u8>, p: &Pattern) {
    out.push(p.num_vertices() as u8);
    let edges = p.edges();
    let anti = p.anti_edges();
    out.push(edges.len() as u8);
    for (u, v) in edges {
        out.push(u as u8);
        out.push(v as u8);
    }
    out.push(anti.len() as u8);
    for (u, v) in anti {
        out.push(u as u8);
        out.push(v as u8);
    }
    match p.labels_vec() {
        Some(labels) => {
            out.push(1);
            for l in labels {
                out.extend_from_slice(&l.to_le_bytes());
            }
        }
        None => out.push(0),
    }
}

fn take_pattern(r: &mut ByteReader<'_>) -> Option<Pattern> {
    let n = r.u8()? as usize;
    if !(1..=MAX_PATTERN_VERTICES).contains(&n) {
        return None;
    }
    let mut p = Pattern::empty(n);
    let n_edges = r.u8()? as usize;
    for _ in 0..n_edges {
        let (u, v) = (r.u8()? as usize, r.u8()? as usize);
        // pre-validate: `add_edge` asserts, and hostile bytes must degrade
        // to "unreadable", never to a panic
        if u >= n || v >= n || u == v || p.has_edge(u, v) {
            return None;
        }
        p.add_edge(u, v);
    }
    let n_anti = r.u8()? as usize;
    for _ in 0..n_anti {
        let (u, v) = (r.u8()? as usize, r.u8()? as usize);
        if u >= n || v >= n || u == v || p.has_edge(u, v) || p.has_anti_edge(u, v) {
            return None;
        }
        p.add_anti_edge(u, v);
    }
    match r.u8()? {
        0 => Some(p),
        1 => {
            let mut labels = Vec::with_capacity(n);
            for _ in 0..n {
                labels.push(r.u32()?);
            }
            Some(p.with_labels(&labels))
        }
        _ => None,
    }
}

fn take_fingerprint(r: &mut ByteReader<'_>) -> Option<GraphFingerprint> {
    GraphFingerprint::from_bytes(r.take(GraphFingerprint::BYTES)?)
}

/// Minimum wire cost of one [`WireSpan`]: rel_parent + start + dur +
/// two u16 string lengths — bounds an honest span count by the payload.
const WIRE_SPAN_MIN: usize = 4 + 8 + 8 + 2 + 2;

fn put_wire_span(out: &mut Vec<u8>, s: &WireSpan) {
    out.extend_from_slice(&s.rel_parent.to_le_bytes());
    out.extend_from_slice(&s.start_us.to_le_bytes());
    out.extend_from_slice(&s.dur_us.to_le_bytes());
    for text in [&s.name, &s.tag] {
        // u16 length caps a span string at 64 KiB; truncate at a char
        // boundary rather than emit a length the bytes don't honor
        let mut len = text.len().min(u16::MAX as usize);
        while len > 0 && !text.is_char_boundary(len) {
            len -= 1;
        }
        out.extend_from_slice(&(len as u16).to_le_bytes());
        out.extend_from_slice(&text.as_bytes()[..len]);
    }
}

fn take_wire_span(r: &mut ByteReader<'_>) -> Option<WireSpan> {
    let rel_parent = r.u32()?;
    let start_us = r.u64()?;
    let dur_us = r.u64()?;
    let mut texts = [String::new(), String::new()];
    for t in &mut texts {
        let len = u16::from_le_bytes(r.take(2)?.try_into().ok()?) as usize;
        // strict UTF-8: span names are generated by our own tracer;
        // garbage means a codec mismatch, not a name worth salvaging
        *t = std::str::from_utf8(r.take(len)?).ok()?.to_string();
    }
    let [name, tag] = texts;
    Some(WireSpan {
        rel_parent,
        start_us,
        dur_us,
        name,
        tag,
    })
}

/// Encode a message into one frame payload (tag + body).
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    match msg {
        Msg::Hello {
            version,
            fingerprint,
            group,
            groups,
            replica,
        } => {
            out.push(TAG_HELLO);
            out.extend_from_slice(MAGIC);
            out.extend_from_slice(&version.to_le_bytes());
            put_fingerprint(&mut out, *fingerprint);
            out.extend_from_slice(&group.to_le_bytes());
            out.extend_from_slice(&groups.to_le_bytes());
            out.extend_from_slice(&replica.to_le_bytes());
        }
        Msg::Welcome { fingerprint, threads } => {
            out.push(TAG_WELCOME);
            out.extend_from_slice(MAGIC);
            out.extend_from_slice(&VERSION.to_le_bytes());
            put_fingerprint(&mut out, *fingerprint);
            out.extend_from_slice(&threads.to_le_bytes());
        }
        Msg::Reject { reason } => {
            out.push(TAG_REJECT);
            out.extend_from_slice(reason.as_bytes());
        }
        Msg::Exec(req) => {
            out.push(TAG_EXEC);
            out.extend_from_slice(&req.id.to_le_bytes());
            out.extend_from_slice(&req.epoch.to_le_bytes());
            put_fingerprint(&mut out, req.fingerprint);
            out.extend_from_slice(&req.lo.to_le_bytes());
            out.extend_from_slice(&req.hi.to_le_bytes());
            out.extend_from_slice(&req.trace_id.to_le_bytes());
            out.extend_from_slice(&req.parent_span.to_le_bytes());
            out.extend_from_slice(&(req.patterns.len() as u32).to_le_bytes());
            for p in &req.patterns {
                put_pattern(&mut out, p);
            }
        }
        Msg::Result(resp) => {
            out.push(TAG_RESULT);
            out.extend_from_slice(&resp.id.to_le_bytes());
            out.extend_from_slice(&resp.epoch.to_le_bytes());
            out.extend_from_slice(&resp.served_from_store.to_le_bytes());
            out.extend_from_slice(&(resp.values.len() as u32).to_le_bytes());
            for (k, v) in &resp.values {
                out.push(k.n);
                out.extend_from_slice(&k.pairs.to_le_bytes());
                out.extend_from_slice(&k.labels.to_le_bytes());
                out.extend_from_slice(&v.to_le_bytes());
            }
            out.extend_from_slice(&(resp.spans.len() as u32).to_le_bytes());
            for s in &resp.spans {
                put_wire_span(&mut out, s);
            }
        }
        Msg::Error { id, message } => {
            out.push(TAG_ERROR);
            out.extend_from_slice(&id.to_le_bytes());
            out.extend_from_slice(message.as_bytes());
        }
        Msg::Ping { nonce } => {
            out.push(TAG_PING);
            out.extend_from_slice(&nonce.to_le_bytes());
        }
        Msg::Pong { nonce, inflight } => {
            out.push(TAG_PONG);
            out.extend_from_slice(&nonce.to_le_bytes());
            out.extend_from_slice(&inflight.to_le_bytes());
        }
        Msg::Stats { id } => {
            out.push(TAG_STATS);
            out.extend_from_slice(&id.to_le_bytes());
        }
        Msg::StatsReply { id, series } => {
            out.push(TAG_STATS_REPLY);
            out.extend_from_slice(&id.to_le_bytes());
            out.extend_from_slice(&(series.len() as u32).to_le_bytes());
            for (name, value) in series {
                out.extend_from_slice(&(name.len() as u32).to_le_bytes());
                out.extend_from_slice(name.as_bytes());
                out.extend_from_slice(&value.to_le_bytes());
            }
        }
        Msg::Update(req) => {
            out.push(TAG_UPDATE);
            out.extend_from_slice(&req.id.to_le_bytes());
            out.push(req.insert as u8);
            out.extend_from_slice(&req.u.to_le_bytes());
            out.extend_from_slice(&req.v.to_le_bytes());
            put_fingerprint(&mut out, req.old_fingerprint);
            put_fingerprint(&mut out, req.new_fingerprint);
            out.extend_from_slice(&req.new_version.to_le_bytes());
        }
        Msg::UpdateAck(ack) => {
            out.push(TAG_UPDATE_ACK);
            out.extend_from_slice(&ack.id.to_le_bytes());
            out.push(ack.applied as u8);
            put_fingerprint(&mut out, ack.fingerprint);
            out.extend_from_slice(&ack.carried.to_le_bytes());
            out.extend_from_slice(&ack.purged.to_le_bytes());
            out.extend_from_slice(ack.error.as_bytes());
        }
    }
    out
}

/// Decode one frame payload. Total: hostile bytes return `None`.
pub fn decode(payload: &[u8]) -> Option<Msg> {
    let mut r = ByteReader::new(payload);
    let msg = match r.u8()? {
        TAG_HELLO => {
            if r.take(MAGIC.len())? != MAGIC {
                return None;
            }
            let version = r.u32()?;
            if version != VERSION {
                // a peer speaking another protocol revision: the rest of
                // the body is that revision's layout and is not
                // interpreted; surface the version so the handshake can
                // reject it by name instead of on a framing error
                return Some(Msg::Hello {
                    version,
                    fingerprint: GraphFingerprint {
                        order: 0,
                        size: 0,
                        hash: 0,
                    },
                    group: 0,
                    groups: 0,
                    replica: 0,
                });
            }
            let fingerprint = take_fingerprint(&mut r)?;
            let group = r.u32()?;
            let groups = r.u32()?;
            let replica = r.u32()?;
            Msg::Hello {
                version,
                fingerprint,
                group,
                groups,
                replica,
            }
        }
        TAG_WELCOME => {
            if r.take(MAGIC.len())? != MAGIC || r.u32()? != VERSION {
                return None;
            }
            let fingerprint = take_fingerprint(&mut r)?;
            let threads = r.u32()?;
            Msg::Welcome { fingerprint, threads }
        }
        TAG_REJECT => {
            return Some(Msg::Reject {
                reason: String::from_utf8_lossy(r.rest()).into_owned(),
            });
        }
        TAG_EXEC => {
            let id = r.u64()?;
            let epoch = r.u64()?;
            let fingerprint = take_fingerprint(&mut r)?;
            let lo = r.u32()?;
            let hi = r.u32()?;
            let trace_id = r.u64()?;
            let parent_span = r.u64()?;
            let n = r.u32()? as usize;
            // an honest count is bounded by the payload: every pattern
            // costs at least 4 bytes on the wire
            if n > payload.len() / 4 + 1 {
                return None;
            }
            let mut patterns = Vec::with_capacity(n);
            for _ in 0..n {
                patterns.push(take_pattern(&mut r)?);
            }
            Msg::Exec(ExecRequest {
                id,
                epoch,
                fingerprint,
                lo,
                hi,
                trace_id,
                parent_span,
                patterns,
            })
        }
        TAG_RESULT => {
            let id = r.u64()?;
            let epoch = r.u64()?;
            let served_from_store = r.u32()?;
            let n = r.u32()? as usize;
            if n > payload.len() / 33 + 1 {
                return None;
            }
            let mut values = Vec::with_capacity(n);
            for _ in 0..n {
                let key = CanonKey {
                    n: r.u8()?,
                    pairs: r.u64()?,
                    labels: r.u64()?,
                };
                let v = i128::from_le_bytes(r.take(16)?.try_into().ok()?);
                values.push((key, v));
            }
            let m = r.u32()? as usize;
            // same bound discipline as values: every span costs at least
            // WIRE_SPAN_MIN bytes on the wire
            if m > payload.len() / WIRE_SPAN_MIN + 1 {
                return None;
            }
            let mut spans = Vec::with_capacity(m);
            for _ in 0..m {
                spans.push(take_wire_span(&mut r)?);
            }
            Msg::Result(ExecResponse {
                id,
                epoch,
                served_from_store,
                values,
                spans,
            })
        }
        TAG_ERROR => {
            let id = r.u64()?;
            return Some(Msg::Error {
                id,
                message: String::from_utf8_lossy(r.rest()).into_owned(),
            });
        }
        TAG_PING => Msg::Ping { nonce: r.u64()? },
        TAG_PONG => Msg::Pong {
            nonce: r.u64()?,
            inflight: r.u32()?,
        },
        TAG_STATS => Msg::Stats { id: r.u64()? },
        TAG_STATS_REPLY => {
            let id = r.u64()?;
            let n = r.u32()? as usize;
            // an honest count is bounded by the payload: every series
            // costs at least 12 bytes on the wire (length + value)
            if n > payload.len() / 12 + 1 {
                return None;
            }
            let mut series = Vec::with_capacity(n);
            for _ in 0..n {
                let name_len = r.u32()? as usize;
                if name_len > payload.len() {
                    return None;
                }
                let name = std::str::from_utf8(r.take(name_len)?).ok()?.to_string();
                let value = r.u64()?;
                series.push((name, value));
            }
            Msg::StatsReply { id, series }
        }
        TAG_UPDATE => {
            let id = r.u64()?;
            // strict booleans: any byte but 0/1 means a codec mismatch
            let insert = match r.u8()? {
                0 => false,
                1 => true,
                _ => return None,
            };
            let u = r.u32()?;
            let v = r.u32()?;
            let old_fingerprint = take_fingerprint(&mut r)?;
            let new_fingerprint = take_fingerprint(&mut r)?;
            let new_version = r.u64()?;
            Msg::Update(UpdateRequest {
                id,
                insert,
                u,
                v,
                old_fingerprint,
                new_fingerprint,
                new_version,
            })
        }
        TAG_UPDATE_ACK => {
            let id = r.u64()?;
            let applied = match r.u8()? {
                0 => false,
                1 => true,
                _ => return None,
            };
            let fingerprint = take_fingerprint(&mut r)?;
            let carried = r.u64()?;
            let purged = r.u64()?;
            // the error text runs to the end of the payload, like REJECT
            return Some(Msg::UpdateAck(UpdateAck {
                id,
                applied,
                fingerprint,
                carried,
                purged,
                error: String::from_utf8_lossy(r.rest()).into_owned(),
            }));
        }
        _ => return None,
    };
    // trailing garbage after a well-formed body means a codec mismatch:
    // refuse rather than guess
    if !r.is_empty() {
        return None;
    }
    Some(msg)
}

/// Write one framed message and flush it.
pub fn write_msg(w: &mut impl Write, msg: &Msg) -> io::Result<()> {
    frame::write_frame(w, &encode(msg))?;
    w.flush()
}

/// Read one framed message from a stream. Any framing or decoding
/// violation is an [`io::Error`] — the caller closes the connection.
pub fn read_msg(r: &mut impl Read) -> io::Result<Msg> {
    let mut head = [0u8; FRAME_HEADER];
    r.read_exact(&mut head)?;
    let len = u32::from_le_bytes(head[..4].try_into().expect("4 bytes")) as usize;
    let crc = u32::from_le_bytes(head[4..].try_into().expect("4 bytes"));
    if len > MAX_MSG_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("shard frame length {len} exceeds MAX_MSG_LEN"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    if frame::crc32(&payload) != crc {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "shard frame CRC mismatch",
        ));
    }
    decode(&payload).ok_or_else(|| {
        io::Error::new(io::ErrorKind::InvalidData, "unreadable shard message")
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;

    fn fp(seed: u64) -> GraphFingerprint {
        GraphFingerprint {
            order: 100,
            size: 250,
            hash: seed,
        }
    }

    fn roundtrip(msg: &Msg) -> Msg {
        let mut buf = Vec::new();
        write_msg(&mut buf, msg).unwrap();
        read_msg(&mut &buf[..]).unwrap()
    }

    #[test]
    fn handshake_roundtrip() {
        let hello = Msg::Hello {
            version: VERSION,
            fingerprint: fp(7),
            group: 1,
            groups: 2,
            replica: 1,
        };
        match roundtrip(&hello) {
            Msg::Hello {
                version,
                fingerprint,
                group,
                groups,
                replica,
            } => {
                assert_eq!((version, fingerprint), (VERSION, fp(7)));
                assert_eq!((group, groups, replica), (1, 2, 1));
            }
            other => panic!("{other:?}"),
        }
        match roundtrip(&Msg::Welcome { fingerprint: fp(9), threads: 4 }) {
            Msg::Welcome { fingerprint, threads } => {
                assert_eq!((fingerprint, threads), (fp(9), 4))
            }
            other => panic!("{other:?}"),
        }
        match roundtrip(&Msg::Reject { reason: "wrong graph".into() }) {
            Msg::Reject { reason } => assert_eq!(reason, "wrong graph"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn exec_roundtrip_preserves_patterns() {
        let patterns = vec![
            catalog::triangle(),
            catalog::cycle(4).vertex_induced(),
            catalog::path(3).with_labels(&[2, 0, 1]),
            Pattern::empty(1),
        ];
        let req = ExecRequest {
            id: 42,
            epoch: 3,
            fingerprint: fp(1),
            lo: 100,
            hi: 200,
            trace_id: 0xFACE_0FF5,
            parent_span: 17,
            patterns: patterns.clone(),
        };
        match roundtrip(&Msg::Exec(req)) {
            Msg::Exec(got) => {
                assert_eq!((got.id, got.epoch, got.lo, got.hi), (42, 3, 100, 200));
                assert_eq!((got.trace_id, got.parent_span), (0xFACE_0FF5, 17));
                assert_eq!(got.fingerprint, fp(1));
                assert_eq!(got.patterns.len(), patterns.len());
                for (a, b) in got.patterns.iter().zip(&patterns) {
                    assert_eq!(a, b, "patterns must survive the wire exactly");
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn result_and_error_roundtrip() {
        let values = vec![
            (catalog::triangle().canonical_key(), 123i128),
            (catalog::clique(4).canonical_key(), -7i128),
            (catalog::cycle(5).canonical_key(), i128::MAX),
        ];
        let spans = vec![
            WireSpan {
                rel_parent: crate::obs::trace::WIRE_PARENT_ROOT,
                start_us: 0,
                dur_us: 1200,
                name: "probe".into(),
                tag: "hits=2 misses=1".into(),
            },
            WireSpan {
                rel_parent: 0,
                start_us: 1200,
                dur_us: 88_000,
                name: "match".into(),
                tag: String::new(), // empty tags survive too
            },
        ];
        let resp = ExecResponse {
            id: 42,
            epoch: 9,
            served_from_store: 2,
            values: values.clone(),
            spans: spans.clone(),
        };
        match roundtrip(&Msg::Result(resp)) {
            Msg::Result(got) => {
                assert_eq!((got.id, got.epoch, got.served_from_store), (42, 9, 2));
                assert_eq!(got.values, values);
                assert_eq!(got.spans, spans);
            }
            other => panic!("{other:?}"),
        }
        // spanless responses are representable (and the common warm case)
        match roundtrip(&Msg::Result(ExecResponse {
            id: 1,
            epoch: 0,
            served_from_store: 0,
            values: vec![],
            spans: vec![],
        })) {
            Msg::Result(got) => assert!(got.values.is_empty() && got.spans.is_empty()),
            other => panic!("{other:?}"),
        }
        match roundtrip(&Msg::Error { id: 5, message: "boom".into() }) {
            Msg::Error { id, message } => assert_eq!((id, message.as_str()), (5, "boom")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn ping_pong_roundtrip() {
        match roundtrip(&Msg::Ping { nonce: u64::MAX }) {
            Msg::Ping { nonce } => assert_eq!(nonce, u64::MAX),
            other => panic!("{other:?}"),
        }
        match roundtrip(&Msg::Pong { nonce: 17, inflight: 3 }) {
            Msg::Pong { nonce, inflight } => assert_eq!((nonce, inflight), (17, 3)),
            other => panic!("{other:?}"),
        }
        // probes are tiny: they must fit well under any frame budget so a
        // probe can always be written even when big replies are in flight
        assert!(encode(&Msg::Ping { nonce: 1 }).len() < 16);
    }

    #[test]
    fn stats_roundtrip() {
        match roundtrip(&Msg::Stats { id: 77 }) {
            Msg::Stats { id } => assert_eq!(id, 77),
            other => panic!("{other:?}"),
        }
        let series = vec![
            ("mm_store_hits_total".to_string(), 123u64),
            ("mm_service_batch_us_bucket{le=\"4095\"}".to_string(), 9),
            (String::new(), u64::MAX), // empty names survive too
        ];
        match roundtrip(&Msg::StatsReply { id: 77, series: series.clone() }) {
            Msg::StatsReply { id, series: got } => {
                assert_eq!(id, 77);
                assert_eq!(got, series);
            }
            other => panic!("{other:?}"),
        }
        // empty registries are representable
        match roundtrip(&Msg::StatsReply { id: 1, series: vec![] }) {
            Msg::StatsReply { series, .. } => assert!(series.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn update_roundtrip() {
        let req = UpdateRequest {
            id: 31,
            insert: true,
            u: 7,
            v: 1999,
            old_fingerprint: fp(4),
            new_fingerprint: fp(5),
            new_version: 12,
        };
        match roundtrip(&Msg::Update(req.clone())) {
            Msg::Update(got) => assert_eq!(got, req),
            other => panic!("{other:?}"),
        }
        // removals survive too (insert=false is a distinct wire byte)
        let removal = UpdateRequest { insert: false, ..req };
        match roundtrip(&Msg::Update(removal.clone())) {
            Msg::Update(got) => assert_eq!(got, removal),
            other => panic!("{other:?}"),
        }
        let ack = UpdateAck {
            id: 31,
            applied: true,
            fingerprint: fp(5),
            carried: 9,
            purged: 4,
            error: String::new(),
        };
        match roundtrip(&Msg::UpdateAck(ack.clone())) {
            Msg::UpdateAck(got) => assert_eq!(got, ack),
            other => panic!("{other:?}"),
        }
        let refused = UpdateAck {
            applied: false,
            error: "fingerprint diverged".into(),
            ..ack
        };
        match roundtrip(&Msg::UpdateAck(refused.clone())) {
            Msg::UpdateAck(got) => assert_eq!(got, refused),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hostile_update_bytes_never_panic() {
        let mut buf = Vec::new();
        let req = UpdateRequest {
            id: 2,
            insert: false,
            u: 0,
            v: 49,
            old_fingerprint: fp(1),
            new_fingerprint: fp(2),
            new_version: 3,
        };
        write_msg(&mut buf, &Msg::Update(req.clone())).unwrap();
        for cut in 0..buf.len() {
            assert!(read_msg(&mut &buf[..cut]).is_err(), "cut at {cut}");
        }
        for at in 0..buf.len() {
            let mut evil = buf.clone();
            evil[at] ^= 0x20;
            let _ = read_msg(&mut &evil[..]);
        }
        // a non-boolean insert byte is a codec mismatch, not "truthy"
        let mut evil = encode(&Msg::Update(req.clone()));
        evil[1 + 8] = 2;
        assert!(decode(&evil).is_none());
        // trailing garbage after a well-formed UPDATE is refused
        let mut ok = encode(&Msg::Update(req));
        ok.push(0);
        assert!(decode(&ok).is_none());
        // a non-boolean applied byte in the ack is refused the same way
        let ack = UpdateAck {
            id: 2,
            applied: true,
            fingerprint: fp(2),
            carried: 1,
            purged: 0,
            error: String::new(),
        };
        let mut evil = encode(&Msg::UpdateAck(ack));
        evil[1 + 8] = 7;
        assert!(decode(&evil).is_none());
    }

    #[test]
    fn hostile_stats_bytes_never_panic() {
        let mut buf = Vec::new();
        let series = vec![("mm_kernel_ops_total".to_string(), 42u64)];
        write_msg(&mut buf, &Msg::StatsReply { id: 3, series }).unwrap();
        for cut in 0..buf.len() {
            assert!(read_msg(&mut &buf[..cut]).is_err(), "cut at {cut}");
        }
        // a count field claiming more series than the payload can hold
        let mut evil = vec![TAG_STATS_REPLY];
        evil.extend_from_slice(&1u64.to_le_bytes());
        evil.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&evil).is_none());
        // a name length pointing past the payload
        let mut evil = vec![TAG_STATS_REPLY];
        evil.extend_from_slice(&1u64.to_le_bytes());
        evil.extend_from_slice(&1u32.to_le_bytes());
        evil.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&evil).is_none());
        // invalid UTF-8 in a series name is refused, not lossily accepted
        // (names are generated by our own exporter; garbage means a codec
        // mismatch)
        let mut evil = vec![TAG_STATS_REPLY];
        evil.extend_from_slice(&1u64.to_le_bytes());
        evil.extend_from_slice(&1u32.to_le_bytes());
        evil.extend_from_slice(&2u32.to_le_bytes());
        evil.extend_from_slice(&[0xFF, 0xFE]);
        evil.extend_from_slice(&7u64.to_le_bytes());
        assert!(decode(&evil).is_none());
        // trailing garbage after a well-formed reply is refused
        let mut ok = encode(&Msg::StatsReply { id: 2, series: vec![] });
        ok.push(0);
        assert!(decode(&ok).is_none());
    }

    #[test]
    fn unknown_hello_version_decodes_tolerantly() {
        // a v1 peer's Hello (no version-99 layouts exist, so fabricate the
        // closest thing: right magic, wrong version, arbitrary tail)
        let mut payload = vec![1u8]; // TAG_HELLO
        payload.extend_from_slice(MAGIC);
        payload.extend_from_slice(&99u32.to_le_bytes());
        payload.extend_from_slice(&[0xAB; 7]); // unintelligible tail
        match decode(&payload) {
            Some(Msg::Hello { version, .. }) => assert_eq!(version, 99),
            other => panic!("version skew must decode to a rejectable Hello, got {other:?}"),
        }
        // but the magic is still load-bearing
        let mut bad_magic = payload.clone();
        bad_magic[1] ^= 0xFF;
        assert!(decode(&bad_magic).is_none());
        // and the current version still validates its full body
        let mut truncated = vec![1u8];
        truncated.extend_from_slice(MAGIC);
        truncated.extend_from_slice(&VERSION.to_le_bytes());
        assert!(decode(&truncated).is_none(), "current version demands a fingerprint");
        // ... including the group identity that follows the fingerprint
        truncated.extend_from_slice(&fp(3).to_bytes());
        assert!(decode(&truncated).is_none(), "current version demands group identity");
    }

    #[test]
    fn hostile_bytes_never_panic() {
        // every truncation of a valid message fails cleanly (the torn-frame
        // walk of frame.rs, applied to the shard codec)
        let mut buf = Vec::new();
        let req = ExecRequest {
            id: 1,
            epoch: 0,
            fingerprint: fp(1),
            lo: 0,
            hi: 50,
            trace_id: 0xABCD,
            parent_span: 3,
            patterns: vec![catalog::triangle(), catalog::diamond().vertex_induced()],
        };
        write_msg(&mut buf, &Msg::Exec(req)).unwrap();
        for cut in 0..buf.len() {
            assert!(read_msg(&mut &buf[..cut]).is_err(), "cut at {cut}");
        }
        // every single-bit flip is caught by the CRC (or decodes to a
        // well-formed message that differs, for flips inside the header's
        // own CRC field — either way, no panic)
        for at in 0..buf.len() {
            let mut evil = buf.clone();
            evil[at] ^= 0x20;
            let _ = read_msg(&mut &evil[..]);
        }
        // garbage payloads with a valid frame never decode
        for payload in [&[][..], &[99u8][..], &[TAG_EXEC, 1, 2, 3][..]] {
            let mut framed = Vec::new();
            frame::write_frame(&mut framed, payload).unwrap();
            assert!(read_msg(&mut &framed[..]).is_err());
        }
        // a pattern with out-of-range vertices is rejected, not asserted on
        let mut evil_exec = vec![TAG_EXEC];
        evil_exec.extend_from_slice(&1u64.to_le_bytes());
        evil_exec.extend_from_slice(&0u64.to_le_bytes());
        evil_exec.extend_from_slice(&fp(1).to_bytes());
        evil_exec.extend_from_slice(&0u32.to_le_bytes());
        evil_exec.extend_from_slice(&10u32.to_le_bytes());
        evil_exec.extend_from_slice(&7u64.to_le_bytes()); // trace_id
        evil_exec.extend_from_slice(&1u64.to_le_bytes()); // parent_span
        evil_exec.extend_from_slice(&1u32.to_le_bytes());
        evil_exec.extend_from_slice(&[3, 1, 0, 7, 0]); // edge (0,7) on a 3-vertex pattern
        assert!(decode(&evil_exec).is_none());
        // trailing garbage after a valid body is refused
        let mut ok = encode(&Msg::Hello {
            version: VERSION,
            fingerprint: fp(2),
            group: 0,
            groups: 1,
            replica: 0,
        });
        ok.push(0);
        assert!(decode(&ok).is_none());
    }

    #[test]
    fn hostile_trace_span_bytes_never_panic() {
        // the v5 fields get the same fuzz walks as the rest of the codec:
        // a spanful RESULT survives every truncation and every bit flip
        let resp = ExecResponse {
            id: 9,
            epoch: 1,
            served_from_store: 0,
            values: vec![(catalog::triangle().canonical_key(), 5i128)],
            spans: vec![
                WireSpan {
                    rel_parent: crate::obs::trace::WIRE_PARENT_ROOT,
                    start_us: 3,
                    dur_us: 400,
                    name: "probe".into(),
                    tag: "hits=1".into(),
                },
                WireSpan {
                    rel_parent: 0,
                    start_us: 403,
                    dur_us: 9000,
                    name: "match".into(),
                    tag: "lo=0 hi=50".into(),
                },
            ],
        };
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Result(resp.clone())).unwrap();
        for cut in 0..buf.len() {
            assert!(read_msg(&mut &buf[..cut]).is_err(), "cut at {cut}");
        }
        for at in 0..buf.len() {
            let mut evil = buf.clone();
            evil[at] ^= 0x20;
            let _ = read_msg(&mut &evil[..]);
        }
        let body = encode(&Msg::Result(resp));
        // a span count claiming more spans than the payload can hold
        let mut evil = body.clone();
        let count_at = body.len()
            - resp_spans_bytes(&body)
            - 4;
        evil[count_at..count_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&evil).is_none());
        // a span tag length pointing past the payload (the final tag's
        // u16 length field sits exactly tag-len + 2 bytes from the end)
        let mut evil = body.clone();
        let at = evil.len() - "lo=0 hi=50".len() - 2;
        evil[at..at + 2].copy_from_slice(&u16::MAX.to_le_bytes());
        assert!(decode(&evil).is_none());
        // invalid UTF-8 in a span name is refused, not lossily accepted
        let mut evil = Vec::new();
        evil.push(TAG_RESULT);
        evil.extend_from_slice(&1u64.to_le_bytes());
        evil.extend_from_slice(&0u64.to_le_bytes());
        evil.extend_from_slice(&0u32.to_le_bytes());
        evil.extend_from_slice(&0u32.to_le_bytes()); // zero values
        evil.extend_from_slice(&1u32.to_le_bytes()); // one span
        evil.extend_from_slice(&0u32.to_le_bytes()); // rel_parent
        evil.extend_from_slice(&0u64.to_le_bytes()); // start
        evil.extend_from_slice(&0u64.to_le_bytes()); // dur
        evil.extend_from_slice(&2u16.to_le_bytes());
        evil.extend_from_slice(&[0xFF, 0xFE]); // not UTF-8
        evil.extend_from_slice(&0u16.to_le_bytes());
        assert!(decode(&evil).is_none());
        // truncated EXEC trace context (v4-shaped body) is unreadable,
        // never misparsed: the old layout is 16 bytes short of v5's
        let req = ExecRequest {
            id: 1,
            epoch: 0,
            fingerprint: fp(1),
            lo: 0,
            hi: 10,
            trace_id: 0,
            parent_span: 0,
            patterns: vec![catalog::triangle()],
        };
        let body = encode(&Msg::Exec(req));
        let mut v4_shaped = body.clone();
        // excise the two trace-context words (they sit after lo/hi)
        let at = 1 + 8 + 8 + GraphFingerprint::BYTES + 4 + 4;
        v4_shaped.drain(at..at + 16);
        assert!(decode(&v4_shaped).is_none());
    }

    /// Bytes the span section occupies at the tail of an encoded RESULT
    /// (everything after the values) — lets the hostile test find the
    /// span-count field without hardcoding offsets.
    fn resp_spans_bytes(body: &[u8]) -> usize {
        // re-decode to learn the span section size structurally
        match decode(body) {
            Some(Msg::Result(r)) => r
                .spans
                .iter()
                .map(|s| WIRE_SPAN_MIN + s.name.len() + s.tag.len())
                .sum(),
            _ => panic!("helper fed a non-RESULT body"),
        }
    }
}
