//! Shard worker: a process that owns one copy of the data graph and
//! answers [`ExecRequest`]s — "match these base patterns with the first
//! level restricted to `[lo, hi)`" — over TCP, mutating that copy in
//! place when the coordinator broadcasts [`Msg::Update`]s.
//!
//! The worker is the service layer in miniature:
//!
//! * **Per-slice stores** — partial counts are pure functions of
//!   `(canonical key, graph content, slice)`, so the worker keeps one
//!   [`ResultStore`] *per first-level slice* it has served. The fabric's
//!   work queue deals sub-slices dynamically — the same worker may serve
//!   `[0, 7)` and `[31, 64)` in one batch and a different mix in the next
//!   — and each slice's partials stay warm independently. Stores live at
//!   the graph's current *version* (epoch); content identity rides on the
//!   [`GraphFingerprint`] checked at handshake *and on every request*.
//! * **Mutation** (proto v6) — an [`Msg::Update`] names the fingerprint
//!   the coordinator mutated from and the one it arrived at. The worker
//!   applies the edge to its own [`DynGraph`], verifies it lands on the
//!   same fingerprint, regenerates its matching snapshot, and *rebases*
//!   every slice store: a base is carried across the epoch only when the
//!   delta pass ([`crate::service::delta`]) proves **no embedding was
//!   created or destroyed** — a zero net delta on an anti-edge-free
//!   pattern (affected embeddings all map a pattern edge onto the mutated
//!   pair, so they all carry the same sign and a zero sum means zero
//!   embeddings). Everything weaker is purged to recompute-on-demand:
//!   per-slice partials split one embedding multiset by first-level
//!   vertex, and a nonzero (or sign-mixed, for vertex-induced patterns)
//!   delta may move counts *between* slices even when the full-graph
//!   total is provably patchable — so the worker never arithmetic-patches
//!   a partial. Requests in flight during an update stay pinned to their
//!   admission state: they matched on the snapshot [`Arc`] they cloned at
//!   admission, and their late store inserts are stale-dropped by the
//!   epoch check. Graph stats (and therefore fused plan orders) are
//!   pinned at bind time, never recomputed, so cached partials and
//!   post-update recomputes always agree on slice boundaries and orders.
//! * **Coalescing** — concurrent connections asking for the same
//!   base × slice register on a per-`(slice, key)` in-flight cell (the
//!   same at-most-once discipline as [`crate::service::serve`]): each
//!   base × slice is matched at most once per worker, whoever asks.
//! * **Pipelining + liveness** — the connection read loop never blocks on
//!   matching: each [`Msg::Exec`] is handed to its own thread and replies
//!   are written (under a shared writer lock) whenever they finish, so
//!   several requests overlap on one connection and replies may be
//!   reordered — the coordinator matches them by id. [`Msg::Ping`] probes
//!   are answered inline with [`Msg::Pong`] carrying the connection's
//!   in-flight request count, which is what lets the coordinator tell a
//!   live worker deep in a heavy slice from one that lost its requests.
//! * **Durability** — with a persist directory configured, each slice's
//!   published partials are mirrored into their own WAL + snapshot
//!   subdirectory (`slice-<lo>-<hi>/`, keyed by [`super::shard_fingerprint`]
//!   — graph × slice) via the same machinery as the coordinator's store
//!   ([`crate::service::persist`]); a clean shutdown
//!   ([`ShardWorker::shutdown`] / drop — embedders and tests) compacts
//!   every slice so a restart recovers from snapshots. The CLI worker
//!   blocks in [`ShardWorker::wait`] and is stopped by killing the
//!   process, which skips that compaction: the WALs are flushed per
//!   record, so the restart replays the logs — slower, never colder — and
//!   a dead owner's dir locks are reclaimed automatically (Linux `/proc`
//!   probe; elsewhere the lock needs the manual removal the startup error
//!   names).
//!
//! [`ExecRequest`]: super::proto::ExecRequest

use super::proto::{self, ExecRequest, ExecResponse, Msg, UpdateAck, UpdateRequest};
use crate::graph::{DataGraph, DynGraph, GraphFingerprint, GraphStats};
use crate::morph::Policy;
use crate::pattern::canon::CanonKey;
use crate::pattern::Pattern;
use crate::service::delta::{self, DeltaOutcome};
use crate::service::persist::{PersistConfig, Persistence};
use crate::service::{QueryPlanner, ResultStore, StoreMetrics};
use crate::util::timer::PhaseProfile;
use anyhow::{ensure, Context, Result};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;

/// Worker tuning.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Matcher threads per request.
    pub threads: usize,
    /// Fuse multi-base requests into one trie traversal.
    pub fused: bool,
    /// Result-store budget in bytes, per served slice.
    pub cache_bytes: usize,
    /// Persist the partial-count stores (keyed by graph × slice, one
    /// subdirectory per slice) so a shard restart recovers warm.
    pub persist: Option<PersistConfig>,
    /// Pin this worker to group `i` of a `k`-group topology
    /// (`--slice i/k`, 0-based): at startup it eagerly re-opens every
    /// persisted slice store overlapping its group's cut of the
    /// first-level range, instead of lazily on the first request that
    /// touches each slice. Group cuts are index-stable
    /// ([`super::weighted_cuts`]), so the pin and the coordinator agree
    /// on the boundaries without talking.
    pub slice_pin: Option<(usize, usize)>,
}

impl Default for WorkerConfig {
    fn default() -> WorkerConfig {
        WorkerConfig {
            threads: crate::exec::parallel::default_threads(),
            fused: true,
            cache_bytes: 64 << 20,
            persist: None,
            slice_pin: None,
        }
    }
}

/// Upper bound on distinct slices the worker keeps stores for. Sub-slice
/// boundaries are a pure function of the graph and the pool size, so an
/// honest fleet produces a few dozen at most; a hostile or churning
/// coordinator population sheds the oldest instead of growing without
/// bound (partials are pure — dropping a store costs recompute, never
/// correctness).
const MAX_SLICE_STORES: usize = 128;

/// Completion cell for one in-flight base × slice (see
/// [`crate::service::serve`]).
#[derive(Default)]
struct Cell {
    value: Mutex<Option<std::result::Result<i128, &'static str>>>,
    ready: Condvar,
}

/// One slice's partial-count store and its durable mirror.
struct SliceStore {
    store: ResultStore<i128>,
    persist: Option<Persistence<i128>>,
}

struct Inner {
    slices: HashMap<(u32, u32), SliceStore>,
    inflight: HashMap<((u32, u32), CanonKey), Arc<Cell>>,
    /// Canonical key → pattern for every base this worker has been asked
    /// to match. An [`Msg::Update`]'s delta pass needs the patterns behind
    /// the cached keys; stored keys whose pattern was never seen (warm
    /// restores from disk before any request) simply rebase as purges.
    patterns: HashMap<CanonKey, Pattern>,
}

/// The worker's mutable graph identity, swapped atomically under one
/// [`RwLock`]: requests clone the [`Arc`]s at admission (pinning
/// themselves to that state), updates take the write lock to mutate.
struct GraphState {
    /// The mutable source of truth, in internal-id space.
    dyn_graph: DynGraph,
    /// Immutable matching snapshot of `dyn_graph`'s current content.
    snapshot: Arc<DataGraph>,
    /// Pinned at bind time and **never recomputed**: fused plan orders
    /// (and therefore what a cached per-slice partial means) are a
    /// function of the stats, so recomputing them after a mutation would
    /// silently re-key every cached partial.
    stats: Arc<GraphStats>,
    fingerprint: GraphFingerprint,
    /// Graph version = store epoch. Starts at 0, set to the
    /// coordinator-supplied `new_version` on every applied update.
    version: u64,
}

struct WorkerState {
    graph: RwLock<GraphState>,
    planner: QueryPlanner,
    cache_bytes: usize,
    persist_config: Option<PersistConfig>,
    // lock order: `graph` before `inner`, never the reverse
    inner: Mutex<Inner>,
}

/// Unwind/error guard for the cells a request registered: disarmed after a
/// successful publish, otherwise fails them so coalesced requests error
/// instead of hanging.
struct OwnedCells<'a> {
    state: &'a WorkerState,
    keys: Vec<((u32, u32), CanonKey)>,
    armed: bool,
}

impl Drop for OwnedCells<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let mut inner = match self.state.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        for k in &self.keys {
            if let Some(cell) = inner.inflight.remove(k) {
                *cell.value.lock().unwrap() = Some(Err("owner failed before publishing"));
                cell.ready.notify_all();
            }
        }
    }
}

/// A running shard worker: a TCP listener plus the shared state behind it.
/// [`ShardWorker::shutdown`] (or drop) stops the accept loop and — when
/// persistence is on — compacts every slice's durable store so the next
/// start recovers from snapshots.
pub struct ShardWorker {
    addr: SocketAddr,
    state: Arc<WorkerState>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ShardWorker {
    /// Bind `listen` (e.g. `127.0.0.1:7401`, port `0` for an ephemeral
    /// port) and start accepting coordinator connections over `graph`.
    pub fn bind(graph: DataGraph, listen: &str, config: WorkerConfig) -> Result<ShardWorker> {
        if let Some((i, k)) = config.slice_pin {
            ensure!(
                k >= 1 && i < k,
                "--slice {i}/{k}: the group index must be below the group count"
            );
        }
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding shard worker listener on {listen}"))?;
        let addr = listener.local_addr().context("reading bound address")?;
        // every exposition from this process (scrape, STATS_REPLY) carries
        // the constant mm_build_info series identifying version and SIMD leg
        crate::obs::register_build_info();
        // the same stats seed as the service layer, so fused order
        // selection on the worker mirrors what a single process would pick
        let stats = GraphStats::compute(&graph, 2000, 0x5E55);
        let fingerprint = graph.fingerprint();
        let dyn_graph = DynGraph::from_data_graph(&graph);
        let state = Arc::new(WorkerState {
            graph: RwLock::new(GraphState {
                dyn_graph,
                snapshot: Arc::new(graph),
                stats: Arc::new(stats),
                fingerprint,
                version: 0,
            }),
            // the policy field is morph-only and workers never morph: they
            // receive already-rewritten base patterns
            planner: QueryPlanner::new(Policy::Off, config.fused, config.threads),
            cache_bytes: config.cache_bytes,
            persist_config: config.persist,
            inner: Mutex::new(Inner {
                slices: HashMap::new(),
                inflight: HashMap::new(),
                patterns: HashMap::new(),
            }),
        });
        if let Some((i, k)) = config.slice_pin {
            // --slice i/k pinning: don't wait for the first coordinator to
            // announce the topology — re-open this group's persisted slice
            // stores now, so the first batch after a restart starts warm
            prewarm_group(&state, i, k, 0);
        }
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let state = state.clone();
            let stop = stop.clone();
            std::thread::spawn(move || accept_loop(&listener, &state, &stop))
        };
        Ok(ShardWorker {
            addr,
            state,
            stop,
            accept: Some(accept),
        })
    }

    /// The address the worker is listening on (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Fingerprint of the graph content this worker currently serves
    /// slices of (moves when a broadcast update is applied).
    pub fn fingerprint(&self) -> GraphFingerprint {
        self.state.graph.read().unwrap().fingerprint
    }

    /// The worker's current graph version (0 until the first applied
    /// update; thereafter the coordinator-supplied version).
    pub fn version(&self) -> u64 {
        self.state.graph.read().unwrap().version
    }

    /// Counters of the worker-local partial-count stores, summed over
    /// every slice this worker has served.
    pub fn store_metrics(&self) -> StoreMetrics {
        let inner = self.state.inner.lock().unwrap();
        let mut m = StoreMetrics::default();
        for ss in inner.slices.values() {
            let s = ss.store.metrics();
            m.hits += s.hits;
            m.misses += s.misses;
            m.inserts += s.inserts;
            m.evictions += s.evictions;
            m.invalidations += s.invalidations;
            m.patched += s.patched;
            m.stale_drops += s.stale_drops;
            m.restored += s.restored;
            m.bytes += s.bytes;
        }
        m
    }

    /// Block until the accept loop ends (i.e. forever, for a CLI worker
    /// that is stopped by killing the process). Shutdown compaction still
    /// runs on drop after an external shutdown.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, join the accept loop and compact the durable
    /// stores. Established connections are not severed: their threads
    /// drain naturally when the peer disconnects.
    pub fn shutdown(self) {
        drop(self);
    }

    fn stop_now(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // graceful-shutdown flush, mirroring Service::drop: fold each
        // slice's WAL into one snapshot so a shard restart skips replay
        if let Ok(mut inner) = self.state.inner.lock() {
            for ss in inner.slices.values_mut() {
                if let Some(p) = &mut ss.persist {
                    if p.compact_on_drop() && p.dirty() {
                        if let Err(e) = p.compact(&ss.store.entries()) {
                            eprintln!("warning: shard store compaction failed: {e}");
                        }
                    }
                }
                // release the persist dir locks deterministically
                ss.persist = None;
            }
        }
    }
}

impl Drop for ShardWorker {
    fn drop(&mut self) {
        self.stop_now();
    }
}

fn accept_loop(listener: &TcpListener, state: &Arc<WorkerState>, stop: &Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if let Ok(stream) = conn {
            let state = state.clone();
            std::thread::spawn(move || serve_connection(state, stream));
        }
    }
}

fn serve_connection(state: Arc<WorkerState>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    // handshake: the coordinator must speak this protocol revision and be
    // mining the exact graph content this worker loaded — partial counts
    // for any other graph are garbage, so a mismatch is a hard reject
    let reject = |stream: &mut TcpStream, reason: String| {
        let _ = proto::write_msg(stream, &Msg::Reject { reason });
    };
    let worker_fp = state.graph.read().unwrap().fingerprint;
    match proto::read_msg(&mut stream) {
        Ok(Msg::Hello { version, .. }) if version != proto::VERSION => {
            reject(
                &mut stream,
                format!(
                    "protocol version mismatch: coordinator speaks v{version}, \
                     this worker speaks v{}",
                    proto::VERSION
                ),
            );
            return;
        }
        Ok(Msg::Hello { fingerprint, group, groups, replica, .. })
            if fingerprint == worker_fp =>
        {
            let welcome = Msg::Welcome {
                fingerprint: worker_fp,
                threads: state.planner.threads as u32,
            };
            if proto::write_msg(&mut stream, &welcome).is_err() {
                return;
            }
            // replica-aware warm-up: the coordinator just told us which
            // group seat this connection serves — eagerly re-open that
            // cut's persisted slice stores (a no-op when none exist or
            // they are already open)
            if (group as usize) < (groups as usize) {
                prewarm_group(&state, group as usize, groups as usize, replica);
            }
        }
        Ok(Msg::Hello { fingerprint, .. }) => {
            reject(
                &mut stream,
                format!(
                    "graph fingerprint mismatch: coordinator mines {fingerprint}, \
                     this worker loaded {worker_fp}"
                ),
            );
            return;
        }
        _ => {
            reject(&mut stream, "expected HELLO".into());
            return;
        }
    }
    // pipelined serving: the read loop only parses; each Exec runs on its
    // own thread and writes its reply (Result or Error, matched by id)
    // under the shared writer lock whenever it finishes. Pings are
    // answered inline so probes are never queued behind matching work.
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let inflight = Arc::new(AtomicU32::new(0));
    loop {
        let msg = match proto::read_msg(&mut stream) {
            Ok(m) => m,
            Err(_) => return, // disconnect or framing violation: done
        };
        match msg {
            Msg::Ping { nonce } => {
                let pong = Msg::Pong {
                    nonce,
                    inflight: inflight.load(Ordering::SeqCst),
                };
                if proto::write_msg(&mut *writer.lock().unwrap(), &pong).is_err() {
                    return;
                }
            }
            Msg::Stats { id } => {
                // answered inline like PING: a registry snapshot is cheap
                // and must not queue behind matching work
                let reply = Msg::StatsReply {
                    id,
                    series: crate::obs::flatten(crate::obs::global()),
                };
                if proto::write_msg(&mut *writer.lock().unwrap(), &reply).is_err() {
                    return;
                }
            }
            Msg::Exec(req) => {
                // count the request before reading the next message: a
                // pong sent for a later ping must already include it
                inflight.fetch_add(1, Ordering::SeqCst);
                let state = state.clone();
                let writer = writer.clone();
                let inflight = inflight.clone();
                std::thread::spawn(move || {
                    // a panicking request must not kill the connection
                    // silently: the OwnedCells guard inside handle_exec
                    // has already failed any cells it owned, and the
                    // coordinator gets an explicit error
                    let started = std::time::Instant::now();
                    let reply = match std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| handle_exec(&state, &req)),
                    ) {
                        Ok(Ok(resp)) => Msg::Result(resp),
                        Ok(Err(message)) => Msg::Error { id: req.id, message },
                        Err(_) => Msg::Error {
                            id: req.id,
                            message: "worker request panicked".into(),
                        },
                    };
                    crate::obs_counter!("mm_worker_requests_total").inc();
                    crate::obs_histogram!("mm_worker_exec_us").record_duration(started.elapsed());
                    let _ = proto::write_msg(&mut *writer.lock().unwrap(), &reply);
                    // decrement only after the reply hit the socket: a
                    // pong reporting zero in-flight therefore proves every
                    // reply is already ordered ahead of it on the wire
                    inflight.fetch_sub(1, Ordering::SeqCst);
                });
            }
            Msg::Update(req) => {
                // handled inline on the read loop: mutations are rare,
                // must not reorder against each other, and in-flight
                // execs are pinned to the snapshot Arcs they cloned at
                // admission — nothing here waits on them
                let ack = handle_update(&state, &req);
                if proto::write_msg(&mut *writer.lock().unwrap(), &Msg::UpdateAck(ack)).is_err()
                {
                    return;
                }
            }
            _ => return,
        }
    }
}

/// Slice ranges with a persisted store under `dir` (subdirectories named
/// `slice-<lo>-<hi>`), sorted. Unreadable dirs and foreign names are
/// skipped — pre-warming is an optimisation, never a correctness gate.
fn persisted_slices(dir: &std::path::Path) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let Ok(rd) = std::fs::read_dir(dir) else {
        return out;
    };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(rest) = name.strip_prefix("slice-") else {
            continue;
        };
        let mut parts = rest.splitn(2, '-');
        if let (Some(lo), Some(hi)) = (parts.next(), parts.next()) {
            if let (Ok(lo), Ok(hi)) = (lo.parse(), hi.parse()) {
                out.push((lo, hi));
            }
        }
    }
    out.sort_unstable();
    out
}

/// Eagerly open every persisted slice store overlapping group `group` of a
/// `groups`-way cut of the first-level range. Used by `--slice i/k`
/// pinning at startup and by the handshake's replica-aware warm-up — both
/// compute the same index-stable cut ([`super::weighted_cuts`]) the
/// coordinator deals from, so the stores restored here are exactly the
/// ones the group's sub-slices will ask for.
fn prewarm_group(state: &WorkerState, group: usize, groups: usize, replica: u32) {
    let Some(pc) = &state.persist_config else {
        return;
    };
    let found = persisted_slices(&pc.dir);
    if found.is_empty() {
        return;
    }
    let gs = state.graph.read().unwrap();
    let weights: Vec<u64> = (0..gs.snapshot.num_vertices() as u32)
        .map(|v| gs.snapshot.degree(v) as u64 + 1)
        .collect();
    let (lo, hi) = super::weighted_cuts(&weights, groups)[group];
    let (fingerprint, version) = (gs.fingerprint, gs.version);
    drop(gs);
    let mut inner = state.inner.lock().unwrap();
    let mut warmed = 0usize;
    for &(slo, shi) in &found {
        if slo >= shi || shi <= lo || slo >= hi {
            continue; // empty or outside this group's cut
        }
        if inner.slices.len() >= MAX_SLICE_STORES {
            break; // respect the store cap; the rest loads lazily
        }
        if !inner.slices.contains_key(&(slo, shi)) {
            ensure_slice(state, &mut inner, (slo, shi), fingerprint, version);
            warmed += 1;
        }
    }
    if warmed > 0 {
        eprintln!(
            "shard persist: replica {replica} of group {}/{groups} pre-warmed \
             {warmed} slice store(s) in [{lo}, {hi})",
            group + 1
        );
    }
}

/// Mirror one accepted store insert into the slice's WAL (same degradation
/// contract as the service layer: first IO error disables persistence).
fn persist_insert(persist: &mut Option<Persistence<i128>>, key: &CanonKey, value: i128) {
    if let Some(p) = persist {
        if let Err(e) = p.record_insert(key, &value) {
            eprintln!("warning: shard WAL append failed, persistence disabled: {e}");
            *persist = None;
        }
    }
}

/// Get-or-create the store bound to `slice`, at the worker's current
/// `fingerprint` × `version`. Each slice's durable store lives in its own
/// subdirectory keyed by [`super::shard_fingerprint`] — graph fingerprint
/// × slice — so a restarted worker recovers warm exactly for the
/// `(graph, slice)` pairs that were persisted, and cold otherwise. The
/// store's epoch is initialised to `version` *before* any restore, so
/// restored entries are servable at the current version and a later
/// update rebase moves them like any other entry.
fn ensure_slice(
    state: &WorkerState,
    inner: &mut Inner,
    slice: (u32, u32),
    fingerprint: GraphFingerprint,
    version: u64,
) {
    if inner.slices.contains_key(&slice) {
        return;
    }
    if inner.slices.len() >= MAX_SLICE_STORES {
        // shed a slice no in-flight request is publishing into
        let victim = inner
            .slices
            .keys()
            .find(|s| !inner.inflight.keys().any(|(is, _)| is == *s))
            .copied();
        if let Some(v) = victim {
            inner.slices.remove(&v);
        }
    }
    let mut ss = SliceStore {
        store: ResultStore::new(state.cache_bytes),
        persist: None,
    };
    ss.store.set_epoch(version);
    if let Some(pc) = &state.persist_config {
        let sfp = super::shard_fingerprint(fingerprint, slice.0, slice.1);
        let dir = pc.dir.join(format!("slice-{}-{}", slice.0, slice.1));
        match Persistence::open(&dir, sfp, pc.opts) {
            Ok((p, warm, report)) => {
                for (k, v) in warm {
                    ss.store.restore(k, v);
                }
                eprintln!(
                    "shard persist: slice [{}, {}) restored {} entries (fingerprint match: {})",
                    slice.0, slice.1, report.restored, report.fingerprint_matched
                );
                ss.persist = Some(p);
            }
            Err(e) => {
                eprintln!(
                    "warning: shard persistence unavailable for slice [{}, {}): {e:#}",
                    slice.0, slice.1
                );
            }
        }
    }
    inner.slices.insert(slice, ss);
}

fn handle_exec(
    state: &WorkerState,
    req: &ExecRequest,
) -> std::result::Result<ExecResponse, String> {
    // admission: pin this request to the worker's current graph state —
    // the snapshot/stats Arcs keep matching consistent even if an update
    // lands mid-request, and the version pins the store epoch so a late
    // publish after such an update is stale-dropped, never misfiled
    let (snapshot, stats, fingerprint, version) = {
        let gs = state.graph.read().unwrap();
        (gs.snapshot.clone(), gs.stats.clone(), gs.fingerprint, gs.version)
    };
    // re-check content identity per request: the coordinator's graph may
    // have mutated since the handshake (or this worker may have missed an
    // update), and partials computed on different content must never
    // merge into its answers
    if req.fingerprint != fingerprint {
        return Err(format!(
            "graph fingerprint mismatch: request is for {} (epoch {}), this worker \
             holds {fingerprint} (version {version})",
            req.fingerprint, req.epoch
        ));
    }
    let n = snapshot.num_vertices() as u32;
    if req.lo > req.hi || req.hi > n {
        return Err(format!(
            "bad shard slice [{}, {}) for a {n}-vertex graph",
            req.lo, req.hi
        ));
    }
    let slice = (req.lo, req.hi);
    let keys: Vec<CanonKey> = req.patterns.iter().map(|p| p.canonical_key()).collect();

    // split the request: store hits / in-flight elsewhere / ours to match
    let probe_timer = std::time::Instant::now();
    let mut values: HashMap<CanonKey, i128> = HashMap::new();
    let mut owned: Vec<usize> = Vec::new();
    let mut awaited: Vec<(CanonKey, Arc<Cell>)> = Vec::new();
    {
        let mut inner = state.inner.lock().unwrap();
        ensure_slice(state, &mut inner, slice, fingerprint, version);
        let inner = &mut *inner;
        // remember the pattern behind every requested key: a later
        // update's delta pass resolves cached keys through this registry
        for (k, p) in keys.iter().zip(&req.patterns) {
            inner.patterns.entry(*k).or_insert_with(|| p.clone());
        }
        let ss = inner.slices.get_mut(&slice).expect("slice store just ensured");
        for (i, k) in keys.iter().enumerate() {
            if values.contains_key(k) {
                continue; // duplicate base in one request
            }
            // every distinct base probes the slice store exactly once, so
            // worker-wide: store hits + misses == bases probed (the CI
            // metrics smoke asserts this across the scrape endpoint)
            crate::obs_counter!("mm_worker_bases_probed_total").inc();
            if let Some(v) = ss.store.get(k, version) {
                crate::obs_counter!("mm_worker_store_hits_total").inc();
                values.insert(*k, v);
            } else if let Some(cell) = inner.inflight.get(&(slice, *k)) {
                crate::obs_counter!("mm_worker_store_misses_total").inc();
                awaited.push((*k, cell.clone()));
            } else {
                crate::obs_counter!("mm_worker_store_misses_total").inc();
                inner.inflight.insert((slice, *k), Arc::new(Cell::default()));
                owned.push(i);
            }
        }
        crate::obs_gauge!("mm_worker_slice_stores").set(inner.slices.len() as u64);
    }
    let probe_us = probe_timer.elapsed().as_micros() as u64;
    let cached = values.len() as u32;
    let awaited_n = awaited.len();
    let mut guard = OwnedCells {
        state,
        keys: owned.iter().map(|&i| (slice, keys[i])).collect(),
        armed: true,
    };

    let mut profile = PhaseProfile::new();
    let fresh = state.planner.execute_bases_range(
        &snapshot,
        &req.patterns,
        &owned,
        &stats,
        &mut profile,
        Some((req.lo, req.hi)),
    );

    // publish: feed the slice's store, mirror into its WAL, wake
    // coalesced peers
    let publish_timer = std::time::Instant::now();
    {
        let mut inner = state.inner.lock().unwrap();
        let inner = &mut *inner;
        // the slice store can only be missing if it was shed under store
        // pressure mid-request — the counts are still correct, they just
        // aren't cached
        if let Some(ss) = inner.slices.get_mut(&slice) {
            for &(k, v) in &fresh {
                // inserted at the ADMISSION version: if an update landed
                // while this request matched, the store's epoch has moved
                // on and these partials are stale-dropped, never misfiled
                if ss.store.insert(k, version, v) {
                    persist_insert(&mut ss.persist, &k, v);
                }
            }
            // compaction runs inline: worker requests are already
            // asynchronous from the coordinator's perspective, so the
            // begin/finish split the service layer needs is not worth the
            // machinery here
            if ss.persist.as_ref().is_some_and(Persistence::wants_compaction) {
                let entries = ss.store.entries();
                let p = ss.persist.as_mut().expect("checked above");
                if let Err(e) = p.compact(&entries) {
                    eprintln!(
                        "warning: shard store compaction failed, persistence disabled: {e}"
                    );
                    ss.persist = None;
                }
            }
        }
        for &(k, v) in &fresh {
            if let Some(cell) = inner.inflight.remove(&(slice, k)) {
                *cell.value.lock().unwrap() = Some(Ok(v));
                cell.ready.notify_all();
            }
        }
    }
    guard.armed = false;
    values.extend(fresh.iter().copied());
    let publish_us = publish_timer.elapsed().as_micros() as u64;

    // block on bases another connection is matching over the same slice
    let await_timer = std::time::Instant::now();
    for (k, cell) in awaited {
        let mut slot = cell.value.lock().unwrap();
        while slot.is_none() {
            slot = cell.ready.wait(slot).unwrap();
        }
        match slot.expect("cell filled") {
            Ok(v) => {
                values.insert(k, v);
            }
            Err(msg) => return Err(format!("coalesced base failed: {msg}")),
        }
    }
    let await_us = await_timer.elapsed().as_micros() as u64;

    // one entry per distinct requested key, in request order
    let mut out: Vec<(CanonKey, i128)> = Vec::with_capacity(values.len());
    let mut emitted: std::collections::HashSet<CanonKey> = std::collections::HashSet::new();
    for k in &keys {
        if emitted.insert(*k) {
            let v = *values
                .get(k)
                .ok_or_else(|| format!("base {k:?} was neither cached nor matched"))?;
            out.push((*k, v));
        }
    }
    // the worker's side of the batch's span tree (proto v5): a flat list
    // of phase children the coordinator grafts under this sub-slice's
    // span. rel_parent = WIRE_PARENT_ROOT attaches every phase directly
    // to the slice span; start offsets are request-relative microseconds,
    // laid out sequentially in execution order (probe → kernel phases →
    // publish → coalesced-await). Always built: the spans are a byproduct
    // of timers the worker runs anyway, so whether the coordinator traces
    // or not cannot change what this function computes.
    let root = crate::obs::trace::WIRE_PARENT_ROOT;
    let mut spans = Vec::with_capacity(3 + profile.entries().len());
    let mut clock_us = 0u64;
    spans.push(proto::WireSpan {
        rel_parent: root,
        start_us: clock_us,
        dur_us: probe_us,
        name: "probe".into(),
        tag: format!("hits={cached} owned={} awaited={awaited_n}", owned.len()),
    });
    clock_us += probe_us;
    for (name, d) in profile.entries() {
        let dur_us = d.as_micros() as u64;
        spans.push(proto::WireSpan {
            rel_parent: root,
            start_us: clock_us,
            dur_us,
            name: name.clone(),
            tag: String::new(),
        });
        clock_us += dur_us;
    }
    spans.push(proto::WireSpan {
        rel_parent: root,
        start_us: clock_us,
        dur_us: publish_us,
        name: "publish".into(),
        tag: String::new(),
    });
    clock_us += publish_us;
    if awaited_n > 0 {
        spans.push(proto::WireSpan {
            rel_parent: root,
            start_us: clock_us,
            dur_us: await_us,
            name: "await".into(),
            tag: format!("coalesced={awaited_n}"),
        });
    }
    Ok(ExecResponse {
        id: req.id,
        epoch: req.epoch,
        served_from_store: cached,
        values: out,
        spans,
    })
}

/// Apply one broadcast edge mutation: mutate the worker's graph copy,
/// verify the fingerprint transition end-to-end, swap in a fresh matching
/// snapshot (stats stay pinned), and rebase every per-slice store. A base
/// is carried across the epoch only when the delta pass proves **no
/// embedding changed**: a zero net delta on an anti-edge-free pattern
/// (every affected embedding maps a pattern edge onto the mutated pair,
/// so all carry one sign and a zero sum means none existed). Anything
/// weaker — nonzero delta, vertex-induced/anti-edge patterns where
/// creations and destructions can cancel, fallbacks, unknown patterns —
/// is purged, because per-slice partials can shift between slices even
/// when the full-graph total is exactly patchable.
fn handle_update(state: &WorkerState, req: &UpdateRequest) -> UpdateAck {
    crate::obs_counter!("mm_worker_updates_total").inc();
    let mut gs = state.graph.write().unwrap();
    let refuse = |gs: &GraphState, error: String| UpdateAck {
        id: req.id,
        applied: false,
        fingerprint: gs.fingerprint,
        carried: 0,
        purged: 0,
        error,
    };
    if gs.fingerprint != req.old_fingerprint {
        return refuse(
            &gs,
            format!(
                "update transition mismatch: coordinator mutates from {}, this worker \
                 holds {} (version {})",
                req.old_fingerprint, gs.fingerprint, gs.version
            ),
        );
    }
    let n = gs.snapshot.num_vertices() as u32;
    if req.u == req.v || req.u >= n || req.v >= n {
        return refuse(
            &gs,
            format!(
                "bad update edge ({}, {}) for a {n}-vertex graph (the fabric \
                 rejects self-loops and vertex growth)",
                req.u, req.v
            ),
        );
    }
    if req.insert == gs.dyn_graph.has_edge(req.u, req.v) {
        // an honest coordinator only broadcasts updates it applied; a
        // no-op here means the copies diverged in edge content without
        // diverging in fingerprint, which the transition check below
        // would catch anyway — refuse it before touching anything
        return refuse(
            &gs,
            format!(
                "no-op update: edge ({}, {}) is already {}",
                req.u,
                req.v,
                if req.insert { "present" } else { "absent" }
            ),
        );
    }

    // bases to classify: every key cached in any slice store whose
    // pattern this worker has seen; unknown keys (warm restores never
    // requested since) purge on rebase
    let bases: Vec<(CanonKey, Pattern)> = {
        let inner = state.inner.lock().unwrap();
        let mut keys: std::collections::HashSet<CanonKey> = std::collections::HashSet::new();
        for ss in inner.slices.values() {
            keys.extend(ss.store.entries().iter().map(|(k, _)| *k));
        }
        keys.iter()
            .filter_map(|k| inner.patterns.get(k).map(|p| (*k, p.clone())))
            .collect()
    };

    // the delta pass runs on the graph WITH the edge present
    let report = if req.insert {
        let inserted = gs.dyn_graph.insert_edge(req.u, req.v);
        debug_assert!(inserted, "presence checked above");
        delta::edge_update_deltas(
            &gs.dyn_graph,
            req.u,
            req.v,
            true,
            &bases,
            delta::DEFAULT_DELTA_BUDGET,
        )
    } else {
        let report = delta::edge_update_deltas(
            &gs.dyn_graph,
            req.u,
            req.v,
            false,
            &bases,
            delta::DEFAULT_DELTA_BUDGET,
        );
        let removed = gs.dyn_graph.remove_edge(req.u, req.v);
        debug_assert!(removed, "presence checked above");
        report
    };

    // the mutation is committed either way: swap in a snapshot of what
    // this worker now actually holds, so even a failed transition leaves
    // graph state and caches self-consistent (and every later request
    // refuses on the fingerprint, loudly)
    let new_fp = gs.dyn_graph.fingerprint();
    let applied = new_fp == req.new_fingerprint;
    gs.snapshot = Arc::new(gs.dyn_graph.to_data_graph("shard"));
    gs.fingerprint = new_fp;
    gs.version = req.new_version;

    let carry: std::collections::HashSet<CanonKey> = if applied {
        bases
            .iter()
            .filter(|(k, p)| {
                matches!(report.deltas.get(k), Some(DeltaOutcome::Patch(0)))
                    && p.anti_edges().is_empty()
            })
            .map(|(k, _)| *k)
            .collect()
    } else {
        std::collections::HashSet::new()
    };

    let (mut carried, mut purged) = (0u64, 0u64);
    {
        let mut inner = state.inner.lock().unwrap();
        for (&(lo, hi), ss) in inner.slices.iter_mut() {
            let (c, p) = ss
                .store
                .rebase_epoch(req.new_version, |k, v| carry.contains(k).then_some(*v));
            carried += c;
            purged += p;
            // the slice's durable identity moved with the graph: rebind
            // its WAL to the new shard fingerprint and compact the
            // carried entries under it (same degradation contract as
            // everywhere else: first IO error disables persistence)
            if let Some(pw) = &mut ss.persist {
                let sfp = super::shard_fingerprint(new_fp, lo, hi);
                let res = pw
                    .record_invalidation(sfp)
                    .and_then(|()| pw.compact(&ss.store.entries()));
                if let Err(e) = res {
                    eprintln!(
                        "warning: shard persist rebase failed, persistence disabled: {e}"
                    );
                    ss.persist = None;
                }
            }
        }
    }
    crate::obs_counter!("mm_worker_update_carried_total").add(carried);
    crate::obs_counter!("mm_worker_update_purged_total").add(purged);
    UpdateAck {
        id: req.id,
        applied,
        fingerprint: new_fp,
        carried,
        purged,
        error: if applied {
            String::new()
        } else {
            format!(
                "update transition diverged: applying ({}, {}) {} landed on {new_fp}, \
                 coordinator expected {}",
                req.u,
                req.v,
                if req.insert { "insert" } else { "removal" },
                req.new_fingerprint
            )
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::pattern::catalog;

    fn worker(seed: u64) -> ShardWorker {
        ShardWorker::bind(
            erdos_renyi(60, 220, seed),
            "127.0.0.1:0",
            WorkerConfig {
                threads: 2,
                fused: true,
                cache_bytes: 1 << 20,
                persist: None,
                slice_pin: None,
            },
        )
        .unwrap()
    }

    fn fp(seed: u64) -> GraphFingerprint {
        GraphFingerprint {
            order: 1,
            size: 1,
            hash: seed,
        }
    }

    fn hello(fingerprint: GraphFingerprint) -> Msg {
        Msg::Hello {
            version: proto::VERSION,
            fingerprint,
            group: 0,
            groups: 1,
            replica: 0,
        }
    }

    #[test]
    fn handshake_and_exec_over_tcp() {
        let w = worker(0x6001);
        let graph_fp = w.fingerprint();
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(&mut stream, &hello(graph_fp)).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Welcome { fingerprint, .. } => assert_eq!(fingerprint, graph_fp),
            other => panic!("expected WELCOME, got {other:?}"),
        }
        let patterns = vec![catalog::triangle(), catalog::path(3)];
        let full = |lo: u32, hi: u32, id: u64| ExecRequest {
            id,
            epoch: 0,
            fingerprint: graph_fp,
            lo,
            hi,
            trace_id: 0,
            parent_span: 0,
            patterns: patterns.clone(),
        };
        proto::write_msg(&mut stream, &Msg::Exec(full(0, 60, 1))).unwrap();
        let whole = match proto::read_msg(&mut stream).unwrap() {
            Msg::Result(r) => r,
            other => panic!("{other:?}"),
        };
        assert_eq!(whole.id, 1);
        assert_eq!(whole.values.len(), 2);
        assert_eq!(whole.served_from_store, 0);
        // the full slice equals the direct engine's map counts
        let g = erdos_renyi(60, 220, 0x6001);
        for ((k, v), p) in whole.values.iter().zip(&patterns) {
            assert_eq!(*k, p.canonical_key());
            let direct = crate::agg::aggregate_pattern(&g, p, &crate::agg::CountAgg, 1);
            assert_eq!(*v, direct, "{p:?}");
        }
        // v5: the reply carries the worker's span list — the store probe
        // plus the kernel-tier phase breakdown, all parented at the root
        // sentinel with sequential request-relative clocks
        let names: Vec<&str> = whole.spans.iter().map(|s| s.name.as_str()).collect();
        assert!(names.contains(&"probe"), "{names:?}");
        assert!(names.contains(&"match"), "{names:?}");
        for pair in whole.spans.windows(2) {
            assert!(pair[0].start_us + pair[0].dur_us <= pair[1].start_us);
        }
        for s in &whole.spans {
            assert_eq!(s.rel_parent, crate::obs::trace::WIRE_PARENT_ROOT);
        }
        assert!(whole.spans[0].tag.contains("hits=0"), "{}", whole.spans[0].tag);
        // re-sent bases are served from the worker-local store
        proto::write_msg(&mut stream, &Msg::Exec(full(0, 60, 2))).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Result(r) => {
                assert_eq!(r.served_from_store, 2);
                assert_eq!(r.values, whole.values);
                // warm replies still report the probe span (with the hits)
                let probe = r.spans.iter().find(|s| s.name == "probe").unwrap();
                assert!(probe.tag.contains("hits=2"), "{}", probe.tag);
            }
            other => panic!("{other:?}"),
        }
        // a different slice has its own store: nothing served warm there
        proto::write_msg(&mut stream, &Msg::Exec(full(0, 30, 3))).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Result(r) => assert_eq!(r.served_from_store, 0),
            other => panic!("{other:?}"),
        }
        // …and the first slice's store survived the detour (per-slice
        // stores, not one store rebound per slice change)
        proto::write_msg(&mut stream, &Msg::Exec(full(0, 60, 4))).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Result(r) => assert_eq!(r.served_from_store, 2),
            other => panic!("{other:?}"),
        }
        drop(stream);
        w.shutdown();
    }

    #[test]
    fn wrong_graph_is_hard_rejected() {
        let w = worker(0x6002);
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(&mut stream, &hello(fp(99))).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Reject { reason } => {
                assert!(reason.contains("fingerprint mismatch"), "{reason}");
            }
            other => panic!("expected REJECT, got {other:?}"),
        }
        // the worker closed the conversation: the next read fails
        assert!(proto::read_msg(&mut stream).is_err());
    }

    #[test]
    fn wrong_protocol_version_is_rejected_by_name() {
        let w = worker(0x6005);
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(
            &mut stream,
            &Msg::Hello {
                version: proto::VERSION + 40,
                fingerprint: w.fingerprint(),
                group: 0,
                groups: 1,
                replica: 0,
            },
        )
        .unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Reject { reason } => {
                assert!(reason.contains("version mismatch"), "{reason}");
                assert!(
                    reason.contains(&format!("v{}", proto::VERSION + 40)),
                    "names the peer's version: {reason}"
                );
            }
            other => panic!("expected REJECT, got {other:?}"),
        }
        assert!(proto::read_msg(&mut stream).is_err());
    }

    #[test]
    fn pings_are_answered_inline_with_inflight_count() {
        let w = worker(0x6006);
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(&mut stream, &hello(w.fingerprint())).unwrap();
        assert!(matches!(proto::read_msg(&mut stream).unwrap(), Msg::Welcome { .. }));
        proto::write_msg(&mut stream, &Msg::Ping { nonce: 42 }).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Pong { nonce, inflight } => assert_eq!((nonce, inflight), (42, 0)),
            other => panic!("expected PONG, got {other:?}"),
        }
    }

    #[test]
    fn stats_requests_snapshot_the_registry_inline() {
        let w = worker(0x6008);
        let graph_fp = w.fingerprint();
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(&mut stream, &hello(graph_fp)).unwrap();
        assert!(matches!(proto::read_msg(&mut stream).unwrap(), Msg::Welcome { .. }));
        // one exec so the probe counters have moved
        let req = ExecRequest {
            id: 1,
            epoch: 0,
            fingerprint: graph_fp,
            lo: 0,
            hi: 60,
            trace_id: 0,
            parent_span: 0,
            patterns: vec![catalog::triangle(), catalog::path(3)],
        };
        proto::write_msg(&mut stream, &Msg::Exec(req)).unwrap();
        assert!(matches!(proto::read_msg(&mut stream).unwrap(), Msg::Result(_)));
        proto::write_msg(&mut stream, &Msg::Stats { id: 9 }).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::StatsReply { id, series } => {
                assert_eq!(id, 9);
                let get = |name: &str| {
                    series.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
                };
                // presence only (the registry is process-global and other
                // tests in this binary move the same counters concurrently;
                // strict hits+misses==probed is asserted by the CI smoke
                // against an isolated worker process)
                assert!(get("mm_worker_bases_probed_total").unwrap_or(0) >= 2);
                assert!(get("mm_worker_requests_total").unwrap_or(0) >= 1);
                assert!(get("mm_worker_exec_us_count").is_some());
            }
            other => panic!("expected STATS_REPLY, got {other:?}"),
        }
        drop(stream);
        w.shutdown();
    }

    #[test]
    fn pipelined_requests_are_answered_by_id() {
        // two different-slice requests sent back to back on one
        // connection: both answered (possibly reordered), matched by id
        let w = worker(0x6007);
        let graph_fp = w.fingerprint();
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(&mut stream, &hello(graph_fp)).unwrap();
        assert!(matches!(proto::read_msg(&mut stream).unwrap(), Msg::Welcome { .. }));
        let req = |lo: u32, hi: u32, id: u64| ExecRequest {
            id,
            epoch: 0,
            fingerprint: graph_fp,
            lo,
            hi,
            trace_id: 0,
            parent_span: 0,
            patterns: vec![catalog::triangle()],
        };
        proto::write_msg(&mut stream, &Msg::Exec(req(0, 30, 10))).unwrap();
        proto::write_msg(&mut stream, &Msg::Exec(req(30, 60, 11))).unwrap();
        let mut got: HashMap<u64, i128> = HashMap::new();
        for _ in 0..2 {
            match proto::read_msg(&mut stream).unwrap() {
                Msg::Result(r) => {
                    assert_eq!(r.values.len(), 1);
                    got.insert(r.id, r.values[0].1);
                }
                other => panic!("{other:?}"),
            }
        }
        // the two slice partials sum to the full-graph count
        let g = erdos_renyi(60, 220, 0x6007);
        let direct =
            crate::agg::aggregate_pattern(&g, &catalog::triangle(), &crate::agg::CountAgg, 1);
        assert_eq!(got[&10] + got[&11], direct, "slice partials sum exactly");
        drop(stream);
        w.shutdown();
    }

    #[test]
    fn updates_mutate_the_graph_and_rebase_the_stores() {
        let w = worker(0x6010);
        let g = erdos_renyi(60, 220, 0x6010);
        let mut dg = DynGraph::from_data_graph(&g);
        let old_fp = w.fingerprint();
        assert_eq!(old_fp, dg.fingerprint(), "worker and mirror start identical");
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(&mut stream, &hello(old_fp)).unwrap();
        assert!(matches!(proto::read_msg(&mut stream).unwrap(), Msg::Welcome { .. }));
        // seed the full-range slice store with an anti-edge-free base and
        // a vertex-induced one (the latter must never be carried)
        let patterns = vec![catalog::triangle(), catalog::cycle(4).vertex_induced()];
        let exec = |fingerprint: GraphFingerprint, epoch: u64, id: u64| {
            Msg::Exec(ExecRequest {
                id,
                epoch,
                fingerprint,
                lo: 0,
                hi: 60,
                trace_id: 0,
                parent_span: 0,
                patterns: patterns.clone(),
            })
        };
        proto::write_msg(&mut stream, &exec(old_fp, 0, 1)).unwrap();
        assert!(matches!(proto::read_msg(&mut stream).unwrap(), Msg::Result(_)));

        // a non-edge whose endpoints share no neighbor: inserting it can
        // create no triangle, so the triangle's delta is provably zero
        let no_common = |a: u32, b: u32| {
            let nb = g.neighbors(b);
            !g.neighbors(a).iter().any(|x| nb.contains(x))
        };
        let (u, v) = (0..60u32)
            .flat_map(|a| (0..60u32).map(move |b| (a, b)))
            .find(|&(a, b)| a < b && !dg.has_edge(a, b) && no_common(a, b))
            .expect("a sparse graph has a distant non-edge");
        assert!(dg.insert_edge(u, v));
        let new_fp = dg.fingerprint();
        let update = UpdateRequest {
            id: 9,
            insert: true,
            u,
            v,
            old_fingerprint: old_fp,
            new_fingerprint: new_fp,
            new_version: 1,
        };
        proto::write_msg(&mut stream, &Msg::Update(update.clone())).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::UpdateAck(ack) => {
                assert!(ack.applied, "{}", ack.error);
                assert_eq!(ack.id, 9);
                assert_eq!(ack.fingerprint, new_fp);
                assert_eq!(
                    (ack.carried, ack.purged),
                    (1, 1),
                    "triangle carried (zero delta, no anti-edges), C4^E purged"
                );
                assert!(ack.error.is_empty());
            }
            other => panic!("expected UPDATE_ACK, got {other:?}"),
        }
        assert_eq!(w.fingerprint(), new_fp);
        assert_eq!(w.version(), 1);

        // a request still naming the pre-update graph is refused loudly
        proto::write_msg(&mut stream, &exec(old_fp, 0, 2)).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Error { id, message } => {
                assert_eq!(id, 2);
                assert!(message.contains("fingerprint mismatch"), "{message}");
            }
            other => panic!("{other:?}"),
        }
        // the mutated graph serves exact counts; the carried triangle
        // comes straight from the rebased store
        proto::write_msg(&mut stream, &exec(new_fp, 1, 3)).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Result(r) => {
                assert_eq!(r.served_from_store, 1, "the carried base serves warm");
                let mutated = dg.to_data_graph("mutated");
                for ((k, got), p) in r.values.iter().zip(&patterns) {
                    assert_eq!(*k, p.canonical_key());
                    let direct =
                        crate::agg::aggregate_pattern(&mutated, p, &crate::agg::CountAgg, 1);
                    assert_eq!(*got, direct, "{p:?}");
                }
            }
            other => panic!("{other:?}"),
        }

        // a duplicate of the same insert is a refused no-op
        let dup = UpdateRequest {
            id: 10,
            old_fingerprint: new_fp,
            ..update.clone()
        };
        proto::write_msg(&mut stream, &Msg::Update(dup)).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::UpdateAck(ack) => {
                assert!(!ack.applied);
                assert_eq!(ack.fingerprint, new_fp, "a refused update changes nothing");
                assert!(ack.error.contains("no-op"), "{}", ack.error);
            }
            other => panic!("{other:?}"),
        }
        // an update naming a stale starting fingerprint is refused by name
        let stale = UpdateRequest { id: 11, ..update };
        proto::write_msg(&mut stream, &Msg::Update(stale)).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::UpdateAck(ack) => {
                assert!(!ack.applied);
                assert!(ack.error.contains("transition mismatch"), "{}", ack.error);
            }
            other => panic!("{other:?}"),
        }
        // removal round-trips the content back to the original fingerprint
        assert!(dg.remove_edge(u, v));
        assert_eq!(dg.fingerprint(), old_fp);
        let removal = UpdateRequest {
            id: 12,
            insert: false,
            u,
            v,
            old_fingerprint: new_fp,
            new_fingerprint: old_fp,
            new_version: 2,
        };
        proto::write_msg(&mut stream, &Msg::Update(removal)).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::UpdateAck(ack) => {
                assert!(ack.applied, "{}", ack.error);
                assert_eq!(ack.fingerprint, old_fp);
            }
            other => panic!("{other:?}"),
        }
        proto::write_msg(&mut stream, &exec(old_fp, 2, 13)).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Result(r) => {
                for ((_, got), p) in r.values.iter().zip(&patterns) {
                    let direct = crate::agg::aggregate_pattern(&g, p, &crate::agg::CountAgg, 1);
                    assert_eq!(*got, direct, "{p:?}");
                }
            }
            other => panic!("{other:?}"),
        }
        drop(stream);
        w.shutdown();
    }

    #[test]
    fn out_of_range_updates_are_refused_without_growth() {
        // sharded graphs never grow: an endpoint past the vertex range is
        // refused, unlike the single-process service which extends the
        // vertex set on demand
        let w = worker(0x6011);
        let fp0 = w.fingerprint();
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(&mut stream, &hello(fp0)).unwrap();
        assert!(matches!(proto::read_msg(&mut stream).unwrap(), Msg::Welcome { .. }));
        for (u, v) in [(0u32, 60u32), (7, 7)] {
            let req = UpdateRequest {
                id: 1,
                insert: true,
                u,
                v,
                old_fingerprint: fp0,
                new_fingerprint: fp0,
                new_version: 1,
            };
            proto::write_msg(&mut stream, &Msg::Update(req)).unwrap();
            match proto::read_msg(&mut stream).unwrap() {
                Msg::UpdateAck(ack) => {
                    assert!(!ack.applied);
                    assert_eq!(ack.fingerprint, fp0);
                    assert!(ack.error.contains("bad update edge"), "{}", ack.error);
                }
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(w.fingerprint(), fp0, "refused updates leave the graph untouched");
        assert_eq!(w.version(), 0);
        drop(stream);
        w.shutdown();
    }

    #[test]
    fn stale_fingerprint_per_request_is_an_error() {
        // handshake with the right graph, then pretend the coordinator's
        // graph mutated (new fingerprint on the request)
        let w = worker(0x6003);
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(&mut stream, &hello(w.fingerprint())).unwrap();
        assert!(matches!(proto::read_msg(&mut stream).unwrap(), Msg::Welcome { .. }));
        let req = ExecRequest {
            id: 7,
            epoch: 1,
            fingerprint: fp(123),
            lo: 0,
            hi: 10,
            trace_id: 0,
            parent_span: 0,
            patterns: vec![catalog::triangle()],
        };
        proto::write_msg(&mut stream, &Msg::Exec(req)).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Error { id, message } => {
                assert_eq!(id, 7);
                assert!(message.contains("fingerprint mismatch"), "{message}");
            }
            other => panic!("{other:?}"),
        }
        // bad slices error too, without killing the connection
        let req = ExecRequest {
            id: 8,
            epoch: 0,
            fingerprint: w.fingerprint(),
            lo: 50,
            hi: 10_000,
            trace_id: 0,
            parent_span: 0,
            patterns: vec![catalog::triangle()],
        };
        proto::write_msg(&mut stream, &Msg::Exec(req)).unwrap();
        assert!(matches!(proto::read_msg(&mut stream).unwrap(), Msg::Error { id: 8, .. }));
    }
}
