//! Shard worker: a process that owns one immutable copy of the data graph
//! and answers [`ExecRequest`]s — "match these base patterns with the
//! first level restricted to `[lo, hi)`" — over TCP.
//!
//! The worker is the service layer in miniature, minus mutation:
//!
//! * **Store** — partial counts are cached in a worker-local
//!   [`ResultStore`] keyed by canonical pattern, so a re-sent base (a
//!   coordinator retry, a second coordinator, a warm repeat) is served
//!   without matching. The worker's graph never mutates, so its store
//!   lives permanently at epoch 0 — content identity rides entirely on
//!   the [`GraphFingerprint`] checked at handshake *and on every request*.
//! * **Coalescing** — concurrent connections asking for the same base
//!   register on a per-canonical-key in-flight cell (the same at-most-once
//!   discipline as [`crate::service::serve`]): each base is matched at
//!   most once per worker, whoever asks.
//! * **Slice identity** — partial counts are only meaningful for the
//!   first-level slice they were computed over. The store is bound to the
//!   worker's current slice; a request with a different slice (the
//!   coordinator pool was resized) resets it, and the durable store is
//!   keyed by [`super::shard_fingerprint`] — graph fingerprint × slice —
//!   so a restarted worker recovers warm exactly when both the graph and
//!   the slice match what was persisted, and cold otherwise.
//! * **Durability** — with a persist directory configured, published
//!   partials are mirrored into the same WAL + snapshot machinery as the
//!   coordinator's store ([`crate::service::persist`]); a clean shutdown
//!   ([`ShardWorker::shutdown`] / drop — embedders and tests) compacts so
//!   a restart recovers from one snapshot. The CLI worker blocks in
//!   [`ShardWorker::wait`] and is stopped by killing the process, which
//!   skips that compaction: the WAL is flushed per record, so the restart
//!   replays the log — slower, never colder — and a dead owner's dir
//!   lock is reclaimed automatically (Linux `/proc` probe; elsewhere the
//!   lock needs the manual removal the startup error names).
//!
//! [`ExecRequest`]: super::proto::ExecRequest

use super::proto::{self, ExecRequest, ExecResponse, Msg};
use crate::graph::{DataGraph, GraphFingerprint, GraphStats};
use crate::morph::Policy;
use crate::pattern::canon::CanonKey;
use crate::service::persist::{PersistConfig, Persistence};
use crate::service::{QueryPlanner, ResultStore, StoreMetrics};
use crate::util::timer::PhaseProfile;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Worker tuning.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Matcher threads per request.
    pub threads: usize,
    /// Fuse multi-base requests into one trie traversal.
    pub fused: bool,
    /// Local result-store budget in bytes.
    pub cache_bytes: usize,
    /// Persist the partial-count store (keyed by graph × slice) so a shard
    /// restart recovers warm.
    pub persist: Option<PersistConfig>,
}

impl Default for WorkerConfig {
    fn default() -> WorkerConfig {
        WorkerConfig {
            threads: crate::exec::parallel::default_threads(),
            fused: true,
            cache_bytes: 64 << 20,
            persist: None,
        }
    }
}

/// Completion cell for one in-flight base (see [`crate::service::serve`]).
#[derive(Default)]
struct Cell {
    value: Mutex<Option<std::result::Result<i128, &'static str>>>,
    ready: Condvar,
}

struct Inner {
    store: ResultStore<i128>,
    persist: Option<Persistence<i128>>,
    /// First-level slice the store's entries were computed over.
    range: Option<(u32, u32)>,
    inflight: HashMap<CanonKey, Arc<Cell>>,
}

struct WorkerState {
    graph: DataGraph,
    stats: GraphStats,
    fingerprint: GraphFingerprint,
    planner: QueryPlanner,
    cache_bytes: usize,
    persist_config: Option<PersistConfig>,
    inner: Mutex<Inner>,
}

/// Unwind/error guard for the cells a request registered: disarmed after a
/// successful publish, otherwise fails them so coalesced requests error
/// instead of hanging.
struct OwnedCells<'a> {
    state: &'a WorkerState,
    keys: Vec<CanonKey>,
    armed: bool,
}

impl Drop for OwnedCells<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let mut inner = match self.state.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        for k in &self.keys {
            if let Some(cell) = inner.inflight.remove(k) {
                *cell.value.lock().unwrap() = Some(Err("owner failed before publishing"));
                cell.ready.notify_all();
            }
        }
    }
}

/// A running shard worker: a TCP listener plus the shared state behind it.
/// [`ShardWorker::shutdown`] (or drop) stops the accept loop and — when
/// persistence is on — compacts the durable store so the next start
/// recovers from one snapshot.
pub struct ShardWorker {
    addr: SocketAddr,
    state: Arc<WorkerState>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ShardWorker {
    /// Bind `listen` (e.g. `127.0.0.1:7401`, port `0` for an ephemeral
    /// port) and start accepting coordinator connections over `graph`.
    pub fn bind(graph: DataGraph, listen: &str, config: WorkerConfig) -> Result<ShardWorker> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding shard worker listener on {listen}"))?;
        let addr = listener.local_addr().context("reading bound address")?;
        // the same stats seed as the service layer, so fused order
        // selection on the worker mirrors what a single process would pick
        let stats = GraphStats::compute(&graph, 2000, 0x5E55);
        let fingerprint = graph.fingerprint();
        let state = Arc::new(WorkerState {
            graph,
            stats,
            fingerprint,
            // the policy field is morph-only and workers never morph: they
            // receive already-rewritten base patterns
            planner: QueryPlanner::new(Policy::Off, config.fused, config.threads),
            cache_bytes: config.cache_bytes,
            persist_config: config.persist,
            inner: Mutex::new(Inner {
                store: ResultStore::new(config.cache_bytes),
                persist: None,
                range: None,
                inflight: HashMap::new(),
            }),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let state = state.clone();
            let stop = stop.clone();
            std::thread::spawn(move || accept_loop(&listener, &state, &stop))
        };
        Ok(ShardWorker {
            addr,
            state,
            stop,
            accept: Some(accept),
        })
    }

    /// The address the worker is listening on (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Fingerprint of the graph this worker serves slices of.
    pub fn fingerprint(&self) -> GraphFingerprint {
        self.state.fingerprint
    }

    /// Counters of the worker-local partial-count store.
    pub fn store_metrics(&self) -> StoreMetrics {
        self.state.inner.lock().unwrap().store.metrics()
    }

    /// Block until the accept loop ends (i.e. forever, for a CLI worker
    /// that is stopped by killing the process). Shutdown compaction still
    /// runs on drop after an external shutdown.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, join the accept loop and compact the durable store.
    pub fn shutdown(self) {
        drop(self);
    }

    fn stop_now(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // graceful-shutdown flush, mirroring Service::drop: fold the
        // session's WAL into one snapshot so a shard restart skips replay
        if let Ok(mut inner) = self.state.inner.lock() {
            let inner = &mut *inner;
            if let Some(p) = &mut inner.persist {
                if p.compact_on_drop() && p.dirty() {
                    if let Err(e) = p.compact(&inner.store.entries()) {
                        eprintln!("warning: shard store compaction failed: {e}");
                    }
                }
            }
            // release the persist dir lock deterministically
            inner.persist = None;
        }
    }
}

impl Drop for ShardWorker {
    fn drop(&mut self) {
        self.stop_now();
    }
}

fn accept_loop(listener: &TcpListener, state: &Arc<WorkerState>, stop: &Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if let Ok(stream) = conn {
            let state = state.clone();
            std::thread::spawn(move || serve_connection(&state, stream));
        }
    }
}

fn serve_connection(state: &WorkerState, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    // handshake: the coordinator must be mining the exact graph content
    // this worker loaded — partial counts for any other graph are garbage,
    // so a mismatch is a hard reject
    match proto::read_msg(&mut stream) {
        Ok(Msg::Hello { fingerprint }) if fingerprint == state.fingerprint => {
            let welcome = Msg::Welcome {
                fingerprint: state.fingerprint,
                threads: state.planner.threads as u32,
            };
            if proto::write_msg(&mut stream, &welcome).is_err() {
                return;
            }
        }
        Ok(Msg::Hello { fingerprint }) => {
            let _ = proto::write_msg(
                &mut stream,
                &Msg::Reject {
                    reason: format!(
                        "graph fingerprint mismatch: coordinator mines {fingerprint}, \
                         this worker loaded {}",
                        state.fingerprint
                    ),
                },
            );
            return;
        }
        _ => {
            let _ = proto::write_msg(
                &mut stream,
                &Msg::Reject {
                    reason: "expected HELLO".into(),
                },
            );
            return;
        }
    }
    loop {
        let msg = match proto::read_msg(&mut stream) {
            Ok(m) => m,
            Err(_) => return, // disconnect or framing violation: done
        };
        let Msg::Exec(req) = msg else { return };
        // a panicking request must not kill the connection silently: the
        // OwnedCells guard inside handle_exec has already failed any cells
        // it owned, and the coordinator gets an explicit error
        let reply = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            handle_exec(state, &req)
        })) {
            Ok(Ok(resp)) => Msg::Result(resp),
            Ok(Err(message)) => Msg::Error { id: req.id, message },
            Err(_) => Msg::Error {
                id: req.id,
                message: "worker request panicked".into(),
            },
        };
        if proto::write_msg(&mut stream, &reply).is_err() {
            return;
        }
    }
}

/// Mirror one accepted store insert into the WAL (same degradation
/// contract as the service layer: first IO error disables persistence).
fn persist_insert(persist: &mut Option<Persistence<i128>>, key: &CanonKey, value: i128) {
    if let Some(p) = persist {
        if let Err(e) = p.record_insert(key, &value) {
            eprintln!("warning: shard WAL append failed, persistence disabled: {e}");
            *persist = None;
        }
    }
}

/// Bind the store (and durable store) to a first-level slice. Partial
/// counts are pure functions of `(canonical key, graph content, slice)`,
/// so a slice change makes every cached entry unusable: the store resets
/// and the durable store rebinds to the slice's own fingerprint.
fn ensure_range(
    state: &WorkerState,
    inner: &mut Inner,
    range: (u32, u32),
) -> std::result::Result<(), String> {
    if inner.range == Some(range) {
        return Ok(());
    }
    if !inner.inflight.is_empty() {
        // another connection is mid-match for the old slice; resetting
        // under it would publish old-slice partials into the new store
        return Err("shard slice changed while bases are in flight — retry".into());
    }
    inner.range = Some(range);
    inner.store = ResultStore::new(state.cache_bytes);
    inner.persist = None; // releases the old slice's session + dir lock
    if let Some(pc) = &state.persist_config {
        let sfp = super::shard_fingerprint(state.fingerprint, range.0, range.1);
        match Persistence::open(&pc.dir, sfp, pc.opts) {
            Ok((p, warm, report)) => {
                for (k, v) in warm {
                    inner.store.restore(k, v);
                }
                eprintln!(
                    "shard persist: slice [{}, {}) restored {} entries (fingerprint match: {})",
                    range.0, range.1, report.restored, report.fingerprint_matched
                );
                inner.persist = Some(p);
            }
            Err(e) => {
                eprintln!("warning: shard persistence unavailable: {e:#}");
            }
        }
    }
    Ok(())
}

fn handle_exec(
    state: &WorkerState,
    req: &ExecRequest,
) -> std::result::Result<ExecResponse, String> {
    // re-check content identity per request: the coordinator's graph may
    // have mutated since the handshake, and partials computed on this
    // worker's (unmutated) copy must never merge into its answers
    if req.fingerprint != state.fingerprint {
        return Err(format!(
            "graph fingerprint mismatch: request is for {}, this worker loaded {}",
            req.fingerprint, state.fingerprint
        ));
    }
    let n = state.graph.num_vertices() as u32;
    if req.lo > req.hi || req.hi > n {
        return Err(format!(
            "bad shard slice [{}, {}) for a {n}-vertex graph",
            req.lo, req.hi
        ));
    }
    let keys: Vec<CanonKey> = req.patterns.iter().map(|p| p.canonical_key()).collect();

    // split the request: store hits / in-flight elsewhere / ours to match
    let mut values: HashMap<CanonKey, i128> = HashMap::new();
    let mut owned: Vec<usize> = Vec::new();
    let mut awaited: Vec<(CanonKey, Arc<Cell>)> = Vec::new();
    {
        let mut inner = state.inner.lock().unwrap();
        ensure_range(state, &mut inner, (req.lo, req.hi))?;
        for (i, k) in keys.iter().enumerate() {
            if values.contains_key(k) {
                continue; // duplicate base in one request
            }
            if let Some(v) = inner.store.get(k, 0) {
                values.insert(*k, v);
            } else if let Some(cell) = inner.inflight.get(k) {
                awaited.push((*k, cell.clone()));
            } else {
                inner.inflight.insert(*k, Arc::new(Cell::default()));
                owned.push(i);
            }
        }
    }
    let cached = values.len() as u32;
    let mut guard = OwnedCells {
        state,
        keys: owned.iter().map(|&i| keys[i]).collect(),
        armed: true,
    };

    let mut profile = PhaseProfile::new();
    let fresh = state.planner.execute_bases_range(
        &state.graph,
        &req.patterns,
        &owned,
        &state.stats,
        &mut profile,
        Some((req.lo, req.hi)),
    );

    // publish: feed the store, mirror into the WAL, wake coalesced peers
    {
        let mut inner = state.inner.lock().unwrap();
        let inner = &mut *inner;
        // belt-and-braces: ensure_range refuses to switch slices while our
        // cells are registered, so this always holds
        let slice_current = inner.range == Some((req.lo, req.hi));
        for &(k, v) in &fresh {
            if slice_current && inner.store.insert(k, 0, v) {
                persist_insert(&mut inner.persist, &k, v);
            }
            if let Some(cell) = inner.inflight.remove(&k) {
                *cell.value.lock().unwrap() = Some(Ok(v));
                cell.ready.notify_all();
            }
        }
        // compaction runs inline: worker requests are already asynchronous
        // from the coordinator's perspective, so the begin/finish split the
        // service layer needs is not worth the machinery here
        if let Some(p) = &mut inner.persist {
            if p.wants_compaction() {
                if let Err(e) = p.compact(&inner.store.entries()) {
                    eprintln!("warning: shard store compaction failed, persistence disabled: {e}");
                    inner.persist = None;
                }
            }
        }
    }
    guard.armed = false;
    values.extend(fresh.iter().copied());

    // block on bases another connection is matching
    for (k, cell) in awaited {
        let mut slot = cell.value.lock().unwrap();
        while slot.is_none() {
            slot = cell.ready.wait(slot).unwrap();
        }
        match slot.expect("cell filled") {
            Ok(v) => {
                values.insert(k, v);
            }
            Err(msg) => return Err(format!("coalesced base failed: {msg}")),
        }
    }

    // one entry per distinct requested key, in request order
    let mut out: Vec<(CanonKey, i128)> = Vec::with_capacity(values.len());
    let mut emitted: std::collections::HashSet<CanonKey> = std::collections::HashSet::new();
    for k in &keys {
        if emitted.insert(*k) {
            let v = *values
                .get(k)
                .ok_or_else(|| format!("base {k:?} was neither cached nor matched"))?;
            out.push((*k, v));
        }
    }
    Ok(ExecResponse {
        id: req.id,
        epoch: req.epoch,
        served_from_store: cached,
        values: out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::pattern::catalog;

    fn worker(seed: u64) -> ShardWorker {
        ShardWorker::bind(
            erdos_renyi(60, 220, seed),
            "127.0.0.1:0",
            WorkerConfig {
                threads: 2,
                fused: true,
                cache_bytes: 1 << 20,
                persist: None,
            },
        )
        .unwrap()
    }

    fn fp(seed: u64) -> GraphFingerprint {
        GraphFingerprint {
            order: 1,
            size: 1,
            hash: seed,
        }
    }

    #[test]
    fn handshake_and_exec_over_tcp() {
        let w = worker(0x6001);
        let graph_fp = w.fingerprint();
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(&mut stream, &Msg::Hello { fingerprint: graph_fp }).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Welcome { fingerprint, .. } => assert_eq!(fingerprint, graph_fp),
            other => panic!("expected WELCOME, got {other:?}"),
        }
        let patterns = vec![catalog::triangle(), catalog::path(3)];
        let full = |lo: u32, hi: u32, id: u64| ExecRequest {
            id,
            epoch: 0,
            fingerprint: graph_fp,
            lo,
            hi,
            patterns: patterns.clone(),
        };
        proto::write_msg(&mut stream, &Msg::Exec(full(0, 60, 1))).unwrap();
        let whole = match proto::read_msg(&mut stream).unwrap() {
            Msg::Result(r) => r,
            other => panic!("{other:?}"),
        };
        assert_eq!(whole.id, 1);
        assert_eq!(whole.values.len(), 2);
        assert_eq!(whole.served_from_store, 0);
        // the full slice equals the direct engine's map counts
        let g = erdos_renyi(60, 220, 0x6001);
        for ((k, v), p) in whole.values.iter().zip(&patterns) {
            assert_eq!(*k, p.canonical_key());
            let direct = crate::agg::aggregate_pattern(&g, p, &crate::agg::CountAgg, 1);
            assert_eq!(*v, direct, "{p:?}");
        }
        // re-sent bases are served from the worker-local store
        proto::write_msg(&mut stream, &Msg::Exec(full(0, 60, 2))).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Result(r) => {
                assert_eq!(r.served_from_store, 2);
                assert_eq!(r.values, whole.values);
            }
            other => panic!("{other:?}"),
        }
        // a slice change resets the store: nothing served warm
        proto::write_msg(&mut stream, &Msg::Exec(full(0, 30, 3))).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Result(r) => assert_eq!(r.served_from_store, 0),
            other => panic!("{other:?}"),
        }
        drop(stream);
        w.shutdown();
    }

    #[test]
    fn wrong_graph_is_hard_rejected() {
        let w = worker(0x6002);
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(&mut stream, &Msg::Hello { fingerprint: fp(99) }).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Reject { reason } => {
                assert!(reason.contains("fingerprint mismatch"), "{reason}");
            }
            other => panic!("expected REJECT, got {other:?}"),
        }
        // the worker closed the conversation: the next read fails
        assert!(proto::read_msg(&mut stream).is_err());
    }

    #[test]
    fn stale_fingerprint_per_request_is_an_error() {
        // handshake with the right graph, then pretend the coordinator's
        // graph mutated (new fingerprint on the request)
        let w = worker(0x6003);
        let mut stream = TcpStream::connect(w.addr()).unwrap();
        proto::write_msg(&mut stream, &Msg::Hello { fingerprint: w.fingerprint() }).unwrap();
        assert!(matches!(proto::read_msg(&mut stream).unwrap(), Msg::Welcome { .. }));
        let req = ExecRequest {
            id: 7,
            epoch: 1,
            fingerprint: fp(123),
            lo: 0,
            hi: 10,
            patterns: vec![catalog::triangle()],
        };
        proto::write_msg(&mut stream, &Msg::Exec(req)).unwrap();
        match proto::read_msg(&mut stream).unwrap() {
            Msg::Error { id, message } => {
                assert_eq!(id, 7);
                assert!(message.contains("fingerprint mismatch"), "{message}");
            }
            other => panic!("{other:?}"),
        }
        // bad slices error too, without killing the connection
        let req = ExecRequest {
            id: 8,
            epoch: 0,
            fingerprint: w.fingerprint(),
            lo: 50,
            hi: 10_000,
            patterns: vec![catalog::triangle()],
        };
        proto::write_msg(&mut stream, &Msg::Exec(req)).unwrap();
        assert!(matches!(proto::read_msg(&mut stream).unwrap(), Msg::Error { id: 8, .. }));
    }
}
