//! Counting aggregation: `λ(m) = 1`, `⊕ = +`, `∘*` = identity.
//!
//! Values are ℤ (i128 to stay safe when values are scaled by `|φ|·|Aut|`
//! coefficients on dense graphs), so the Corollary 3.1 subtraction is exact.

use super::Aggregation;
use crate::graph::VertexId;

/// The counting aggregation of the paper's simplest example.
pub struct CountAgg;

impl Aggregation for CountAgg {
    type Value = i128;

    fn identity(&self) -> i128 {
        0
    }

    #[inline]
    fn accumulate(&self, acc: &mut i128, _m: &[VertexId]) {
        *acc += 1;
    }

    fn combine(&self, a: i128, b: i128) -> i128 {
        a + b
    }

    fn permute(&self, v: &i128, _f: &[usize]) -> i128 {
        *v // counts are permutation-invariant: a(m ∘ f) = a(m)
    }

    fn scale(&self, v: &i128, c: i64) -> i128 {
        v * c as i128
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_laws() {
        let a = CountAgg;
        assert_eq!(a.identity(), 0);
        let mut x = a.identity();
        a.accumulate(&mut x, &[1, 2, 3]);
        a.accumulate(&mut x, &[4, 5, 6]);
        assert_eq!(x, 2);
        assert_eq!(a.combine(x, 3), 5);
        assert_eq!(a.permute(&x, &[2, 0, 1]), x);
        assert_eq!(a.scale(&x, -3), -6);
    }
}
