//! Aggregation framework — the paper's `a = (λ, ⊕)` abstraction with the
//! permute operator `∘*` (§3.2.3).
//!
//! An [`Aggregation`] maps matches to values (`λ`), combines values (`⊕`),
//! permutes values along pattern-to-pattern vertex maps (`∘*`, needed by the
//! Aggregation Conversion Theorem) and — for the Corollary 3.1 direction —
//! scales values by *signed* integers. Counting is ℤ-valued; enumeration and
//! MNI tables are represented as signed multisets so that the disjoint set
//! difference of Corollary 3.1 is exact (the paper notes the image must be
//! additive for that direction).
//!
//! **Convention:** all values aggregate over the *full* match set `M(p)`
//! (all subgraph-isomorphism maps, `|Aut(p)|` per subgraph). The matcher
//! explores canonical (symmetry-broken) matches; [`aggregate_pattern`]
//! symmetrizes over `Aut(p)` at the end:
//! `a(M_full) = ⨁_{α ∈ Aut(p)} a(M_canon) ∘* α`.

pub mod count;
pub mod enumerate;
pub mod mni;

pub use count::CountAgg;
pub use enumerate::EnumerateAgg;
pub use mni::MniAgg;

use crate::graph::{DataGraph, VertexId};
use crate::pattern::{iso, Pattern};
use crate::plan::Plan;

/// An aggregation `a = (λ, ⊕, ∘*)` in the sense of §3.2.3.
pub trait Aggregation: Sync {
    type Value: Clone + Send + PartialEq + std::fmt::Debug;

    /// Identity of `⊕`.
    fn identity(&self) -> Self::Value;

    /// Accumulate one match into `acc` (in-place `acc ⊕= λ(m)`).
    /// `m` is indexed by **pattern vertex** (not matching-order position).
    fn accumulate(&self, acc: &mut Self::Value, m: &[VertexId]);

    /// `⊕` of two values.
    fn combine(&self, a: Self::Value, b: Self::Value) -> Self::Value;

    /// `∘*`: reindex a value computed over pattern `q` along a vertex map
    /// `f : V(p) → V(q)`, producing a value over pattern `p`.
    /// Must satisfy `a(m ∘ f) = a(m) ∘* f`.
    fn permute(&self, v: &Self::Value, f: &[usize]) -> Self::Value;

    /// Scale by a signed integer (repeated `⊕` / formal inverse).
    fn scale(&self, v: &Self::Value, c: i64) -> Self::Value;
}

/// Aggregate a pattern over the full match set `M(p, G)`:
/// runs the symmetry-broken matcher in parallel, then symmetrizes over the
/// automorphism group.
pub fn aggregate_pattern<A: Aggregation>(
    graph: &DataGraph,
    pattern: &Pattern,
    agg: &A,
    threads: usize,
) -> A::Value {
    aggregate_pattern_range(graph, pattern, agg, threads, 0, graph.num_vertices() as u32)
}

/// [`aggregate_pattern`] restricted to first-level vertices in `[lo, hi)`.
/// Symmetrization distributes over `⊕`, so per-range values over a disjoint
/// cover of `0..|V|` combine to the full value — the partial-aggregation
/// contract the distributed driver ([`crate::shard`]) merges under.
pub fn aggregate_pattern_range<A: Aggregation>(
    graph: &DataGraph,
    pattern: &Pattern,
    agg: &A,
    threads: usize,
    lo: u32,
    hi: u32,
) -> A::Value {
    let plan = Plan::compile(pattern);
    let canon = aggregate_canonical_range(graph, &plan, agg, threads, lo, hi);
    symmetrize(pattern, agg, &canon)
}

/// Aggregate a whole base pattern set over full match sets `M(p_i, G)` in
/// **one fused traversal** of the data graph: runs the shared-prefix trie
/// executor ([`crate::exec::fused`]) once, accumulating per-pattern values,
/// then symmetrizes each over its pattern's automorphism group. Returns
/// values aligned with [`crate::plan::fused::FusedPlan::plans`].
pub fn aggregate_patterns_fused<A: Aggregation>(
    graph: &DataGraph,
    fused: &crate::plan::fused::FusedPlan,
    agg: &A,
    threads: usize,
) -> Vec<A::Value> {
    aggregate_patterns_fused_range(graph, fused, agg, threads, 0, graph.num_vertices() as u32)
}

/// [`aggregate_patterns_fused`] restricted to first-level vertices in
/// `[lo, hi)` — the fused counterpart of [`aggregate_pattern_range`], with
/// the same disjoint-cover summation contract per pattern.
pub fn aggregate_patterns_fused_range<A: Aggregation>(
    graph: &DataGraph,
    fused: &crate::plan::fused::FusedPlan,
    agg: &A,
    threads: usize,
    lo: u32,
    hi: u32,
) -> Vec<A::Value> {
    let n_pat = fused.num_patterns();
    let (vals, _) = crate::exec::fused::par_fused_run_range(
        graph,
        fused,
        threads,
        lo,
        hi,
        || {
            let accs: Vec<A::Value> = (0..n_pat).map(|_| agg.identity()).collect();
            let scratch = vec![0 as VertexId; crate::pattern::MAX_PATTERN_VERTICES];
            (accs, scratch)
        },
        |(accs, scratch), i, m| {
            // positions → pattern vertices, through pattern i's own order
            let order = &fused.plans[i].order;
            for (pos, &pv) in order.iter().enumerate() {
                scratch[pv] = m[pos];
            }
            agg.accumulate(&mut accs[i], &scratch[..order.len()]);
        },
        |(a, s), (b, _)| {
            (
                a.into_iter()
                    .zip(b)
                    .map(|(x, y)| agg.combine(x, y))
                    .collect(),
                s,
            )
        },
    );
    vals.into_iter()
        .zip(&fused.plans)
        .map(|(v, plan)| symmetrize(&plan.pattern, agg, &v))
        .collect()
}

/// Aggregate over canonical (symmetry-broken) matches only.
pub fn aggregate_canonical<A: Aggregation>(
    graph: &DataGraph,
    plan: &Plan,
    agg: &A,
    threads: usize,
) -> A::Value {
    aggregate_canonical_range(graph, plan, agg, threads, 0, graph.num_vertices() as u32)
}

/// [`aggregate_canonical`] restricted to first-level vertices in
/// `[lo, hi)` — the one copy of the positions→pattern-vertices remap all
/// per-pattern aggregation goes through.
pub fn aggregate_canonical_range<A: Aggregation>(
    graph: &DataGraph,
    plan: &Plan,
    agg: &A,
    threads: usize,
    lo: u32,
    hi: u32,
) -> A::Value {
    let order = &plan.order;
    let n = order.len();
    crate::exec::parallel::par_run_range(
        graph,
        plan,
        threads,
        lo,
        hi,
        || (agg.identity(), vec![0 as VertexId; n]),
        |(acc, scratch), m| {
            // positions → pattern vertices
            for (pos, &pv) in order.iter().enumerate() {
                scratch[pv] = m[pos];
            }
            agg.accumulate(acc, scratch);
        },
        |(a, s), (b, _)| (agg.combine(a, b), s),
    )
    .0
}

/// `a(M_full) = ⨁_{α ∈ Aut(p)} a(M_canon) ∘* α`.
pub fn symmetrize<A: Aggregation>(pattern: &Pattern, agg: &A, canon: &A::Value) -> A::Value {
    let mut acc = agg.identity();
    for alpha in iso::automorphisms(pattern) {
        acc = agg.combine(acc, agg.permute(canon, &alpha));
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::pattern::catalog;

    #[test]
    fn aggregate_full_count_is_aut_times_canonical() {
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)])
            .build("k4");
        let p = catalog::triangle();
        let full = aggregate_pattern(&g, &p, &CountAgg, 2);
        // 4 triangles × |Aut| = 6 maps each
        assert_eq!(full, 24);
    }

    #[test]
    fn fused_aggregation_matches_per_pattern() {
        let g = crate::graph::generators::erdos_renyi(50, 200, 31);
        let base = vec![catalog::path(3), catalog::triangle(), catalog::cycle(4)];
        let fused = crate::plan::fused::FusedPlan::build(
            &base,
            None,
            &crate::plan::cost::CostParams::counting(),
        );
        let vals = aggregate_patterns_fused(&g, &fused, &CountAgg, 2);
        for (i, p) in base.iter().enumerate() {
            assert_eq!(vals[i], aggregate_pattern(&g, p, &CountAgg, 1), "{p:?}");
        }
    }

    #[test]
    fn symmetrize_respects_permute_law() {
        // enumeration: canonical triangle matches symmetrized give all maps
        let g = GraphBuilder::new().edges(&[(0, 1), (1, 2), (2, 0)]).build("k3");
        let p = catalog::triangle();
        let v = aggregate_pattern(&g, &p, &EnumerateAgg, 1);
        assert_eq!(v.positive_len(), 6, "3! maps of the single triangle");
    }
}
