//! Enumeration aggregation: the value is the (signed multiset of) matches
//! themselves.
//!
//! Signed multisets make the Corollary 3.1 set difference exact: subtracted
//! matches cancel to zero. A well-formed final value has only positive
//! multiplicities ([`MatchSet::assert_consistent`]); negative residues would
//! indicate a morphing bug (the property tests rely on this).

use super::Aggregation;
use crate::graph::VertexId;
use std::collections::HashMap;

/// Signed multiset of matches. Keys are maps `pattern vertex → data vertex`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MatchSet {
    pub counts: HashMap<Vec<VertexId>, i64>,
}

impl MatchSet {
    /// Number of entries with positive multiplicity, weighted.
    pub fn positive_len(&self) -> u64 {
        self.counts.values().filter(|&&c| c > 0).map(|&c| c as u64).sum()
    }

    /// All distinct matches with positive multiplicity, sorted.
    pub fn matches(&self) -> Vec<Vec<VertexId>> {
        let mut v: Vec<_> = self
            .counts
            .iter()
            .filter(|(_, &c)| c > 0)
            .map(|(k, _)| k.clone())
            .collect();
        v.sort();
        v
    }

    /// Distinct *subgraphs* (vertex sets) with positive multiplicity.
    pub fn unique_subgraphs(&self) -> Vec<Vec<VertexId>> {
        let mut seen = std::collections::HashSet::new();
        for (m, &c) in &self.counts {
            if c > 0 {
                let mut s = m.clone();
                s.sort_unstable();
                seen.insert(s);
            }
        }
        let mut v: Vec<_> = seen.into_iter().collect();
        v.sort();
        v
    }

    /// Panic if any multiplicity is negative (morphing must never produce
    /// negative residues on a consistent query).
    pub fn assert_consistent(&self) {
        for (m, &c) in &self.counts {
            assert!(c >= 0, "negative multiplicity {c} for match {m:?}");
        }
    }

    fn insert(&mut self, m: Vec<VertexId>, c: i64) {
        let e = self.counts.entry(m).or_insert(0);
        *e += c;
        if *e == 0 {
            // keep the map compact; removal also makes PartialEq meaningful
            let key = self
                .counts
                .iter()
                .find(|(_, &v)| v == 0)
                .map(|(k, _)| k.clone());
            if let Some(k) = key {
                self.counts.remove(&k);
            }
        }
    }
}

/// Enumerating aggregation: `λ(m) = {m}`, `⊕` = multiset sum,
/// `∘*` = per-match domain permutation.
pub struct EnumerateAgg;

impl Aggregation for EnumerateAgg {
    type Value = MatchSet;

    fn identity(&self) -> MatchSet {
        MatchSet::default()
    }

    fn accumulate(&self, acc: &mut MatchSet, m: &[VertexId]) {
        acc.insert(m.to_vec(), 1);
    }

    fn combine(&self, mut a: MatchSet, b: MatchSet) -> MatchSet {
        for (m, c) in b.counts {
            a.insert(m, c);
        }
        a
    }

    fn permute(&self, v: &MatchSet, f: &[usize]) -> MatchSet {
        // value over q, f : V(p) → V(q); each match m over q becomes m ∘ f
        let mut out = MatchSet::default();
        for (m, &c) in &v.counts {
            let pm: Vec<VertexId> = f.iter().map(|&fq| m[fq]).collect();
            out.insert(pm, c);
        }
        out
    }

    fn scale(&self, v: &MatchSet, c: i64) -> MatchSet {
        let mut out = MatchSet::default();
        for (m, &k) in &v.counts {
            out.insert(m.clone(), k * c);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiset_cancellation() {
        let a = EnumerateAgg;
        let mut x = a.identity();
        a.accumulate(&mut x, &[1, 2]);
        a.accumulate(&mut x, &[3, 4]);
        let y = a.scale(&x, -1);
        let z = a.combine(x, y);
        assert_eq!(z.positive_len(), 0);
        assert!(z.counts.is_empty(), "cancelled entries are removed");
    }

    #[test]
    fn permute_reindexes_matches() {
        let a = EnumerateAgg;
        let mut x = a.identity();
        a.accumulate(&mut x, &[10, 20, 30]); // match over q
        let f = vec![2, 0]; // p has 2 vertices; f: V(p)→V(q)
        let y = a.permute(&x, &f);
        assert_eq!(y.matches(), vec![vec![30, 10]]);
    }

    #[test]
    fn unique_subgraphs_dedupes_automorphic_maps() {
        let a = EnumerateAgg;
        let mut x = a.identity();
        a.accumulate(&mut x, &[1, 2, 3]);
        a.accumulate(&mut x, &[3, 2, 1]);
        assert_eq!(x.positive_len(), 2);
        assert_eq!(x.unique_subgraphs(), vec![vec![1, 2, 3]]);
    }

    #[test]
    #[should_panic]
    fn negative_residue_detected() {
        let a = EnumerateAgg;
        let mut x = a.identity();
        a.accumulate(&mut x, &[1, 2]);
        let y = a.scale(&x, -2);
        a.combine(x, y).assert_consistent();
    }
}
