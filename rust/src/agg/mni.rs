//! MNI (minimum node image) support aggregation [Bringmann & Nijssen], used
//! by Frequent Subgraph Mining.
//!
//! The MNI table has a column per pattern vertex; column `v` collects the
//! data vertices `m(v)` over all matches `m`. The support is the size of the
//! smallest column. It is anti-monotonic, which FSM's level-wise pruning
//! relies on.
//!
//! Columns are stored as signed multisets (`data vertex → multiplicity`) so
//! the aggregation is additive: Corollary 3.1's disjoint subtraction
//! cancels exactly, and the domain of a column is its positive support.
//! (Since full match sets are closed under `Aut(p)`, symmetric vertices end
//! up with identical columns — the "groups of symmetric vertices" in the
//! paper's formulation.)

use super::Aggregation;
use crate::graph::VertexId;
use std::collections::HashMap;

/// MNI table: one signed-multiset column per pattern vertex.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MniTable {
    pub columns: Vec<HashMap<VertexId, i64>>,
}

impl MniTable {
    pub fn new(n: usize) -> MniTable {
        MniTable {
            columns: vec![HashMap::new(); n],
        }
    }

    /// The MNI support: size of the smallest column domain (positive keys).
    pub fn support(&self) -> u64 {
        self.columns
            .iter()
            .map(|c| c.values().filter(|&&x| x > 0).count() as u64)
            .min()
            .unwrap_or(0)
    }

    /// Domain of column `v` (sorted, positive multiplicities only).
    pub fn domain(&self, v: usize) -> Vec<VertexId> {
        let mut d: Vec<_> = self.columns[v]
            .iter()
            .filter(|(_, &c)| c > 0)
            .map(|(&u, _)| u)
            .collect();
        d.sort_unstable();
        d
    }

    /// Panic on negative multiplicities (morphing must cancel exactly).
    pub fn assert_consistent(&self) {
        for (v, col) in self.columns.iter().enumerate() {
            for (&u, &c) in col {
                assert!(c >= 0, "column {v}: negative multiplicity {c} for vertex {u}");
            }
        }
    }
}

/// The MNI aggregation: `λ(m)` = table with `{m(v)}` in column `v`,
/// `⊕` = column-wise multiset sum, `∘*` = column reindexing.
pub struct MniAgg {
    /// Number of pattern vertices (table width).
    pub n: usize,
}

impl Aggregation for MniAgg {
    type Value = MniTable;

    fn identity(&self) -> MniTable {
        MniTable::new(self.n)
    }

    fn accumulate(&self, acc: &mut MniTable, m: &[VertexId]) {
        debug_assert_eq!(m.len(), self.n);
        for (v, &u) in m.iter().enumerate() {
            *acc.columns[v].entry(u).or_insert(0) += 1;
        }
    }

    fn combine(&self, mut a: MniTable, b: MniTable) -> MniTable {
        debug_assert_eq!(a.columns.len(), b.columns.len());
        for (ca, cb) in a.columns.iter_mut().zip(b.columns) {
            for (u, c) in cb {
                let e = ca.entry(u).or_insert(0);
                *e += c;
                if *e == 0 {
                    ca.remove(&u);
                }
            }
        }
        a
    }

    fn permute(&self, v: &MniTable, f: &[usize]) -> MniTable {
        // value over q; f : V(p) → V(q); result column i = input column f[i].
        // The result width is |p| = f.len() (may differ from self.n when
        // converting across patterns of different size — not used in
        // practice since morphing is same-size, but keep it correct).
        MniTable {
            columns: f.iter().map(|&fq| v.columns[fq].clone()).collect(),
        }
    }

    fn scale(&self, v: &MniTable, c: i64) -> MniTable {
        MniTable {
            columns: v
                .columns
                .iter()
                .map(|col| col.iter().map(|(&u, &k)| (u, k * c)).collect())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg::aggregate_pattern;
    use crate::graph::GraphBuilder;
    use crate::pattern::catalog;

    #[test]
    fn support_is_min_column() {
        let mut t = MniTable::new(2);
        t.columns[0].insert(1, 2);
        t.columns[0].insert(2, 1);
        t.columns[1].insert(9, 1);
        assert_eq!(t.support(), 1);
        assert_eq!(t.domain(0), vec![1, 2]);
    }

    #[test]
    fn star_center_support() {
        // star with center 0, leaves 1..4 — pattern: labeled edge (hub=a, leaf=b)
        let g = GraphBuilder::new()
            .edges(&[(0, 1), (0, 2), (0, 3), (0, 4)])
            .labels(vec![0, 1, 1, 1, 1])
            .build("star");
        let p = crate::pattern::Pattern::from_edges(2, &[(0, 1)]).with_labels(&[0, 1]);
        let agg = MniAgg { n: 2 };
        let t = aggregate_pattern(&g, &p, &agg, 1);
        // column 0 = {center}, column 1 = 4 leaves → MNI support 1
        assert_eq!(t.domain(0), vec![0]);
        assert_eq!(t.domain(1).len(), 4);
        assert_eq!(t.support(), 1);
    }

    #[test]
    fn symmetric_vertices_equal_domains() {
        // full match set: wedge (path3) endpoints are symmetric
        let g = GraphBuilder::new().edges(&[(0, 1), (1, 2), (2, 3)]).build("p4");
        let p = catalog::path(3);
        let agg = MniAgg { n: 3 };
        let t = aggregate_pattern(&g, &p, &agg, 1);
        assert_eq!(t.domain(0), t.domain(2), "symmetric endpoints");
    }

    #[test]
    fn combine_cancels() {
        let agg = MniAgg { n: 1 };
        let mut a = agg.identity();
        agg.accumulate(&mut a, &[5]);
        let b = agg.scale(&a, -1);
        let c = agg.combine(a, b);
        assert_eq!(c.support(), 0);
        c.assert_consistent();
    }

    #[test]
    fn permute_reindexes_columns() {
        let agg = MniAgg { n: 3 };
        let mut t = agg.identity();
        agg.accumulate(&mut t, &[10, 20, 30]);
        let f = vec![2, 1, 0];
        let u = agg.permute(&t, &f);
        assert_eq!(u.domain(0), vec![30]);
        assert_eq!(u.domain(2), vec![10]);
    }
}
