//! Small self-contained utilities: deterministic RNG, timers, a tiny
//! property-testing harness, and bitset helpers.
//!
//! The build environment is offline with a minimal vendored crate set, so we
//! provide our own replacements for `rand` ([`rng`]), `proptest`
//! ([`proptest`]) and `criterion`-style timing ([`timer`]).

pub mod bitset;
pub mod proptest;
pub mod rng;
pub mod timer;

/// Binomial coefficient C(n, k) as u64 (saturating; fine for mining counts
/// of small k).
pub fn choose(n: u64, k: u64) -> u64 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num: u128 = 1;
    let mut den: u128 = 1;
    for i in 0..k {
        num = num.saturating_mul((n - i) as u128);
        den *= (i + 1) as u128;
    }
    (num / den) as u64
}

/// Factorial for small n (pattern sizes ≤ 8 ⇒ fits easily in u64).
pub fn factorial(n: u64) -> u64 {
    (1..=n).product::<u64>().max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choose_basics() {
        assert_eq!(choose(4, 2), 6);
        assert_eq!(choose(5, 0), 1);
        assert_eq!(choose(5, 5), 1);
        assert_eq!(choose(3, 4), 0);
        assert_eq!(choose(10, 3), 120);
    }

    #[test]
    fn factorial_basics() {
        assert_eq!(factorial(0), 1);
        assert_eq!(factorial(1), 1);
        assert_eq!(factorial(5), 120);
        assert_eq!(factorial(8), 40320);
    }
}
