//! Lightweight timing + phase breakdown instrumentation.
//!
//! Used by the coordinator to attribute execution time to *matching* vs
//! *aggregation* (the Figure-2 breakdown in the paper) and by the bench
//! harness in place of criterion (not available offline).

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Accumulating phase profile: named buckets of wall time.
#[derive(Debug, Default, Clone)]
pub struct PhaseProfile {
    entries: Vec<(String, Duration)>,
}

impl PhaseProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `d` to phase `name` (creating it if needed).
    pub fn add(&mut self, name: &str, d: Duration) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += d;
        } else {
            self.entries.push((name.to_string(), d));
        }
    }

    /// Time a closure and attribute it to `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let r = f();
        self.add(name, t.elapsed());
        r
    }

    pub fn get(&self, name: &str) -> Duration {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.entries.iter().map(|(_, d)| *d).sum()
    }

    pub fn entries(&self) -> &[(String, Duration)] {
        &self.entries
    }

    /// Merge another profile into this one.
    pub fn merge(&mut self, other: &PhaseProfile) {
        for (n, d) in &other.entries {
            self.add(n, *d);
        }
    }
}

/// Benchmark runner: median-of-runs with warmup, criterion-lite.
pub struct BenchRunner {
    pub warmup: usize,
    pub runs: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup: 1, runs: 3 }
    }
}

impl BenchRunner {
    pub fn new(warmup: usize, runs: usize) -> Self {
        BenchRunner { warmup, runs }
    }

    /// Run `f` with warmup, return (median_secs, min_secs, max_secs).
    pub fn measure<T>(&self, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times: Vec<f64> = Vec::with_capacity(self.runs);
        for _ in 0..self.runs.max(1) {
            let t = Timer::start();
            std::hint::black_box(f());
            times.push(t.secs());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        BenchStats {
            median: times[times.len() / 2],
            min: times[0],
            max: *times.last().unwrap(),
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub median: f64,
    pub min: f64,
    pub max: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_accumulates() {
        let mut p = PhaseProfile::new();
        p.add("match", Duration::from_millis(5));
        p.add("match", Duration::from_millis(7));
        p.add("agg", Duration::from_millis(3));
        assert_eq!(p.get("match"), Duration::from_millis(12));
        assert_eq!(p.total(), Duration::from_millis(15));
    }

    #[test]
    fn profile_time_closure() {
        let mut p = PhaseProfile::new();
        let v = p.time("work", || 21 * 2);
        assert_eq!(v, 42);
        assert!(p.get("work") > Duration::ZERO);
    }

    #[test]
    fn profile_merge() {
        let mut a = PhaseProfile::new();
        a.add("x", Duration::from_millis(1));
        let mut b = PhaseProfile::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.get("x"), Duration::from_millis(3));
        assert_eq!(a.get("y"), Duration::from_millis(3));
    }

    #[test]
    fn bench_runner_runs() {
        let stats = BenchRunner::new(0, 3).measure(|| (0..1000).sum::<u64>());
        assert!(stats.min <= stats.median && stats.median <= stats.max);
    }
}
