//! Bitset helpers.
//!
//! [`SmallSet`] is a `u64`-backed set over indices `< 64` used for pattern
//! vertices (patterns have ≤ 8 vertices, so a single word is plenty).
//! [`DynBitset`] is a growable bitset used over data-graph vertices (MNI
//! domains, visited marks).

/// Fixed-capacity set over `0..64`, backed by one `u64`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct SmallSet(pub u64);

impl SmallSet {
    #[inline]
    pub fn empty() -> Self {
        SmallSet(0)
    }

    /// Set of all indices `0..n`.
    #[inline]
    pub fn full(n: usize) -> Self {
        debug_assert!(n <= 64);
        if n == 64 {
            SmallSet(!0)
        } else {
            SmallSet((1u64 << n) - 1)
        }
    }

    #[inline]
    pub fn insert(&mut self, i: usize) {
        self.0 |= 1u64 << i;
    }

    #[inline]
    pub fn remove(&mut self, i: usize) {
        self.0 &= !(1u64 << i);
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        (self.0 >> i) & 1 == 1
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.0.count_ones() as usize
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.0 == 0
    }

    #[inline]
    pub fn union(&self, o: &Self) -> Self {
        SmallSet(self.0 | o.0)
    }

    #[inline]
    pub fn intersect(&self, o: &Self) -> Self {
        SmallSet(self.0 & o.0)
    }

    #[inline]
    pub fn minus(&self, o: &Self) -> Self {
        SmallSet(self.0 & !o.0)
    }

    /// Iterate set indices in increasing order.
    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        let mut bits = self.0;
        std::iter::from_fn(move || {
            if bits == 0 {
                None
            } else {
                let i = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                Some(i)
            }
        })
    }
}

impl std::fmt::Debug for SmallSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_set().entries(self.iter()).finish()
    }
}

impl FromIterator<usize> for SmallSet {
    fn from_iter<T: IntoIterator<Item = usize>>(iter: T) -> Self {
        let mut s = SmallSet::empty();
        for i in iter {
            s.insert(i);
        }
        s
    }
}

/// Growable bitset over `0..n`.
#[derive(Clone, Debug, Default)]
pub struct DynBitset {
    words: Vec<u64>,
    len: usize,
}

impl DynBitset {
    pub fn new(n: usize) -> Self {
        DynBitset {
            words: vec![0; n.div_ceil(64)],
            len: n,
        }
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn clear_bit(&mut self, i: usize) {
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Reset all bits to zero, keeping capacity.
    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// Iterate set indices in increasing order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let i = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some(wi * 64 + i)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smallset_ops() {
        let a: SmallSet = [0, 2, 5].into_iter().collect();
        let b: SmallSet = [2, 3].into_iter().collect();
        assert_eq!(a.len(), 3);
        assert!(a.contains(2) && !a.contains(1));
        assert_eq!(a.intersect(&b).iter().collect::<Vec<_>>(), vec![2]);
        assert_eq!(a.union(&b).len(), 4);
        assert_eq!(a.minus(&b).iter().collect::<Vec<_>>(), vec![0, 5]);
        assert_eq!(SmallSet::full(3).iter().collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(SmallSet::full(64).len(), 64);
    }

    #[test]
    fn smallset_remove() {
        let mut s = SmallSet::full(4);
        s.remove(1);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![0, 2, 3]);
    }

    #[test]
    fn dynbitset_ops() {
        let mut b = DynBitset::new(200);
        b.set(0);
        b.set(63);
        b.set(64);
        b.set(199);
        assert_eq!(b.count(), 4);
        assert!(b.get(63) && b.get(64) && !b.get(65));
        assert_eq!(b.iter().collect::<Vec<_>>(), vec![0, 63, 64, 199]);
        b.clear_bit(63);
        assert_eq!(b.count(), 3);
        b.clear();
        assert_eq!(b.count(), 0);
        assert_eq!(b.capacity(), 200);
    }
}
