//! Deterministic pseudo-random number generation (splitmix64 + xoshiro256**).
//!
//! Used by the synthetic graph generators and the property-test harness.
//! Fully deterministic given a seed so every dataset and every test case is
//! reproducible across runs and machines.

/// splitmix64 — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Small, fast, good statistical quality; plenty for
/// synthetic workload generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's method, bias negligible for our use).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply-shift
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random permutation of `0..n` (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below_usize(i + 1);
            v.swap(i, j);
        }
        v
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below_usize(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below_usize(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Geometric-ish power-law sample in `[0, n)` with exponent `alpha`
    /// (inverse-CDF of a truncated Pareto). Used for skewed label
    /// distributions.
    pub fn powerlaw(&mut self, n: usize, alpha: f64) -> usize {
        let u = self.f64().max(1e-12);
        let x = (1.0 - u * (1.0 - (n as f64).powf(1.0 - alpha))).powf(1.0 / (1.0 - alpha));
        // x ∈ [1, n]; shift to [0, n-1] so label 0 is the most frequent
        ((x - 1.0) as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_in_unit() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(20);
        let mut s = p.clone();
        s.sort_unstable();
        assert_eq!(s, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(4);
        let s = r.sample_distinct(100, 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&x| x < 100));
    }

    #[test]
    fn chance_rates() {
        let mut r = Rng::new(5);
        let hits = (0..10_000).filter(|_| r.chance(0.3)).count();
        assert!((2_500..3_500).contains(&hits), "hits={hits}");
    }

    #[test]
    fn powerlaw_skews_small() {
        let mut r = Rng::new(6);
        let mut lo = 0;
        for _ in 0..1000 {
            if r.powerlaw(100, 2.0) < 10 {
                lo += 1;
            }
        }
        assert!(lo > 600, "power-law should concentrate mass at small values, lo={lo}");
    }
}
