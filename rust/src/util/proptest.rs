//! Minimal property-testing harness (the `proptest` crate is not available
//! in this offline environment).
//!
//! A property is a closure taking a seeded [`Rng`](super::rng::Rng); the
//! harness runs it across many derived seeds and reports the failing seed on
//! panic so failures are reproducible with `PROP_SEED=<n>`.

use super::rng::Rng;

/// Number of cases to run, overridable with `PROP_CASES`.
pub fn cases(default_cases: usize) -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_cases)
}

/// Run `prop` for `n` cases with deterministic per-case seeds derived from
/// `base_seed`. If `PROP_SEED` is set, runs only that case (for shrinking a
/// failure by hand).
pub fn check(base_seed: u64, n: usize, prop: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    if let Ok(s) = std::env::var("PROP_SEED") {
        let seed: u64 = s.parse().expect("PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        prop(&mut rng);
        return;
    }
    for case in 0..cases(n) {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            eprintln!("property failed at case {case} — rerun with PROP_SEED={seed}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let counter = std::sync::atomic::AtomicUsize::new(0);
        check(7, 25, |_rng| {
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        // PROP_CASES may override; only assert it ran at least once.
        assert!(counter.load(std::sync::atomic::Ordering::SeqCst) >= 1);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check(9, 10, |rng| {
            // always fails eventually (first case already fails)
            assert!(rng.below(10) > 100);
        });
    }
}
