//! # morphmine
//!
//! A pattern-aware graph mining framework implementing **Pattern Morphing**
//! (Jamshidi & Vora, 2020): a structure-aware algebra over graph patterns that
//! converts a query pattern set into an equivalent *alternative* pattern set
//! that is cheaper to match, then reconstructs exact results for the original
//! queries from the alternative matches.
//!
//! The crate is organised as a three-layer system:
//!
//! * **Layer 3 (this crate)** — the mining coordinator: data-graph substrate,
//!   pattern algebra, Peregrine-style pattern-aware matching engine,
//!   aggregation framework (counting / enumeration / MNI support), the
//!   morphing engine with its cost-based optimizer, and the applications
//!   (motif counting, FSM, pattern matching, clique finding).
//!
//!   Multi-pattern base sets are matched by **fused co-execution** by
//!   default: [`plan::fused`] merges the per-pattern matching plans into a
//!   shared-prefix trie (choosing matching orders that maximize shared
//!   connected prefixes via the [`plan::cost`] prefix-sharing term), and
//!   [`exec::fused`] walks that trie in a single data-graph traversal —
//!   one first-level sweep for the whole morphed base set instead of one
//!   per pattern. Toggle with `--fused on|off` / [`morph::ExecOpts`].
//!
//!   On top of the coordinator sits the [`service`] layer: a result cache
//!   keyed by canonical pattern × graph epoch plus a batched, multi-worker
//!   query service (`morphmine serve` / `morphmine batch`) that executes
//!   only the base patterns missing from the cache and composes the rest
//!   through the morph algebra. With `--persist <dir>` the cache is
//!   durable ([`service::persist`]): a WAL + snapshot store keyed by a
//!   cross-process graph fingerprint, so restarts begin warm. The
//!   [`shard`] layer scales the whole stack out across processes:
//!   `morphmine shard-worker` serves first-level slices over a framed TCP
//!   protocol and `batch|serve --shards <addr,…>` merges the exact
//!   per-slice partial counts (see `docs/ARCHITECTURE.md` for the
//!   layer-by-layer map).
//! * **Layer 2 (python/compile/model.py)** — a dense adjacency-matrix motif
//!   census written in JAX, AOT-lowered to HLO and executed from Rust via
//!   PJRT ([`runtime`]). It encodes the same morphing equations in dense
//!   linear algebra and acts as an alternative counting backend.
//! * **Layer 1 (python/compile/kernels/census.py)** — the Pallas kernel for
//!   the census hot-spot (blocked masked matmul + fused reductions).
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod agg;
pub mod apps;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod exec;
pub mod graph;
pub mod morph;
pub mod obs;
pub mod pattern;
pub mod plan;
pub mod runtime;
pub mod service;
pub mod shard;
pub mod util;

pub use graph::DataGraph;
pub use pattern::Pattern;
