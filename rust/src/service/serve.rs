//! Batched query service: a multi-threaded request loop over one mutable
//! graph, one result store, and one in-flight registry.
//!
//! * **Admission** — batches of [`coordinator::query::Query`] texts
//!   (`motifs:4`, `match:cycle4,p3`, `cliques:4`) arrive over an mpsc
//!   channel and are picked up by worker threads. FSM is rejected at parse
//!   time: its support aggregation is not per-base-pattern cacheable.
//! * **Reuse** — each worker probes the [`ResultStore`] and executes only
//!   the missing bases through the [`QueryPlanner`] (the cached bases drop
//!   out of the fused plan trie).
//! * **Coalescing** — bases already being computed by *another* in-flight
//!   batch at the same epoch are not recomputed: the worker registers
//!   interest in the owner's completion cell and blocks on it after
//!   finishing its own share. Each `(canonical key, epoch)` pair is
//!   matched at most once process-wide.
//! * **Maintenance** — the service owns a [`DynGraph`];
//!   [`Service::insert_edge`]/[`Service::remove_edge`] delegate to it, and
//!   every *applied* mutation bumps [`DynGraph::version`]. Instead of
//!   purging the store, an applied update runs the delta-morphing pass
//!   ([`crate::service::delta`]): per-base count deltas computed from the
//!   updated edge's neighborhood **patch cached values in place** under
//!   the epoch bump ([`ResultStore::rebase_epoch`]); bases the pass cannot
//!   prove (labeled, disconnected, neighborhood over budget, or bases
//!   whose pattern this process has never planned) fall back to an
//!   explicit counted purge — `mm_delta_fallback_total` — and recompute
//!   cold on next touch. Batches still pin the epoch at admission: the
//!   CSR snapshot is rebuilt lazily on the first batch after a mutation,
//!   and results computed against a superseded snapshot never enter the
//!   cache — stale counts are structurally unservable.
//! * **Durability** — with [`ServiceConfig::persist`] set, published
//!   inserts are mirrored into a write-ahead log and folded into
//!   snapshots ([`crate::service::persist`]); a restart recovers the
//!   store warm when the live graph's fingerprint matches what was
//!   persisted, and cold otherwise. All WAL and snapshot IO runs on a
//!   dedicated writer thread so the state mutex is never held across a
//!   disk write; ordering against invalidations is preserved because
//!   commands are *enqueued* under that mutex (see [`WalCmd`]). WAL
//!   appends are flushed per record, so an abrupt kill (SIGINT, OOM)
//!   loses at most the records still queued or mid-write — replay
//!   truncates a torn tail; a graceful [`Drop`] drains the queue and
//!   compacts so the next start skips the replay.
//! * **Containment** — a batch that panics (an internal invariant
//!   failure) is caught at the worker boundary: that batch's caller gets
//!   an error from [`Service::call`], cells the batch owned are failed so
//!   coalesced batches error instead of hanging, and the worker keeps
//!   serving subsequent batches.
//!
//! [`coordinator::query::Query`]: crate::coordinator::query::Query

use super::persist::{PersistConfig, Persistence, RecoveryReport};
use super::planner::{BatchStats, QueryPlanner};
use super::store::{ResultStore, StoreMetrics};
use crate::coordinator::query::Query;
use crate::graph::{DataGraph, DynGraph, GraphFingerprint, GraphStats, Relabeling, VertexId};
use crate::morph::Policy;
use crate::obs::{Trace, TraceBuilder};
use crate::pattern::canon::CanonKey;
use crate::pattern::Pattern;
use crate::util::timer::PhaseProfile;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Cap on how many new vertices a single edge update may create by naming
/// an ID beyond the current graph (a fat-finger guard for the interactive
/// `serve` loop: `+ 0 4000000000` must error, not allocate gigabytes of
/// adjacency slots).
pub const MAX_UPDATE_GROWTH: usize = 1 << 20;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Request-loop worker threads (concurrent batches).
    pub workers: usize,
    /// Matcher threads per batch execution (total parallelism is
    /// `workers × threads` when batches overlap).
    pub threads: usize,
    /// Morphing policy for admitted queries.
    pub policy: Policy,
    /// Fuse multi-pattern executions into one traversal.
    pub fused: bool,
    /// Result-store eviction budget in bytes.
    pub cache_bytes: usize,
    /// Persist the result store to this directory (WAL + snapshots, see
    /// [`crate::service::persist`]) so a restart recovers warm. `None`
    /// keeps the store purely in-memory.
    pub persist: Option<PersistConfig>,
    /// Delta-morphing enumeration budget: the cap on distinct connected
    /// neighborhood sets examined per pattern size when an edge update
    /// patches the store in place (see [`crate::service::delta`]). `0`
    /// disables the delta pass — every update purges, the pre-delta
    /// behavior, with the fallback still explicitly counted.
    pub delta_budget: usize,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            workers: 2,
            threads: crate::exec::parallel::default_threads(),
            policy: Policy::CostBased,
            fused: true,
            cache_bytes: 64 << 20,
            persist: None,
            delta_budget: super::delta::DEFAULT_DELTA_BUDGET,
        }
    }
}

/// One admitted query: its original text plus the expanded pattern set
/// whose unique-match counts answer it.
#[derive(Clone, Debug)]
pub struct ServiceQuery {
    pub text: String,
    pub patterns: Vec<Pattern>,
}

impl ServiceQuery {
    /// Parse a query text (`motifs:4`, `match:…`, `cliques:k`). FSM texts
    /// are rejected — not servable from a per-pattern cache.
    pub fn parse(text: &str) -> Result<ServiceQuery> {
        let q = Query::parse(text)?;
        let Some(patterns) = q.patterns() else {
            bail!("query {text:?} is not cacheable per-pattern (use `morphmine fsm`)");
        };
        Ok(ServiceQuery {
            text: text.to_string(),
            patterns,
        })
    }
}

/// Counts for one admitted query, aligned with its expanded patterns.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QueryResult {
    /// The admitted query text.
    pub query: String,
    /// `(pattern, unique-match count)` in expansion order.
    pub counts: Vec<(Pattern, u64)>,
}

/// Response for one batch.
#[derive(Clone, Debug)]
pub struct BatchResponse {
    /// Per-query results, in admission order.
    pub results: Vec<QueryResult>,
    /// Base-pattern reuse accounting for this batch.
    pub stats: BatchStats,
    /// Graph epoch the batch was answered at.
    pub epoch: u64,
    /// Phase breakdown (plan / probe / fuse / match / convert / persist).
    pub profile: PhaseProfile,
    /// The batch's span tree: a root `batch` span with one child per
    /// pipeline stage, and — on the sharded path — one child per remote
    /// sub-slice under the `match` stage, with the worker's own phase
    /// spans grafted beneath (proto v5). Always populated; rendering and
    /// retention are the caller's choice (`--trace-tree`, the flight
    /// recorder, `/trace.json`).
    pub trace: Trace,
}

/// Completion cell for one in-flight base computation: owners fill it
/// (`Ok` on publish, `Err` if the owner unwound first), coalesced waiters
/// block on it.
#[derive(Default)]
struct Cell {
    value: Mutex<Option<Result<i128, &'static str>>>,
    ready: Condvar,
}

/// One command for the WAL writer thread.
///
/// Commands are **enqueued while holding the service state mutex**, at
/// the exact point the corresponding store transition happens, so the
/// FIFO channel pins on-disk record order to store state order: an
/// insert published before an epoch invalidation can never be written
/// after it (which replay would bind to the wrong fingerprint). The IO
/// itself — per-record flushed appends and multi-MB snapshot writes —
/// runs entirely off the mutex, on the writer thread.
enum WalCmd {
    /// Mirror one store-accepted insert into the WAL.
    Insert(CanonKey, i128),
    /// The graph mutated: rebind the log to the new content fingerprint.
    Invalidate(GraphFingerprint),
    /// Fold this live store image (captured under the state mutex, so it
    /// is consistent with every record enqueued before it) into a
    /// snapshot and reset the WAL.
    Compact(Vec<(CanonKey, i128)>),
    /// Drain and stop. `image` is the final store image for the
    /// graceful-shutdown compaction (`None` skips it — used when the
    /// state mutex was poisoned and the image cannot be trusted).
    Shutdown {
        image: Option<Vec<(CanonKey, i128)>>,
    },
}

/// Handle to the dedicated WAL writer thread, which owns the
/// [`Persistence`] session for the service's lifetime.
struct WalWriter {
    tx: mpsc::Sender<WalCmd>,
    /// Set by the writer when the log cadence wants a compaction; the
    /// next publish observes it under the state mutex, captures the
    /// image there, and enqueues [`WalCmd::Compact`].
    compact_due: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl WalWriter {
    fn spawn(persist: Persistence<i128>) -> WalWriter {
        let (tx, rx) = mpsc::channel();
        let compact_due = Arc::new(AtomicBool::new(false));
        let due = compact_due.clone();
        let join = std::thread::spawn(move || wal_writer_loop(&rx, persist, &due));
        WalWriter {
            tx,
            compact_due,
            join: Some(join),
        }
    }

    fn insert(&self, key: CanonKey, value: i128) {
        if self.tx.send(WalCmd::Insert(key, value)).is_ok() {
            crate::obs_gauge!("mm_wal_queue_depth").inc();
        }
    }

    fn invalidate(&self, fp: GraphFingerprint) {
        if self.tx.send(WalCmd::Invalidate(fp)).is_ok() {
            crate::obs_gauge!("mm_wal_queue_depth").inc();
        }
    }

    fn compact(&self, image: Vec<(CanonKey, i128)>) {
        if self.tx.send(WalCmd::Compact(image)).is_ok() {
            crate::obs_gauge!("mm_wal_queue_depth").inc();
        }
    }

    /// Whether the writer asked for a cadence compaction (one-shot: the
    /// caller that takes the flag owes the writer a [`WalCmd::Compact`]).
    fn take_compact_due(&self) -> bool {
        self.compact_due.swap(false, Ordering::Relaxed)
    }

    /// Graceful shutdown: hand over the final image, then block until
    /// every queued record (and the shutdown compaction) hit disk.
    fn shutdown(mut self, image: Option<Vec<(CanonKey, i128)>>) {
        let _ = self.tx.send(WalCmd::Shutdown { image });
        if let Some(h) = self.join.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WalWriter {
    fn drop(&mut self) {
        // backstop for paths that bypass Service::drop's explicit
        // shutdown (e.g. a poisoned state mutex): stop the thread without
        // a final compaction — the flushed WAL already holds everything
        // published, so recovery replays it
        if let Some(h) = self.join.take() {
            let _ = self.tx.send(WalCmd::Shutdown { image: None });
            let _ = h.join();
        }
    }
}

/// The writer thread: applies commands in channel order. On the first IO
/// error, persistence degrades to in-memory-only for the rest of the
/// session (commands are drained and dropped) — recovery's fingerprint
/// gate keeps whatever partial state is on disk safe to (not) serve, so
/// a broken disk can only cool a future restart, never corrupt answers.
fn wal_writer_loop(rx: &mpsc::Receiver<WalCmd>, mut p: Persistence<i128>, due: &AtomicBool) {
    while let Ok(cmd) = rx.recv() {
        crate::obs_gauge!("mm_wal_queue_depth").dec();
        let result = match cmd {
            WalCmd::Insert(k, v) => {
                let t = std::time::Instant::now();
                let r = p.record_insert(&k, &v);
                crate::obs_histogram!("mm_wal_append_us").record_duration(t.elapsed());
                r
            }
            WalCmd::Invalidate(fp) => p.record_invalidation(fp),
            WalCmd::Compact(image) => {
                let t = std::time::Instant::now();
                let r = p.compact(&image);
                crate::obs_histogram!("mm_wal_compaction_us").record_duration(t.elapsed());
                r
            }
            WalCmd::Shutdown { image } => {
                if let Some(image) = image {
                    // skip when nothing was logged since the last
                    // compaction: the snapshot on disk already equals the
                    // live image
                    if p.compact_on_drop() && p.dirty() {
                        if let Err(e) = p.compact(&image) {
                            eprintln!("warning: final store compaction failed: {e}");
                        }
                    }
                }
                return;
            }
        };
        if let Err(e) = result {
            eprintln!("warning: WAL write failed, persistence disabled: {e}");
            break;
        }
        due.store(p.wants_compaction(), Ordering::Relaxed);
    }
    // degraded: keep draining so enqueuers never see a closed channel
    // mid-session and shutdown still joins promptly
    for cmd in rx.iter() {
        crate::obs_gauge!("mm_wal_queue_depth").dec();
        if matches!(cmd, WalCmd::Shutdown { .. }) {
            return;
        }
    }
}

/// State behind the service mutex.
struct State {
    graph: DynGraph,
    snapshot: Option<Arc<DataGraph>>,
    snapshot_epoch: u64,
    stats: Option<Arc<GraphStats>>,
    store: ResultStore<i128>,
    /// `(canonical key, epoch)` → completion cell of the batch computing it.
    inflight: HashMap<(CanonKey, u64), Arc<Cell>>,
    /// Handle to the WAL writer thread, when persistence is configured.
    /// Mutating the store and enqueuing the mirroring command happen
    /// under the same lock hold, which is what keeps on-disk record
    /// order equal to store state order — the IO itself never runs here.
    persist: Option<WalWriter>,
    /// Degree-ordered relabeling of the *initial* graph, if any: public
    /// edge updates arrive in original (input) IDs and are translated into
    /// the engine's internal ID space, which snapshots keep forever.
    relabel: Option<Relabeling>,
    /// Every base pattern this process has planned, by canonical key —
    /// the delta pass needs the *pattern* behind each stored key to count
    /// its perturbed maps. Keys the registry cannot resolve (e.g. entries
    /// restored from disk before their base was ever planned here) are
    /// purged on update, never guessed.
    patterns: HashMap<CanonKey, Pattern>,
    /// See [`ServiceConfig::delta_budget`].
    delta_budget: usize,
}

impl State {
    /// Original (input) vertex ID → internal engine ID. Vertices beyond
    /// the initial graph (created by later inserts) never went through the
    /// relabeling and are addressed identically in both spaces.
    fn internal(&self, v: VertexId) -> VertexId {
        match &self.relabel {
            Some(r) if (v as usize) < r.len() => r.new_id(v),
            _ => v,
        }
    }
}

struct Shared {
    state: Mutex<State>,
}

/// Unwind guard for the cells a batch registered: disarmed after a
/// successful publish; on an owner panic it fails the still-pending cells
/// so coalesced batches propagate an error instead of waiting forever.
struct OwnedCells<'a> {
    shared: &'a Shared,
    keys: Vec<(CanonKey, u64)>,
    armed: bool,
}

impl Drop for OwnedCells<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let mut st = match self.shared.state.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        for key in &self.keys {
            if let Some(cell) = st.inflight.remove(key) {
                *cell.value.lock().unwrap() = Some(Err("owner batch panicked before publishing"));
                cell.ready.notify_all();
            }
        }
    }
}

struct Job {
    queries: Vec<ServiceQuery>,
    respond: mpsc::Sender<BatchResponse>,
}

/// The batched query service. Dropping it shuts the request loop down,
/// joins the workers, and (when persistence is on) compacts the durable
/// store so the next start recovers from one snapshot.
pub struct Service {
    shared: Arc<Shared>,
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    recovery: Option<RecoveryReport>,
}

impl Service {
    /// Start the service over `graph` (converted to a mutable [`DynGraph`]
    /// internally; the given CSR becomes the epoch-0 snapshot). Panics if
    /// the configured persist directory cannot be opened — use
    /// [`Service::try_start`] to handle that as an error.
    pub fn start(graph: DataGraph, config: ServiceConfig) -> Service {
        Self::try_start(graph, config).expect("service start failed")
    }

    /// [`Service::start`], surfacing persistence IO failures as errors.
    /// When `config.persist` names a directory, the store persisted there
    /// is recovered first: entries whose [`crate::graph::GraphFingerprint`]
    /// matches `graph` seed the result store (the warm restart), anything
    /// else — fresh directory, torn/corrupt files, or state from a
    /// different or mutated graph — degrades to a cold store.
    pub fn try_start(graph: DataGraph, config: ServiceConfig) -> Result<Service> {
        let dyn_graph = DynGraph::from_data_graph(&graph);
        let relabel = graph.relabeling().cloned();
        let stats = GraphStats::compute(&graph, 2000, 0x5E55);
        let mut store = ResultStore::new(config.cache_bytes);
        let (persist, recovery) = match &config.persist {
            Some(pc) => {
                let fp = graph.fingerprint();
                let (p, warm, report) = Persistence::open(&pc.dir, fp, pc.opts)
                    .with_context(|| format!("opening persist dir {}", pc.dir.display()))?;
                for (k, v) in warm {
                    store.restore(k, v);
                }
                (Some(WalWriter::spawn(p)), Some(report))
            }
            None => (None, None),
        };
        // expose the store's live counters under mm_store_* for scraping
        // (last service started in-process wins the binding — fine for the
        // one-service CLI processes and for tests)
        store.register_metrics(crate::obs::global(), "mm_store_");
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                graph: dyn_graph,
                snapshot: Some(Arc::new(graph)),
                snapshot_epoch: 0,
                stats: Some(Arc::new(stats)),
                store,
                inflight: HashMap::new(),
                relabel,
                persist,
                patterns: HashMap::new(),
                delta_budget: config.delta_budget,
            }),
        });
        let planner = QueryPlanner::new(config.policy, config.fused, config.threads);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..config.workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                let rx = rx.clone();
                std::thread::spawn(move || worker_loop(&shared, &rx, planner))
            })
            .collect();
        Ok(Service {
            shared,
            tx: Some(tx),
            workers,
            recovery,
        })
    }

    /// What startup recovery found (`None` when persistence is off).
    pub fn recovery_report(&self) -> Option<RecoveryReport> {
        self.recovery
    }

    /// Parse and serve one batch, blocking until the response is ready.
    pub fn call(&self, queries: &[&str]) -> Result<BatchResponse> {
        let parsed = queries
            .iter()
            .map(|q| ServiceQuery::parse(q))
            .collect::<Result<Vec<_>>>()?;
        self.submit(parsed)
            .recv()
            .context("service worker dropped the batch")
    }

    /// Enqueue a pre-parsed batch; the returned channel yields the
    /// response when a worker finishes it. If the batch cannot be served
    /// (request loop gone, or the batch's worker panicked mid-execution),
    /// the channel reports disconnection instead — [`Service::call`]
    /// surfaces that as an error, never a panic in the caller.
    pub fn submit(&self, queries: Vec<ServiceQuery>) -> mpsc::Receiver<BatchResponse> {
        let (respond, rx) = mpsc::channel();
        let job = Job { queries, respond };
        if let Some(tx) = &self.tx {
            // a failed send drops the job and thus its respond sender;
            // the caller's recv then reports the disconnection
            let _ = tx.send(job);
        }
        rx
    }

    /// Apply an edge insertion. `Ok(true)` means the update was applied
    /// and bumped the graph epoch ([`DynGraph::insert_edge`]); the result
    /// store is **delta-patched in place** across the bump
    /// ([`crate::service::delta`]), so cached bases in the proven
    /// fragment stay servable — only unprovable ones recompute cold.
    /// `Ok(false)` is a duplicate insert (no-op, cache stays warm);
    /// self-loops and IDs that would grow the graph by more than
    /// [`MAX_UPDATE_GROWTH`] vertices are errors. Vertex IDs are the
    /// graph's **original** (input) IDs — any degree-ordered relabeling
    /// from the initial build is translated internally.
    pub fn insert_edge(&self, u: VertexId, v: VertexId) -> Result<bool> {
        ensure!(u != v, "self loop ({u},{u}) not allowed");
        let mut st = self.shared.state.lock().unwrap();
        let (u, v) = (st.internal(u), st.internal(v));
        let hi = u.max(v) as usize;
        ensure!(
            hi < st.graph.num_vertices() + MAX_UPDATE_GROWTH,
            "vertex {hi} would grow the {}-vertex graph past the {MAX_UPDATE_GROWTH}-vertex update cap",
            st.graph.num_vertices()
        );
        if !st.graph.insert_edge(u, v) {
            return Ok(false);
        }
        // the graph now contains the edge — the state the delta pass walks
        rebase_after_update(&mut st, u, v, true);
        Ok(true)
    }

    /// Apply an edge removal (see [`Service::insert_edge`]). Out-of-range
    /// IDs name no edge and return `Ok(false)`.
    pub fn remove_edge(&self, u: VertexId, v: VertexId) -> Result<bool> {
        let mut st = self.shared.state.lock().unwrap();
        let (u, v) = (st.internal(u), st.internal(v));
        if u == v || u.max(v) as usize >= st.graph.num_vertices() {
            return Ok(false);
        }
        if !st.graph.has_edge(u, v) {
            return Ok(false);
        }
        // removal deltas are computed on the pre-removal graph — the one
        // that still contains the edge — then the removal is applied and
        // the store rebased to the post-removal epoch
        rebase_after_update(&mut st, u, v, false);
        Ok(true)
    }

    /// Current graph epoch (count of applied mutations).
    pub fn epoch(&self) -> u64 {
        self.shared.state.lock().unwrap().graph.version()
    }

    /// Result-store counters.
    pub fn store_metrics(&self) -> StoreMetrics {
        self.shared.state.lock().unwrap().store.metrics()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        // closing the channel ends the workers' recv loops
        self.tx.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // graceful-shutdown flush: capture the final store image under
        // the lock and hand it to the WAL writer, which drains every
        // queued record and folds the session's log into one snapshot so
        // the next start recovers without a replay. On a poisoned lock
        // (a worker panicked mid-publish) the image is not trusted and
        // the writer stops without compacting — the flushed WAL already
        // holds everything published, so recovery replays it. The same
        // applies to an abrupt kill (e.g. SIGINT): every insert was
        // flushed when the writer dequeued it, so skipping this step only
        // costs replay time, never data.
        let (writer, image) = match self.shared.state.lock() {
            Ok(mut st) => {
                let st = &mut *st;
                let image = st.persist.is_some().then(|| st.store.entries());
                (st.persist.take(), image)
            }
            Err(poisoned) => (poisoned.into_inner().persist.take(), None),
        };
        if let Some(writer) = writer {
            writer.shutdown(image);
        }
    }
}

/// Delta-rebase the service state across one applied edge update.
///
/// Call with the edge `(u,v)` **present** in `st.graph`: for an insertion
/// the caller has already applied it; for a removal this function computes
/// the deltas first (on the graph that still contains the edge), then
/// applies the removal itself. Stored values whose delta the pass proved
/// are patched in place; everything else — explicit fallbacks and keys
/// whose pattern the registry cannot resolve — is purged and recomputes
/// cold on next touch. The WAL is rebound to the mutated fingerprint and
/// the patched image folded into a snapshot under this same lock hold, so
/// a restart on the mutated graph recovers the patched values warm.
fn rebase_after_update(st: &mut State, u: VertexId, v: VertexId, inserted: bool) {
    debug_assert!(st.graph.has_edge(u, v), "delta pass needs the edge present");
    let bases: Vec<(CanonKey, Pattern)> = st
        .store
        .entries()
        .iter()
        .filter_map(|(k, _)| st.patterns.get(k).map(|p| (*k, p.clone())))
        .collect();
    let report =
        super::delta::edge_update_deltas(&st.graph, u, v, inserted, &bases, st.delta_budget);
    if !inserted {
        let removed = st.graph.remove_edge(u, v);
        debug_assert!(removed, "caller checked the edge exists");
    }
    let epoch = st.graph.version();
    crate::obs_counter!("mm_delta_updates_total").inc();
    let (patched, _dropped) = st.store.rebase_epoch(epoch, |k, old| {
        match report.deltas.get(k) {
            Some(super::delta::DeltaOutcome::Patch(d)) => {
                let next = old + d;
                // a negative full-map count means a broken delta; purge
                // defensively rather than ever serving it
                (next >= 0).then_some(next)
            }
            _ => None,
        }
    });
    crate::obs_counter!("mm_delta_patched_total").add(patched);
    // everything persisted so far describes a graph that no longer
    // exists: rebind the log to the mutated fingerprint, then fold the
    // freshly patched image into a snapshot. Both are enqueued under this
    // lock hold, so no concurrent batch's insert can slip between them.
    if let Some(w) = &st.persist {
        w.invalidate(st.graph.fingerprint());
        w.compact(st.store.entries());
    }
}

fn worker_loop(shared: &Shared, rx: &Mutex<mpsc::Receiver<Job>>, planner: QueryPlanner) {
    loop {
        // hold the receiver lock only while waiting for the next job;
        // processing runs unlocked so workers overlap
        let job = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // service dropped
            }
        };
        // a panicking batch (internal invariant failure) must not kill the
        // worker: catch the unwind, drop the responder so THIS batch's
        // caller gets a disconnection error, and keep serving. The
        // OwnedCells guard inside process() has already failed any cells
        // the batch owned, so coalesced batches error out too instead of
        // hanging.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            process(shared, &planner, &job.queries)
        }));
        if let Ok(response) = result {
            // a caller that gave up on the response is not an error
            let _ = job.respond.send(response);
        }
    }
}

/// Serve one batch: snapshot, morph, split bases into cached / owned /
/// coalesced, execute owned, publish, await coalesced, compose.
fn process(shared: &Shared, planner: &QueryPlanner, queries: &[ServiceQuery]) -> BatchResponse {
    let batch_start = std::time::Instant::now();
    // flatten the batch into one pattern list (the morph plan dedups bases
    // across all queries)
    let mut flat: Vec<Pattern> = Vec::new();
    let mut spans: Vec<(usize, usize)> = Vec::with_capacity(queries.len());
    for q in queries {
        let start = flat.len();
        flat.extend(q.patterns.iter().cloned());
        spans.push((start, flat.len()));
    }

    // pin the epoch and (re)build the CSR snapshot + stats if a mutation
    // landed since the last batch
    let (graph, stats, epoch) = {
        let mut st = shared.state.lock().unwrap();
        let st = &mut *st;
        let epoch = st.graph.version();
        st.store.set_epoch(epoch);
        if st.snapshot.is_none() || st.snapshot_epoch != epoch {
            // the WAL was already rebound to the mutated fingerprint at
            // update time (rebase_after_update, under this same mutex) —
            // this branch only rebuilds the execution snapshot and stats
            let g = st.graph.to_data_graph("service");
            st.stats = Some(Arc::new(GraphStats::compute(&g, 2000, 0x5E55)));
            st.snapshot = Some(Arc::new(g));
            st.snapshot_epoch = epoch;
        }
        (
            st.snapshot.clone().expect("snapshot just ensured"),
            st.stats.clone().expect("stats just ensured"),
            epoch,
        )
    };

    let mut profile = PhaseProfile::new();
    let plan = profile.time("plan", || planner.morph(&flat, &stats));

    // split the base set: store hits / in-flight elsewhere / ours to run
    let mut values: HashMap<CanonKey, i128> = HashMap::new();
    let mut awaited: Vec<(CanonKey, Arc<Cell>)> = Vec::new();
    let mut owned: Vec<usize> = Vec::new();
    let mut owned_keys: Vec<(CanonKey, u64)> = Vec::new();
    profile.time("probe", || {
        let mut st = shared.state.lock().unwrap();
        for (i, p) in plan.base.iter().enumerate() {
            let k = p.canonical_key();
            // remember the pattern behind every planned base key: the
            // delta pass resolves stored keys through this registry
            st.patterns.entry(k).or_insert_with(|| p.clone());
            if let Some(v) = st.store.get(&k, epoch) {
                values.insert(k, v);
            } else if let Some(cell) = st.inflight.get(&(k, epoch)) {
                awaited.push((k, cell.clone()));
            } else {
                st.inflight.insert((k, epoch), Arc::new(Cell::default()));
                owned.push(i);
                owned_keys.push((k, epoch));
            }
        }
    });
    crate::obs_counter!("mm_planner_batches_total").inc();
    crate::obs_counter!("mm_planner_cache_hits_total").add(values.len() as u64);
    crate::obs_counter!("mm_planner_cache_misses_total")
        .add((owned.len() + awaited.len()) as u64);
    crate::obs_counter!("mm_planner_coalesced_total").add(awaited.len() as u64);
    // from here until publish, an unwind must fail our registered cells —
    // otherwise batches coalesced onto them would wait forever
    let mut guard = OwnedCells {
        shared,
        keys: owned_keys,
        armed: true,
    };

    let fresh = planner.execute_bases(&graph, &plan.base, &owned, &stats, &mut profile);

    // publish: feed the store (stale inserts are dropped there) and wake
    // any batch coalesced onto our bases
    profile.time("persist", || {
        let mut st = shared.state.lock().unwrap();
        let st = &mut *st;
        for &(k, v) in &fresh {
            // mirror exactly the inserts the store accepted: a stale
            // insert (epoch moved mid-batch) must not reach the WAL
            // either. The append itself runs on the writer thread; the
            // enqueue happens here, under the state lock, on purpose —
            // record order must match store state transitions (an insert
            // appended after another batch's invalidation record would
            // be replayed under the wrong fingerprint)
            if st.store.insert(k, epoch, v) {
                if let Some(w) = &st.persist {
                    w.insert(k, v);
                }
            }
            if let Some(cell) = st.inflight.remove(&(k, epoch)) {
                *cell.value.lock().unwrap() = Some(Ok(v));
                cell.ready.notify_all();
            }
        }
        // cadence compaction: the writer flags when the log is due; the
        // image is captured under this lock (consistent with every record
        // enqueued above) and written off-lock, on the writer thread
        if let Some(w) = &st.persist {
            if w.take_compact_due() {
                w.compact(st.store.entries());
            }
        }
    });
    guard.armed = false;
    let executed = fresh.len();
    values.extend(fresh);

    // block on bases another batch is computing (no state lock held; the
    // owner fills every registered cell, on success or unwind)
    let coalesced = awaited.len();
    for (k, cell) in awaited {
        let mut slot = cell.value.lock().unwrap();
        while slot.is_none() {
            slot = cell.ready.wait(slot).unwrap();
        }
        match slot.expect("cell filled") {
            Ok(v) => {
                values.insert(k, v);
            }
            Err(msg) => panic!("coalesced base computation failed: {msg}"),
        }
    }

    let vals = planner.compose(&plan, &values, &mut profile);
    let results = to_query_results(queries, &spans, &vals);
    crate::obs_histogram!("mm_service_batch_us").record_duration(batch_start.elapsed());

    let trace = build_batch_trace(&profile, batch_start.elapsed(), queries.len(), epoch);
    BatchResponse {
        results,
        stats: BatchStats {
            total_bases: plan.base.len(),
            cached_bases: plan.base.len() - executed - coalesced,
            executed_bases: executed,
            coalesced_bases: coalesced,
            remote_bases: 0,
        },
        epoch,
        profile,
        trace,
    }
}

/// Assemble one batch's span tree from its phase profile: a root `batch`
/// span covering the whole wall time with one child per pipeline stage,
/// laid out sequentially — the profile records durations, not
/// timestamps, and the stages run in order. The sharded coordinator
/// builds its richer tree (remote sub-slice spans, failovers, hedges)
/// itself; this is the single-process shape.
pub(crate) fn build_batch_trace(
    profile: &PhaseProfile,
    total: std::time::Duration,
    queries: usize,
    epoch: u64,
) -> Trace {
    let mut tb = TraceBuilder::new();
    let batch_span = tb.span(
        0,
        "batch",
        0,
        total.as_micros() as u64,
        format!("queries={queries} epoch={epoch}"),
    );
    let mut clock_us = 0u64;
    for (name, d) in profile.entries() {
        let dur_us = d.as_micros() as u64;
        tb.span(batch_span, name, clock_us, dur_us, String::new());
        clock_us += dur_us;
    }
    tb.finish()
}

/// Convert composed per-pattern **map counts** (aligned with the batch's
/// flattened pattern list via `spans`) into per-query **unique-match
/// counts** — the one place map→unique conversion happens, shared by the
/// in-process worker loop above and the sharded coordinator
/// ([`crate::shard::ShardCoordinator`]) so the two paths can never round
/// differently.
pub(crate) fn to_query_results(
    queries: &[ServiceQuery],
    spans: &[(usize, usize)],
    vals: &[i128],
) -> Vec<QueryResult> {
    queries
        .iter()
        .zip(spans)
        .map(|(q, &(start, end))| QueryResult {
            query: q.text.clone(),
            counts: q
                .patterns
                .iter()
                .zip(&vals[start..end])
                .map(|(p, &maps)| {
                    let aut = crate::pattern::iso::automorphisms(p).len() as i128;
                    assert!(maps >= 0 && maps % aut == 0, "bad map count {maps} for {p:?}");
                    (p.clone(), (maps / aut) as u64)
                })
                .collect(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;

    fn config(workers: usize, delta_budget: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            threads: 2,
            policy: Policy::Naive,
            fused: true,
            cache_bytes: 1 << 20,
            persist: None,
            delta_budget,
        }
    }

    fn service(seed: u64, workers: usize) -> Service {
        Service::start(
            erdos_renyi(50, 180, seed),
            config(workers, crate::service::delta::DEFAULT_DELTA_BUDGET),
        )
    }

    #[test]
    fn call_roundtrip_and_warm_cache() {
        let svc = service(0x5001, 2);
        let cold = svc.call(&["motifs:3", "cliques:3"]).unwrap();
        assert_eq!(cold.results.len(), 2);
        assert_eq!(cold.results[0].counts.len(), 2, "two 3-motifs");
        assert_eq!(cold.stats.cached_bases, 0);
        let warm = svc.call(&["motifs:3", "cliques:3"]).unwrap();
        assert_eq!(warm.stats.executed_bases, 0);
        for (a, b) in cold.results.iter().zip(&warm.results) {
            assert_eq!(a.counts, b.counts);
        }
        // triangle count appears in both expansions and must agree
        let tri = cold.results[0].counts.iter().find(|(p, _)| p.is_clique()).unwrap().1;
        assert_eq!(cold.results[1].counts[0].1, tri);
    }

    #[test]
    fn rejects_fsm_and_garbage() {
        let svc = service(0x5002, 1);
        assert!(svc.call(&["fsm:3:100"]).is_err());
        assert!(svc.call(&["bogus:1"]).is_err());
        assert!(ServiceQuery::parse("fsm:2:5").is_err());
    }

    #[test]
    fn edge_updates_delta_patch_the_store_in_place() {
        let svc = service(0x5003, 1);
        let r0 = svc.call(&["motifs:3"]).unwrap();
        assert_eq!(r0.epoch, 0);
        // find a non-edge deterministically via a fresh generator copy
        let g = erdos_renyi(50, 180, 0x5003);
        let (u, v) = (0..50u32)
            .flat_map(|a| (0..50u32).map(move |b| (a, b)))
            .find(|&(a, b)| a < b && !g.has_edge(a, b))
            .expect("sparse graph has a non-edge");
        assert!(svc.insert_edge(u, v).unwrap());
        assert_eq!(svc.epoch(), 1);
        let r1 = svc.call(&["motifs:3"]).unwrap();
        assert_eq!(r1.epoch, 1);
        assert_eq!(
            r1.stats.executed_bases, 0,
            "the whole motif base set is in the delta fragment: the \
             mutation patches it in place, nothing recomputes"
        );
        assert!(svc.store_metrics().patched > 0, "patches must be counted");
        // the patched counts are the truth: a cold service on the mutated
        // graph must answer identically
        let mut mutated = crate::graph::DynGraph::from_data_graph(&g);
        assert!(mutated.insert_edge(u, v));
        let cold = Service::start(
            mutated.to_data_graph("mutated"),
            config(1, crate::service::delta::DEFAULT_DELTA_BUDGET),
        );
        assert_eq!(r1.results, cold.call(&["motifs:3"]).unwrap().results);
        // removing the edge restores the original counts — again patched,
        // not recomputed
        assert!(svc.remove_edge(u, v).unwrap());
        assert!(!svc.remove_edge(u, v).unwrap(), "second removal is a no-op");
        assert_eq!(svc.epoch(), 2);
        let r2 = svc.call(&["motifs:3"]).unwrap();
        assert_eq!(r2.stats.executed_bases, 0);
        for (a, b) in r0.results.iter().zip(&r2.results) {
            assert_eq!(a.counts, b.counts, "counts must match the restored graph");
        }
    }

    #[test]
    fn delta_budget_zero_purges_and_counts_the_fallback() {
        let fallback = crate::obs_counter!("mm_delta_fallback_total");
        let fb0 = fallback.get();
        let svc = Service::start(erdos_renyi(50, 180, 0x5003), config(1, 0));
        svc.call(&["motifs:3"]).unwrap();
        let g = erdos_renyi(50, 180, 0x5003);
        let (u, v) = (0..50u32)
            .flat_map(|a| (0..50u32).map(move |b| (a, b)))
            .find(|&(a, b)| a < b && !g.has_edge(a, b))
            .unwrap();
        assert!(svc.insert_edge(u, v).unwrap());
        let r = svc.call(&["motifs:3"]).unwrap();
        assert_eq!(
            r.stats.executed_bases, r.stats.total_bases,
            "budget 0 disables the delta pass: every base recomputes"
        );
        assert_eq!(svc.store_metrics().patched, 0);
        assert!(
            fallback.get() > fb0,
            "disabled delta must surface as counted fallbacks, never silence"
        );
    }

    #[test]
    fn edge_updates_use_original_ids_on_relabeled_graphs() {
        // star centered at ORIGINAL vertex 3; degree ordering renames it
        // to internal 0 — updates must still address the input IDs
        let g = crate::graph::GraphBuilder::new()
            .edges(&[(3, 0), (3, 1), (3, 2), (3, 4)])
            .degree_ordered(true)
            .build("star");
        assert_eq!(g.original_id(0), 3, "center relabeled to 0");
        let svc = Service::start(
            g,
            ServiceConfig {
                workers: 1,
                threads: 1,
                policy: Policy::Naive,
                fused: true,
                cache_bytes: 1 << 20,
                persist: None,
                delta_budget: crate::service::delta::DEFAULT_DELTA_BUDGET,
            },
        );
        // 5-vertex star: C(4,2) = 6 wedges, no triangles
        let r = svc.call(&["match:wedge,triangle"]).unwrap();
        assert_eq!(r.results[0].counts[0].1, 6);
        assert_eq!(r.results[0].counts[1].1, 0);
        // closing ORIGINAL leaves (0,1) forms exactly one triangle; if the
        // IDs were taken as internal, (0,1) would hit the center's existing
        // edge and be rejected as a duplicate
        assert!(svc.insert_edge(0, 1).unwrap());
        let r = svc.call(&["match:triangle"]).unwrap();
        assert_eq!(r.results[0].counts[0].1, 1);
        // duplicate detection also happens in original-ID space
        assert!(!svc.insert_edge(1, 0).unwrap());
        assert!(svc.remove_edge(0, 1).unwrap());
        let r = svc.call(&["match:triangle"]).unwrap();
        assert_eq!(r.results[0].counts[0].1, 0);
    }

    #[test]
    fn hostile_updates_are_rejected_not_fatal() {
        let svc = service(0x5005, 1);
        // out-of-range removal: no such edge, no panic
        assert!(!svc.remove_edge(9_999_999, 0).unwrap());
        // an ID that would allocate gigabytes of adjacency slots errors
        assert!(svc.insert_edge(4_000_000_000, 0).is_err());
        // self loops error on insert, no-op on remove
        assert!(svc.insert_edge(7, 7).is_err());
        assert!(!svc.remove_edge(7, 7).unwrap());
        assert_eq!(svc.epoch(), 0, "rejected updates must not bump the epoch");
        // modest growth past the current vertex count is still allowed
        assert!(svc.insert_edge(60, 61).unwrap());
        assert_eq!(svc.epoch(), 1);
    }

    #[test]
    fn persistent_service_restarts_warm() {
        let dir = std::env::temp_dir().join("mm_serve_persist_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let config = || ServiceConfig {
            workers: 1,
            threads: 2,
            policy: Policy::Naive,
            fused: true,
            cache_bytes: 1 << 20,
            persist: Some(crate::service::persist::PersistConfig::new(&dir)),
            delta_budget: crate::service::delta::DEFAULT_DELTA_BUDGET,
        };
        let g = || erdos_renyi(50, 180, 0x5EAE);
        let svc = Service::try_start(g(), config()).unwrap();
        let cold = svc.call(&["motifs:3"]).unwrap();
        assert!(cold.stats.executed_bases > 0);
        drop(svc); // graceful shutdown compacts WAL → snapshot
        let svc = Service::try_start(g(), config()).unwrap();
        let rep = svc.recovery_report().expect("persistence configured");
        assert!(rep.fingerprint_matched, "same graph content must match");
        assert!(rep.restored > 0);
        let warm = svc.call(&["motifs:3"]).unwrap();
        assert_eq!(warm.stats.executed_bases, 0, "restart must serve warm");
        assert_eq!(cold.results, warm.results);
        assert!(svc.store_metrics().restored > 0);
    }

    #[test]
    fn delta_patched_store_persists_and_restarts_warm_on_the_mutated_graph() {
        // an update rebinds the WAL to the mutated fingerprint and folds
        // the PATCHED image into a snapshot, so a restart on the mutated
        // graph recovers those patched values warm — the "never restarts
        // cold" half of the materialized-view story
        let dir = std::env::temp_dir().join("mm_serve_delta_persist_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let mk = || ServiceConfig {
            workers: 1,
            threads: 2,
            policy: Policy::Naive,
            fused: true,
            cache_bytes: 1 << 20,
            persist: Some(crate::service::persist::PersistConfig::new(&dir)),
            delta_budget: crate::service::delta::DEFAULT_DELTA_BUDGET,
        };
        let g = || erdos_renyi(50, 180, 0x5EB0);
        let fresh = g();
        let (u, v) = (0..50u32)
            .flat_map(|a| (0..50u32).map(move |b| (a, b)))
            .find(|&(a, b)| a < b && !fresh.has_edge(a, b))
            .expect("sparse graph has a non-edge");
        let svc = Service::try_start(g(), mk()).unwrap();
        svc.call(&["motifs:3"]).unwrap();
        assert!(svc.insert_edge(u, v).unwrap());
        let patched = svc.call(&["motifs:3"]).unwrap();
        assert_eq!(patched.stats.executed_bases, 0, "served from patched entries");
        drop(svc);
        // restart on the MUTATED graph: its fingerprint is what the WAL
        // was rebound to at update time
        let mut mutated = crate::graph::DynGraph::from_data_graph(&g());
        assert!(mutated.insert_edge(u, v));
        let svc = Service::try_start(mutated.to_data_graph("mutated"), mk()).unwrap();
        let rep = svc.recovery_report().expect("persistence configured");
        assert!(rep.fingerprint_matched, "mutated-graph fingerprint must match");
        assert!(rep.restored > 0);
        let warm = svc.call(&["motifs:3"]).unwrap();
        assert_eq!(warm.stats.executed_bases, 0, "patched values recovered warm");
        assert_eq!(warm.results, patched.results);
    }

    #[test]
    fn wal_writer_keeps_record_order_across_interleaved_epoch_bumps() {
        // inserts and epoch invalidations now reach disk via the writer
        // thread; this interleaves them aggressively and then restarts.
        // A record written out of order (an insert slipping behind the
        // next epoch's invalidation) would be replayed under the final
        // fingerprint and surface as stale counts served warm — caught by
        // the result comparison below.
        let dir = std::env::temp_dir().join("mm_serve_wal_writer_unit");
        let _ = std::fs::remove_dir_all(&dir);
        // delta_budget 0: this test exercises the purge path's WAL record
        // ordering, so updates must invalidate rather than patch
        let config = || ServiceConfig {
            workers: 2,
            threads: 2,
            policy: Policy::Naive,
            fused: true,
            cache_bytes: 1 << 20,
            persist: Some(crate::service::persist::PersistConfig::new(&dir)),
            delta_budget: 0,
        };
        let g = || erdos_renyi(50, 180, 0x5EAF);
        let svc = Service::try_start(g(), config()).unwrap();
        let baseline = svc.call(&["motifs:3", "cliques:3"]).unwrap();
        // each (insert, query, remove, query) round bumps the epoch twice
        // and logs a fresh result set in between, so the WAL sees
        // insert/invalidate sequences from competing worker batches
        let fresh = erdos_renyi(50, 180, 0x5EAF);
        let (u, v) = (0..50u32)
            .flat_map(|a| (0..50u32).map(move |b| (a, b)))
            .find(|&(a, b)| a < b && !fresh.has_edge(a, b))
            .expect("sparse graph has a non-edge");
        for _ in 0..3 {
            assert!(svc.insert_edge(u, v).unwrap());
            let perturbed = svc.call(&["motifs:3", "cliques:3"]).unwrap();
            assert!(perturbed.stats.executed_bases > 0, "epoch bump must invalidate");
            assert!(svc.remove_edge(u, v).unwrap());
            let restored = svc.call(&["motifs:3", "cliques:3"]).unwrap();
            assert_eq!(restored.results, baseline.results);
        }
        drop(svc); // joins the writer: queue drained, log compacted
        // the final graph content equals the original, so the restart must
        // recover warm — and with the ORIGINAL counts, not any epoch's
        // stale intermediates
        let svc = Service::try_start(g(), config()).unwrap();
        assert!(svc.recovery_report().unwrap().fingerprint_matched);
        assert!(svc.store_metrics().restored > 0);
        let warm = svc.call(&["motifs:3", "cliques:3"]).unwrap();
        assert_eq!(warm.stats.executed_bases, 0, "restart must serve warm");
        assert_eq!(warm.results, baseline.results);
    }

    #[test]
    fn concurrent_identical_batches_coalesce() {
        let svc = Arc::new(service(0x5004, 4));
        let responses: Vec<BatchResponse> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let svc = svc.clone();
                    s.spawn(move || svc.call(&["motifs:4"]).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let total = responses[0].stats.total_bases;
        for r in &responses {
            assert_eq!(r.results[0].counts.len(), 6);
            assert_eq!(r.results, responses[0].results, "all answers identical");
            let s = r.stats;
            assert_eq!(s.cached_bases + s.executed_bases + s.coalesced_bases, s.total_bases);
        }
        // each (base, epoch) pair is computed at most once process-wide:
        // the store saw exactly one insert per base
        assert_eq!(svc.store_metrics().inserts as usize, total);
        assert_eq!(svc.store_metrics().stale_drops, 0);
    }
}
