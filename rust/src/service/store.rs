//! Morph-aware result store: per-base-pattern aggregation values keyed by
//! **canonical pattern key × graph epoch**, with LRU + byte-budget eviction
//! and hit/miss/bytes metrics.
//!
//! The store is the memory behind the cross-query reuse the service layer
//! adds on top of the morph algebra: a base pattern matched for one query
//! set answers *any* future query whose morph expression references the
//! same canonical pattern — as long as the graph has not changed. The
//! epoch (see [`crate::graph::DynGraph::version`]) makes "has not changed"
//! explicit: lookups carry the epoch the caller's snapshot was taken at,
//! and values cached under any other epoch are invisible (and purged on
//! [`ResultStore::set_epoch`]), so incremental updates can never leak
//! stale counts.

use crate::pattern::canon::CanonKey;
use std::collections::HashMap;

/// Approximate heap weight of a cached value, for the byte budget.
pub trait CacheWeight {
    fn weight_bytes(&self) -> usize;
}

impl CacheWeight for i128 {
    fn weight_bytes(&self) -> usize {
        std::mem::size_of::<i128>()
    }
}

/// Fixed bookkeeping cost charged per entry on top of the value weight
/// (key, LRU stamp, hash-map slot).
const ENTRY_OVERHEAD: usize = 64;

/// Store counters. `bytes` is the current footprint; everything else is
/// cumulative since construction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreMetrics {
    /// Lookups served from the store.
    pub hits: u64,
    /// Lookups that found nothing (wrong epoch counts as a miss).
    pub misses: u64,
    /// Values inserted.
    pub inserts: u64,
    /// Entries evicted by the LRU byte budget.
    pub evictions: u64,
    /// Entries purged because the graph epoch moved past them.
    pub invalidations: u64,
    /// Inserts dropped because they were computed against an old epoch.
    pub stale_drops: u64,
    /// Current footprint (value weights + per-entry overhead).
    pub bytes: usize,
}

struct Entry<V> {
    value: V,
    bytes: usize,
    last_used: u64,
}

/// LRU result store for one graph. All live entries belong to the current
/// epoch — [`ResultStore::set_epoch`] purges everything older, which keeps
/// the key a plain [`CanonKey`] while the lookup contract stays
/// "canonical key × epoch".
pub struct ResultStore<V> {
    budget_bytes: usize,
    epoch: u64,
    tick: u64,
    map: HashMap<CanonKey, Entry<V>>,
    metrics: StoreMetrics,
}

impl<V: CacheWeight + Clone> ResultStore<V> {
    /// Store with an eviction budget of `budget_bytes` (entries are small;
    /// a few MiB caches thousands of base patterns).
    pub fn new(budget_bytes: usize) -> ResultStore<V> {
        ResultStore {
            budget_bytes,
            epoch: 0,
            tick: 0,
            map: HashMap::new(),
            metrics: StoreMetrics::default(),
        }
    }

    /// The epoch current entries were computed at.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Cumulative counters plus the current byte footprint.
    pub fn metrics(&self) -> StoreMetrics {
        self.metrics
    }

    /// Advance to `epoch`, purging entries cached under older epochs.
    /// Epochs are monotone (they come from [`crate::graph::DynGraph::version`]);
    /// calls with the current epoch are no-ops.
    pub fn set_epoch(&mut self, epoch: u64) {
        debug_assert!(epoch >= self.epoch, "epochs must be monotone");
        if epoch == self.epoch {
            return;
        }
        self.metrics.invalidations += self.map.len() as u64;
        self.metrics.bytes = 0;
        self.map.clear();
        self.epoch = epoch;
    }

    /// Look up the value for `key` computed at `epoch`. A hit refreshes the
    /// entry's LRU position; an epoch mismatch is a miss (the caller's
    /// snapshot does not match what the store holds).
    pub fn get(&mut self, key: &CanonKey, epoch: u64) -> Option<V> {
        if epoch != self.epoch {
            self.metrics.misses += 1;
            return None;
        }
        match self.map.get_mut(key) {
            Some(e) => {
                self.tick += 1;
                e.last_used = self.tick;
                self.metrics.hits += 1;
                Some(e.value.clone())
            }
            None => {
                self.metrics.misses += 1;
                None
            }
        }
    }

    /// Insert a value computed at `epoch`. Values computed against a
    /// superseded snapshot are dropped (`stale_drops`) — the caller still
    /// uses them for its own response, they just don't enter the cache.
    pub fn insert(&mut self, key: CanonKey, epoch: u64, value: V) {
        if epoch != self.epoch {
            self.metrics.stale_drops += 1;
            return;
        }
        let bytes = value.weight_bytes() + ENTRY_OVERHEAD;
        self.tick += 1;
        if let Some(old) = self.map.insert(
            key,
            Entry {
                value,
                bytes,
                last_used: self.tick,
            },
        ) {
            self.metrics.bytes -= old.bytes;
        }
        self.metrics.bytes += bytes;
        self.metrics.inserts += 1;
        self.evict_to_budget();
    }

    /// Evict least-recently-used entries until the footprint fits the
    /// budget. A single entry larger than the whole budget is kept — the
    /// store must still be able to serve it. Linear LRU scan: the store
    /// holds at most a few thousand base patterns, eviction is rare, and
    /// it keeps hits allocation-free.
    fn evict_to_budget(&mut self) {
        while self.metrics.bytes > self.budget_bytes && self.map.len() > 1 {
            let key = *self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k)
                .expect("map non-empty");
            let e = self.map.remove(&key).expect("key just found");
            self.metrics.bytes -= e.bytes;
            self.metrics.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;

    fn key(i: usize) -> CanonKey {
        catalog::paper_pattern(i % 7 + 1).canonical_key()
    }

    #[test]
    fn hit_miss_and_bytes() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        assert!(s.is_empty());
        assert_eq!(s.get(&key(1), 0), None);
        s.insert(key(1), 0, 42);
        assert_eq!(s.get(&key(1), 0), Some(42));
        assert_eq!(s.len(), 1);
        let m = s.metrics();
        assert_eq!((m.hits, m.misses, m.inserts), (1, 1, 1));
        assert_eq!(m.bytes, 16 + ENTRY_OVERHEAD);
    }

    #[test]
    fn epoch_mismatch_is_invisible() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        s.insert(key(1), 0, 7);
        // lookup at a later epoch misses even before set_epoch
        assert_eq!(s.get(&key(1), 1), None);
        // inserts only land on the store's current epoch
        s.insert(key(2), 1, 9);
        assert_eq!(s.metrics().stale_drops, 1);
        s.set_epoch(1);
        assert_eq!(s.metrics().invalidations, 1);
        assert!(s.is_empty());
        assert_eq!(s.metrics().bytes, 0);
        s.insert(key(3), 0, 5); // computed against the old snapshot
        assert_eq!(s.metrics().stale_drops, 2);
        assert!(s.is_empty());
    }

    #[test]
    fn set_epoch_same_is_noop() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        s.insert(key(1), 0, 1);
        s.set_epoch(0);
        assert_eq!(s.len(), 1);
        assert_eq!(s.metrics().invalidations, 0);
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        // budget fits exactly two entries
        let per = 16 + ENTRY_OVERHEAD;
        let mut s: ResultStore<i128> = ResultStore::new(2 * per);
        s.insert(key(1), 0, 1);
        s.insert(key(2), 0, 2);
        // touch key(1) so key(2) is the LRU victim
        assert_eq!(s.get(&key(1), 0), Some(1));
        s.insert(key(3), 0, 3);
        assert_eq!(s.metrics().evictions, 1);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(&key(1), 0), Some(1));
        assert_eq!(s.get(&key(2), 0), None, "LRU entry evicted");
        assert_eq!(s.get(&key(3), 0), Some(3));
        assert!(s.metrics().bytes <= 2 * per);
    }

    #[test]
    fn oversized_single_entry_is_kept() {
        let mut s: ResultStore<i128> = ResultStore::new(1);
        s.insert(key(1), 0, 9);
        assert_eq!(s.get(&key(1), 0), Some(9), "sole entry survives any budget");
        s.insert(key(2), 0, 8);
        assert_eq!(s.len(), 1, "second entry forces eviction down to one");
    }

    #[test]
    fn reinsert_replaces_without_double_charge() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        s.insert(key(1), 0, 1);
        let b = s.metrics().bytes;
        s.insert(key(1), 0, 2);
        assert_eq!(s.metrics().bytes, b, "replacement must not leak bytes");
        assert_eq!(s.get(&key(1), 0), Some(2));
    }
}
