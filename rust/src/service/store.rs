//! Morph-aware result store: per-base-pattern aggregation values keyed by
//! **canonical pattern key × graph epoch**, with LRU + byte-budget eviction
//! and hit/miss/bytes metrics.
//!
//! The store is the memory behind the cross-query reuse the service layer
//! adds on top of the morph algebra: a base pattern matched for one query
//! set answers *any* future query whose morph expression references the
//! same canonical pattern — as long as the graph has not changed. The
//! epoch (see [`crate::graph::DynGraph::version`]) makes "has not changed"
//! explicit: lookups carry the epoch the caller's snapshot was taken at,
//! and values cached under any other epoch are invisible (and purged on
//! [`ResultStore::set_epoch`]), so incremental updates can never leak
//! stale counts.

use crate::obs::{Counter, Gauge, Registry};
use crate::pattern::canon::CanonKey;
use std::collections::HashMap;
use std::sync::Arc;

/// Approximate heap weight of a cached value, for the byte budget.
pub trait CacheWeight {
    fn weight_bytes(&self) -> usize;
}

impl CacheWeight for i128 {
    fn weight_bytes(&self) -> usize {
        std::mem::size_of::<i128>()
    }
}

/// Byte codec for values crossing the persistence boundary
/// ([`crate::service::persist`]). The encoding must be self-contained
/// within the byte slice handed to `decode` (records and snapshot fields
/// carry explicit lengths), stable across processes, and total on the
/// decode side: hostile bytes return `None`, never panic.
pub trait PersistValue: Sized {
    /// Append the encoded value to `out`.
    fn encode(&self, out: &mut Vec<u8>);
    /// Decode from exactly the bytes `encode` produced.
    fn decode(bytes: &[u8]) -> Option<Self>;
}

impl PersistValue for i128 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn decode(bytes: &[u8]) -> Option<i128> {
        Some(i128::from_le_bytes(bytes.try_into().ok()?))
    }
}

/// Fixed bookkeeping cost charged per entry on top of the value weight
/// (key, LRU stamp, hash-map slot).
const ENTRY_OVERHEAD: usize = 64;

/// Point-in-time view of the store counters, rendered from the live
/// [`crate::obs`] atomics by [`ResultStore::metrics`]. `bytes` is the
/// current footprint; everything else is cumulative since construction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreMetrics {
    /// Lookups served from the store.
    pub hits: u64,
    /// Lookups that found nothing (wrong epoch counts as a miss).
    pub misses: u64,
    /// Values inserted.
    pub inserts: u64,
    /// Entries evicted by the LRU byte budget.
    pub evictions: u64,
    /// Entries purged because the graph epoch moved past them.
    pub invalidations: u64,
    /// Entries carried across an epoch bump by a delta patch
    /// ([`ResultStore::rebase_epoch`]) instead of being purged.
    pub patched: u64,
    /// Inserts dropped because they were computed against an old epoch.
    pub stale_drops: u64,
    /// Entries seeded from a recovered persistent image at startup
    /// (counted separately from `inserts` so cache-effectiveness metrics
    /// stay attributable to this process's own work).
    pub restored: u64,
    /// Current footprint (value weights + per-entry overhead).
    pub bytes: usize,
}

struct Entry<V> {
    value: V,
    bytes: usize,
    last_used: u64,
}

/// The store's live counters: [`crate::obs`] atomics, privately owned so
/// per-instance snapshots ([`ResultStore::metrics`]) stay exact, and
/// `Arc`-shared so [`ResultStore::register_metrics`] can expose the very
/// same atomics to a scrape registry — one counter implementation, two
/// views.
#[derive(Default)]
struct StoreCounters {
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    inserts: Arc<Counter>,
    evictions: Arc<Counter>,
    invalidations: Arc<Counter>,
    patched: Arc<Counter>,
    stale_drops: Arc<Counter>,
    restored: Arc<Counter>,
    bytes: Arc<Gauge>,
}

/// LRU result store for one graph. All live entries belong to the current
/// epoch — [`ResultStore::set_epoch`] purges everything older, which keeps
/// the key a plain [`CanonKey`] while the lookup contract stays
/// "canonical key × epoch".
pub struct ResultStore<V> {
    budget_bytes: usize,
    epoch: u64,
    tick: u64,
    map: HashMap<CanonKey, Entry<V>>,
    counters: StoreCounters,
}

impl<V: CacheWeight + Clone> ResultStore<V> {
    /// Store with an eviction budget of `budget_bytes` (entries are small;
    /// a few MiB caches thousands of base patterns).
    pub fn new(budget_bytes: usize) -> ResultStore<V> {
        ResultStore {
            budget_bytes,
            epoch: 0,
            tick: 0,
            map: HashMap::new(),
            counters: StoreCounters::default(),
        }
    }

    /// The epoch current entries were computed at.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Cumulative counters plus the current byte footprint, snapshotted
    /// from the live atomics.
    pub fn metrics(&self) -> StoreMetrics {
        StoreMetrics {
            hits: self.counters.hits.get(),
            misses: self.counters.misses.get(),
            inserts: self.counters.inserts.get(),
            evictions: self.counters.evictions.get(),
            invalidations: self.counters.invalidations.get(),
            patched: self.counters.patched.get(),
            stale_drops: self.counters.stale_drops.get(),
            restored: self.counters.restored.get(),
            bytes: self.counters.bytes.get() as usize,
        }
    }

    /// Expose this store's live counters to `reg` under
    /// `{prefix}hits_total`, `{prefix}misses_total`, …, `{prefix}bytes`.
    /// Registration shares the atomics — the scrape view tracks every
    /// subsequent store operation with no copying or polling.
    pub fn register_metrics(&self, reg: &Registry, prefix: &str) {
        reg.register_counter(&format!("{prefix}hits_total"), self.counters.hits.clone());
        reg.register_counter(&format!("{prefix}misses_total"), self.counters.misses.clone());
        reg.register_counter(&format!("{prefix}inserts_total"), self.counters.inserts.clone());
        reg.register_counter(
            &format!("{prefix}evictions_total"),
            self.counters.evictions.clone(),
        );
        reg.register_counter(
            &format!("{prefix}invalidations_total"),
            self.counters.invalidations.clone(),
        );
        reg.register_counter(&format!("{prefix}patched_total"), self.counters.patched.clone());
        reg.register_counter(
            &format!("{prefix}stale_drops_total"),
            self.counters.stale_drops.clone(),
        );
        reg.register_counter(&format!("{prefix}restored_total"), self.counters.restored.clone());
        reg.register_gauge(&format!("{prefix}bytes"), self.counters.bytes.clone());
    }

    /// Advance to `epoch`, purging entries cached under older epochs.
    /// Epochs are monotone (they come from [`crate::graph::DynGraph::version`]);
    /// calls with the current epoch are no-ops.
    pub fn set_epoch(&mut self, epoch: u64) {
        debug_assert!(epoch >= self.epoch, "epochs must be monotone");
        if epoch == self.epoch {
            return;
        }
        self.counters.invalidations.add(self.map.len() as u64);
        self.counters.bytes.set(0);
        self.map.clear();
        self.epoch = epoch;
    }

    /// Advance to `epoch` like [`ResultStore::set_epoch`], but give the
    /// caller the chance to **carry** each entry across the bump instead
    /// of purging it: `patch(key, value)` returns `Some(new_value)` to
    /// keep the entry at the new epoch (re-weighed, recency preserved) or
    /// `None` to drop it — dropped entries count as invalidations exactly
    /// like a purge, carried ones as `patched`. This is the delta-morphing
    /// entry point ([`crate::service::delta`]): patched entries stay
    /// servable across an edge update, the unprovable rest recomputes
    /// cold. Same-epoch calls are no-ops. Returns `(patched, dropped)`.
    pub fn rebase_epoch(
        &mut self,
        epoch: u64,
        mut patch: impl FnMut(&CanonKey, &V) -> Option<V>,
    ) -> (u64, u64) {
        debug_assert!(epoch >= self.epoch, "epochs must be monotone");
        if epoch == self.epoch {
            return (0, 0);
        }
        let (mut patched, mut dropped) = (0u64, 0u64);
        let mut byte_delta: i64 = 0;
        self.map.retain(|k, e| match patch(k, &e.value) {
            Some(v) => {
                let bytes = v.weight_bytes() + ENTRY_OVERHEAD;
                byte_delta += bytes as i64 - e.bytes as i64;
                e.value = v;
                e.bytes = bytes;
                patched += 1;
                true
            }
            None => {
                byte_delta -= e.bytes as i64;
                dropped += 1;
                false
            }
        });
        if byte_delta >= 0 {
            self.counters.bytes.add(byte_delta as u64);
        } else {
            self.counters.bytes.sub((-byte_delta) as u64);
        }
        self.counters.patched.add(patched);
        self.counters.invalidations.add(dropped);
        self.epoch = epoch;
        self.evict_to_budget();
        (patched, dropped)
    }

    /// Look up the value for `key` computed at `epoch`. A hit refreshes the
    /// entry's LRU position; an epoch mismatch is a miss (the caller's
    /// snapshot does not match what the store holds).
    pub fn get(&mut self, key: &CanonKey, epoch: u64) -> Option<V> {
        if epoch != self.epoch {
            self.counters.misses.inc();
            return None;
        }
        match self.map.get_mut(key) {
            Some(e) => {
                self.tick += 1;
                e.last_used = self.tick;
                self.counters.hits.inc();
                Some(e.value.clone())
            }
            None => {
                self.counters.misses.inc();
                None
            }
        }
    }

    /// Insert a value computed at `epoch`. Values computed against a
    /// superseded snapshot are dropped (`stale_drops`) — the caller still
    /// uses them for its own response, they just don't enter the cache.
    /// Returns whether the value entered the store; mirrors of the store
    /// (the WAL in [`crate::service::persist`]) must key off this, not
    /// re-derive the staleness predicate.
    pub fn insert(&mut self, key: CanonKey, epoch: u64, value: V) -> bool {
        if epoch != self.epoch {
            self.counters.stale_drops.inc();
            return false;
        }
        self.put(key, value);
        self.counters.inserts.inc();
        self.evict_to_budget();
        true
    }

    /// Seed a recovered entry at the **current** epoch (the persistence
    /// layer has already verified, via the graph fingerprint, that the
    /// value describes the live graph). Counted under
    /// [`StoreMetrics::restored`]; the byte budget applies as usual, so
    /// restoring more than the budget holds simply evicts the
    /// least-recently-restored surplus.
    pub fn restore(&mut self, key: CanonKey, value: V) {
        self.put(key, value);
        self.counters.restored.inc();
        self.evict_to_budget();
    }

    /// Live entries in least-recently-used-first order — the order a
    /// snapshot should be written in, so that restoring entries in
    /// sequence rebuilds the same recency ranking.
    pub fn entries(&self) -> Vec<(CanonKey, V)> {
        let mut es: Vec<(&CanonKey, &Entry<V>)> = self.map.iter().collect();
        es.sort_by_key(|(_, e)| e.last_used);
        es.into_iter().map(|(k, e)| (*k, e.value.clone())).collect()
    }

    fn put(&mut self, key: CanonKey, value: V) {
        let bytes = value.weight_bytes() + ENTRY_OVERHEAD;
        self.tick += 1;
        if let Some(old) = self.map.insert(
            key,
            Entry {
                value,
                bytes,
                last_used: self.tick,
            },
        ) {
            self.counters.bytes.sub(old.bytes as u64);
        }
        self.counters.bytes.add(bytes as u64);
    }

    /// Evict least-recently-used entries until the footprint fits the
    /// budget. A single entry larger than the whole budget is kept — the
    /// store must still be able to serve it. Linear LRU scan: the store
    /// holds at most a few thousand base patterns, eviction is rare, and
    /// it keeps hits allocation-free.
    fn evict_to_budget(&mut self) {
        while self.counters.bytes.get() > self.budget_bytes as u64 && self.map.len() > 1 {
            let key = *self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k)
                .expect("map non-empty");
            let e = self.map.remove(&key).expect("key just found");
            self.counters.bytes.sub(e.bytes as u64);
            self.counters.evictions.inc();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;

    fn key(i: usize) -> CanonKey {
        catalog::paper_pattern(i % 7 + 1).canonical_key()
    }

    #[test]
    fn hit_miss_and_bytes() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        assert!(s.is_empty());
        assert_eq!(s.get(&key(1), 0), None);
        s.insert(key(1), 0, 42);
        assert_eq!(s.get(&key(1), 0), Some(42));
        assert_eq!(s.len(), 1);
        let m = s.metrics();
        assert_eq!((m.hits, m.misses, m.inserts), (1, 1, 1));
        assert_eq!(m.bytes, 16 + ENTRY_OVERHEAD);
    }

    #[test]
    fn epoch_mismatch_is_invisible() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        s.insert(key(1), 0, 7);
        // lookup at a later epoch misses even before set_epoch
        assert_eq!(s.get(&key(1), 1), None);
        // inserts only land on the store's current epoch
        s.insert(key(2), 1, 9);
        assert_eq!(s.metrics().stale_drops, 1);
        s.set_epoch(1);
        assert_eq!(s.metrics().invalidations, 1);
        assert!(s.is_empty());
        assert_eq!(s.metrics().bytes, 0);
        s.insert(key(3), 0, 5); // computed against the old snapshot
        assert_eq!(s.metrics().stale_drops, 2);
        assert!(s.is_empty());
    }

    #[test]
    fn set_epoch_same_is_noop() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        s.insert(key(1), 0, 1);
        s.set_epoch(0);
        assert_eq!(s.len(), 1);
        assert_eq!(s.metrics().invalidations, 0);
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        // budget fits exactly two entries
        let per = 16 + ENTRY_OVERHEAD;
        let mut s: ResultStore<i128> = ResultStore::new(2 * per);
        s.insert(key(1), 0, 1);
        s.insert(key(2), 0, 2);
        // touch key(1) so key(2) is the LRU victim
        assert_eq!(s.get(&key(1), 0), Some(1));
        s.insert(key(3), 0, 3);
        assert_eq!(s.metrics().evictions, 1);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(&key(1), 0), Some(1));
        assert_eq!(s.get(&key(2), 0), None, "LRU entry evicted");
        assert_eq!(s.get(&key(3), 0), Some(3));
        assert!(s.metrics().bytes <= 2 * per);
    }

    #[test]
    fn oversized_single_entry_is_kept() {
        let mut s: ResultStore<i128> = ResultStore::new(1);
        s.insert(key(1), 0, 9);
        assert_eq!(s.get(&key(1), 0), Some(9), "sole entry survives any budget");
        s.insert(key(2), 0, 8);
        assert_eq!(s.len(), 1, "second entry forces eviction down to one");
    }

    #[test]
    fn eviction_boundary_is_inclusive_and_ties_break_by_recency() {
        // satellite: exact byte-budget ties. Every i128 entry weighs the
        // same, so a budget of exactly 3 entries sits precisely on the
        // boundary after the third insert.
        let per = 16 + ENTRY_OVERHEAD;
        let mut s: ResultStore<i128> = ResultStore::new(3 * per);
        s.insert(key(1), 0, 1);
        s.insert(key(2), 0, 2);
        s.insert(key(3), 0, 3);
        assert_eq!(s.metrics().bytes, 3 * per, "exactly at budget");
        assert_eq!(s.metrics().evictions, 0, "budget is inclusive: no eviction at ==");
        assert_eq!(s.len(), 3);
        // all three tie on weight; recency alone picks the victim. Touch
        // 1 then 3, leaving 2 as the unique LRU entry.
        assert_eq!(s.get(&key(1), 0), Some(1));
        assert_eq!(s.get(&key(3), 0), Some(3));
        s.insert(key(4), 0, 4);
        assert_eq!(s.metrics().evictions, 1, "one over budget evicts exactly one");
        assert_eq!(s.get(&key(2), 0), None, "the least-recently-used tie loser goes");
        assert_eq!(s.get(&key(1), 0), Some(1));
        assert_eq!(s.get(&key(3), 0), Some(3));
        assert_eq!(s.get(&key(4), 0), Some(4));
        assert_eq!(s.metrics().bytes, 3 * per, "back on the boundary");
        // re-inserting an existing key at the boundary replaces in place:
        // no eviction, no footprint change
        s.insert(key(4), 0, 44);
        assert_eq!(s.metrics().evictions, 1);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn restore_seeds_entries_and_entries_orders_by_recency() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        s.restore(key(1), 10);
        s.restore(key(2), 20);
        let m = s.metrics();
        assert_eq!((m.restored, m.inserts), (2, 0), "restores are not inserts");
        assert_eq!(s.get(&key(1), 0), Some(10), "restored entries serve epoch 0");
        // entries(): LRU first — key(2) was restored after key(1), but the
        // get above made key(1) the most recent
        let es = s.entries();
        assert_eq!(es, vec![(key(2), 20), (key(1), 10)]);
        // snapshot → restore round trip preserves values and recency
        let mut t: ResultStore<i128> = ResultStore::new(1 << 20);
        for (k, v) in es {
            t.restore(k, v);
        }
        assert_eq!(t.entries(), s.entries());
        // the budget applies to restores too
        let per = 16 + ENTRY_OVERHEAD;
        let mut small: ResultStore<i128> = ResultStore::new(per);
        small.restore(key(1), 1);
        small.restore(key(2), 2);
        assert_eq!(small.len(), 1, "restore respects the byte budget");
        assert_eq!(small.get(&key(2), 0), Some(2), "most recent restore survives");
    }

    #[test]
    fn rebase_epoch_patches_in_place_and_drops_the_rest() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        s.insert(key(1), 0, 10);
        s.insert(key(2), 0, 20);
        s.insert(key(3), 0, 30);
        let bytes_before = s.metrics().bytes;
        let (patched, dropped) = s.rebase_epoch(1, |k, v| {
            if *k == key(2) {
                None // unprovable: must recompute cold
            } else {
                Some(v + 5)
            }
        });
        assert_eq!((patched, dropped), (2, 1));
        assert_eq!(s.epoch(), 1);
        assert_eq!(s.get(&key(1), 1), Some(15), "carried to the new epoch");
        assert_eq!(s.get(&key(3), 1), Some(35));
        assert_eq!(s.get(&key(2), 1), None, "dropped entry misses");
        assert_eq!(s.get(&key(1), 0), None, "old epoch no longer served");
        let m = s.metrics();
        assert_eq!(m.patched, 2);
        assert_eq!(m.invalidations, 1, "drops count like a purge");
        assert_eq!(m.bytes, bytes_before - (16 + ENTRY_OVERHEAD));
    }

    #[test]
    fn rebase_epoch_same_epoch_is_noop() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        s.insert(key(1), 0, 1);
        let (patched, dropped) = s.rebase_epoch(0, |_, _| None);
        assert_eq!((patched, dropped), (0, 0));
        assert_eq!(s.get(&key(1), 0), Some(1), "no-op must not touch entries");
        assert_eq!(s.metrics().patched, 0);
    }

    #[test]
    fn rebase_epoch_drop_all_equals_purge() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        s.insert(key(1), 0, 1);
        s.insert(key(2), 0, 2);
        let (patched, dropped) = s.rebase_epoch(3, |_, _| None);
        assert_eq!((patched, dropped), (0, 2));
        assert!(s.is_empty());
        assert_eq!(s.metrics().bytes, 0);
        assert_eq!(s.metrics().invalidations, 2);
        assert_eq!(s.epoch(), 3);
    }

    #[test]
    fn persist_value_codec_roundtrip() {
        for v in [0i128, 1, -1, i128::MAX, i128::MIN, 123_456_789_012_345] {
            let mut bytes = Vec::new();
            v.encode(&mut bytes);
            assert_eq!(bytes.len(), 16);
            assert_eq!(i128::decode(&bytes), Some(v));
        }
        assert_eq!(i128::decode(&[1, 2, 3]), None, "short buffers fail cleanly");
        assert_eq!(i128::decode(&[0u8; 17]), None, "long buffers fail cleanly");
    }

    #[test]
    fn reinsert_replaces_without_double_charge() {
        let mut s: ResultStore<i128> = ResultStore::new(1 << 20);
        s.insert(key(1), 0, 1);
        let b = s.metrics().bytes;
        s.insert(key(1), 0, 2);
        assert_eq!(s.metrics().bytes, b, "replacement must not leak bytes");
        assert_eq!(s.get(&key(1), 0), Some(2));
    }
}
