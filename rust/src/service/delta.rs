//! DELTA-MORPHING — incremental maintenance of cached base-pattern counts.
//!
//! An edge update only perturbs the matches that touch the updated edge's
//! neighborhood, so the cached full-map count of a base pattern can be
//! *patched* instead of recomputed: for edge `(u,v)` the count delta of a
//! connected `k`-vertex pattern is confined to the connected `k`-vertex
//! sets of the data graph that contain **both** endpoints (any map whose
//! constraint evaluation differs between the two graph states must place
//! `u` and `v` in its image, and any set hosting a map of a connected
//! pattern is itself connected). [`edge_update_deltas`] enumerates those
//! sets once per pattern size and counts constraint-satisfying bijections
//! with the edge present and with it absent; the signed difference is the
//! exact delta in the same symmetrized full-map-count space the
//! [`ResultStore`](super::ResultStore) holds (no automorphism scaling —
//! bijections *are* full maps).
//!
//! The fragment this proves is deliberately conservative: **unlabeled,
//! connected patterns of ≥ 2 vertices** (anti-edges and open pairs are
//! fine — the bijection counter checks them directly). Anything outside
//! it, or any update whose neighborhood enumeration exceeds the caller's
//! budget, gets an explicit [`DeltaOutcome::Fallback`] with a reason —
//! counted in `mm_delta_fallback_total`, never a silent wrong answer. The
//! caller purges those entries (cold recompute on next touch); it patches
//! the rest in place under the same epoch bump.
//!
//! Contract: the graph passed in must **contain** the edge `(u,v)` — call
//! after applying an insertion, and *before* applying a removal (the
//! enumeration walks the graph state in which the edge exists, which is a
//! superset of both states' relevant sets).

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::time::Instant;

use crate::graph::{DynGraph, VertexId};
use crate::pattern::canon::CanonKey;
use crate::pattern::Pattern;
use crate::{obs_counter, obs_histogram};

/// Default cap on distinct connected vertex sets examined per pattern
/// size during one update's delta pass (see [`edge_update_deltas`]).
pub const DEFAULT_DELTA_BUDGET: usize = 1 << 16;

/// Per-base result of a delta pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaOutcome {
    /// Exact signed change of the stored (symmetrized full-map) count.
    Patch(i128),
    /// The delta pass cannot prove this base — the caller must purge it.
    /// The reason is a short static tag (`"labeled"`, `"disconnected"`,
    /// `"trivial"`, `"delta disabled"`, `"neighborhood budget exceeded"`).
    Fallback(&'static str),
}

/// Everything [`edge_update_deltas`] learned about one edge update:
/// exactly one outcome per distinct base-pattern key passed in.
#[derive(Debug, Default)]
pub struct DeltaReport {
    pub deltas: HashMap<CanonKey, DeltaOutcome>,
    /// Connected vertex sets enumerated across all pattern sizes.
    pub sets_examined: u64,
}

impl DeltaReport {
    /// Number of bases that fell back (must be purged by the caller).
    pub fn fallbacks(&self) -> u64 {
        self.deltas
            .values()
            .filter(|o| matches!(o, DeltaOutcome::Fallback(_)))
            .count() as u64
    }
}

/// Compute per-base count deltas for the edge update `(u, v)`.
///
/// `inserted` selects the sign: `true` means the edge was just inserted
/// (the delta moves counts from the without-edge state to the current
/// state), `false` means it is about to be removed. Either way the graph
/// must currently contain the edge (see module docs).
///
/// `max_sets` bounds the enumeration per pattern size; `0` disables the
/// delta pass entirely (every base falls back — the purge baseline).
pub fn edge_update_deltas(
    graph: &DynGraph,
    u: VertexId,
    v: VertexId,
    inserted: bool,
    bases: &[(CanonKey, Pattern)],
    max_sets: usize,
) -> DeltaReport {
    debug_assert!(
        graph.has_edge(u, v),
        "delta contract: the graph must contain the updated edge"
    );
    let start = Instant::now();
    let mut report = DeltaReport::default();
    // Partition supported bases by size; everything else falls back now.
    let mut by_size: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (i, (key, p)) in bases.iter().enumerate() {
        let unsupported = if max_sets == 0 {
            Some("delta disabled")
        } else if p.is_labeled() {
            Some("labeled")
        } else if p.num_vertices() < 2 {
            Some("trivial")
        } else if !p.is_connected() {
            Some("disconnected")
        } else {
            None
        };
        match unsupported {
            Some(reason) => {
                report.deltas.insert(*key, DeltaOutcome::Fallback(reason));
            }
            None => by_size.entry(p.num_vertices()).or_default().push(i),
        }
    }
    for (k, idxs) in by_size {
        match connected_supersets(graph, u, v, k, max_sets, &mut report.sets_examined) {
            None => {
                for &i in &idxs {
                    report
                        .deltas
                        .insert(bases[i].0, DeltaOutcome::Fallback("neighborhood budget exceeded"));
                }
            }
            Some(sets) => {
                for &i in &idxs {
                    let p = &bases[i].1;
                    let mut d: i128 = 0;
                    for s in &sets {
                        d += count_maps(graph, p, s, (u, v), false)
                            - count_maps(graph, p, s, (u, v), true);
                    }
                    let delta = if inserted { d } else { -d };
                    report.deltas.insert(bases[i].0, DeltaOutcome::Patch(delta));
                }
            }
        }
    }
    obs_counter!("mm_delta_sets_examined_total").add(report.sets_examined);
    obs_counter!("mm_delta_fallback_total").add(report.fallbacks());
    obs_histogram!("mm_delta_us").record_duration(start.elapsed());
    report
}

/// Enumerate every vertex set `S` with `|S| = k`, `{u,v} ⊆ S`, and `G[S]`
/// connected, by breadth-first growth from `{u,v}` (an edge, hence
/// connected): a connected superset is always reachable by adding one
/// adjacent vertex at a time. Returns `None` — delta pass abandoned for
/// this size — if the frontier exceeds `max_sets` distinct sets or the
/// growth work exceeds a proportional cap (dense hubs can generate far
/// more candidate extensions than surviving sets).
fn connected_supersets(
    graph: &DynGraph,
    u: VertexId,
    v: VertexId,
    k: usize,
    max_sets: usize,
    sets_examined: &mut u64,
) -> Option<Vec<Vec<VertexId>>> {
    let mut seed = vec![u, v];
    seed.sort_unstable();
    let mut frontier: BTreeSet<Vec<VertexId>> = BTreeSet::new();
    frontier.insert(seed);
    let work_cap = max_sets.saturating_mul(64).max(1024);
    let mut work = 0usize;
    for _ in 2..k {
        let mut next: BTreeSet<Vec<VertexId>> = BTreeSet::new();
        for s in &frontier {
            for &w in s {
                for &x in graph.neighbors(w) {
                    let pos = match s.binary_search(&x) {
                        Ok(_) => continue, // already a member
                        Err(pos) => pos,
                    };
                    work += 1;
                    if work > work_cap {
                        return None;
                    }
                    let mut t = Vec::with_capacity(s.len() + 1);
                    t.extend_from_slice(&s[..pos]);
                    t.push(x);
                    t.extend_from_slice(&s[pos..]);
                    next.insert(t);
                    if next.len() > max_sets {
                        return None;
                    }
                }
            }
        }
        frontier = next;
    }
    *sets_examined += frontier.len() as u64;
    Some(frontier.into_iter().collect())
}

/// Count bijections `φ : V(p) → S` under which every pattern edge maps to
/// a graph edge and every pattern anti-edge to a non-edge (open pairs are
/// unconstrained). With `exclude_uv` the pair `{u,v}` is treated as
/// absent — the without-edge state — so the *same* enumeration serves
/// both sides of the delta.
fn count_maps(
    graph: &DynGraph,
    p: &Pattern,
    set: &[VertexId],
    uv: (VertexId, VertexId),
    exclude_uv: bool,
) -> i128 {
    debug_assert_eq!(set.len(), p.num_vertices());
    let mut assigned: Vec<VertexId> = Vec::with_capacity(set.len());
    let mut used = vec![false; set.len()];
    extend_maps(graph, p, set, uv, exclude_uv, &mut assigned, &mut used)
}

fn extend_maps(
    graph: &DynGraph,
    p: &Pattern,
    set: &[VertexId],
    uv: (VertexId, VertexId),
    exclude_uv: bool,
    assigned: &mut Vec<VertexId>,
    used: &mut [bool],
) -> i128 {
    let i = assigned.len();
    if i == set.len() {
        return 1;
    }
    let mut total = 0i128;
    for slot in 0..set.len() {
        if used[slot] {
            continue;
        }
        let g = set[slot];
        let consistent = (0..i).all(|j| {
            let present = edge_present(graph, assigned[j], g, uv, exclude_uv);
            if p.has_edge(j, i) {
                present
            } else if p.has_anti_edge(j, i) {
                !present
            } else {
                true
            }
        });
        if consistent {
            used[slot] = true;
            assigned.push(g);
            total += extend_maps(graph, p, set, uv, exclude_uv, assigned, used);
            assigned.pop();
            used[slot] = false;
        }
    }
    total
}

#[inline]
fn edge_present(
    graph: &DynGraph,
    x: VertexId,
    y: VertexId,
    uv: (VertexId, VertexId),
    exclude_uv: bool,
) -> bool {
    if exclude_uv && ((x, y) == uv || (y, x) == uv) {
        return false;
    }
    graph.has_edge(x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agg::{aggregate_pattern, CountAgg};
    use crate::graph::generators::erdos_renyi;
    use crate::pattern::catalog;

    /// Bases the delta fragment must prove exactly: every flavor of
    /// constraint (edge-induced open pairs, vertex-induced anti-edges,
    /// cliques, stars) across sizes 2–4.
    fn exact_bases() -> Vec<(CanonKey, Pattern)> {
        let mut pats = vec![
            catalog::path(2),
            catalog::triangle(),
            catalog::path(3),
            catalog::path(4),
            catalog::star(4),
            catalog::cycle(4),
            catalog::cycle(4).vertex_induced(),
            catalog::diamond().vertex_induced(),
            catalog::clique(4),
        ];
        pats.extend(catalog::motifs_vertex_induced(4));
        let mut out: Vec<(CanonKey, Pattern)> = Vec::new();
        for p in pats {
            let k = p.canonical_key();
            if !out.iter().any(|(k0, _)| *k0 == k) {
                out.push((k, p));
            }
        }
        out
    }

    /// Symmetrized full-map counts straight from the batch matcher — the
    /// store-value convention the deltas must patch.
    fn full_counts(g: &DynGraph, bases: &[(CanonKey, Pattern)]) -> HashMap<CanonKey, i128> {
        let dg = g.to_data_graph("delta-oracle");
        bases
            .iter()
            .map(|(k, p)| (*k, aggregate_pattern(&dg, p, &CountAgg, 1)))
            .collect()
    }

    fn assert_deltas_exact(
        old: &HashMap<CanonKey, i128>,
        new: &HashMap<CanonKey, i128>,
        report: &DeltaReport,
        bases: &[(CanonKey, Pattern)],
        ctx: &str,
    ) {
        assert_eq!(report.deltas.len(), bases.len(), "{ctx}: one outcome per base");
        for (k, p) in bases {
            match report.deltas.get(k) {
                Some(DeltaOutcome::Patch(d)) => assert_eq!(
                    old[k] + d,
                    new[k],
                    "{ctx}: wrong delta {d} for {p:?} (old {} new {})",
                    old[k],
                    new[k]
                ),
                other => panic!("{ctx}: expected exact delta for {p:?}, got {other:?}"),
            }
        }
    }

    #[test]
    fn insert_deltas_match_recount() {
        let bases = exact_bases();
        for seed in [3u64, 11, 42] {
            let mut g = DynGraph::from_data_graph(&erdos_renyi(30, 80, seed));
            let (a, b) = (0..30u32)
                .flat_map(|a| (0..30u32).map(move |b| (a, b)))
                .find(|&(a, b)| a < b && !g.has_edge(a, b) && g.degree(a) > 0 && g.degree(b) > 0)
                .expect("sparse graph has a non-edge between non-isolated vertices");
            let old = full_counts(&g, &bases);
            assert!(g.insert_edge(a, b));
            let report = edge_update_deltas(&g, a, b, true, &bases, DEFAULT_DELTA_BUDGET);
            let new = full_counts(&g, &bases);
            assert_deltas_exact(&old, &new, &report, &bases, &format!("insert seed {seed}"));
            assert!(report.sets_examined > 0);
            assert_eq!(report.fallbacks(), 0);
        }
    }

    #[test]
    fn removal_deltas_match_recount() {
        let bases = exact_bases();
        for seed in [7u64, 19] {
            let mut g = DynGraph::from_data_graph(&erdos_renyi(30, 80, seed));
            let (a, b) = (0..30u32)
                .flat_map(|a| (0..30u32).map(move |b| (a, b)))
                .find(|&(a, b)| a < b && g.has_edge(a, b))
                .expect("graph has an edge");
            let old = full_counts(&g, &bases);
            // Deltas are computed on the pre-removal graph (which still
            // contains the edge), then the removal is applied.
            let report = edge_update_deltas(&g, a, b, false, &bases, DEFAULT_DELTA_BUDGET);
            assert!(g.remove_edge(a, b));
            let new = full_counts(&g, &bases);
            assert_deltas_exact(&old, &new, &report, &bases, &format!("remove seed {seed}"));
        }
    }

    #[test]
    fn hub_disconnect_deltas_are_exact() {
        // A star: removing a hub edge is the worst case for "which
        // matches died" bookkeeping — wedges and stars through the hub.
        let mut g = DynGraph::new(12);
        for leaf in 1..12u32 {
            g.insert_edge(0, leaf);
        }
        g.insert_edge(1, 2);
        let bases = exact_bases();
        let old = full_counts(&g, &bases);
        let report = edge_update_deltas(&g, 0, 7, false, &bases, DEFAULT_DELTA_BUDGET);
        assert!(g.remove_edge(0, 7));
        let new = full_counts(&g, &bases);
        assert_deltas_exact(&old, &new, &report, &bases, "hub disconnect");
    }

    #[test]
    fn single_edge_base_delta_is_aut_sized() {
        // The 2-vertex base: one new edge adds exactly |Aut(edge)| = 2
        // full maps (both orientations).
        let mut g = DynGraph::from_data_graph(&erdos_renyi(10, 12, 1));
        let (a, b) = (0..10u32)
            .flat_map(|a| (0..10u32).map(move |b| (a, b)))
            .find(|&(a, b)| a < b && !g.has_edge(a, b))
            .unwrap();
        assert!(g.insert_edge(a, b));
        let edge = catalog::path(2);
        let bases = vec![(edge.canonical_key(), edge)];
        let report = edge_update_deltas(&g, a, b, true, &bases, DEFAULT_DELTA_BUDGET);
        assert_eq!(
            report.deltas[&bases[0].0],
            DeltaOutcome::Patch(2),
            "insert: +2 maps"
        );
        let report = edge_update_deltas(&g, a, b, false, &bases, DEFAULT_DELTA_BUDGET);
        assert_eq!(
            report.deltas[&bases[0].0],
            DeltaOutcome::Patch(-2),
            "removal: the same magnitude, negated"
        );
    }

    #[test]
    fn budget_zero_disables_the_delta_pass() {
        let mut g = DynGraph::new(4);
        g.insert_edge(0, 1);
        let bases = exact_bases();
        let report = edge_update_deltas(&g, 0, 1, true, &bases, 0);
        assert_eq!(report.sets_examined, 0);
        assert_eq!(report.fallbacks(), bases.len() as u64);
        for (k, _) in &bases {
            assert_eq!(report.deltas[k], DeltaOutcome::Fallback("delta disabled"));
        }
    }

    #[test]
    fn unsupported_fragments_fall_back_supported_still_patch() {
        let mut g = DynGraph::from_data_graph(&erdos_renyi(20, 50, 5));
        let (a, b) = (0..20u32)
            .flat_map(|a| (0..20u32).map(move |b| (a, b)))
            .find(|&(a, b)| a < b && !g.has_edge(a, b))
            .unwrap();
        let labeled = catalog::triangle().with_labels(&[1, 1, 1]);
        let lonely = Pattern::from_edges(1, &[]);
        let split = Pattern::from_edges(4, &[(0, 1), (2, 3)]);
        let tri = catalog::triangle();
        let bases = vec![
            (labeled.canonical_key(), labeled),
            (lonely.canonical_key(), lonely),
            (split.canonical_key(), split),
            (tri.canonical_key(), tri),
        ];
        let old = full_counts(&g, &[bases[3].clone()]);
        assert!(g.insert_edge(a, b));
        let report = edge_update_deltas(&g, a, b, true, &bases, DEFAULT_DELTA_BUDGET);
        let new = full_counts(&g, &[bases[3].clone()]);
        assert_eq!(report.deltas[&bases[0].0], DeltaOutcome::Fallback("labeled"));
        assert_eq!(report.deltas[&bases[1].0], DeltaOutcome::Fallback("trivial"));
        assert_eq!(
            report.deltas[&bases[2].0],
            DeltaOutcome::Fallback("disconnected")
        );
        match report.deltas[&bases[3].0] {
            DeltaOutcome::Patch(d) => {
                assert_eq!(old[&bases[3].0] + d, new[&bases[3].0], "triangle stays exact")
            }
            ref other => panic!("triangle should patch, got {other:?}"),
        }
        assert_eq!(report.fallbacks(), 3);
    }

    #[test]
    fn tight_budget_falls_back_loudly() {
        // K5: three connected 3-sets contain any given edge, so a budget
        // of one set must abandon the pass rather than undercount.
        let mut g = DynGraph::new(5);
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                g.insert_edge(u, v);
            }
        }
        let tri = catalog::triangle();
        let bases = vec![(tri.canonical_key(), tri)];
        let report = edge_update_deltas(&g, 0, 1, true, &bases, 1);
        assert_eq!(
            report.deltas[&bases[0].0],
            DeltaOutcome::Fallback("neighborhood budget exceeded")
        );
    }
}
