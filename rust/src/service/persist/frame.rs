//! Record framing for the durable store: every record on disk — WAL
//! records and the snapshot image alike — is a **length-prefixed,
//! CRC32-guarded frame**:
//!
//! ```text
//! [payload len: u32 LE][crc32(payload): u32 LE][payload bytes]
//! ```
//!
//! The frame layer is what makes recovery total: a reader walks frames
//! from the start of a file and stops at the first frame that does not
//! check out — a short header, a length running past the end of the file
//! (a torn append killed mid-write), or a CRC mismatch (a bit flip). The
//! walked prefix is trusted, the tail is reported for truncation, and
//! nothing in this module ever panics on hostile bytes.

use std::io::{self, Write};

/// Bytes of frame header: payload length + CRC32.
pub const FRAME_HEADER: usize = 8;

/// Upper bound on a single frame's payload. Far above anything the store
/// writes (records are tens of bytes; a snapshot of a million entries is
/// tens of MiB) — this only stops a corrupt length field from asking the
/// reader to allocate or skip gigabytes.
pub const MAX_FRAME_LEN: usize = 1 << 30;

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE 802.3 polynomial, the zlib/PNG variant).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = (c >> 8) ^ CRC_TABLE[((c ^ b as u32) & 0xFF) as usize];
    }
    c ^ 0xFFFF_FFFF
}

/// Append one framed record to `w`. Oversized payloads are a hard error,
/// not a debug assertion: a frame no reader would accept must never be
/// written, because the caller may destroy other state (e.g. reset the
/// WAL after "successfully" writing a snapshot) on the strength of this
/// returning `Ok`.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("frame payload of {} bytes exceeds MAX_FRAME_LEN", payload.len()),
        ));
    }
    let mut head = [0u8; FRAME_HEADER];
    head[..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    head[4..].copy_from_slice(&crc32(payload).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)
}

/// Iterator over the valid frame prefix of a byte buffer. After iteration
/// ends, [`Frames::valid_len`] is the byte length of the trusted prefix
/// and [`Frames::corrupt`] reports whether a bad tail was dropped.
pub struct Frames<'a> {
    buf: &'a [u8],
    pos: usize,
    corrupt: bool,
}

impl<'a> Frames<'a> {
    pub fn new(buf: &'a [u8]) -> Frames<'a> {
        Frames {
            buf,
            pos: 0,
            corrupt: false,
        }
    }

    /// Bytes covered by the frames yielded so far (a safe truncation
    /// point once iteration has stopped).
    pub fn valid_len(&self) -> usize {
        self.pos
    }

    /// Whether iteration stopped on a torn or corrupt tail rather than a
    /// clean end of buffer.
    pub fn corrupt(&self) -> bool {
        self.corrupt
    }
}

impl<'a> Iterator for Frames<'a> {
    type Item = &'a [u8];

    fn next(&mut self) -> Option<&'a [u8]> {
        let rest = &self.buf[self.pos..];
        if rest.is_empty() {
            return None;
        }
        if rest.len() < FRAME_HEADER {
            self.corrupt = true; // torn header
            return None;
        }
        let len = u32::from_le_bytes(rest[..4].try_into().expect("4 bytes")) as usize;
        let crc = u32::from_le_bytes(rest[4..8].try_into().expect("4 bytes"));
        if len > MAX_FRAME_LEN || FRAME_HEADER + len > rest.len() {
            self.corrupt = true; // torn payload or garbage length
            return None;
        }
        let payload = &rest[FRAME_HEADER..FRAME_HEADER + len];
        if crc32(payload) != crc {
            self.corrupt = true; // bit flip
            return None;
        }
        self.pos += FRAME_HEADER + len;
        Some(payload)
    }
}

/// Bounds-checked little-endian cursor for decoding frame payloads. Every
/// accessor returns `None` past the end — decoding corrupt bytes degrades
/// to "record unreadable", never to a panic.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        let s = self.buf.get(self.pos..end)?;
        self.pos = end;
        Some(s)
    }

    pub fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|b| b[0])
    }

    pub fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().expect("4 bytes")))
    }

    pub fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|b| u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    /// Bytes not yet consumed.
    pub fn rest(self) -> &'a [u8] {
        &self.buf[self.pos..]
    }

    pub fn is_empty(&self) -> bool {
        self.pos == self.buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // the standard IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn frames_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"alpha").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[7u8; 300]).unwrap();
        let mut it = Frames::new(&buf);
        assert_eq!(it.next(), Some(&b"alpha"[..]));
        assert_eq!(it.next(), Some(&b""[..]));
        assert_eq!(it.next(), Some(&[7u8; 300][..]));
        assert_eq!(it.next(), None);
        assert!(!it.corrupt());
        assert_eq!(it.valid_len(), buf.len());
    }

    #[test]
    fn torn_tail_is_dropped_at_every_cut() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"one").unwrap();
        write_frame(&mut buf, b"two!").unwrap();
        let first_len = FRAME_HEADER + 3;
        // every possible kill point: the valid prefix is always recovered
        for cut in 0..buf.len() {
            let mut it = Frames::new(&buf[..cut]);
            let got: Vec<&[u8]> = (&mut it).collect();
            if cut < first_len {
                assert!(got.is_empty());
                assert_eq!(it.valid_len(), 0);
            } else if cut < buf.len() {
                assert_eq!(got, vec![&b"one"[..]]);
                assert_eq!(it.valid_len(), first_len);
            }
            assert_eq!(it.corrupt(), cut != 0 && cut != first_len);
        }
    }

    #[test]
    fn bit_flip_stops_at_the_bad_frame() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"good").unwrap();
        write_frame(&mut buf, b"evil").unwrap();
        let flip_at = FRAME_HEADER + 4 + FRAME_HEADER + 1; // inside "evil"
        buf[flip_at] ^= 0x40;
        let mut it = Frames::new(&buf);
        assert_eq!(it.next(), Some(&b"good"[..]));
        assert_eq!(it.next(), None);
        assert!(it.corrupt());
        assert_eq!(it.valid_len(), FRAME_HEADER + 4);
    }

    #[test]
    fn hostile_length_field_is_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 64]);
        let mut it = Frames::new(&buf);
        assert_eq!(it.next(), None);
        assert!(it.corrupt());
        assert_eq!(it.valid_len(), 0);
    }

    #[test]
    fn byte_reader_bounds() {
        let mut r = ByteReader::new(&[1, 0, 0, 0, 2, 0, 0, 0, 0, 0, 0, 0, 9]);
        assert_eq!(r.u32(), Some(1));
        assert_eq!(r.u64(), Some(2));
        assert_eq!(r.u8(), Some(9));
        assert!(r.is_empty());
        assert_eq!(r.u8(), None);
        let mut r = ByteReader::new(&[5, 6]);
        assert_eq!(r.u32(), None, "short reads fail cleanly");
        assert_eq!(r.take(1), Some(&[5u8][..]));
        assert_eq!(r.rest(), &[6u8][..]);
    }
}
