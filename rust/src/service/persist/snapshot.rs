//! Full store image on disk: one CRC-framed payload holding every live
//! `(canonical key, value)` pair plus the [`GraphFingerprint`] they are
//! valid for.
//!
//! Snapshots are written **atomically**: the image goes to `snapshot.tmp`
//! first and is published by a rename, so a reader never observes a
//! half-written file under the real name — a crash mid-write leaves the
//! previous snapshot (or none) intact. The single surrounding frame's CRC
//! covers the whole payload, so a bit-flipped snapshot is rejected as a
//! unit and recovery falls back to the WAL.

use super::frame::{self, ByteReader, Frames};
use crate::graph::GraphFingerprint;
use crate::pattern::canon::CanonKey;
use crate::service::store::PersistValue;
use std::fs::File;
use std::io;
use std::path::Path;

/// Snapshot file name inside a persist directory.
pub const SNAPSHOT_FILE: &str = "snapshot.bin";
/// Prefix of the scratch files images are staged under before the
/// publishing rename. Each write stages under a unique name
/// (`snapshot.tmp.<pid>.<seq>`): two concurrent compactions then cannot
/// interleave bytes in one staging file — whichever rename lands last
/// publishes a *complete*, CRC-valid image (possibly the older one,
/// which is merely colder on restart, never corrupt).
pub const SNAPSHOT_TMP: &str = "snapshot.tmp";

const SNAP_MAGIC: &[u8; 8] = b"MMSNAP01";

/// Per-process staging sequence (uniqueness across threads).
static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Write the image atomically (stage + rename). Entries should be in
/// least-recently-used-first order so restoring them in sequence rebuilds
/// the store's recency.
pub fn write<V: PersistValue>(
    dir: &Path,
    fp: GraphFingerprint,
    entries: &[(CanonKey, V)],
) -> io::Result<()> {
    let mut payload = Vec::with_capacity(64 + entries.len() * 48);
    payload.extend_from_slice(SNAP_MAGIC);
    payload.extend_from_slice(&fp.to_bytes());
    payload.extend_from_slice(&(entries.len() as u64).to_le_bytes());
    let mut value_buf = Vec::new();
    for (key, value) in entries {
        payload.push(key.n);
        payload.extend_from_slice(&key.pairs.to_le_bytes());
        payload.extend_from_slice(&key.labels.to_le_bytes());
        value_buf.clear();
        value.encode(&mut value_buf);
        payload.extend_from_slice(&(value_buf.len() as u32).to_le_bytes());
        payload.extend_from_slice(&value_buf);
    }
    let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = dir.join(format!("{SNAPSHOT_TMP}.{}.{seq}", std::process::id()));
    let staged = stage_and_publish(dir, &tmp, &payload);
    if staged.is_err() {
        // don't leave a half-written staging file behind
        let _ = std::fs::remove_file(&tmp);
    }
    staged
}

fn stage_and_publish(dir: &Path, tmp: &Path, payload: &[u8]) -> io::Result<()> {
    let mut f = File::create(tmp)?;
    frame::write_frame(&mut f, payload)?;
    // best effort: make the bytes durable before the rename publishes
    // them (a failed sync is not fatal — the WAL still holds the data)
    let _ = f.sync_all();
    std::fs::rename(tmp, dir.join(SNAPSHOT_FILE))
}

/// Read a snapshot image. `None` for anything unusable — missing file,
/// torn frame, CRC mismatch, bad magic or malformed entries — recovery
/// then proceeds from the WAL alone.
pub fn read<V: PersistValue>(dir: &Path) -> Option<(GraphFingerprint, Vec<(CanonKey, V)>)> {
    let bytes = std::fs::read(dir.join(SNAPSHOT_FILE)).ok()?;
    let payload = Frames::new(&bytes).next()?;
    let mut r = ByteReader::new(payload);
    if r.take(SNAP_MAGIC.len())? != SNAP_MAGIC {
        return None;
    }
    let fp = GraphFingerprint::from_bytes(r.take(GraphFingerprint::BYTES)?)?;
    let count = r.u64()? as usize;
    let mut entries = Vec::with_capacity(count.min(1 << 20));
    for _ in 0..count {
        let n = r.u8()?;
        let pairs = r.u64()?;
        let labels = r.u64()?;
        let vlen = r.u32()? as usize;
        let value = V::decode(r.take(vlen)?)?;
        entries.push((CanonKey { n, pairs, labels }, value));
    }
    if !r.is_empty() {
        return None; // trailing bytes: not an image we wrote
    }
    Some((fp, entries))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;

    fn fp() -> GraphFingerprint {
        GraphFingerprint {
            order: 3,
            size: 2,
            hash: 0xDEAD,
        }
    }

    fn key(i: usize) -> CanonKey {
        catalog::paper_pattern(i % 7 + 1).canonical_key()
    }

    fn dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("mm_snap_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_and_atomic_publish() {
        let d = dir("roundtrip");
        assert!(read::<i128>(&d).is_none(), "missing file is None");
        let entries = vec![(key(1), 11i128), (key(2), -22i128), (key(3), 0i128)];
        write(&d, fp(), &entries).unwrap();
        let leftovers = std::fs::read_dir(&d)
            .unwrap()
            .filter(|e| {
                e.as_ref()
                    .unwrap()
                    .file_name()
                    .to_string_lossy()
                    .starts_with(SNAPSHOT_TMP)
            })
            .count();
        assert_eq!(leftovers, 0, "staging files renamed away");
        let (got_fp, got) = read::<i128>(&d).expect("snapshot readable");
        assert_eq!(got_fp, fp());
        assert_eq!(got, entries);
        // empty image is valid too (post-invalidation compaction)
        write::<i128>(&d, fp(), &[]).unwrap();
        let (_, got) = read::<i128>(&d).expect("empty snapshot readable");
        assert!(got.is_empty());
    }

    #[test]
    fn bit_flip_rejects_whole_image() {
        let d = dir("flip");
        write(&d, fp(), &[(key(1), 5i128), (key(2), 6i128)]).unwrap();
        let mut bytes = std::fs::read(d.join(SNAPSHOT_FILE)).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(d.join(SNAPSHOT_FILE), &bytes).unwrap();
        assert!(read::<i128>(&d).is_none(), "CRC must reject the image");
    }

    #[test]
    fn truncation_rejects_whole_image() {
        let d = dir("trunc");
        write(&d, fp(), &[(key(1), 5i128)]).unwrap();
        let bytes = std::fs::read(d.join(SNAPSHOT_FILE)).unwrap();
        std::fs::write(d.join(SNAPSHOT_FILE), &bytes[..bytes.len() - 3]).unwrap();
        assert!(read::<i128>(&d).is_none());
    }
}
