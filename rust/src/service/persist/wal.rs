//! Append-only write-ahead log of result-store mutations.
//!
//! Layout: one header frame (magic + the [`GraphFingerprint`] the log's
//! entries are valid for), then one frame per record:
//!
//! * **insert** — a `(canonical key, value)` pair the store published;
//! * **invalidate** — the graph mutated: everything before this record is
//!   dead, and subsequent inserts belong to the new fingerprint carried by
//!   the record.
//!
//! Replay is total: it walks the valid frame prefix (torn/corrupt tails
//! are measured for truncation, never panicked on), applies records onto a
//! base image, and reports the fingerprint the surviving image is valid
//! for. Correctness never depends on the log being complete — values are
//! pure functions of `(canonical key, graph content)`, so a lost suffix
//! only makes recovery colder, never wrong.

use super::frame::{self, ByteReader, Frames};
use crate::graph::GraphFingerprint;
use crate::pattern::canon::CanonKey;
use crate::service::store::PersistValue;
use std::fs::{File, OpenOptions};
use std::io::{self, Seek, Write};
use std::path::Path;

/// WAL file name inside a persist directory.
pub const WAL_FILE: &str = "wal.log";

const WAL_MAGIC: &[u8; 8] = b"MMWAL001";
const TAG_INSERT: u8 = 1;
const TAG_INVALIDATE: u8 = 2;

/// Open WAL handle: appends framed records, flushing each one so a killed
/// process loses at most the record being written — which replay then
/// truncates as a torn tail.
///
/// Flushing reaches the OS page cache, not the platters: that survives a
/// process kill but not a power loss. An optional **fsync cadence**
/// (`fsync_every = Some(n)`) additionally calls `sync_data` after every
/// `n`th record — `Some(1)` gives true power-loss durability at one disk
/// sync per record, larger cadences bound the loss window to `n` records,
/// and the default `None` keeps the flush-only behavior (replay handles
/// any lost suffix either way; durability is the only thing at stake,
/// never correctness).
pub struct Wal {
    file: File,
    records: usize,
    /// `Some(n)`: `sync_data` after every `n`th appended record.
    fsync_every: Option<u32>,
    appended_since_sync: u32,
    syncs: u64,
}

impl Wal {
    /// Create (truncating any previous log) with a header binding the log
    /// to `fp`. With a sync cadence configured the header itself is
    /// synced — **and so is the parent directory**, because a
    /// freshly-created file whose dirent was never fsynced can vanish
    /// wholesale on power loss, taking every per-record sync the caller
    /// paid for with it. A power loss must never leave a
    /// published-but-missing log that a cadence-1 caller believed durable.
    pub fn create(dir: &Path, fp: GraphFingerprint, fsync_every: Option<u32>) -> io::Result<Wal> {
        let mut file = File::create(dir.join(WAL_FILE))?;
        let mut payload = Vec::with_capacity(WAL_MAGIC.len() + GraphFingerprint::BYTES);
        payload.extend_from_slice(WAL_MAGIC);
        payload.extend_from_slice(&fp.to_bytes());
        frame::write_frame(&mut file, &payload)?;
        file.flush()?;
        if fsync_every.is_some() {
            file.sync_data()?;
            File::open(dir)?.sync_all()?;
        }
        Ok(Wal {
            file,
            records: 0,
            fsync_every,
            appended_since_sync: 0,
            syncs: 0,
        })
    }

    /// Reopen for append after a replay trusted the first `valid_len`
    /// bytes: the torn/corrupt tail (if any) is cut off so new records
    /// extend a clean prefix.
    pub fn open_append(
        dir: &Path,
        valid_len: u64,
        records: usize,
        fsync_every: Option<u32>,
    ) -> io::Result<Wal> {
        let mut file = OpenOptions::new().read(true).write(true).open(dir.join(WAL_FILE))?;
        file.set_len(valid_len)?;
        file.seek(io::SeekFrom::End(0))?;
        Ok(Wal {
            file,
            records,
            fsync_every,
            appended_since_sync: 0,
            syncs: 0,
        })
    }

    /// Records appended plus records replayed at open.
    pub fn records(&self) -> usize {
        self.records
    }

    /// `sync_data` calls made by the cadence (0 under the flush-only
    /// default) — observable so tests can pin the cadence contract.
    pub fn syncs(&self) -> u64 {
        self.syncs
    }

    pub fn append_insert<V: PersistValue>(&mut self, key: &CanonKey, value: &V) -> io::Result<()> {
        let mut payload = Vec::with_capacity(32);
        payload.push(TAG_INSERT);
        payload.push(key.n);
        payload.extend_from_slice(&key.pairs.to_le_bytes());
        payload.extend_from_slice(&key.labels.to_le_bytes());
        value.encode(&mut payload);
        self.append(&payload)
    }

    pub fn append_invalidate(&mut self, fp: GraphFingerprint) -> io::Result<()> {
        let mut payload = Vec::with_capacity(1 + GraphFingerprint::BYTES);
        payload.push(TAG_INVALIDATE);
        payload.extend_from_slice(&fp.to_bytes());
        self.append(&payload)
    }

    fn append(&mut self, payload: &[u8]) -> io::Result<()> {
        frame::write_frame(&mut self.file, payload)?;
        self.records += 1;
        self.file.flush()?;
        if let Some(n) = self.fsync_every {
            self.appended_since_sync += 1;
            if self.appended_since_sync >= n.max(1) {
                let t = std::time::Instant::now();
                self.file.sync_data()?;
                crate::obs_histogram!("mm_wal_fsync_us").record_duration(t.elapsed());
                self.appended_since_sync = 0;
                self.syncs += 1;
            }
        }
        Ok(())
    }
}

/// Outcome of replaying a WAL over a base image. Never an error: a
/// missing, empty or corrupt log degrades to the base image (or to
/// nothing), and `valid_len`/`truncated` tell the caller how much of the
/// file to keep.
pub struct Replay<V> {
    /// Fingerprint the surviving `entries` are valid for (`None` when
    /// neither a usable header nor a base image exists).
    pub fingerprint: Option<GraphFingerprint>,
    /// The reconstructed image, in apply order (oldest first).
    pub entries: Vec<(CanonKey, V)>,
    /// Records applied from this log.
    pub records: usize,
    /// Byte length of the trusted frame prefix.
    pub valid_len: u64,
    /// Whether a torn/corrupt tail (or an unreadable record) was dropped.
    pub truncated: bool,
    /// The log file exists on disk.
    pub file_present: bool,
    /// The header frame was intact (magic + fingerprint).
    pub header_ok: bool,
}

/// Decode the body of an insert record (tag already consumed).
fn decode_insert<V: PersistValue>(mut r: ByteReader<'_>) -> Option<(CanonKey, V)> {
    let n = r.u8()?;
    let pairs = r.u64()?;
    let labels = r.u64()?;
    let value = V::decode(r.rest())?;
    Some((CanonKey { n, pairs, labels }, value))
}

/// Replay the WAL at `dir` over `base` (a snapshot image and the
/// fingerprint it was taken at). The base contributes only when it matches
/// the log's header fingerprint — a base from some other graph state is
/// ignored rather than mixed in.
pub fn replay<V: PersistValue>(
    dir: &Path,
    base: Option<(GraphFingerprint, Vec<(CanonKey, V)>)>,
) -> Replay<V> {
    let path = dir.join(WAL_FILE);
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(_) => {
            // no log: the snapshot alone is the image
            let (fingerprint, entries) = match base {
                Some((fp, es)) => (Some(fp), es),
                None => (None, Vec::new()),
            };
            return Replay {
                fingerprint,
                entries,
                records: 0,
                valid_len: 0,
                truncated: false,
                file_present: false,
                header_ok: false,
            };
        }
    };

    let mut frames = Frames::new(&bytes);
    let header_fp = frames.next().and_then(|payload| {
        let mut r = ByteReader::new(payload);
        if r.take(WAL_MAGIC.len())? != WAL_MAGIC {
            return None;
        }
        GraphFingerprint::from_bytes(r.rest())
    });
    let Some(header_fp) = header_fp else {
        // unusable header: nothing in this file can be attributed — fall
        // back to the snapshot image alone
        let (fingerprint, entries) = match base {
            Some((fp, es)) => (Some(fp), es),
            None => (None, Vec::new()),
        };
        return Replay {
            fingerprint,
            entries,
            records: 0,
            valid_len: 0,
            truncated: true,
            file_present: true,
            header_ok: false,
        };
    };

    // the snapshot seeds the image only if it describes the same graph
    // state the log starts from
    let mut entries: Vec<(CanonKey, V)> = match base {
        Some((fp, es)) if fp == header_fp => es,
        _ => Vec::new(),
    };
    let mut index: std::collections::HashMap<CanonKey, usize> =
        entries.iter().enumerate().map(|(i, (k, _))| (*k, i)).collect();
    let mut fingerprint = header_fp;
    let mut records = 0usize;
    let mut unreadable = false;

    for payload in &mut frames {
        let mut r = ByteReader::new(payload);
        match r.u8() {
            Some(TAG_INSERT) => {
                match decode_insert::<V>(r) {
                    Some((key, value)) => {
                        match index.get(&key) {
                            Some(&i) => entries[i].1 = value,
                            None => {
                                index.insert(key, entries.len());
                                entries.push((key, value));
                            }
                        }
                        records += 1;
                    }
                    None => {
                        unreadable = true;
                        break;
                    }
                }
            }
            Some(TAG_INVALIDATE) => match GraphFingerprint::from_bytes(r.rest()) {
                Some(fp) => {
                    entries.clear();
                    index.clear();
                    fingerprint = fp;
                    records += 1;
                }
                None => {
                    unreadable = true;
                    break;
                }
            },
            _ => {
                // unknown tag: a future format or garbage that passed the
                // CRC — stop trusting the file here
                unreadable = true;
                break;
            }
        }
    }

    // an unreadable record truncates like a corrupt frame would, except
    // the frame iterator already advanced past it: recompute the trusted
    // length as "everything before the record that failed to decode"
    let valid_len = if unreadable {
        // walk again, trusting only the header plus the `records` frames
        // that decoded cleanly
        let mut it = Frames::new(&bytes);
        let mut len = 0usize;
        for _ in 0..=records {
            if it.next().is_some() {
                len = it.valid_len();
            }
        }
        len as u64
    } else {
        frames.valid_len() as u64
    };

    Replay {
        fingerprint: Some(fingerprint),
        entries,
        records,
        valid_len,
        truncated: unreadable || frames.corrupt(),
        file_present: true,
        header_ok: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;

    fn fp(seed: u64) -> GraphFingerprint {
        GraphFingerprint {
            order: 10,
            size: 20,
            hash: seed,
        }
    }

    fn key(i: usize) -> CanonKey {
        catalog::paper_pattern(i % 7 + 1).canonical_key()
    }

    fn dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("mm_wal_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn append_replay_roundtrip() {
        let d = dir("roundtrip");
        let mut w = Wal::create(&d, fp(1), None).unwrap();
        w.append_insert(&key(1), &42i128).unwrap();
        w.append_insert(&key(2), &-7i128).unwrap();
        w.append_insert(&key(1), &43i128).unwrap(); // later insert wins
        drop(w);
        let r = replay::<i128>(&d, None);
        assert_eq!(r.fingerprint, Some(fp(1)));
        assert_eq!(r.records, 3);
        assert!(!r.truncated);
        assert!(r.header_ok && r.file_present);
        assert_eq!(r.entries, vec![(key(1), 43), (key(2), -7)]);
    }

    #[test]
    fn invalidate_clears_and_rebinds() {
        let d = dir("invalidate");
        let mut w = Wal::create(&d, fp(1), None).unwrap();
        w.append_insert(&key(1), &1i128).unwrap();
        w.append_invalidate(fp(2)).unwrap();
        w.append_insert(&key(2), &2i128).unwrap();
        drop(w);
        let r = replay::<i128>(&d, None);
        assert_eq!(r.fingerprint, Some(fp(2)));
        assert_eq!(r.entries, vec![(key(2), 2)]);
        assert_eq!(r.records, 3);
    }

    #[test]
    fn base_applies_only_on_matching_fingerprint() {
        let d = dir("base");
        let mut w = Wal::create(&d, fp(1), None).unwrap();
        w.append_insert(&key(2), &9i128).unwrap();
        drop(w);
        let matching = replay::<i128>(&d, Some((fp(1), vec![(key(1), 5)])));
        assert_eq!(matching.entries, vec![(key(1), 5), (key(2), 9)]);
        let stale = replay::<i128>(&d, Some((fp(7), vec![(key(1), 5)])));
        assert_eq!(stale.entries, vec![(key(2), 9)], "stale snapshot ignored");
    }

    #[test]
    fn torn_and_corrupt_tails_truncate() {
        let d = dir("torn");
        let mut w = Wal::create(&d, fp(1), None).unwrap();
        w.append_insert(&key(1), &1i128).unwrap();
        w.append_insert(&key(2), &2i128).unwrap();
        drop(w);
        let full = std::fs::read(d.join(WAL_FILE)).unwrap();
        let clean = replay::<i128>(&d, None);
        assert_eq!(clean.valid_len as usize, full.len());
        // kill mid-record: every cut recovers a clean prefix, no panic
        for cut in (0..full.len()).step_by(3).chain([full.len() - 1]) {
            std::fs::write(d.join(WAL_FILE), &full[..cut]).unwrap();
            let r = replay::<i128>(&d, None);
            assert!(r.records <= 2);
            assert!(r.valid_len as usize <= cut);
            for (k, v) in &r.entries {
                let expect = if *k == key(1) { 1 } else { 2 };
                assert_eq!(*v, expect);
            }
        }
        // bit flip in the second record
        let mut flipped = full.clone();
        let at = clean.valid_len as usize - 2;
        flipped[at] ^= 0x10;
        std::fs::write(d.join(WAL_FILE), &flipped).unwrap();
        let r = replay::<i128>(&d, None);
        assert!(r.truncated);
        assert_eq!(r.entries, vec![(key(1), 1)]);
        // reopening for append truncates the bad tail away
        let w = Wal::open_append(&d, r.valid_len, r.records, None).unwrap();
        assert_eq!(w.records(), 1);
        drop(w);
        assert_eq!(
            std::fs::metadata(d.join(WAL_FILE)).unwrap().len(),
            r.valid_len
        );
    }

    #[test]
    fn corrupt_header_degrades_to_base() {
        let d = dir("header");
        let mut w = Wal::create(&d, fp(1), None).unwrap();
        w.append_insert(&key(1), &1i128).unwrap();
        drop(w);
        let mut bytes = std::fs::read(d.join(WAL_FILE)).unwrap();
        bytes[10] ^= 0xFF; // inside the header payload
        std::fs::write(d.join(WAL_FILE), &bytes).unwrap();
        let r = replay::<i128>(&d, Some((fp(3), vec![(key(2), 2)])));
        assert!(!r.header_ok);
        assert!(r.truncated);
        assert_eq!(r.fingerprint, Some(fp(3)), "snapshot image survives alone");
        assert_eq!(r.entries, vec![(key(2), 2)]);
    }

    #[test]
    fn missing_file_is_empty_not_error() {
        let d = dir("missing");
        let r = replay::<i128>(&d, None);
        assert!(!r.file_present);
        assert_eq!(r.fingerprint, None);
        assert!(r.entries.is_empty());
    }
}
