//! DURABLE RESULT STORE — crash-safe persistence for the service-layer
//! [`ResultStore`](crate::service::ResultStore), so `morphmine serve`
//! restarts **warm** instead of recomputing the matches that were most
//! expensive to produce.
//!
//! A persist directory holds three files:
//!
//! * [`wal::WAL_FILE`] — an append-only log of store inserts and epoch
//!   invalidations, one CRC-framed record each ([`frame`]). Every record
//!   is flushed as it is written, so a killed process loses at most the
//!   record mid-write — which replay truncates as a torn tail.
//! * [`snapshot::SNAPSHOT_FILE`] — a periodic full image of the store
//!   (compaction), staged to a tmp file and published by an atomic
//!   rename; writing it resets the WAL to an empty log.
//! * [`LOCK_FILE`] — single-writer guard ([`DirLock`]): a second live
//!   process opening the same directory fails fast instead of
//!   interleaving WAL frames; stale locks from dead processes are
//!   reclaimed automatically.
//!
//! **The fingerprint invariant.** The in-process epoch counter
//! ([`crate::graph::DynGraph::version`]) restarts at zero with every
//! process, so it cannot key durable state. Every persisted artifact is
//! instead bound to a [`GraphFingerprint`] — order, size and a streamed
//! hash of the engine-facing CSR — and recovery hands entries to the
//! store **only when the live graph hashes to the same value**. A store
//! persisted against a different or mutated graph is structurally
//! unservable: recovery degrades to cold, never to stale counts. This is
//! also why recovery is total rather than transactional — cached values
//! are pure functions of `(canonical key, graph content)`, so losing a
//! WAL suffix or a whole snapshot only makes the restarted store colder.
//!
//! CLI: `morphmine serve|batch --persist <dir>` (plus `--fsync-every N`
//! for power-loss durability) wires this into the service; `morphmine
//! store inspect|compact|purge|verify --dir <dir>` operates on a
//! directory offline. Benchmark: A9 `bench --exp persist`
//! (cold vs warm-restart vs replay-heavy → `BENCH_persist.json`).
//!
//! The restart contract in one example — same content recovers warm,
//! different content recovers cold:
//!
//! ```
//! use morphmine::graph::generators::erdos_renyi;
//! use morphmine::graph::GraphFingerprint;
//! use morphmine::pattern::catalog;
//! use morphmine::service::persist::{Persistence, PersistOpts};
//!
//! let dir = std::env::temp_dir().join("mm_persist_doctest");
//! let _ = std::fs::remove_dir_all(&dir);
//! let fp = erdos_renyi(30, 60, 1).fingerprint();
//! let key = catalog::triangle().canonical_key();
//!
//! // first "process": log one published result; drop releases the lock
//! let (mut p, warm, _) = Persistence::<i128>::open(&dir, fp, PersistOpts::default()).unwrap();
//! assert!(warm.is_empty(), "a fresh directory recovers cold");
//! p.record_insert(&key, &42).unwrap();
//! drop(p);
//!
//! // second "process", same graph content: warm restart
//! let (p, warm, report) = Persistence::<i128>::open(&dir, fp, PersistOpts::default()).unwrap();
//! assert_eq!(warm, vec![(key, 42)]);
//! assert!(report.fingerprint_matched);
//! drop(p);
//!
//! // a different graph: structurally unservable — cold, never stale
//! let other = GraphFingerprint { order: 31, size: 60, hash: 0xBAD };
//! let (_p, warm, report) = Persistence::<i128>::open(&dir, other, PersistOpts::default()).unwrap();
//! assert!(warm.is_empty());
//! assert!(!report.fingerprint_matched);
//! ```

pub mod frame;
pub mod snapshot;
pub mod wal;

use crate::graph::GraphFingerprint;
use crate::pattern::canon::CanonKey;
use crate::service::store::PersistValue;
use anyhow::{bail, Context, Result};
use std::io;
use std::path::{Path, PathBuf};

/// Lock file marking a persist directory as owned by a live process.
pub const LOCK_FILE: &str = "lock";

/// Exclusive ownership of a persist directory for one process lifetime.
///
/// Two live writers appending to one WAL interleave frames: the CRC layer
/// keeps wrong answers from ever being served, but replay stops at the
/// first torn frame — silently destroying the durability the directory
/// exists for. So opening a locked directory fails fast instead. The lock
/// records the owner's PID; a lock left behind by a dead process (kill
/// -9, OOM) is detected via `/proc` and reclaimed, so unattended
/// crash-restart — the whole point of the subsystem — still works on
/// Linux. (Off Linux liveness cannot be probed, so stale locks need the
/// manual removal the error message names; a recycled PID can likewise
/// make a stale lock look alive.)
///
/// Acquisition protocol (no `flock` available in a std-only crate): the
/// PID is staged in a scratch file and published with an atomic
/// `hard_link`, so the lock file never exists without its content, and
/// after linking the owner **re-reads the file and keeps the lock only
/// if it still names this process** — a concurrent reclaimer acting on a
/// stale "owner is dead" read may delete and replace the link in the
/// meantime, and the verify step demotes every racer except the one the
/// file finally names. The single theoretical loser window (verify
/// passing just before a stale-read deletion lands) costs warm-restart
/// durability, never answer correctness — the CRC layer guarantees that.
struct DirLock {
    path: PathBuf,
}

impl DirLock {
    fn acquire(dir: &Path) -> Result<DirLock> {
        let path = dir.join(LOCK_FILE);
        let me = std::process::id();
        // stage content aside so existence and content publish atomically
        let staged = dir.join(format!("{LOCK_FILE}.{me}"));
        std::fs::write(&staged, format!("{me}"))
            .with_context(|| format!("staging lock {}", staged.display()))?;
        let result = Self::acquire_inner(dir, &path, &staged, me);
        let _ = std::fs::remove_file(&staged);
        result
    }

    fn acquire_inner(dir: &Path, path: &Path, staged: &Path, me: u32) -> Result<DirLock> {
        for _ in 0..4 {
            match std::fs::hard_link(staged, path) {
                Ok(()) => {}
                Err(e) if e.kind() == io::ErrorKind::AlreadyExists => {
                    let owner = std::fs::read_to_string(path)
                        .ok()
                        .and_then(|s| s.trim().parse::<u32>().ok());
                    // a live owner — including this very process (two
                    // services sharing one dir in-process) — excludes us
                    if let Some(pid) = owner {
                        if pid_alive(pid) {
                            bail!(
                                "persist dir {} is locked by live process {pid} — two \
                                 writers on one WAL would corrupt it (remove {} if the \
                                 lock is stale)",
                                dir.display(),
                                path.display()
                            );
                        }
                    }
                    // dead or unreadable owner: reclaim and retry
                    let _ = std::fs::remove_file(path);
                    continue;
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("creating lock {}", path.display()))
                }
            }
            // confirm we won any concurrent reclaim of the same stale lock
            let holder = std::fs::read_to_string(path)
                .ok()
                .and_then(|s| s.trim().parse::<u32>().ok());
            if holder == Some(me) {
                return Ok(DirLock {
                    path: path.to_path_buf(),
                });
            }
            // raced out: whoever the file names now is live — next loop
            // iteration reports them
        }
        bail!("could not acquire persist lock at {} (contended)", path.display())
    }
}

impl Drop for DirLock {
    fn drop(&mut self) {
        // belt-and-braces: never delete a lock that no longer names us
        let ours = std::fs::read_to_string(&self.path)
            .ok()
            .and_then(|s| s.trim().parse::<u32>().ok())
            == Some(std::process::id());
        if ours {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// Whether `pid` names a live process (Linux `/proc` probe; on other
/// platforms assume alive — failing safe toward "locked", at the cost of
/// manual stale-lock removal there).
fn pid_alive(pid: u32) -> bool {
    #[cfg(target_os = "linux")]
    {
        Path::new(&format!("/proc/{pid}")).exists()
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = pid;
        true
    }
}

/// Tuning knobs for one persistence session.
#[derive(Clone, Copy, Debug)]
pub struct PersistOpts {
    /// Compact (snapshot + WAL reset) after this many WAL records. An
    /// epoch invalidation forces compaction regardless, since it makes
    /// the whole log prefix dead weight.
    pub snapshot_every: usize,
    /// Compact once more when the owning service shuts down cleanly, so a
    /// restart reads one snapshot instead of replaying the session's log.
    pub compact_on_drop: bool,
    /// `Some(n)`: `sync_data` the WAL after every `n`th record for real
    /// power-loss durability (`Some(1)` = one disk sync per record; larger
    /// cadences bound the loss window to `n` records). The default `None`
    /// keeps flush-only appends — durable across process kills, not power
    /// loss. Either way a lost suffix only cools recovery, never corrupts
    /// it. CLI: `--fsync-every N`.
    pub fsync_every: Option<u32>,
}

impl Default for PersistOpts {
    fn default() -> PersistOpts {
        PersistOpts {
            snapshot_every: 256,
            compact_on_drop: true,
            fsync_every: None,
        }
    }
}

/// Where (and how) a service persists its result store.
#[derive(Clone, Debug)]
pub struct PersistConfig {
    pub dir: PathBuf,
    pub opts: PersistOpts,
}

impl PersistConfig {
    pub fn new(dir: impl Into<PathBuf>) -> PersistConfig {
        PersistConfig {
            dir: dir.into(),
            opts: PersistOpts::default(),
        }
    }
}

/// What recovery found at startup.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryReport {
    /// Entries the snapshot contributed (before the fingerprint gate).
    pub snapshot_entries: usize,
    /// WAL records replayed.
    pub wal_records: usize,
    /// A torn/corrupt WAL tail was truncated.
    pub wal_truncated: bool,
    /// The persisted state's fingerprint matched the live graph.
    pub fingerprint_matched: bool,
    /// Entries handed to the store (0 unless `fingerprint_matched`).
    pub restored: usize,
}

/// One open persistence session: owns the WAL handle and the compaction
/// cadence for a store bound to `fingerprint`.
pub struct Persistence<V> {
    dir: PathBuf,
    fingerprint: GraphFingerprint,
    wal: wal::Wal,
    records_since_snapshot: usize,
    force_compact: bool,
    opts: PersistOpts,
    /// Held for the session; released (file removed) on drop.
    _lock: DirLock,
    _value: std::marker::PhantomData<V>,
}

impl<V: PersistValue> Persistence<V> {
    /// Open `dir` (creating it if needed) and recover the image persisted
    /// for `fp`. Returns the session handle, the warm entries to seed the
    /// store with (empty when the directory is fresh, unreadable, or was
    /// persisted against a different graph — in which case a fresh log is
    /// started and any stale snapshot is replaced at the next compaction),
    /// and a report of what recovery saw.
    pub fn open(
        dir: &Path,
        fp: GraphFingerprint,
        opts: PersistOpts,
    ) -> Result<(Persistence<V>, Vec<(CanonKey, V)>, RecoveryReport)> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating persist dir {}", dir.display()))?;
        let lock = DirLock::acquire(dir)?;
        let snap = snapshot::read::<V>(dir);
        let snapshot_entries = snap.as_ref().map_or(0, |(_, es)| es.len());
        let rep = wal::replay::<V>(dir, snap);
        let matched = rep.fingerprint == Some(fp);
        // a reused log's replayed records count toward the next compaction
        // (so a long never-compacted log gets folded soon after start); a
        // fresh log starts clean — the discarded old-graph records are gone
        let (warm, wal, pending) = if matched && rep.file_present && rep.header_ok {
            // continue the existing log, clean tail only
            let w = wal::Wal::open_append(dir, rep.valid_len, rep.records, opts.fsync_every)
                .with_context(|| format!("reopening WAL in {}", dir.display()))?;
            (rep.entries, w, rep.records)
        } else {
            // fresh dir, unreadable log, or state for another graph: start
            // a new log for the live graph (keeping the snapshot entries
            // when only the WAL was unusable)
            let warm = if matched { rep.entries } else { Vec::new() };
            let w = wal::Wal::create(dir, fp, opts.fsync_every)
                .with_context(|| format!("creating WAL in {}", dir.display()))?;
            (warm, w, 0)
        };
        let report = RecoveryReport {
            snapshot_entries,
            wal_records: rep.records,
            wal_truncated: rep.truncated,
            fingerprint_matched: matched,
            restored: warm.len(),
        };
        let persist = Persistence {
            dir: dir.to_path_buf(),
            fingerprint: fp,
            wal,
            records_since_snapshot: pending,
            force_compact: false,
            opts,
            _lock: lock,
            _value: std::marker::PhantomData,
        };
        Ok((persist, warm, report))
    }

    /// Fingerprint the current entries are bound to.
    pub fn fingerprint(&self) -> GraphFingerprint {
        self.fingerprint
    }

    pub fn compact_on_drop(&self) -> bool {
        self.opts.compact_on_drop
    }

    /// Whether anything has been logged since the last compaction — when
    /// false, the on-disk snapshot already equals the live image and a
    /// shutdown compaction would be pure wasted IO.
    pub fn dirty(&self) -> bool {
        self.force_compact || self.records_since_snapshot > 0
    }

    /// Append one published store insert. Flushed before returning (and
    /// synced per [`PersistOpts::fsync_every`]).
    pub fn record_insert(&mut self, key: &CanonKey, value: &V) -> io::Result<()> {
        self.wal.append_insert(key, value)?;
        self.records_since_snapshot += 1;
        Ok(())
    }

    /// `sync_data` calls the current WAL made under the fsync cadence
    /// (0 under the flush-only default). Resets when a compaction swaps
    /// the log out.
    pub fn wal_syncs(&self) -> u64 {
        self.wal.syncs()
    }

    /// The graph mutated: everything persisted so far is dead, and future
    /// inserts belong to `fp`. Forces a compaction at the next
    /// opportunity (the live image is empty, so it is nearly free and
    /// shrinks the log to a header).
    pub fn record_invalidation(&mut self, fp: GraphFingerprint) -> io::Result<()> {
        self.fingerprint = fp;
        self.wal.append_invalidate(fp)?;
        self.records_since_snapshot += 1;
        self.force_compact = true;
        Ok(())
    }

    /// Whether the caller should hand over the live image for compaction.
    pub fn wants_compaction(&self) -> bool {
        self.force_compact || self.records_since_snapshot >= self.opts.snapshot_every
    }

    /// Write `entries` (the full live image, LRU-first) as the snapshot
    /// and reset the WAL to an empty log bound to the current fingerprint.
    /// Blocking form — fine at shutdown or offline; the live service uses
    /// [`Persistence::begin_compaction`] so the snapshot write happens
    /// outside its state lock.
    pub fn compact(&mut self, entries: &[(CanonKey, V)]) -> io::Result<()> {
        snapshot::write(&self.dir, self.fingerprint, entries)?;
        self.wal = wal::Wal::create(&self.dir, self.fingerprint, self.opts.fsync_every)?;
        self.records_since_snapshot = 0;
        self.force_compact = false;
        Ok(())
    }

    /// Cheap half of a compaction, safe to run under a contended lock:
    /// reset the WAL (subsequent records extend the post-image log) and
    /// hand the image back as a [`PendingSnapshot`] the caller writes
    /// **outside** the lock. A crash — or a failed write — between the
    /// two halves leaves a fresh WAL without its snapshot: recovery then
    /// restarts colder (the image existed only in memory), never wrong,
    /// per the subsystem's fingerprint invariant.
    pub fn begin_compaction(
        &mut self,
        entries: Vec<(CanonKey, V)>,
    ) -> io::Result<PendingSnapshot<V>> {
        self.wal = wal::Wal::create(&self.dir, self.fingerprint, self.opts.fsync_every)?;
        self.records_since_snapshot = 0;
        self.force_compact = false;
        Ok(PendingSnapshot {
            dir: self.dir.clone(),
            fingerprint: self.fingerprint,
            entries,
        })
    }
}

/// The deferred half of [`Persistence::begin_compaction`]: a store image
/// waiting to be written as the snapshot, with no lock requirements.
pub struct PendingSnapshot<V> {
    dir: PathBuf,
    fingerprint: GraphFingerprint,
    entries: Vec<(CanonKey, V)>,
}

impl<V: PersistValue> PendingSnapshot<V> {
    /// Atomically publish the image (stage + rename).
    pub fn write(self) -> io::Result<()> {
        snapshot::write(&self.dir, self.fingerprint, &self.entries)
    }
}

/// Offline view of a persist directory (the `store inspect` subcommand).
#[derive(Debug)]
pub struct DirInspection {
    /// `(fingerprint, entry count)` of a readable snapshot.
    pub snapshot: Option<(GraphFingerprint, usize)>,
    /// Snapshot file size in bytes, if present (even when unreadable).
    pub snapshot_bytes: Option<u64>,
    /// WAL file size in bytes, if present.
    pub wal_bytes: Option<u64>,
    /// WAL records that replay cleanly.
    pub wal_records: usize,
    /// A torn/corrupt WAL tail exists.
    pub wal_truncated: bool,
    /// Fingerprint of the final recovered image, if any state is usable.
    pub fingerprint: Option<GraphFingerprint>,
    /// Entries in the final recovered image.
    pub live_entries: usize,
}

/// Read-only recovery pass over `dir` — no file is modified.
pub fn inspect<V: PersistValue>(dir: &Path) -> DirInspection {
    let snap = snapshot::read::<V>(dir);
    let snapshot = snap.as_ref().map(|(fp, es)| (*fp, es.len()));
    let rep = wal::replay::<V>(dir, snap);
    DirInspection {
        snapshot,
        snapshot_bytes: std::fs::metadata(dir.join(snapshot::SNAPSHOT_FILE))
            .ok()
            .map(|m| m.len()),
        wal_bytes: std::fs::metadata(dir.join(wal::WAL_FILE)).ok().map(|m| m.len()),
        wal_records: rep.records,
        wal_truncated: rep.truncated,
        fingerprint: rep.fingerprint,
        live_entries: rep.entries.len(),
    }
}

/// Outcome of [`verify_dir`]: does a persist directory's recoverable
/// state describe a given graph?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DirVerify {
    /// The directory holds usable state AND its fingerprint equals the
    /// graph's — a service started over this graph with `--persist` on
    /// this directory would recover warm.
    pub matched: bool,
    /// Fingerprint of the recoverable image (`None`: no usable state).
    pub stored: Option<GraphFingerprint>,
    /// Entries that would be restored on a match.
    pub entries: usize,
}

/// Offline fingerprint check (the `store verify` subcommand): would a
/// service over a graph with fingerprint `fp` recover this directory's
/// state warm? Read-only — same recovery pass as [`inspect`], no file is
/// modified and no service is started.
pub fn verify_dir<V: PersistValue>(dir: &Path, fp: GraphFingerprint) -> DirVerify {
    let insp = inspect::<V>(dir);
    DirVerify {
        matched: insp.fingerprint == Some(fp),
        stored: insp.fingerprint,
        entries: insp.live_entries,
    }
}

/// Offline compaction (the `store compact` subcommand): recover whatever
/// image the directory holds — under **its own** recorded fingerprint, no
/// live graph required — and rewrite it as one snapshot plus an empty WAL.
/// Returns `(entries, wal records folded in)`, or an error when the
/// directory holds no usable state to bind a fingerprint to.
pub fn compact_dir<V: PersistValue>(dir: &Path) -> Result<(usize, usize)> {
    let _lock = DirLock::acquire(dir)?; // never rewrite under a live service
    let snap = snapshot::read::<V>(dir);
    let rep = wal::replay::<V>(dir, snap);
    let fp = rep.fingerprint.context(
        "no usable persisted state (missing or corrupt snapshot and WAL header) — nothing to compact",
    )?;
    snapshot::write(dir, fp, &rep.entries)?;
    wal::Wal::create(dir, fp, None)?;
    Ok((rep.entries.len(), rep.records))
}

/// Delete the persist files in `dir` (the `store purge` subcommand).
/// Only the files this subsystem writes are touched; returns how many
/// were removed.
pub fn purge_dir(dir: &Path) -> Result<usize> {
    if !dir.exists() {
        return Ok(0);
    }
    let _lock = DirLock::acquire(dir)?; // never delete under a live service
    let mut removed = 0;
    for name in [snapshot::SNAPSHOT_FILE, wal::WAL_FILE] {
        let p = dir.join(name);
        if p.exists() {
            std::fs::remove_file(&p).with_context(|| format!("removing {}", p.display()))?;
            removed += 1;
        }
    }
    // staging files are uniquely named (crashed compactions may leave
    // orphans): match them by prefix
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            if e.file_name().to_string_lossy().starts_with(snapshot::SNAPSHOT_TMP) {
                std::fs::remove_file(e.path())
                    .with_context(|| format!("removing {}", e.path().display()))?;
                removed += 1;
            }
        }
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;

    fn fp(seed: u64) -> GraphFingerprint {
        GraphFingerprint {
            order: 5,
            size: 6,
            hash: seed,
        }
    }

    fn key(i: usize) -> CanonKey {
        catalog::paper_pattern(i % 7 + 1).canonical_key()
    }

    fn dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mm_persist_mod_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn fresh_dir_opens_cold_then_recovers_warm() {
        let d = dir("fresh");
        let (mut p, warm, rep) =
            Persistence::<i128>::open(&d, fp(1), PersistOpts::default()).unwrap();
        assert!(warm.is_empty());
        assert!(!rep.fingerprint_matched || rep.restored == 0);
        p.record_insert(&key(1), &10).unwrap();
        p.record_insert(&key(2), &20).unwrap();
        drop(p);
        // same graph: warm
        let (_, warm, rep) = Persistence::<i128>::open(&d, fp(1), PersistOpts::default()).unwrap();
        assert!(rep.fingerprint_matched);
        assert_eq!(rep.restored, 2);
        assert_eq!(warm, vec![(key(1), 10), (key(2), 20)]);
        // different graph: structurally unservable, log restarted
        let (_, warm, rep) = Persistence::<i128>::open(&d, fp(9), PersistOpts::default()).unwrap();
        assert!(!rep.fingerprint_matched);
        assert!(warm.is_empty());
        // and the restart retargeted the dir to fp(9): fp(1) is gone now
        let (_, warm, _) = Persistence::<i128>::open(&d, fp(1), PersistOpts::default()).unwrap();
        assert!(warm.is_empty(), "retargeted log no longer serves the old graph");
    }

    #[test]
    fn invalidation_rebinds_and_forces_compaction() {
        let d = dir("invalidate");
        let (mut p, _, _) = Persistence::<i128>::open(&d, fp(1), PersistOpts::default()).unwrap();
        p.record_insert(&key(1), &1).unwrap();
        assert!(!p.wants_compaction());
        p.record_invalidation(fp(2)).unwrap();
        assert!(p.wants_compaction());
        p.record_insert(&key(2), &2).unwrap();
        p.compact(&[(key(2), 2)]).unwrap();
        assert!(!p.wants_compaction());
        drop(p);
        // entries recovered only under the post-mutation fingerprint
        let (_, warm, _) = Persistence::<i128>::open(&d, fp(2), PersistOpts::default()).unwrap();
        assert_eq!(warm, vec![(key(2), 2)]);
        let (_, warm, _) = Persistence::<i128>::open(&d, fp(1), PersistOpts::default()).unwrap();
        assert!(warm.is_empty());
    }

    #[test]
    fn compaction_cadence_counts_records() {
        let d = dir("cadence");
        let opts = PersistOpts {
            snapshot_every: 3,
            compact_on_drop: true,
            fsync_every: None,
        };
        let (mut p, _, _) = Persistence::<i128>::open(&d, fp(1), opts).unwrap();
        p.record_insert(&key(1), &1).unwrap();
        p.record_insert(&key(2), &2).unwrap();
        assert!(!p.wants_compaction());
        p.record_insert(&key(3), &3).unwrap();
        assert!(p.wants_compaction());
        p.compact(&[(key(1), 1), (key(2), 2), (key(3), 3)]).unwrap();
        drop(p);
        // replayed records count toward the next compaction: a reopened
        // log that was never compacted asks for one quickly
        let insp = inspect::<i128>(&d);
        assert_eq!(insp.live_entries, 3);
        assert_eq!(insp.wal_records, 0, "compaction reset the log");
        assert_eq!(insp.snapshot, Some((fp(1), 3)));
    }

    #[test]
    fn lock_excludes_live_writers_and_reclaims_stale() {
        let d = dir("lock");
        let (p, _, _) = Persistence::<i128>::open(&d, fp(1), PersistOpts::default()).unwrap();
        // a second live open must fail fast instead of sharing the WAL
        assert!(Persistence::<i128>::open(&d, fp(1), PersistOpts::default()).is_err());
        // offline rewrites are excluded the same way; read-only inspect is not
        assert!(compact_dir::<i128>(&d).is_err());
        assert!(purge_dir(&d).is_err());
        let _ = inspect::<i128>(&d);
        drop(p); // releases the lock
        // a lock file left by a dead process is reclaimed automatically
        std::fs::write(d.join(LOCK_FILE), "4294967294").unwrap();
        let (p, _, _) = Persistence::<i128>::open(&d, fp(1), PersistOpts::default()).unwrap();
        drop(p);
        assert!(!d.join(LOCK_FILE).exists(), "drop removes the lock");
    }

    #[test]
    fn split_compaction_halves_compose_and_fail_cold() {
        let d = dir("split");
        let (mut p, _, _) = Persistence::<i128>::open(&d, fp(1), PersistOpts::default()).unwrap();
        p.record_insert(&key(1), &1).unwrap();
        p.record_insert(&key(2), &2).unwrap();
        // begin resets the log immediately; the image is only durable
        // once the pending write lands
        let pending = p.begin_compaction(vec![(key(1), 1), (key(2), 2)]).unwrap();
        assert!(!p.wants_compaction());
        pending.write().unwrap();
        drop(p);
        let (_, warm, rep) = Persistence::<i128>::open(&d, fp(1), PersistOpts::default()).unwrap();
        assert_eq!(warm, vec![(key(1), 1), (key(2), 2)]);
        assert_eq!(rep.snapshot_entries, 2);
        assert_eq!(rep.wal_records, 0);
        // crash between the halves: begin without write loses the image
        // (it lived only in memory) but recovery stays clean — colder,
        // never wrong
        let d2 = dir("split_crash");
        let (mut p, _, _) = Persistence::<i128>::open(&d2, fp(1), PersistOpts::default()).unwrap();
        p.record_insert(&key(3), &3).unwrap();
        let pending = p.begin_compaction(vec![(key(3), 3)]).unwrap();
        drop(pending); // "crash" before the snapshot write
        drop(p);
        let (_, warm, _) = Persistence::<i128>::open(&d2, fp(1), PersistOpts::default()).unwrap();
        assert!(warm.is_empty(), "unwritten image is gone, not corrupt");
    }

    #[test]
    fn fsync_cadence_syncs_per_record_and_default_stays_flush_only() {
        // cadence 1: one sync_data per appended record (power-loss mode)
        let d = dir("fsync");
        let opts = PersistOpts {
            fsync_every: Some(1),
            ..PersistOpts::default()
        };
        let (mut p, _, _) = Persistence::<i128>::open(&d, fp(1), opts).unwrap();
        assert_eq!(p.wal_syncs(), 0);
        p.record_insert(&key(1), &1).unwrap();
        p.record_insert(&key(2), &2).unwrap();
        p.record_insert(&key(3), &3).unwrap();
        assert_eq!(p.wal_syncs(), 3, "cadence 1 must sync every record");
        drop(p);
        // cadence 2: sync on every second record
        let d2 = dir("fsync2");
        let opts2 = PersistOpts {
            fsync_every: Some(2),
            ..PersistOpts::default()
        };
        let (mut p, _, _) = Persistence::<i128>::open(&d2, fp(1), opts2).unwrap();
        for i in 0..5 {
            p.record_insert(&key(i + 1), &(i as i128)).unwrap();
        }
        assert_eq!(p.wal_syncs(), 2, "5 records at cadence 2 = 2 syncs");
        drop(p);
        // the default keeps today's flush-only behavior: zero syncs
        let d3 = dir("fsync_default");
        let (mut p, _, _) =
            Persistence::<i128>::open(&d3, fp(1), PersistOpts::default()).unwrap();
        p.record_insert(&key(1), &1).unwrap();
        p.record_insert(&key(2), &2).unwrap();
        assert_eq!(p.wal_syncs(), 0, "default must not sync");
        drop(p);
        // synced logs replay exactly like flushed ones
        let (_, warm, _) = Persistence::<i128>::open(&d, fp(1), opts).unwrap();
        assert_eq!(warm, vec![(key(1), 1), (key(2), 2), (key(3), 3)]);
    }

    #[test]
    fn verify_dir_checks_fingerprint_without_a_service() {
        let d = dir("verify");
        // empty / missing dir: nothing to match
        let v = verify_dir::<i128>(&d, fp(1));
        assert!(!v.matched);
        assert_eq!(v.stored, None);
        assert_eq!(v.entries, 0);
        let (mut p, _, _) = Persistence::<i128>::open(&d, fp(1), PersistOpts::default()).unwrap();
        p.record_insert(&key(1), &10).unwrap();
        p.record_insert(&key(2), &20).unwrap();
        drop(p);
        // right graph: matches, reporting what recovery would restore
        let v = verify_dir::<i128>(&d, fp(1));
        assert!(v.matched);
        assert_eq!(v.stored, Some(fp(1)));
        assert_eq!(v.entries, 2);
        // wrong graph: reports the stored identity, does not match
        let v = verify_dir::<i128>(&d, fp(9));
        assert!(!v.matched);
        assert_eq!(v.stored, Some(fp(1)));
        // read-only: verifying changed nothing
        assert_eq!(inspect::<i128>(&d).wal_records, 2);
    }

    #[test]
    fn inspect_compact_purge_offline() {
        let d = dir("offline");
        let (mut p, _, _) = Persistence::<i128>::open(&d, fp(4), PersistOpts::default()).unwrap();
        p.record_insert(&key(1), &7).unwrap();
        p.record_insert(&key(2), &8).unwrap();
        drop(p); // no compaction: WAL-only state
        let insp = inspect::<i128>(&d);
        assert_eq!(insp.wal_records, 2);
        assert_eq!(insp.live_entries, 2);
        assert_eq!(insp.fingerprint, Some(fp(4)));
        assert!(insp.snapshot.is_none());
        // offline compaction folds the log into a snapshot without a graph
        let (entries, folded) = compact_dir::<i128>(&d).unwrap();
        assert_eq!((entries, folded), (2, 2));
        let insp = inspect::<i128>(&d);
        assert_eq!(insp.snapshot, Some((fp(4), 2)));
        assert_eq!(insp.wal_records, 0);
        assert_eq!(insp.live_entries, 2, "image preserved across compaction");
        // purge removes exactly our files
        let removed = purge_dir(&d).unwrap();
        assert_eq!(removed, 2);
        let insp = inspect::<i128>(&d);
        assert_eq!(insp.live_entries, 0);
        assert!(compact_dir::<i128>(&d).is_err(), "nothing left to compact");
    }
}
