//! Batch query planner: intersects a batch's morphed base-pattern set
//! against the result store **before** executing, so only the missing
//! bases reach the matcher.
//!
//! The pipeline per batch:
//!
//! 1. Morph the query patterns into a [`MorphPlan`] under the configured
//!    policy — exactly the plan a cold execution would use.
//! 2. Probe the store for every base pattern (canonical key × epoch).
//!    Hits are spliced straight into the value map.
//! 3. Fuse-plan **only the missing subset**
//!    ([`FusedPlan::build_for_subset`] — the cached bases drop out of the
//!    plan trie entirely) and execute it in one traversal; singleton
//!    leftovers take a plain per-pattern sweep.
//! 4. Compose cached + fresh values through the morph expressions
//!    (Theorem 3.2) into per-query map counts.
//!
//! [`QueryPlanner::serve_batch`] runs the whole pipeline against one store
//! — a single-threaded reference implementation for tests and embedders
//! that don't need a request loop. The multi-worker [`super::Service`]
//! orchestrates the same [`QueryPlanner::morph`] /
//! [`QueryPlanner::execute_bases`] / [`QueryPlanner::compose`] steps
//! itself, because cross-batch in-flight coalescing splits the missing
//! set into owned and awaited halves between probe and execution — a
//! contract change here (probe semantics, store feeding, stats
//! accounting) must land in `serve.rs::process` too.

use super::store::ResultStore;
use crate::agg::CountAgg;
use crate::graph::{DataGraph, GraphStats, VertexId};
use crate::morph::{self, MorphPlan, Policy};
use crate::pattern::canon::CanonKey;
use crate::pattern::Pattern;
use crate::plan::cost::CostParams;
use crate::util::timer::PhaseProfile;
use std::collections::HashMap;

/// Per-batch reuse accounting. `total_bases` always equals
/// `cached + executed + coalesced`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchStats {
    /// Distinct base patterns the batch's morph plan references.
    pub total_bases: usize,
    /// Bases served from the result store.
    pub cached_bases: usize,
    /// Bases this batch matched itself.
    pub executed_bases: usize,
    /// Bases neither cached nor executed here: another in-flight batch was
    /// already computing them and this batch reused its result (only the
    /// multi-worker [`super::Service`] produces these).
    pub coalesced_bases: usize,
    /// Of `executed_bases`, how many were matched by shard workers
    /// ([`crate::shard`]) instead of in-process (only
    /// [`QueryPlanner::serve_batch_sharded`] produces these).
    pub remote_bases: usize,
}

/// Stateless batch planner (the store carries all cross-batch state).
#[derive(Clone, Copy, Debug)]
pub struct QueryPlanner {
    /// Morphing policy for incoming query sets.
    pub policy: Policy,
    /// Fuse multi-pattern executions into one traversal.
    pub fused: bool,
    /// Matcher threads per execution.
    pub threads: usize,
}

impl QueryPlanner {
    pub fn new(policy: Policy, fused: bool, threads: usize) -> QueryPlanner {
        QueryPlanner {
            policy,
            fused,
            threads,
        }
    }

    /// Morph a flattened batch of query patterns into one plan (base
    /// patterns deduplicated across the whole batch).
    ///
    /// Repeated queries are deduplicated **before** morphing: a batch of
    /// N identical (or merely isomorphic) texts runs the rewrite — and,
    /// under [`Policy::CostBased`], the optimizer — once, and every
    /// repeat shares the one expression. Isomorphic patterns have equal
    /// map counts, so sharing is exact; per-query automorphism conversion
    /// happens downstream against each query's own pattern.
    pub fn morph(&self, queries: &[Pattern], stats: &GraphStats) -> MorphPlan {
        let mut seen: HashMap<CanonKey, usize> = HashMap::new();
        let mut uniq: Vec<Pattern> = Vec::new();
        let mut slot: Vec<usize> = Vec::with_capacity(queries.len());
        for q in queries {
            let idx = *seen.entry(q.canonical_key()).or_insert_with(|| {
                uniq.push(q.clone());
                uniq.len() - 1
            });
            slot.push(idx);
        }
        let plan = morph::plan_queries(&uniq, self.policy, Some(stats), &CostParams::counting());
        if uniq.len() == queries.len() {
            return plan; // no repeats: slot is the identity
        }
        let exprs = slot.iter().map(|&i| plan.exprs[i].clone()).collect();
        MorphPlan {
            exprs,
            base: plan.base,
        }
    }

    /// The deduplicated base-pattern set the morph plan for `queries`
    /// executes over. Mutable embedders ([`crate::service::Service`], the
    /// sharded coordinator) record these in a `CanonKey → Pattern`
    /// registry so delta-morphing can resolve stored keys back to the
    /// patterns the delta pass needs — the store alone only knows keys.
    pub fn plan_bases(&self, queries: &[Pattern], stats: &GraphStats) -> Vec<Pattern> {
        self.morph(queries, stats).base
    }

    /// Execute the subset of `base` selected by `indices`: one fused
    /// traversal when two or more patterns are missing (the cached bases
    /// never enter the plan trie), a single sweep otherwise. Returns
    /// `(canonical key, map count)` pairs. The dispatch itself is the
    /// engine's ([`crate::morph::engine::match_base_subset`] — the same
    /// code path `morph::execute_opts` matches with), so the service can
    /// never drift from cold execution semantics.
    pub fn execute_bases(
        &self,
        graph: &DataGraph,
        base: &[Pattern],
        indices: &[usize],
        stats: &GraphStats,
        profile: &mut PhaseProfile,
    ) -> Vec<(CanonKey, i128)> {
        self.execute_bases_range(graph, base, indices, stats, profile, None)
    }

    /// [`QueryPlanner::execute_bases`] with the first exploration level
    /// restricted to `[lo, hi)` — the shard-worker entry point
    /// ([`crate::shard::ShardWorker`] matches its slice through this, so a
    /// shard can never drift from single-process execution semantics).
    /// `None` explores the whole graph.
    pub fn execute_bases_range(
        &self,
        graph: &DataGraph,
        base: &[Pattern],
        indices: &[usize],
        stats: &GraphStats,
        profile: &mut PhaseProfile,
        first_level: Option<(VertexId, VertexId)>,
    ) -> Vec<(CanonKey, i128)> {
        let mut opts = morph::ExecOpts::new(self.threads)
            .with_fused(self.fused)
            .with_stats(stats.clone());
        if let Some((lo, hi)) = first_level {
            opts = opts.with_first_level(lo, hi);
        }
        morph::engine::match_base_subset(graph, base, indices, &CountAgg, &opts, profile)
    }

    /// Evaluate every query's morph expression against the composed base
    /// values (cached + fresh), returning per-query **map counts** in
    /// input order.
    pub fn compose(
        &self,
        plan: &MorphPlan,
        values: &HashMap<CanonKey, i128>,
        profile: &mut PhaseProfile,
    ) -> Vec<i128> {
        plan.exprs
            .iter()
            .map(|e| profile.time("convert", || e.evaluate(&CountAgg, values)))
            .collect()
    }

    /// Serve one batch against `store`: probe, execute the missing bases,
    /// feed them back into the store, compose. This is the single-threaded
    /// pipeline; [`super::Service`] adds worker threads and cross-batch
    /// coalescing on top.
    pub fn serve_batch(
        &self,
        graph: &DataGraph,
        queries: &[Pattern],
        stats: &GraphStats,
        store: &mut ResultStore<i128>,
        epoch: u64,
        profile: &mut PhaseProfile,
    ) -> (Vec<i128>, BatchStats) {
        store.set_epoch(epoch);
        let plan = profile.time("plan", || self.morph(queries, stats));
        let mut values: HashMap<CanonKey, i128> = HashMap::new();
        let mut missing: Vec<usize> = Vec::new();
        profile.time("probe", || {
            for (i, p) in plan.base.iter().enumerate() {
                let k = p.canonical_key();
                match store.get(&k, epoch) {
                    Some(v) => {
                        values.insert(k, v);
                    }
                    None => missing.push(i),
                }
            }
        });
        crate::obs_counter!("mm_planner_batches_total").inc();
        crate::obs_counter!("mm_planner_cache_hits_total")
            .add((plan.base.len() - missing.len()) as u64);
        crate::obs_counter!("mm_planner_cache_misses_total").add(missing.len() as u64);
        let fresh = self.execute_bases(graph, &plan.base, &missing, stats, profile);
        for (k, v) in fresh {
            store.insert(k, epoch, v);
            values.insert(k, v);
        }
        let stats_out = BatchStats {
            total_bases: plan.base.len(),
            cached_bases: plan.base.len() - missing.len(),
            executed_bases: missing.len(),
            coalesced_bases: 0,
            remote_bases: 0,
        };
        (self.compose(&plan, &values, profile), stats_out)
    }

    /// [`QueryPlanner::serve_batch`] with the missing bases matched by a
    /// [`crate::shard::ShardPool`] instead of in-process: probe the store,
    /// fan the missing bases out across the pool's first-level slices, sum
    /// the per-shard partials (exact — each match roots at one first-level
    /// vertex), feed the totals back into the local store, compose.
    ///
    /// Worker failures do not fail the batch: the pool retries with
    /// backoff and re-fans a dead worker's sub-slices across survivors
    /// (all-slices-eventually), so this errors only when no live worker
    /// remains — merging a partial pool would silently undercount, so
    /// that terminal case still fails the whole batch loudly. The store
    /// is untouched by a failed batch, so a retry (or a local fallback
    /// via [`QueryPlanner::serve_batch`]) starts from the same state.
    ///
    /// Tracing rides through transparently: if the caller armed the pool
    /// with [`crate::shard::ShardPool::set_trace`], the `match` stage's
    /// fan-out carries the trace context in every EXEC and the pool
    /// collects the fabric's spans for the caller to drain — this method
    /// neither reads nor alters them, so traced and untraced batches
    /// compute identical results.
    #[allow(clippy::too_many_arguments)]
    pub fn serve_batch_sharded(
        &self,
        queries: &[Pattern],
        stats: &GraphStats,
        store: &mut ResultStore<i128>,
        epoch: u64,
        pool: &mut crate::shard::ShardPool,
        profile: &mut PhaseProfile,
    ) -> anyhow::Result<(Vec<i128>, BatchStats)> {
        store.set_epoch(epoch);
        let plan = profile.time("plan", || self.morph(queries, stats));
        let mut values: HashMap<CanonKey, i128> = HashMap::new();
        let mut missing: Vec<usize> = Vec::new();
        profile.time("probe", || {
            for (i, p) in plan.base.iter().enumerate() {
                let k = p.canonical_key();
                match store.get(&k, epoch) {
                    Some(v) => {
                        values.insert(k, v);
                    }
                    None => missing.push(i),
                }
            }
        });
        crate::obs_counter!("mm_planner_batches_total").inc();
        crate::obs_counter!("mm_planner_cache_hits_total")
            .add((plan.base.len() - missing.len()) as u64);
        crate::obs_counter!("mm_planner_cache_misses_total").add(missing.len() as u64);
        let fresh = profile.time("match", || pool.execute_bases(&plan.base, &missing, epoch))?;
        for (k, v) in fresh {
            store.insert(k, epoch, v);
            values.insert(k, v);
        }
        let stats_out = BatchStats {
            total_bases: plan.base.len(),
            cached_bases: plan.base.len() - missing.len(),
            executed_bases: missing.len(),
            coalesced_bases: 0,
            remote_bases: missing.len(),
        };
        Ok((self.compose(&plan, &values, profile), stats_out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;
    use crate::pattern::catalog;

    fn setup() -> (DataGraph, GraphStats) {
        let g = erdos_renyi(60, 220, 0x5EC1);
        let s = GraphStats::compute(&g, 2000, 0x5EC2);
        (g, s)
    }

    #[test]
    fn warm_batch_executes_zero_bases() {
        let (g, stats) = setup();
        let planner = QueryPlanner::new(Policy::Naive, true, 2);
        let mut store = ResultStore::new(1 << 20);
        let mut prof = PhaseProfile::new();
        let queries = catalog::motifs_vertex_induced(4);
        let (cold, s1) = planner.serve_batch(&g, &queries, &stats, &mut store, 0, &mut prof);
        assert_eq!(s1.cached_bases, 0);
        assert!(s1.executed_bases > 0);
        let (warm, s2) = planner.serve_batch(&g, &queries, &stats, &mut store, 0, &mut prof);
        assert_eq!(cold, warm);
        assert_eq!(s2.executed_bases, 0, "warm batch must be fully cached");
        assert_eq!(s2.cached_bases, s1.total_bases);
        assert!(store.metrics().hits as usize >= s1.total_bases);
    }

    #[test]
    fn partial_overlap_executes_only_missing() {
        let (g, stats) = setup();
        let planner = QueryPlanner::new(Policy::Naive, true, 2);
        let mut store = ResultStore::new(1 << 20);
        let mut prof = PhaseProfile::new();
        // C4^E morphs into {C4^V, diamond^V, K4} under Naive PMR
        let (_, s1) =
            planner.serve_batch(&g, &[catalog::cycle(4)], &stats, &mut store, 0, &mut prof);
        assert_eq!(s1.executed_bases, s1.total_bases);
        // the tailed triangle's alternative set shares bases with C4^E's
        let (_, s2) = planner.serve_batch(
            &g,
            &[catalog::cycle(4), catalog::tailed_triangle()],
            &stats,
            &mut store,
            0,
            &mut prof,
        );
        assert!(s2.cached_bases >= s1.total_bases, "C4 bases all reused: {s2:?}");
        assert!(s2.executed_bases > 0, "tailed-triangle bases are new");
        assert!(s2.executed_bases < s2.total_bases);
    }

    #[test]
    fn planner_matches_direct_engine() {
        let (g, stats) = setup();
        let mut prof = PhaseProfile::new();
        let queries = vec![
            catalog::cycle(4),
            catalog::cycle(4).vertex_induced(),
            catalog::diamond().vertex_induced(),
        ];
        for policy in [Policy::Off, Policy::Naive, Policy::CostBased] {
            let planner = QueryPlanner::new(policy, true, 2);
            let mut store = ResultStore::new(1 << 20);
            let (vals, _) = planner.serve_batch(&g, &queries, &stats, &mut store, 0, &mut prof);
            let direct = morph::engine::count_queries(&g, &queries, policy, 2);
            for ((v, q), d) in vals.iter().zip(&queries).zip(&direct) {
                let aut = crate::pattern::iso::automorphisms(q).len() as i128;
                assert_eq!(v % aut, 0, "{policy:?} {q:?}");
                assert_eq!((v / aut) as u64, *d, "{policy:?} {q:?}");
            }
        }
    }

    #[test]
    fn repeated_queries_plan_each_base_once() {
        // satellite: a batch of N identical query texts must morph/plan
        // exactly like one copy — same base set, one shared expression —
        // and answer every repeat identically
        let (g, stats) = setup();
        for policy in [Policy::Off, Policy::Naive, Policy::CostBased] {
            let planner = QueryPlanner::new(policy, true, 2);
            let single = planner.morph(&[catalog::cycle(4)], &stats);
            let repeats: Vec<Pattern> = vec![catalog::cycle(4); 6];
            let plan = planner.morph(&repeats, &stats);
            assert_eq!(plan.exprs.len(), 6, "one expression per admitted query");
            assert_eq!(
                plan.base.len(),
                single.base.len(),
                "{policy:?}: repeats must not add bases"
            );
            let mut store = ResultStore::new(1 << 20);
            let mut prof = PhaseProfile::new();
            let (vals, s) = planner.serve_batch(&g, &repeats, &stats, &mut store, 0, &mut prof);
            assert_eq!(vals.len(), 6);
            assert!(vals.windows(2).all(|w| w[0] == w[1]), "{policy:?}: {vals:?}");
            assert_eq!(s.total_bases, single.base.len());
            // single-copy answer agrees
            let mut store2 = ResultStore::new(1 << 20);
            let (one, _) =
                planner.serve_batch(&g, &[catalog::cycle(4)], &stats, &mut store2, 0, &mut prof);
            assert_eq!(vals[0], one[0], "{policy:?}");
        }
        // isomorphic-but-relabeled repeats collapse too
        let planner = QueryPlanner::new(Policy::Naive, true, 2);
        let p = catalog::path(4);
        let q = p.permuted(&[3, 1, 0, 2]);
        let plan = planner.morph(&[p.clone(), q], &stats);
        assert_eq!(plan.exprs.len(), 2);
        assert_eq!(
            plan.base.len(),
            planner.morph(&[p], &stats).base.len(),
            "isomorphic repeats share one rewrite"
        );
    }

    #[test]
    fn epoch_change_forces_reexecution() {
        let (g, stats) = setup();
        let planner = QueryPlanner::new(Policy::Naive, true, 1);
        let mut store = ResultStore::new(1 << 20);
        let mut prof = PhaseProfile::new();
        let queries = [catalog::triangle()];
        let (_, s1) = planner.serve_batch(&g, &queries, &stats, &mut store, 0, &mut prof);
        assert!(s1.executed_bases > 0);
        let (_, s2) = planner.serve_batch(&g, &queries, &stats, &mut store, 1, &mut prof);
        assert_eq!(
            s2.executed_bases, s2.total_bases,
            "new epoch must invalidate every cached base"
        );
        assert!(store.metrics().invalidations > 0);
    }
}
