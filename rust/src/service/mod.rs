//! SERVICE LAYER — morph-aware result cache + batched query service.
//!
//! Everything below [`crate::coordinator`] mines from scratch on every
//! call; this layer sits between query admission and execution and makes
//! the morph algebra a **cross-query cache**. The observation: morph plans
//! address their base patterns by canonical key, so a base matched for one
//! query set answers *any* later query whose rewrite references the same
//! canonical pattern — repeated and overlapping batches (the ROADMAP's
//! heavy-traffic scenario) pay only for the bases nobody has asked for
//! yet.
//!
//! * [`store`] — [`ResultStore`]: per-base-pattern values keyed by
//!   canonical key × graph epoch, LRU + byte-budget eviction,
//!   hit/miss/bytes metrics.
//! * [`planner`] — [`QueryPlanner`]: morphs a batch, probes the store,
//!   fuse-executes **only the missing bases**
//!   ([`crate::plan::fused::FusedPlan::build_for_subset`]), and composes
//!   cached + fresh values through the morph expressions.
//! * [`serve`] — [`Service`]: a multi-threaded request loop (mpsc channel
//!   workers) that admits batches of query texts, coalesces duplicate
//!   in-flight base patterns across concurrent batches, and wires epoch
//!   invalidation to [`crate::graph::DynGraph::insert_edge`] /
//!   [`remove_edge`](crate::graph::DynGraph::remove_edge) so incremental
//!   updates bump the epoch instead of silently serving stale counts.
//!
//! * [`delta`] — delta-morphing: an applied edge update computes per-base
//!   count *deltas* from the updated edge's neighborhood and patches
//!   cached values in place under the epoch bump; bases outside the
//!   proven fragment fall back to a counted, explicit purge. The store
//!   behaves as a maintained materialized view, not a cache that
//!   restarts cold on every write.
//!
//! * [`persist`] — durable result store: a CRC-framed write-ahead log of
//!   store inserts/invalidations plus periodic snapshot compaction, keyed
//!   by a [`crate::graph::GraphFingerprint`] so a restarted `serve`
//!   recovers warm exactly when the live graph matches what was persisted
//!   — and degrades to cold (never to stale counts) otherwise.
//!
//! CLI: `morphmine batch` (one-shot batches, `--repeat` for warm-cache
//! runs), `morphmine serve` (interactive loop with `+ u v` / `- u v`
//! edge updates) — both take `--persist <dir>` and `--shards <addr,…>`
//! ([`crate::shard`]) — and `morphmine store` (offline
//! `inspect`/`compact`/`purge`/`verify` of a persist directory).
//! Benchmarks: A8 `bench --exp service` (cold / warm / overlapping-batch
//! throughput → `BENCH_service.json`) and A9 `bench --exp persist`
//! (cold vs warm-restart vs replay-heavy recovery → `BENCH_persist.json`).
//!
//! The single-threaded pipeline, end to end — a second identical batch
//! executes **zero** bases:
//!
//! ```
//! use morphmine::graph::generators::erdos_renyi;
//! use morphmine::graph::GraphStats;
//! use morphmine::morph::Policy;
//! use morphmine::pattern::catalog;
//! use morphmine::service::{QueryPlanner, ResultStore};
//! use morphmine::util::timer::PhaseProfile;
//!
//! let g = erdos_renyi(50, 180, 7);
//! let stats = GraphStats::compute(&g, 2000, 7);
//! let planner = QueryPlanner::new(Policy::Naive, true, 2);
//! let mut store = ResultStore::new(1 << 20);
//! let mut prof = PhaseProfile::new();
//!
//! let queries = catalog::motifs_vertex_induced(3); // wedge + triangle, V/I
//! let (cold, s1) = planner.serve_batch(&g, &queries, &stats, &mut store, 0, &mut prof);
//! assert!(s1.executed_bases > 0, "first batch matches its bases");
//! let (warm, s2) = planner.serve_batch(&g, &queries, &stats, &mut store, 0, &mut prof);
//! assert_eq!(cold, warm, "the cache never changes answers");
//! assert_eq!(s2.executed_bases, 0, "second batch is fully cache-served");
//! ```

pub mod delta;
pub mod persist;
pub mod planner;
pub mod serve;
pub mod store;

pub use delta::{edge_update_deltas, DeltaOutcome, DeltaReport, DEFAULT_DELTA_BUDGET};
pub use persist::{PersistConfig, PersistOpts, RecoveryReport};
pub use planner::{BatchStats, QueryPlanner};
pub use serve::{BatchResponse, QueryResult, Service, ServiceConfig, ServiceQuery};
pub use store::{CacheWeight, PersistValue, ResultStore, StoreMetrics};
