//! Pattern-set generation: all connected patterns of a given size (motif
//! sets) and the **non-isomorphic superpattern lattice** `q ⊃n p` that
//! drives the Match Conversion Theorem.

use super::canon::CanonKey;
use super::Pattern;
use std::collections::HashMap;

/// All connected unlabeled edge-induced patterns on `n` vertices, deduped up
/// to isomorphism. (3 → 2 patterns, 4 → 6, 5 → 21, 6 → 112.)
///
/// Enumerates the `2^C(n,2)` edge masks and dedupes by canonical key, so it
/// is intended for `n ≤ 6` (the paper's motif sizes are 3–5).
pub fn connected_patterns(n: usize) -> Vec<Pattern> {
    assert!((2..=6).contains(&n), "connected_patterns supports 2..=6, got {n}");
    let pairs: Vec<(usize, usize)> = (0..n)
        .flat_map(|u| ((u + 1)..n).map(move |v| (u, v)))
        .collect();
    let mut seen: HashMap<CanonKey, ()> = HashMap::new();
    let mut out = Vec::new();
    for mask in 0u32..(1u32 << pairs.len()) {
        if (mask.count_ones() as usize) < n - 1 {
            continue; // connectivity needs ≥ n-1 edges
        }
        let mut p = Pattern::empty(n);
        for (i, &(u, v)) in pairs.iter().enumerate() {
            if (mask >> i) & 1 == 1 {
                p.add_edge(u, v);
            }
        }
        if !p.is_connected() {
            continue;
        }
        let key = p.canonical_key();
        if seen.insert(key, ()).is_none() {
            out.push(super::canon::canonical_form(&p));
        }
    }
    // deterministic order: by edge count, then canonical key
    out.sort_by_key(|p| (p.num_edges(), p.canonical_key()));
    out
}

/// All **strict** non-isomorphic superpatterns of `p` on the same vertex
/// set (`q ⊃n p` in the paper): every edge-superset of `E(p)` up to the
/// clique, deduped up to isomorphism. Anti-edges of `p` are ignored — the
/// lattice is defined over the edge-induced skeleton. Labels (if any) are
/// preserved on the fixed vertex set and participate in the isomorphism
/// dedup.
pub fn superpatterns(p: &Pattern) -> Vec<Pattern> {
    let base = p.edge_induced();
    let n = base.num_vertices();
    let pairs: Vec<(usize, usize)> = (0..n)
        .flat_map(|u| ((u + 1)..n).map(move |v| (u, v)))
        .filter(|&(u, v)| !base.has_edge(u, v))
        .collect();
    let mut seen: HashMap<CanonKey, ()> = HashMap::new();
    seen.insert(base.canonical_key(), ());
    let mut out = Vec::new();
    // pairs.len() ≤ C(8,2)=28, but realistic patterns have few open pairs;
    // enumerate all non-empty subsets of added edges.
    let total = 1u32 << pairs.len();
    for mask in 1..total {
        let mut q = base.clone();
        for (i, &(u, v)) in pairs.iter().enumerate() {
            if (mask >> i) & 1 == 1 {
                q.add_edge(u, v);
            }
        }
        let key = q.canonical_key();
        if seen.insert(key, ()).is_none() {
            out.push(q);
        }
    }
    out.sort_by_key(|q| (q.num_edges(), q.canonical_key()));
    out
}

/// Memoized superpattern lattice, used heavily by the morphing engine.
#[derive(Default)]
pub struct SuperpatternCache {
    cache: HashMap<CanonKey, Vec<Pattern>>,
}

impl SuperpatternCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&mut self, p: &Pattern) -> &[Pattern] {
        let key = p.edge_induced().canonical_key();
        self.cache
            .entry(key)
            .or_insert_with(|| superpatterns(p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;

    #[test]
    fn motif_counts_match_oeis() {
        // numbers of connected graphs on n nodes: 1, 2, 6, 21, 112
        assert_eq!(connected_patterns(2).len(), 1);
        assert_eq!(connected_patterns(3).len(), 2);
        assert_eq!(connected_patterns(4).len(), 6);
        assert_eq!(connected_patterns(5).len(), 21);
        assert_eq!(connected_patterns(6).len(), 112);
    }

    #[test]
    fn generated_patterns_are_edge_induced_and_connected() {
        for p in connected_patterns(4) {
            assert!(p.is_connected());
            assert!(p.is_edge_induced());
            assert_eq!(p.num_vertices(), 4);
        }
    }

    #[test]
    fn superpatterns_of_cycle4() {
        // C4 + {1 chord} = diamond; + {2 chords} = K4 → exactly 2
        let sups = superpatterns(&catalog::cycle(4));
        assert_eq!(sups.len(), 2);
        assert_eq!(sups[0].num_edges(), 5); // diamond
        assert_eq!(sups[1].num_edges(), 6); // K4
    }

    #[test]
    fn superpatterns_of_clique_empty() {
        assert!(superpatterns(&catalog::clique(4)).is_empty());
        assert!(superpatterns(&catalog::clique(5)).is_empty());
    }

    #[test]
    fn superpatterns_of_tailed_triangle() {
        // tailed triangle (4v, 4e) → diamond (5e), K4 (6e); adding the one
        // of the two open pairs gives diamond either way (iso), both gives K4
        let sups = superpatterns(&catalog::tailed_triangle());
        assert_eq!(sups.len(), 2);
    }

    #[test]
    fn superpatterns_ignore_anti_edges() {
        let c4v = catalog::cycle(4).vertex_induced();
        let sups = superpatterns(&c4v);
        assert_eq!(sups.len(), 2);
        assert!(sups.iter().all(|q| q.is_edge_induced()));
    }

    #[test]
    fn superpatterns_of_path3() {
        // path 0-1-2 → triangle only
        let sups = superpatterns(&catalog::path(3));
        assert_eq!(sups.len(), 1);
        assert!(sups[0].is_clique());
    }

    #[test]
    fn labeled_superpatterns_keep_labels() {
        let p = catalog::path(3).with_labels(&[1, 2, 3]);
        let sups = superpatterns(&p);
        assert_eq!(sups.len(), 1);
        assert_eq!(sups[0].labels_vec(), Some(vec![1, 2, 3]));
    }

    #[test]
    fn labeled_dedup_distinguishes_labelings() {
        // path 0-1-2 labels (1,1,2): adding edge 0-2 gives triangle(1,1,2);
        // with labels (1,2,1) → triangle(1,2,1) ≅ triangle(1,1,2). Only one
        // superpattern each, but they are isomorphic across the two bases.
        let a = superpatterns(&catalog::path(3).with_labels(&[1, 1, 2]));
        let b = superpatterns(&catalog::path(3).with_labels(&[1, 2, 1]));
        assert_eq!(a.len(), 1);
        assert_eq!(b.len(), 1);
        assert_eq!(a[0].canonical_key(), b[0].canonical_key());
    }

    #[test]
    fn cache_returns_same() {
        let mut c = SuperpatternCache::new();
        let p = catalog::cycle(4);
        let a = c.get(&p).to_vec();
        let b = c.get(&p).to_vec();
        assert_eq!(a.len(), b.len());
    }
}
