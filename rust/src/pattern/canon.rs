//! Pattern canonicalization.
//!
//! Two patterns are isomorphic (same edges, anti-edges and labels up to
//! vertex renaming) iff their canonical keys are equal. Patterns have ≤ 8
//! vertices, so we canonicalize by exact minimization over vertex
//! permutations, pruned by vertex invariants (degree, anti-degree, label):
//! only permutations mapping vertices to same-invariant vertices are
//! considered.

use super::{Pattern, MAX_PATTERN_VERTICES};

/// Canonical key: `(n, packed pair codes, packed labels)`.
///
/// Pair `(u,v)`, `u<v`, contributes 2 bits: `01` edge, `10` anti-edge,
/// `00` none. With n ≤ 8 there are ≤ 28 pairs → 56 bits; labels are hashed
/// into a second word.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct CanonKey {
    pub n: u8,
    pub pairs: u64,
    pub labels: u64,
}

/// Encode a pattern under the identity permutation.
fn encode(p: &Pattern, perm: &[usize]) -> (u64, u64) {
    let n = p.num_vertices();
    let mut pairs = 0u64;
    let mut idx = 0;
    for u in 0..n {
        for v in (u + 1)..n {
            let code = if p.has_edge(perm[u], perm[v]) {
                1u64
            } else if p.has_anti_edge(perm[u], perm[v]) {
                2u64
            } else {
                0u64
            };
            pairs |= code << (2 * idx);
            idx += 1;
        }
    }
    let mut labels = 0u64;
    if p.is_labeled() {
        for v in 0..n {
            // labels are small in practice (≤ 38 in the paper's datasets);
            // 8 bits each is enough for patterns; larger labels fold.
            labels |= ((p.label(perm[v]) as u64) & 0xFF) << (8 * v);
        }
    }
    (pairs, labels)
}

/// Vertex invariant used to prune the permutation search.
fn invariant(p: &Pattern, v: usize) -> u64 {
    ((p.degree(v) as u64) << 40) | ((p.anti(v).len() as u64) << 32) | p.label(v) as u64
}

/// Compute the canonical key of a pattern (exact, invariant-pruned search).
pub fn canonical_key(p: &Pattern) -> CanonKey {
    let n = p.num_vertices();
    let invs: Vec<u64> = (0..n).map(|v| invariant(p, v)).collect();

    let mut best: Option<(u64, u64)> = None;
    let mut perm = [0usize; MAX_PATTERN_VERTICES];
    let mut used = [false; MAX_PATTERN_VERTICES];

    // Backtracking over permutations: position i gets vertex cand only if
    // its invariant class matches the smallest available ordering — we
    // enumerate all, pruning only by invariant multiset equality implicitly
    // (all permutations of same-invariant vertices are tried).
    fn rec(
        p: &Pattern,
        invs: &[u64],
        pos: usize,
        perm: &mut [usize; MAX_PATTERN_VERTICES],
        used: &mut [bool; MAX_PATTERN_VERTICES],
        best: &mut Option<(u64, u64)>,
    ) {
        let n = p.num_vertices();
        if pos == n {
            let code = encode(p, &perm[..n]);
            if best.is_none() || code < best.unwrap() {
                *best = Some(code);
            }
            return;
        }
        // order candidates by invariant so the search tends to hit the
        // minimum early (pure heuristic; correctness is exhaustiveness)
        let mut cands: Vec<usize> = (0..n).filter(|&v| !used[v]).collect();
        cands.sort_by_key(|&v| invs[v]);
        for v in cands {
            perm[pos] = v;
            used[v] = true;
            rec(p, invs, pos + 1, perm, used, best);
            used[v] = false;
        }
    }

    rec(p, &invs, 0, &mut perm, &mut used, &mut best);
    let (pairs, labels) = best.unwrap();
    CanonKey {
        n: n as u8,
        pairs,
        labels,
    }
}

/// Are two patterns isomorphic (edges + anti-edges + labels)?
pub fn isomorphic(p: &Pattern, q: &Pattern) -> bool {
    p.num_vertices() == q.num_vertices()
        && p.num_edges() == q.num_edges()
        && p.num_anti_edges() == q.num_anti_edges()
        && p.canonical_key() == q.canonical_key()
}

/// Return the canonical representative (a relabeled copy realizing the key).
pub fn canonical_form(p: &Pattern) -> Pattern {
    canonical_form_with_iso(p).0
}

/// Canonical representative together with the isomorphism
/// `σ : V(p) → V(canon)` (as a vertex map: `σ[v]` = canonical vertex for
/// `p`'s vertex `v`). Needed by the morphing algebra to re-express
/// pattern-to-pattern maps against canonical representatives.
pub fn canonical_form_with_iso(p: &Pattern) -> (Pattern, Vec<usize>) {
    let n = p.num_vertices();
    let target = canonical_key(p);
    // find a permutation realizing the key (re-run the search, stop at match)
    let mut perm_out: Option<Vec<usize>> = None;
    let mut perm = vec![0usize; n];
    let mut used = vec![false; n];
    fn rec(
        p: &Pattern,
        target: &CanonKey,
        pos: usize,
        perm: &mut Vec<usize>,
        used: &mut Vec<bool>,
        out: &mut Option<Vec<usize>>,
    ) {
        if out.is_some() {
            return;
        }
        let n = p.num_vertices();
        if pos == n {
            let (pairs, labels) = encode(p, perm);
            if pairs == target.pairs && labels == target.labels {
                *out = Some(perm.clone());
            }
            return;
        }
        for v in 0..n {
            if !used[v] {
                perm[pos] = v;
                used[v] = true;
                rec(p, target, pos + 1, perm, used, out);
                used[v] = false;
            }
        }
    }
    rec(p, &target, 0, &mut perm, &mut used, &mut perm_out);
    let perm = perm_out.expect("canonical permutation must exist");
    // canon vertex v corresponds to p vertex perm[v] ⇒ σ = perm⁻¹
    let mut sigma = vec![0usize; n];
    for (v, &pv) in perm.iter().enumerate() {
        sigma[pv] = v;
    }
    (p.permuted(&perm), sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    fn path4_a() -> Pattern {
        Pattern::from_edges(4, &[(0, 1), (1, 2), (2, 3)])
    }

    fn path4_b() -> Pattern {
        Pattern::from_edges(4, &[(2, 0), (0, 3), (3, 1)])
    }

    #[test]
    fn isomorphic_paths() {
        assert!(isomorphic(&path4_a(), &path4_b()));
        assert_eq!(path4_a().canonical_key(), path4_b().canonical_key());
    }

    #[test]
    fn non_isomorphic_distinguished() {
        let star = Pattern::from_edges(4, &[(0, 1), (0, 2), (0, 3)]);
        assert!(!isomorphic(&path4_a(), &star));
    }

    #[test]
    fn anti_edges_matter() {
        let e = Pattern::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let v = e.vertex_induced();
        assert!(!isomorphic(&e, &v));
    }

    #[test]
    fn labels_matter() {
        let a = Pattern::from_edges(2, &[(0, 1)]).with_labels(&[1, 2]);
        let b = Pattern::from_edges(2, &[(0, 1)]).with_labels(&[2, 1]);
        let c = Pattern::from_edges(2, &[(0, 1)]).with_labels(&[1, 1]);
        assert!(isomorphic(&a, &b), "label-swapped edge is isomorphic");
        assert!(!isomorphic(&a, &c));
    }

    #[test]
    fn canonical_form_is_isomorphic_and_stable() {
        let p = path4_b().vertex_induced();
        let c = canonical_form(&p);
        assert!(isomorphic(&p, &c));
        assert_eq!(c.canonical_key(), p.canonical_key());
        // idempotent
        assert_eq!(canonical_form(&c), c);
    }

    /// Property: canonical key is invariant under random permutation.
    #[test]
    fn prop_key_permutation_invariant() {
        proptest::check(0xC0DE, 60, |rng: &mut Rng| {
            let p = random_pattern(rng);
            let perm = rng.permutation(p.num_vertices());
            let q = p.permuted(&perm);
            assert_eq!(
                p.canonical_key(),
                q.canonical_key(),
                "p={p:?} q={q:?} perm={perm:?}"
            );
        });
    }

    /// Random pattern generator shared by canon/iso property tests.
    pub(crate) fn random_pattern(rng: &mut Rng) -> Pattern {
        let n = 2 + rng.below_usize(5); // 2..=6
        let mut p = Pattern::empty(n);
        for u in 0..n {
            for v in (u + 1)..n {
                let r = rng.f64();
                if r < 0.45 {
                    p.add_edge(u, v);
                } else if r < 0.65 {
                    p.add_anti_edge(u, v);
                }
            }
        }
        if rng.chance(0.4) {
            let labels: Vec<u32> = (0..n).map(|_| rng.below(3) as u32).collect();
            p = p.with_labels(&labels);
        }
        p
    }
}
