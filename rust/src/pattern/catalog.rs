//! Named patterns used throughout the paper and its evaluation (Fig. 7).
//!
//! The paper's Figure 7 lists evaluation patterns `p1..p7`; the figure
//! artwork is not machine-readable, so the mapping below is reconstructed
//! from the surrounding text and tables (Table 1 names the 4-cycle, chordal
//! 4-cycle and 5-cycle; Fig. 6 names `p1` = tailed triangle, `p2` = 4-cycle,
//! `p3` = chordal 4-cycle, `p4` = 4-clique; Table 4's alternative sets are
//! consistent with this mapping). `p5`/`p6` are 5-vertex patterns chosen as
//! the house and gem — representative sparse/dense 5-vertex queries with
//! non-trivial superpattern lattices; see DESIGN.md §5.

use super::Pattern;

/// Path on `n` vertices (`n-1` edges): `0-1-…-(n-1)`.
pub fn path(n: usize) -> Pattern {
    Pattern::from_edges(n, &(0..n - 1).map(|i| (i, i + 1)).collect::<Vec<_>>())
}

/// Cycle on `n` vertices.
pub fn cycle(n: usize) -> Pattern {
    let mut es: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
    es.push((n - 1, 0));
    Pattern::from_edges(n, &es)
}

/// Clique on `n` vertices.
pub fn clique(n: usize) -> Pattern {
    let es: Vec<(usize, usize)> = (0..n)
        .flat_map(|u| ((u + 1)..n).map(move |v| (u, v)))
        .collect();
    Pattern::from_edges(n, &es)
}

/// Star on `n` vertices: center `0`, leaves `1..n`.
pub fn star(n: usize) -> Pattern {
    Pattern::from_edges(n, &(1..n).map(|v| (0, v)).collect::<Vec<_>>())
}

/// Triangle (3-clique).
pub fn triangle() -> Pattern {
    clique(3)
}

/// Tailed triangle: triangle `0-1-2` with pendant `3` attached to `2`.
pub fn tailed_triangle() -> Pattern {
    Pattern::from_edges(4, &[(0, 1), (1, 2), (2, 0), (2, 3)])
}

/// Chordal 4-cycle (diamond): 4-cycle `0-1-2-3` plus chord `0-2`.
pub fn diamond() -> Pattern {
    Pattern::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)])
}

/// House: square `0-1-2-3` with roof apex `4` on edge `0-1`.
pub fn house() -> Pattern {
    Pattern::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 0), (0, 4), (1, 4)])
}

/// Gem: path `0-1-2-3` plus apex `4` adjacent to all path vertices.
pub fn gem() -> Pattern {
    Pattern::from_edges(5, &[(0, 1), (1, 2), (2, 3), (0, 4), (1, 4), (2, 4), (3, 4)])
}

/// The paper's evaluation pattern `p<i>` (edge-induced form; apply
/// [`Pattern::vertex_induced`] for the `p^V` variants).
pub fn paper_pattern(i: usize) -> Pattern {
    match i {
        1 => tailed_triangle(),
        2 => cycle(4),
        3 => diamond(),
        4 => clique(4),
        5 => house(),
        6 => gem(),
        7 => cycle(5),
        _ => panic!("paper patterns are p1..p7, got p{i}"),
    }
}

/// The motif set of size `n`: all connected unlabeled patterns, in the
/// vertex-induced form used by motif counting.
pub fn motifs_vertex_induced(n: usize) -> Vec<Pattern> {
    super::gen::connected_patterns(n)
        .into_iter()
        .map(|p| p.vertex_induced())
        .collect()
}

/// Look up a pattern by name (CLI convenience).
pub fn by_name(name: &str) -> Option<Pattern> {
    let (base, induced) = match name.strip_suffix("-vi") {
        Some(b) => (b, true),
        None => (name, false),
    };
    let p = match base {
        "triangle" | "k3" => triangle(),
        "wedge" | "path3" => path(3),
        "path4" => path(4),
        "star4" | "claw" => star(4),
        "cycle4" | "c4" => cycle(4),
        "diamond" | "chordal4" => diamond(),
        "tailed-triangle" | "tailed" => tailed_triangle(),
        "clique4" | "k4" => clique(4),
        "cycle5" | "c5" => cycle(5),
        "house" => house(),
        "gem" => gem(),
        "clique5" | "k5" => clique(5),
        _ => {
            if let Some(num) = base.strip_prefix('p') {
                let i: usize = num.parse().ok()?;
                if (1..=7).contains(&i) {
                    paper_pattern(i)
                } else {
                    return None;
                }
            } else {
                return None;
            }
        }
    };
    Some(if induced { p.vertex_induced() } else { p })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        assert_eq!(path(4).num_edges(), 3);
        assert_eq!(cycle(5).num_edges(), 5);
        assert_eq!(clique(5).num_edges(), 10);
        assert_eq!(star(4).num_edges(), 3);
        assert_eq!(tailed_triangle().num_edges(), 4);
        assert_eq!(diamond().num_edges(), 5);
        assert_eq!(house().num_edges(), 6);
        assert_eq!(gem().num_edges(), 7);
    }

    #[test]
    fn all_connected() {
        for i in 1..=7 {
            assert!(paper_pattern(i).is_connected(), "p{i}");
        }
    }

    #[test]
    fn motif_sets() {
        assert_eq!(motifs_vertex_induced(3).len(), 2);
        assert_eq!(motifs_vertex_induced(4).len(), 6);
        assert_eq!(motifs_vertex_induced(5).len(), 21);
        for m in motifs_vertex_induced(4) {
            assert!(m.is_vertex_induced());
        }
    }

    #[test]
    fn by_name_variants() {
        assert!(by_name("cycle4").unwrap().is_edge_induced());
        assert!(by_name("cycle4-vi").unwrap().is_vertex_induced());
        assert_eq!(
            by_name("p2").unwrap().canonical_key(),
            cycle(4).canonical_key()
        );
        assert!(by_name("nonsense").is_none());
        assert!(by_name("p9").is_none());
    }

    #[test]
    fn diamond_is_chordal_cycle() {
        // diamond contains C4 as subpattern
        assert!(crate::pattern::iso::is_subpattern(&cycle(4), &diamond()));
    }
}
