//! Pattern algebra: the query-side graph representation.
//!
//! A *pattern* (paper §2) is a small simple connected graph with optional
//! vertex labels and optional **anti-edges** — pairs of vertices that must
//! *not* be adjacent in a match. Anti-edges encode vertex-induced semantics:
//!
//! * an **edge-induced** pattern `p^E` has no anti-edges;
//! * a **vertex-induced** pattern `p^V` has anti-edges between every
//!   non-adjacent vertex pair;
//! * cliques are simultaneously both.
//!
//! Patterns are tiny (≤ [`MAX_PATTERN_VERTICES`] vertices) so adjacency is
//! stored as per-vertex [`SmallSet`] bit masks and all pattern-level
//! algorithms (canonicalization, isomorphism, superpattern enumeration) are
//! exact brute-force with invariant pruning.

pub mod canon;
pub mod catalog;
pub mod gen;
pub mod iso;
pub mod parse;

use crate::graph::Label;
use crate::util::bitset::SmallSet;

/// Maximum number of vertices in a pattern. The paper uses ≤ 5; we allow 8
/// (40320 permutations — still trivially brute-forceable).
pub const MAX_PATTERN_VERTICES: usize = 8;

/// A query pattern: edges, anti-edges and optional labels.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Pattern {
    n: usize,
    /// adjacency masks (edges)
    adj: [SmallSet; MAX_PATTERN_VERTICES],
    /// anti-adjacency masks (anti-edges)
    anti: [SmallSet; MAX_PATTERN_VERTICES],
    /// vertex labels; `None` = unlabeled pattern
    labels: Option<[Label; MAX_PATTERN_VERTICES]>,
}

impl Pattern {
    /// Empty pattern on `n` vertices (no edges yet).
    pub fn empty(n: usize) -> Pattern {
        assert!(
            (1..=MAX_PATTERN_VERTICES).contains(&n),
            "pattern size {n} out of range"
        );
        Pattern {
            n,
            adj: [SmallSet::empty(); MAX_PATTERN_VERTICES],
            anti: [SmallSet::empty(); MAX_PATTERN_VERTICES],
            labels: None,
        }
    }

    /// Edge-induced pattern from an edge list.
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Pattern {
        let mut p = Pattern::empty(n);
        for &(u, v) in edges {
            p.add_edge(u, v);
        }
        p
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        (0..self.n).map(|v| self.adj[v].len()).sum::<usize>() / 2
    }

    /// Number of anti-edges.
    pub fn num_anti_edges(&self) -> usize {
        (0..self.n).map(|v| self.anti[v].len()).sum::<usize>() / 2
    }

    pub fn add_edge(&mut self, u: usize, v: usize) {
        assert!(u < self.n && v < self.n && u != v, "bad edge ({u},{v})");
        assert!(!self.anti[u].contains(v), "({u},{v}) already an anti-edge");
        self.adj[u].insert(v);
        self.adj[v].insert(u);
    }

    pub fn add_anti_edge(&mut self, u: usize, v: usize) {
        assert!(u < self.n && v < self.n && u != v, "bad anti-edge ({u},{v})");
        assert!(!self.adj[u].contains(v), "({u},{v}) already an edge");
        self.anti[u].insert(v);
        self.anti[v].insert(u);
    }

    pub fn remove_edge(&mut self, u: usize, v: usize) {
        self.adj[u].remove(v);
        self.adj[v].remove(u);
    }

    /// Set all vertex labels at once.
    pub fn with_labels(mut self, labels: &[Label]) -> Pattern {
        assert_eq!(labels.len(), self.n);
        let mut arr = [0; MAX_PATTERN_VERTICES];
        arr[..self.n].copy_from_slice(labels);
        self.labels = Some(arr);
        self
    }

    #[inline]
    pub fn is_labeled(&self) -> bool {
        self.labels.is_some()
    }

    /// Label of vertex `v` (0 if unlabeled).
    #[inline]
    pub fn label(&self, v: usize) -> Label {
        self.labels.map_or(0, |l| l[v])
    }

    pub fn labels_vec(&self) -> Option<Vec<Label>> {
        self.labels.map(|l| l[..self.n].to_vec())
    }

    #[inline]
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u].contains(v)
    }

    #[inline]
    pub fn has_anti_edge(&self, u: usize, v: usize) -> bool {
        self.anti[u].contains(v)
    }

    /// Neighbor mask of `v` (edges).
    #[inline]
    pub fn adj(&self, v: usize) -> SmallSet {
        self.adj[v]
    }

    /// Anti-neighbor mask of `v`.
    #[inline]
    pub fn anti(&self, v: usize) -> SmallSet {
        self.anti[v]
    }

    /// Degree of `v` (edges only).
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    /// Edge list `(u < v)`.
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut es = Vec::with_capacity(self.num_edges());
        for u in 0..self.n {
            for v in self.adj[u].iter() {
                if u < v {
                    es.push((u, v));
                }
            }
        }
        es
    }

    /// Anti-edge list `(u < v)`.
    pub fn anti_edges(&self) -> Vec<(usize, usize)> {
        let mut es = Vec::new();
        for u in 0..self.n {
            for v in self.anti[u].iter() {
                if u < v {
                    es.push((u, v));
                }
            }
        }
        es
    }

    /// Non-adjacent, non-anti pairs `(u < v)` — candidates for edge addition
    /// (superpattern enumeration) or anti-edge completion.
    pub fn open_pairs(&self) -> Vec<(usize, usize)> {
        let mut ps = Vec::new();
        for u in 0..self.n {
            for v in (u + 1)..self.n {
                if !self.has_edge(u, v) && !self.has_anti_edge(u, v) {
                    ps.push((u, v));
                }
            }
        }
        ps
    }

    /// Is the (edge-)graph connected?
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = SmallSet::empty();
        let mut stack = vec![0usize];
        seen.insert(0);
        while let Some(v) = stack.pop() {
            for u in self.adj[v].iter() {
                if !seen.contains(u) {
                    seen.insert(u);
                    stack.push(u);
                }
            }
        }
        seen.len() == self.n
    }

    /// Is every vertex pair adjacent? (cliques are both E/I and V/I)
    pub fn is_clique(&self) -> bool {
        self.num_edges() == self.n * (self.n - 1) / 2
    }

    /// Purely edge-induced (no anti-edges)?
    pub fn is_edge_induced(&self) -> bool {
        self.num_anti_edges() == 0
    }

    /// Fully vertex-induced (every non-edge is an anti-edge)?
    pub fn is_vertex_induced(&self) -> bool {
        self.num_edges() + self.num_anti_edges() == self.n * (self.n - 1) / 2
    }

    /// The edge-induced variant `p^E`: same edges, anti-edges dropped.
    pub fn edge_induced(&self) -> Pattern {
        let mut p = self.clone();
        p.anti = [SmallSet::empty(); MAX_PATTERN_VERTICES];
        p
    }

    /// The vertex-induced variant `p^V`: anti-edges on every non-edge.
    pub fn vertex_induced(&self) -> Pattern {
        let mut p = self.edge_induced();
        for u in 0..self.n {
            for v in (u + 1)..self.n {
                if !p.has_edge(u, v) {
                    p.add_anti_edge(u, v);
                }
            }
        }
        p
    }

    /// Relabel vertices according to permutation `perm` (vertex `v` of the
    /// result is vertex `perm[v]` of `self`).
    pub fn permuted(&self, perm: &[usize]) -> Pattern {
        debug_assert_eq!(perm.len(), self.n);
        let mut p = Pattern::empty(self.n);
        for u in 0..self.n {
            for v in (u + 1)..self.n {
                if self.has_edge(perm[u], perm[v]) {
                    p.add_edge(u, v);
                }
                if self.has_anti_edge(perm[u], perm[v]) {
                    p.add_anti_edge(u, v);
                }
            }
        }
        if let Some(l) = self.labels {
            let mut arr = [0; MAX_PATTERN_VERTICES];
            for v in 0..self.n {
                arr[v] = l[perm[v]];
            }
            p.labels = Some(arr);
        }
        p
    }

    /// Canonical key (see [`canon`]): equal iff patterns are isomorphic
    /// (respecting edges, anti-edges and labels).
    pub fn canonical_key(&self) -> canon::CanonKey {
        canon::canonical_key(self)
    }

    /// Human-readable one-line description, e.g. `[4v: 0-1 1-2 2-3 3-0 | anti: 0-2 1-3]`.
    pub fn describe(&self) -> String {
        let mut s = format!("[{}v:", self.n);
        for (u, v) in self.edges() {
            s.push_str(&format!(" {u}-{v}"));
        }
        let anti = self.anti_edges();
        if !anti.is_empty() {
            s.push_str(" | anti:");
            for (u, v) in anti {
                s.push_str(&format!(" {u}-{v}"));
            }
        }
        if let Some(l) = self.labels {
            s.push_str(" | labels:");
            for v in 0..self.n {
                s.push_str(&format!(" {}", l[v]));
            }
        }
        s.push(']');
        s
    }
}

impl std::fmt::Debug for Pattern {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cycle4() -> Pattern {
        Pattern::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)])
    }

    #[test]
    fn basic_counts() {
        let p = cycle4();
        assert_eq!(p.num_vertices(), 4);
        assert_eq!(p.num_edges(), 4);
        assert_eq!(p.num_anti_edges(), 0);
        assert!(p.is_connected());
        assert!(!p.is_clique());
        assert!(p.is_edge_induced());
        assert!(!p.is_vertex_induced());
    }

    #[test]
    fn vertex_induced_closure() {
        let p = cycle4().vertex_induced();
        assert_eq!(p.num_anti_edges(), 2);
        assert!(p.has_anti_edge(0, 2));
        assert!(p.has_anti_edge(1, 3));
        assert!(p.is_vertex_induced());
        assert!(!p.is_edge_induced());
        // round trip
        assert_eq!(p.edge_induced(), cycle4());
    }

    #[test]
    fn clique_is_both() {
        let k4 = Pattern::from_edges(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        assert!(k4.is_clique());
        assert!(k4.is_edge_induced());
        assert!(k4.is_vertex_induced());
        assert_eq!(k4.vertex_induced(), k4);
    }

    #[test]
    fn disconnected_detected() {
        let p = Pattern::from_edges(4, &[(0, 1), (2, 3)]);
        assert!(!p.is_connected());
    }

    #[test]
    fn permute_roundtrip() {
        let p = Pattern::from_edges(4, &[(0, 1), (1, 2), (2, 3)]).with_labels(&[5, 6, 7, 8]);
        let perm = [2, 0, 3, 1];
        let q = p.permuted(&perm);
        // q has edge (u,v) iff p has (perm[u], perm[v])
        assert_eq!(q.has_edge(1, 0), p.has_edge(0, 2));
        assert_eq!(q.label(0), 7);
        // inverse permutation recovers p
        let mut inv = [0usize; 4];
        for (i, &pi) in perm.iter().enumerate() {
            inv[pi] = i;
        }
        assert_eq!(q.permuted(&inv), p);
    }

    #[test]
    fn open_pairs_excludes_edges_and_antis() {
        let mut p = cycle4();
        p.add_anti_edge(0, 2);
        assert_eq!(p.open_pairs(), vec![(1, 3)]);
    }

    #[test]
    #[should_panic]
    fn edge_conflicts_with_anti() {
        let mut p = Pattern::empty(3);
        p.add_anti_edge(0, 1);
        p.add_edge(0, 1);
    }

    #[test]
    fn describe_readable() {
        let d = cycle4().vertex_induced().describe();
        assert!(d.contains("anti:"), "{d}");
    }
}
