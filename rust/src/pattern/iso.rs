//! Subgraph isomorphisms **between patterns**: the `φ(p, q)` sets of the
//! paper (§3.2.1) and pattern automorphism groups.
//!
//! A subgraph isomorphism from pattern `p` to pattern `q` is an injective
//! map `f : V(p) → V(q)` such that
//! * `(u,v) ∈ E(p) ⇒ (f(u), f(v)) ∈ E(q)`,
//! * `(u,v) ∈ A(p) ⇒ (f(u), f(v)) ∈ A(q)` (anti-edges map to anti-edges),
//! * labels are preserved when both patterns are labeled.
//!
//! For the morphing equations, `p` and `q` always have the same number of
//! vertices, so each `f` is a permutation; `|φ(p^E, q^E)|` becomes the
//! coefficient of `M(q^V)` in the Match Conversion Theorem.

use super::Pattern;

/// A map `f : V(p) → V(q)` as a dense vector: `f[u] = image of u`.
pub type VertexMap = Vec<usize>;

/// Enumerate all subgraph isomorphisms from `p` into `q`.
pub fn sub_isomorphisms(p: &Pattern, q: &Pattern) -> Vec<VertexMap> {
    let np = p.num_vertices();
    let nq = q.num_vertices();
    let mut out = Vec::new();
    if np > nq {
        return out;
    }
    let labeled = p.is_labeled() && q.is_labeled();
    let mut f = vec![usize::MAX; np];
    let mut used = vec![false; nq];

    fn feasible(
        p: &Pattern,
        q: &Pattern,
        f: &[usize],
        u: usize,
        img: usize,
        labeled: bool,
    ) -> bool {
        if labeled && p.label(u) != q.label(img) {
            return false;
        }
        // degree pruning: u's edges/antis must fit within img's
        if p.degree(u) > q.degree(img) || p.anti(u).len() > q.anti(img).len() {
            return false;
        }
        // check constraints against already-mapped vertices
        for w in 0..u {
            let fw = f[w];
            if p.has_edge(u, w) && !q.has_edge(img, fw) {
                return false;
            }
            if p.has_anti_edge(u, w) && !q.has_anti_edge(img, fw) {
                return false;
            }
        }
        true
    }

    fn rec(
        p: &Pattern,
        q: &Pattern,
        u: usize,
        f: &mut Vec<usize>,
        used: &mut Vec<bool>,
        labeled: bool,
        out: &mut Vec<VertexMap>,
    ) {
        let np = p.num_vertices();
        if u == np {
            out.push(f.clone());
            return;
        }
        for img in 0..q.num_vertices() {
            if !used[img] && feasible(p, q, f, u, img, labeled) {
                f[u] = img;
                used[img] = true;
                rec(p, q, u + 1, f, used, labeled, out);
                used[img] = false;
                f[u] = usize::MAX;
            }
        }
    }

    rec(p, q, 0, &mut f, &mut used, labeled, &mut out);
    out
}

/// `|φ(p, q)|` without materializing the maps.
pub fn phi_count(p: &Pattern, q: &Pattern) -> usize {
    // For the pattern sizes in play (≤8), enumerating is cheap; keep one
    // code path to avoid divergence bugs.
    sub_isomorphisms(p, q).len()
}

/// The automorphism group of a pattern (as vertex maps). `φ(p, p)` — every
/// edge/anti-edge-preserving bijection of a finite structure onto itself is
/// an automorphism.
pub fn automorphisms(p: &Pattern) -> Vec<VertexMap> {
    sub_isomorphisms(p, p)
}

/// Left-coset representatives of `φ(p, q)` modulo `Aut(q)`:
/// `f₁ ~ f₂  ⟺  f₁ = α ∘ f₂` for some `α ∈ Aut(q)`.
///
/// These are the maps the Match Conversion Theorem needs: because `M(q)` is
/// closed under post-composition with `Aut(q)`, the sets `M(q) ∘ f` over
/// coset representatives are **disjoint** and their union is the full
/// `M(q) ∘ φ(p, q)` — so summing `a(M(q)) ∘* f` over representatives counts
/// every converted match exactly once. (The paper's Figure 6 draws exactly
/// these representatives — e.g. *three* subgraph isomorphisms from the
/// 4-cycle into the 4-clique, not the raw `24` vertex maps.)
pub fn phi_coset_reps(p: &Pattern, q: &Pattern) -> Vec<VertexMap> {
    let all = sub_isomorphisms(p, q);
    if all.is_empty() {
        return all;
    }
    let auts = automorphisms(q);
    let mut reps: Vec<VertexMap> = Vec::new();
    let mut seen: std::collections::HashSet<VertexMap> = std::collections::HashSet::new();
    for f in all {
        if seen.contains(&f) {
            continue;
        }
        // mark the whole orbit {α ∘ f}
        for a in &auts {
            let g: VertexMap = f.iter().map(|&x| a[x]).collect();
            seen.insert(g);
        }
        reps.push(f);
    }
    reps
}

/// Orbits of the automorphism group: vertices in the same orbit are
/// structurally equivalent. Used for symmetry breaking (plan layer) and MNI
/// domains (FSM support). Returns `orbit_id[v]`, ids dense from 0 in order
/// of first appearance.
pub fn orbits(p: &Pattern) -> Vec<usize> {
    let n = p.num_vertices();
    let auts = automorphisms(p);
    let mut orbit = vec![usize::MAX; n];
    let mut next = 0;
    for v in 0..n {
        if orbit[v] != usize::MAX {
            continue;
        }
        orbit[v] = next;
        for a in &auts {
            // v can map to a[v]
            let img = a[v];
            if orbit[img] == usize::MAX {
                orbit[img] = next;
            }
        }
        next += 1;
    }
    orbit
}

/// Is `p` a subpattern of `q` (∃ a subgraph isomorphism p → q)?
pub fn is_subpattern(p: &Pattern, q: &Pattern) -> bool {
    !sub_isomorphisms(p, q).is_empty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;
    use crate::util::factorial;

    #[test]
    fn phi_cycle4_into_clique4_is_3() {
        // paper Fig. 6: three subgraph isomorphisms from the edge-induced
        // 4-cycle into the 4-clique... per *unique* matches; raw map count
        // is |Aut(C4)| * 3 = 8 * 3 = 24
        let c4 = catalog::cycle(4);
        let k4 = catalog::clique(4);
        assert_eq!(phi_count(&c4, &k4), 24);
        // unique embeddings = phi / |Aut(C4)|
        assert_eq!(automorphisms(&c4).len(), 8);
    }

    #[test]
    fn phi_tailed_triangle_into_diamond() {
        // paper Fig. 6: φ(p1^E, p3^V) has four subgraph isomorphisms from
        // the edge-induced tailed triangle into the vertex-induced chordal
        // 4-cycle — as unique embeddings; raw maps = 4 * |Aut(tailed)| = 4.
        // |Aut(tailed triangle)| = 1 (all four vertices structurally
        // distinct? no: the two triangle vertices not on the tail swap) = 2.
        let tt = catalog::tailed_triangle();
        assert_eq!(automorphisms(&tt).len(), 2);
        let dia_e = catalog::diamond();
        assert_eq!(phi_count(&tt, &dia_e), 4 * 2 / 2 * 2); // 8 raw maps
    }

    #[test]
    fn automorphism_group_sizes() {
        assert_eq!(automorphisms(&catalog::clique(4)).len(), factorial(4) as usize);
        assert_eq!(automorphisms(&catalog::cycle(5)).len(), 10);
        assert_eq!(automorphisms(&catalog::path(4)).len(), 2);
        assert_eq!(automorphisms(&catalog::star(4)).len(), 6); // 3! leaves
    }

    #[test]
    fn anti_edges_constrain_phi() {
        // Edge-induced C4 maps into K4; vertex-induced C4 does NOT
        // (its anti-edges cannot map to K4's edges).
        let c4v = catalog::cycle(4).vertex_induced();
        let k4 = catalog::clique(4);
        assert_eq!(phi_count(&c4v, &k4), 0);
        // but it maps into itself
        assert_eq!(phi_count(&c4v, &c4v), 8);
    }

    #[test]
    fn labels_constrain_phi() {
        let e_ab = Pattern::from_edges(2, &[(0, 1)]).with_labels(&[1, 2]);
        let tri = Pattern::from_edges(3, &[(0, 1), (1, 2), (2, 0)]).with_labels(&[1, 2, 2]);
        // edge (1,2)-labeled maps: 0→v with label 1 (only vertex 0), 1→{1,2}
        assert_eq!(phi_count(&e_ab, &tri), 2);
    }

    #[test]
    fn orbits_of_tailed_triangle() {
        // vertices: 0-1-2 triangle, 3 pendant on 2 (see catalog) —
        // orbit classes: {0,1} (swap), {2}, {3}
        let tt = catalog::tailed_triangle();
        let o = orbits(&tt);
        assert_eq!(o[0], o[1]);
        assert_ne!(o[0], o[2]);
        assert_ne!(o[2], o[3]);
    }

    #[test]
    fn orbits_of_cycle_all_equal() {
        let o = orbits(&catalog::cycle(4));
        assert!(o.iter().all(|&x| x == 0));
    }

    #[test]
    fn coset_reps_cycle4_to_clique4() {
        // the paper's "three subgraph isomorphisms" from C4 into K4
        let c4 = catalog::cycle(4);
        let k4 = catalog::clique(4);
        assert_eq!(phi_coset_reps(&c4, &k4).len(), 24 / 24);
        // ... as LEFT cosets mod Aut(K4) there is 1; the figure's 3 are the
        // unique 4-cycle subgraphs = |φ| / |Aut(C4)| = 24/8
        assert_eq!(phi_count(&c4, &k4) / automorphisms(&c4).len(), 3);
    }

    #[test]
    fn coset_reps_cycle4_to_diamond() {
        let c4 = catalog::cycle(4);
        let dia = catalog::diamond().vertex_induced();
        // φ_raw = 8 (one 4-cycle in the diamond), |Aut(diamond)| = 4
        assert_eq!(phi_count(&c4, &dia), 8);
        assert_eq!(automorphisms(&dia).len(), 4);
        assert_eq!(phi_coset_reps(&c4, &dia).len(), 2);
    }

    #[test]
    fn coset_reps_partition_phi() {
        // |reps| * |Aut(q)| = |φ| (free action)
        for (p, q) in [
            (catalog::path(3), catalog::triangle()),
            (catalog::tailed_triangle(), catalog::diamond()),
            (catalog::cycle(4), catalog::clique(4)),
            (catalog::path(4), catalog::cycle(4)),
        ] {
            let reps = phi_coset_reps(&p, &q).len();
            assert_eq!(reps * automorphisms(&q).len(), phi_count(&p, &q), "{p:?}→{q:?}");
        }
    }

    #[test]
    fn subpattern_relation() {
        assert!(is_subpattern(&catalog::path(3), &catalog::cycle(4)));
        assert!(!is_subpattern(&catalog::clique(4), &catalog::cycle(4)));
        // smaller into larger
        assert!(is_subpattern(&catalog::path(2), &catalog::clique(4)));
    }
}
