//! Textual pattern format for the CLI and config files.
//!
//! Grammar (whitespace-insensitive):
//!
//! ```text
//! pattern   := name | spec
//! name      := catalog name, e.g. "cycle4", "p3", "diamond-vi"
//! spec      := edges [";anti:" edges] [";labels:" ints] [";vi"]
//! edges     := pair ("," pair)*
//! pair      := int "-" int
//! ```
//!
//! Examples: `0-1,1-2,2-0` (triangle), `0-1,1-2,2-3,3-0;anti:0-2,1-3`
//! (explicit vertex-induced 4-cycle), `0-1,1-2,2-3,3-0;vi` (same).

use super::{catalog, Pattern};
use anyhow::{bail, Context, Result};

fn parse_pairs(s: &str) -> Result<Vec<(usize, usize)>> {
    s.split(',')
        .map(|tok| {
            let tok = tok.trim();
            let (a, b) = tok
                .split_once('-')
                .with_context(|| format!("expected 'u-v', got {tok:?}"))?;
            let u: usize = a.trim().parse().with_context(|| format!("bad vertex {a:?}"))?;
            let v: usize = b.trim().parse().with_context(|| format!("bad vertex {b:?}"))?;
            if u == v {
                bail!("self loop {u}-{v} not allowed in patterns");
            }
            Ok((u, v))
        })
        .collect()
}

/// Parse a pattern string (catalog name or explicit spec).
pub fn parse(input: &str) -> Result<Pattern> {
    let input = input.trim();
    if let Some(p) = catalog::by_name(input) {
        return Ok(p);
    }
    let mut edges: Option<Vec<(usize, usize)>> = None;
    let mut anti: Vec<(usize, usize)> = Vec::new();
    let mut labels: Option<Vec<u32>> = None;
    let mut vi = false;
    for (i, part) in input.split(';').enumerate() {
        let part = part.trim();
        if i == 0 {
            edges = Some(parse_pairs(part).context("parsing edge list")?);
        } else if let Some(rest) = part.strip_prefix("anti:") {
            anti = parse_pairs(rest).context("parsing anti-edge list")?;
        } else if let Some(rest) = part.strip_prefix("labels:") {
            labels = Some(
                rest.split(',')
                    .map(|t| t.trim().parse::<u32>().context("bad label"))
                    .collect::<Result<Vec<_>>>()?,
            );
        } else if part == "vi" {
            vi = true;
        } else {
            bail!("unknown pattern clause {part:?}");
        }
    }
    let edges = edges.context("empty pattern spec")?;
    let n = edges
        .iter()
        .chain(anti.iter())
        .map(|&(u, v)| u.max(v) + 1)
        .max()
        .unwrap_or(0)
        .max(labels.as_ref().map_or(0, |l| l.len()));
    if n == 0 {
        bail!("pattern has no vertices");
    }
    let mut p = Pattern::from_edges(n, &edges);
    for (u, v) in anti {
        p.add_anti_edge(u, v);
    }
    if let Some(l) = labels {
        if l.len() != n {
            bail!("expected {n} labels, got {}", l.len());
        }
        p = p.with_labels(&l);
    }
    if vi {
        if p.num_anti_edges() > 0 {
            bail!(";vi cannot be combined with explicit anti-edges");
        }
        p = p.vertex_induced();
    }
    if !p.is_connected() {
        bail!("pattern must be connected: {}", p.describe());
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::catalog;

    #[test]
    fn parses_catalog_names() {
        assert_eq!(
            parse("cycle4").unwrap().canonical_key(),
            catalog::cycle(4).canonical_key()
        );
    }

    #[test]
    fn parses_explicit_triangle() {
        let p = parse("0-1,1-2,2-0").unwrap();
        assert!(p.is_clique());
        assert_eq!(p.num_vertices(), 3);
    }

    #[test]
    fn parses_anti_edges() {
        let p = parse("0-1,1-2,2-3,3-0;anti:0-2,1-3").unwrap();
        assert!(p.is_vertex_induced());
        assert_eq!(
            p.canonical_key(),
            catalog::cycle(4).vertex_induced().canonical_key()
        );
    }

    #[test]
    fn vi_shorthand() {
        let a = parse("0-1,1-2,2-3,3-0;vi").unwrap();
        let b = parse("0-1,1-2,2-3,3-0;anti:0-2,1-3").unwrap();
        assert_eq!(a.canonical_key(), b.canonical_key());
    }

    #[test]
    fn parses_labels() {
        let p = parse("0-1,1-2;labels:4,5,4").unwrap();
        assert!(p.is_labeled());
        assert_eq!(p.label(1), 5);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("0-0").is_err());
        assert!(parse("0-1;bogus:2").is_err());
        assert!(parse("0-1,2-3").is_err(), "disconnected");
        assert!(parse("0-1;labels:1").is_err(), "label count mismatch");
        assert!(parse("0-1,1-2;anti:0-2;vi").is_err(), "vi + explicit anti");
    }

    #[test]
    fn roundtrip_describe_isomorphism() {
        let p = parse("0-1,1-2,2-3,3-0,0-2").unwrap();
        assert_eq!(p.canonical_key(), catalog::diamond().canonical_key());
    }
}
