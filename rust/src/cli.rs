//! Hand-rolled CLI (clap is not available offline).
//!
//! ```text
//! morphmine motifs  --graph <spec> [--size 4] [--pmr off|naive|cost] [--threads N] [--fused on|off]
//! morphmine match   --graph <spec> --patterns <p1,p2,…> [--pmr …] [--fused …] [--explain]
//! morphmine fsm     --graph <spec> [--edges 3] [--support 100] [--pmr …] [--fused …]
//! morphmine cliques --graph <spec> [--k 4]
//! morphmine census  --graph <spec> [--artifacts artifacts]
//! morphmine gen     --dataset mico[:scale] --out <path>
//! morphmine bench   [--exp all|table1|table2|table3|table4|fig2|fig5|fused|kernels|service|persist|shard|incremental|ablations] [--scale tiny|small|medium]
//! morphmine info    --graph <spec>
//! morphmine batch   --graph <spec> --queries "motifs:4;match:cycle4,p3" [--repeat 2] [--workers 2] [--cache-mb 64] [--delta-budget N] [--persist <dir>] [--fsync-every N] [--shards 'a1|a2,b1|b2'] [--connect-timeout S] [--shard-timeout S] [--probe-interval S] [--hedge-timeout S] [--verify-reads F] [--assert-warm-hits] [--trace] [--trace-tree] [--slow-query-ms N] [--metrics-dump <path>] [--cluster-stats]
//! morphmine serve   --graph <spec> [--workers 2] [--cache-mb 64] [--delta-budget N] [--persist <dir>] [--fsync-every N] [--shards 'a1|a2,b1|b2'] [--connect-timeout S] [--shard-timeout S] [--probe-interval S] [--hedge-timeout S] [--verify-reads F] [--metrics <addr:port>] [--trace] [--trace-tree] [--slow-query-ms N] [--cluster-stats]
//! morphmine shard-worker --graph <spec> --listen <addr:port> [--threads N] [--cache-mb 64] [--persist <dir>] [--fsync-every N] [--slice i/k] [--metrics <addr:port>]
//! morphmine store   <inspect|compact|purge|verify> --dir <dir> [--graph <spec>]
//! ```
//!
//! Graph specs: dataset names (`mico`, `patents`, `youtube`, `orkut`,
//! optionally `:tiny|:small|:medium`) or a path to an edge-list file.
//!
//! `batch` runs one query batch (`;`-separated query texts) through the
//! result-cache service, `--repeat` re-submitting it to demonstrate warm
//! throughput; `--assert-warm-hits` exits nonzero unless the final repeat
//! was fully cache-served (the CI smoke leg; with `--repeat 1` it instead
//! requires the single batch to be served entirely from a store recovered
//! via `--persist` — the warm-restart smoke). `serve` is the interactive
//! loop: one batch per stdin line, `+ u v` / `- u v` applies an edge
//! update (bumping the cache epoch), `quit` exits.
//!
//! `--persist <dir>` makes the result store durable (WAL + snapshots, see
//! [`crate::service::persist`]): a restart against the same graph content
//! recovers warm; against different content it recovers cold.
//! `--fsync-every N` additionally syncs the WAL every `N` records for
//! power-loss durability (default: flush-only). `store` operates on such
//! a directory offline: `inspect` prints what recovery would find,
//! `compact` folds the WAL into one snapshot, `purge` deletes the
//! persisted files, and `verify --graph <spec>` checks whether the
//! directory's state would recover warm for that graph — without starting
//! a service (exits nonzero on a mismatch).
//!
//! Sharded mode ([`crate::shard`]): start `shard-worker` processes, each
//! loading the **same** graph spec, then point `batch`/`serve` at them
//! with a `--shards` topology — comma-separated replica groups, each a
//! pipe-separated replica set (`a1|a2,b1|b2` is two groups of two;
//! `a,b,c` is the unreplicated flat pool). The coordinator deals
//! degree-weighted first-level sub-slices of each batch's missing base
//! patterns from per-group work queues and sums the exact per-slice
//! partial counts; answers are identical to single-process runs,
//! including when workers die mid-batch. In a replicated group a dead
//! member's sub-slices **fail over** to a sibling replica and stragglers
//! are **hedged** after `--hedge-timeout` seconds; the batch fails loudly
//! only when a whole group is dead. The unreplicated pool keeps the
//! retry + re-fan semantics (re-fan is the last resort — it only exists
//! where there is no sibling to fail over to). `--verify-reads F` sends a
//! sampled fraction `F` of sub-slices to two replicas and hard-fails the
//! batch if their (deterministic, byte-identical) partials disagree — a
//! built-in corruption detector. `--connect-timeout` bounds the
//! handshake, `--shard-timeout` is how long a connected worker may stay
//! silent before it is declared wedged, and `--probe-interval` is how
//! often an idle-looking worker is PINGed for signs of life (all in
//! seconds). `shard-worker --slice i/k` pins a worker to group `i` of a
//! `k`-group topology so it pre-warms its group's persisted slices at
//! startup instead of lazily on first request. Sharded serve accepts the
//! same `+ u v` / `- u v` edge updates as the single-process loop: the
//! coordinator delta-patches its composed totals and broadcasts the
//! mutation to every worker (proto v6 `UPDATE`, fingerprint-verified on
//! both ends), which rebase their per-slice stores in place — the session
//! never restarts cold. Updates between existing vertices only (worker
//! copies are fixed-size); `--delta-budget N` caps the delta pass's
//! neighborhood enumeration (0 disables patching — every update purges).
//!
//! Observability ([`crate::obs`]): `--metrics <addr:port>` (on the
//! long-lived `serve` / `shard-worker` processes only) binds a plain-HTTP
//! scrape endpoint — `curl http://addr/metrics` returns the process's
//! metric registry as text, `/metrics.json` as JSON. `--trace` (on
//! `batch` / `serve`) prints one per-batch line of stage wall times
//! (plan / probe / match / fuse / convert / persist), and
//! `--slow-query-ms N` logs any batch slower than `N` ms to stderr with
//! its stage split. `--cluster-stats` (with `--shards`) sweeps every
//! worker's registry over proto v4 `STATS` and prints the combined
//! cluster view (plain series sum by name, histogram buckets merge
//! exactly), with percentiles re-derived from the merged buckets.
//!
//! Distributed tracing ([`crate::obs::trace`]): every served batch also
//! carries a span tree under a process-unique trace id — one child per
//! pipeline stage and, in sharded mode, one span per remote sub-slice
//! with the worker's own spans (store probe, match) grafted underneath
//! and failover / hedge / retry events as tagged siblings. `--trace-tree`
//! (on `batch` / `serve`) renders the indented tree with per-span
//! wall/self times; once a span tree exists, the `--trace` line derives
//! its stage numbers from it, so the two renderings can never disagree.
//! Finished traces land in the in-process flight recorder (the last few
//! batches, slow ones pinned), which the `--metrics` listener serves as
//! `/trace.json`. `--metrics-dump <path>` (on `batch` only) writes the
//! final metric registry as JSON at exit — the one-shot counterpart of
//! the scrape endpoint — and every registry carries a constant
//! `mm_build_info{version,simd}` series identifying what produced it.

use crate::coordinator::{Config, Coordinator};
use crate::graph::io::load_spec;
use crate::morph::Policy;
use crate::service::{persist, BatchResponse, PersistConfig, Service, ServiceConfig};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;

/// Parsed flags: the positional subcommand, optional positional
/// subactions immediately after it (e.g. `store inspect`), then
/// `--key value` pairs.
pub struct Args {
    pub cmd: String,
    pos: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            bail!("usage: morphmine <motifs|match|fsm|cliques|census|gen|bench|info|batch|serve|shard-worker|store> [--flags]\nsee `morphmine help`");
        }
        let cmd = argv[0].clone();
        let mut pos = Vec::new();
        let mut i = 1;
        while i < argv.len() && !argv[i].starts_with("--") {
            pos.push(argv[i].clone());
            i += 1;
        }
        // only `store` takes positional subactions; everywhere else a bare
        // word is a typo'd flag and must fail fast, not be ignored
        if cmd != "store" && !pos.is_empty() {
            bail!("expected --flag, got {:?}", pos[0]);
        }
        let mut flags = HashMap::new();
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("expected --flag, got {a:?}");
            };
            let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                i += 1;
                argv[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), val);
            i += 1;
        }
        Ok(Args { cmd, pos, flags })
    }

    /// Positional subaction after the command (`store inspect` → `pos(0)
    /// == Some("inspect")`).
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.pos.get(i).map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("bad --{key} {s:?}: {e}")),
        }
    }
}

fn policy_of(args: &Args) -> Result<Policy> {
    let s = args.get_or("pmr", "cost");
    Policy::parse(&s).with_context(|| format!("bad --pmr {s:?} (off|naive|cost)"))
}

fn fused_of(args: &Args) -> Result<bool> {
    match args.get("fused") {
        None | Some("on") | Some("true") => Ok(true),
        Some("off") | Some("false") => Ok(false),
        Some(other) => bail!("bad --fused {other:?} (on|off)"),
    }
}

/// Durable-store config from `--persist <dir>` + `--fsync-every N`.
fn persist_of(args: &Args) -> Result<Option<PersistConfig>> {
    let Some(dir) = args.get("persist") else {
        ensure!(
            args.get("fsync-every").is_none(),
            "--fsync-every needs --persist <dir> (there is no WAL to sync without one)"
        );
        return Ok(None);
    };
    let mut pc = PersistConfig::new(dir);
    if args.get("fsync-every").is_some() {
        let n: u32 = args.parse_num("fsync-every", 1u32)?;
        ensure!(n >= 1, "--fsync-every must be ≥ 1");
        pc.opts.fsync_every = Some(n);
    }
    Ok(Some(pc))
}

/// Delta-morphing enumeration budget from `--delta-budget N`. `0`
/// disables in-place patching: every edge update purges the store (the
/// pre-delta behavior), with the fallback still explicitly counted.
fn delta_budget_of(args: &Args) -> Result<usize> {
    args.parse_num("delta-budget", crate::service::delta::DEFAULT_DELTA_BUDGET)
}

fn service_of(args: &Args) -> Result<Service> {
    ensure_no_shard_timing_flags(args)?;
    let spec = args
        .get("graph")
        .context("missing --graph <dataset[:scale] | path>")?;
    let graph = load_spec(spec)?;
    let config = ServiceConfig {
        workers: args.parse_num("workers", 2usize)?,
        threads: args.parse_num("threads", crate::exec::parallel::default_threads())?,
        policy: policy_of(args)?,
        fused: fused_of(args)?,
        cache_bytes: args.parse_num("cache-mb", 64usize)? << 20,
        persist: persist_of(args)?,
        delta_budget: delta_budget_of(args)?,
    };
    let svc = Service::try_start(graph, config)?;
    if let Some(r) = svc.recovery_report() {
        println!(
            "persist: restored {} entries (snapshot {}, wal records {}, truncated tail: {}, fingerprint match: {})",
            r.restored, r.snapshot_entries, r.wal_records, r.wal_truncated, r.fingerprint_matched
        );
    }
    Ok(svc)
}

/// Parse a `--<key> <seconds>` duration flag (fractional seconds allowed).
fn duration_flag(args: &Args, key: &str, default: std::time::Duration) -> Result<std::time::Duration> {
    let Some(s) = args.get(key) else {
        return Ok(default);
    };
    let secs: f64 = s
        .parse()
        .map_err(|e| anyhow::anyhow!("bad --{key} {s:?}: {e}"))?;
    ensure!(
        secs.is_finite() && secs > 0.0,
        "bad --{key} {s:?}: must be a positive number of seconds"
    );
    Ok(std::time::Duration::from_secs_f64(secs))
}

/// Fabric tuning from `--connect-timeout`/`--shard-timeout`/
/// `--probe-interval`/`--hedge-timeout` (seconds) and `--verify-reads`
/// (fraction), on top of [`crate::shard::PoolConfig`] defaults.
fn pool_config_of(args: &Args) -> Result<crate::shard::PoolConfig> {
    let defaults = crate::shard::PoolConfig::default();
    let mut config = crate::shard::PoolConfig {
        connect_timeout: duration_flag(args, "connect-timeout", defaults.connect_timeout)?,
        shard_timeout: duration_flag(args, "shard-timeout", defaults.shard_timeout)?,
        probe_interval: duration_flag(args, "probe-interval", defaults.probe_interval)?,
        hedge_timeout: duration_flag(args, "hedge-timeout", defaults.hedge_timeout)?,
        ..defaults
    };
    if let Some(s) = args.get("verify-reads") {
        let f: f64 = s
            .parse()
            .map_err(|e| anyhow::anyhow!("bad --verify-reads {s:?}: {e}"))?;
        ensure!(
            f.is_finite() && (0.0..=1.0).contains(&f),
            "bad --verify-reads {s:?}: must be a fraction in [0, 1]"
        );
        config.verify_reads = f;
    }
    ensure!(
        config.shard_timeout >= config.probe_interval,
        "--shard-timeout ({:?}) must be ≥ --probe-interval ({:?}): the wedge \
         deadline is measured in missed probes",
        config.shard_timeout,
        config.probe_interval
    );
    Ok(config)
}

/// The fabric flags only mean something on a sharded coordinator; reject
/// them elsewhere so a typo'd deployment fails instead of running with
/// silently ignored timeouts.
fn ensure_no_shard_timing_flags(args: &Args) -> Result<()> {
    for key in [
        "connect-timeout",
        "shard-timeout",
        "probe-interval",
        "hedge-timeout",
        "verify-reads",
    ] {
        ensure!(
            args.get(key).is_none(),
            "--{key} needs --shards a1|a2,b1|b2,… (it configures the shard fabric)"
        );
    }
    Ok(())
}

/// The observability flags are only meaningful where they act:
/// `--metrics` binds a scrape endpoint, which only the long-lived serving
/// processes have; `--trace` / `--trace-tree` / `--slow-query-ms` render
/// per-batch timings, which only the batch-serving front doors produce;
/// `--cluster-stats` sweeps shard-worker registries, which needs a
/// coordinator; `--metrics-dump` is the one-shot exporter for the
/// exits-when-done `batch` command. Reject them anywhere else so a
/// typo'd deployment fails instead of silently not observing.
fn ensure_obs_flags(args: &Args) -> Result<()> {
    let cmd = args.cmd.as_str();
    if !matches!(cmd, "serve" | "shard-worker") {
        ensure!(
            args.get("metrics").is_none(),
            "--metrics needs a long-lived process to scrape: it is accepted on \
             `serve` and `shard-worker` only"
        );
    }
    if !matches!(cmd, "batch" | "serve") {
        for key in ["trace", "trace-tree", "slow-query-ms"] {
            ensure!(
                args.get(key).is_none(),
                "--{key} renders per-batch timings: it is accepted on `batch` and `serve` only"
            );
        }
        ensure!(
            args.get("cluster-stats").is_none(),
            "--cluster-stats aggregates shard-worker registries: it is accepted on \
             `batch` and `serve` (with --shards) only"
        );
    }
    if cmd != "batch" {
        ensure!(
            args.get("metrics-dump").is_none(),
            "--metrics-dump writes the registry once at exit: it is accepted on `batch` \
             only (long-lived processes expose --metrics instead)"
        );
    }
    Ok(())
}

/// Parse `--slow-query-ms N` (a threshold of 0 logs every batch).
fn slow_query_ms_of(args: &Args) -> Result<Option<u64>> {
    match args.get("slow-query-ms") {
        None => Ok(None),
        Some(_) => Ok(Some(args.parse_num("slow-query-ms", 0u64)?)),
    }
}

/// Bind the `--metrics` scrape endpoint (global registry, detached
/// thread) and announce where it landed — `--metrics 127.0.0.1:0` picks
/// an ephemeral port, so the announcement is the only way to find it.
fn spawn_metrics_of(args: &Args) -> Result<()> {
    let Some(addr) = args.get("metrics") else {
        return Ok(());
    };
    let bound = crate::obs::spawn_scrape_listener(addr)
        .with_context(|| format!("binding --metrics {addr}"))?;
    println!("metrics: http://{bound}/metrics (text; /metrics.json for JSON)");
    Ok(())
}

/// `--trace`: one line of per-batch stage wall times in pipeline order
/// (stages a batch never entered are omitted; wall time outside the
/// instrumented stages shows as `other`). When the response carries a
/// span tree the stage numbers are derived from it via
/// [`crate::obs::Trace::stage_us`] — one timing source, so this line
/// and `--trace-tree` can never disagree — and the [`PhaseProfile`]
/// remains only as the fallback for trace-less responses.
///
/// [`PhaseProfile`]: crate::util::timer::PhaseProfile
fn print_trace(r: &BatchResponse, elapsed: std::time::Duration) {
    const STAGES: [&str; 7] = ["plan", "probe", "match", "fuse", "convert", "stats", "persist"];
    let ms = |d: std::time::Duration| d.as_secs_f64() * 1e3;
    print!("trace: epoch={} total={:.3}ms", r.epoch, ms(elapsed));
    let mut known_ms = 0.0;
    let mut stage = |name: &str, stage_ms: f64| {
        if stage_ms > 0.0 {
            known_ms += stage_ms;
            print!(" {name}={stage_ms:.3}ms");
        }
    };
    if let Some(root) = r.trace.root() {
        for s in STAGES {
            stage(s, r.trace.stage_us(s) as f64 / 1e3);
        }
        // stage names the builder recorded beyond the pipeline set (a
        // future stage, a per-pattern profile entry) still show up
        let mut seen: Vec<&str> = STAGES.to_vec();
        for s in &r.trace.spans {
            if s.parent == root.id && !seen.contains(&s.name.as_str()) {
                seen.push(&s.name);
                stage(&s.name, r.trace.stage_us(&s.name) as f64 / 1e3);
            }
        }
    } else {
        for s in STAGES {
            stage(s, ms(r.profile.get(s)));
        }
        for (name, d) in r.profile.entries() {
            if !STAGES.contains(&name.as_str()) {
                stage(name, ms(*d));
            }
        }
    }
    if ms(elapsed) > known_ms {
        print!(" other={:.3}ms", ms(elapsed) - known_ms);
    }
    println!();
}

/// `--slow-query-ms`: log a batch that blew the threshold to stderr, with
/// its stage split inline so the log line is actionable on its own.
fn maybe_log_slow(slow_ms: Option<u64>, elapsed: std::time::Duration, queries: &str, r: &BatchResponse) {
    let Some(threshold) = slow_ms else {
        return;
    };
    let total_ms = elapsed.as_secs_f64() * 1e3;
    if total_ms < threshold as f64 {
        return;
    }
    use std::fmt::Write;
    let mut stages = String::new();
    for (name, d) in r.profile.entries() {
        let _ = write!(stages, " {name}={:.3}ms", d.as_secs_f64() * 1e3);
    }
    eprintln!("slow-batch: {total_ms:.3}ms ≥ {threshold}ms — queries {queries:?} —{stages}");
}

/// `--cluster-stats`: sweep every worker's metric registry (proto v4
/// `STATS`) and print the combined view — plain series sum by name,
/// histogram buckets merge exactly ([`crate::obs::aggregate`]), and the
/// `_p50/_p95/_p99` lines are re-derived from the merged buckets, never
/// averaged.
fn print_cluster_stats(coord: &mut crate::shard::ShardCoordinator) {
    let per_worker = coord.collect_stats();
    println!("cluster: {} worker(s) answered STATS", per_worker.len());
    for (addr, series) in &per_worker {
        println!("cluster worker={addr}: {} series", series.len());
    }
    let images: Vec<Vec<(String, u64)>> = per_worker.into_iter().map(|(_, s)| s).collect();
    let mut agg = crate::obs::aggregate(&images);
    agg.extend(crate::obs::derive_quantiles(&agg));
    agg.sort_by(|a, b| a.0.cmp(&b.0));
    for (name, v) in &agg {
        println!("cluster {name} {v}");
    }
}

/// Sharded coordinator from a `--shards` topology spec — comma-separated
/// replica groups, pipe-separated members (used by `batch`/`serve`).
fn shard_coordinator_of(args: &Args, spec_shards: &str) -> Result<crate::shard::ShardCoordinator> {
    let spec = args
        .get("graph")
        .context("missing --graph <dataset[:scale] | path>")?;
    let graph = load_spec(spec)?;
    ensure!(
        args.get("persist").is_none(),
        "--persist applies to shard workers in sharded mode: run \
         `morphmine shard-worker --persist <dir>` on each worker instead"
    );
    ensure!(
        args.get("fsync-every").is_none(),
        "--fsync-every applies to shard workers in sharded mode: pass it to \
         `morphmine shard-worker` alongside --persist instead"
    );
    let groups = crate::shard::parse_topology(spec_shards)?;
    let planner = crate::service::QueryPlanner::new(
        policy_of(args)?,
        fused_of(args)?,
        args.parse_num("threads", crate::exec::parallel::default_threads())?,
    );
    let cache_bytes = args.parse_num("cache-mb", 64usize)? << 20;
    let config = pool_config_of(args)?;
    let mut coord = crate::shard::ShardCoordinator::connect_with(
        graph,
        &groups,
        planner,
        cache_bytes,
        config,
    )?;
    coord.set_delta_budget(delta_budget_of(args)?);
    let rendered: Vec<String> = groups.iter().map(|g| g.join("|")).collect();
    println!(
        "sharded across {} workers in {} group(s) ({} sub-slices): {}",
        coord.num_shards(),
        coord.num_groups(),
        coord.num_sub_slices(),
        rendered.join(", ")
    );
    Ok(coord)
}

fn print_shard_metrics(coord: &crate::shard::ShardCoordinator) {
    let m = coord.shard_metrics();
    println!(
        "shards: requests={} bases_sent={} partials_merged={} remote_cached={} errors={}",
        m.requests, m.bases_sent, m.partials_merged, m.remote_cached, m.errors
    );
    println!(
        "fabric: worker_failures={} retries={} refanned={} failovers={} hedges={} \
         verify_mismatches={} probes={}",
        m.worker_failures,
        m.retries,
        m.refanned,
        m.failovers,
        m.hedges,
        m.verify_mismatches,
        m.probes
    );
    // per-worker service-time distributions, from the histograms the
    // coordinator records per reply — extra lines on purpose: the
    // `fabric:` line's format above is pinned by the CI smokes
    for (name, sample) in crate::obs::global().snapshot() {
        let Some(rest) = name.strip_prefix("mm_shard_worker_service_us{worker=\"") else {
            continue;
        };
        let Some(addr) = rest.strip_suffix("\"}") else {
            continue;
        };
        if let crate::obs::Sample::Hist(h) = sample {
            println!(
                "fabric worker={addr}: served={} p50_ms={:.3} p99_ms={:.3}",
                h.count(),
                h.p50() as f64 / 1e3,
                h.p99() as f64 / 1e3
            );
        }
    }
}

fn print_batch(r: &BatchResponse) {
    let s = &r.stats;
    println!(
        "epoch={}  bases: total={} cached={} executed={} coalesced={}",
        r.epoch, s.total_bases, s.cached_bases, s.executed_bases, s.coalesced_bases
    );
    for q in &r.results {
        for (p, n) in &q.counts {
            println!("{n:>16}  {p:?}   [{}]", q.query);
        }
    }
    print_profile(&r.profile);
}

fn coordinator_of(args: &Args) -> Result<Coordinator> {
    let spec = args
        .get("graph")
        .context("missing --graph <dataset[:scale] | path>")?;
    let graph = load_spec(spec)?;
    let mut config = Config {
        policy: policy_of(args)?,
        threads: args.parse_num("threads", crate::exec::parallel::default_threads())?,
        artifacts_dir: None,
        fused: fused_of(args)?,
        ..Config::default()
    };
    if let Some(dir) = args.get("artifacts") {
        config.artifacts_dir = Some(dir.into());
    }
    Coordinator::new(graph, config)
}

/// CLI entrypoint.
pub fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(&argv)?;
    ensure_obs_flags(&args)?;
    // every process carries the build-info series, so any scrape,
    // STATS_REPLY, or --metrics-dump identifies what produced it
    crate::obs::register_build_info();
    match args.cmd.as_str() {
        "motifs" => {
            let c = coordinator_of(&args)?;
            let size = args.parse_num("size", 4usize)?;
            println!("{}", c.describe());
            let t = crate::util::timer::Timer::start();
            let (counts, backend) = c.motifs(size)?;
            println!("backend: {backend:?}   elapsed: {:.3}s", t.secs());
            for (p, n) in &counts.counts {
                println!("{:>16}  {:?}", n, p);
            }
            print_profile(&counts.profile);
        }
        "match" => {
            let c = coordinator_of(&args)?;
            let specs = args.get("patterns").context("missing --patterns p1,p2,…")?;
            let queries = specs
                .split(',')
                .map(crate::pattern::parse::parse)
                .collect::<Result<Vec<_>>>()?;
            println!("{}", c.describe());
            let t = crate::util::timer::Timer::start();
            let r = c.match_patterns(&queries);
            println!("elapsed: {:.3}s", t.secs());
            for (q, n) in queries.iter().zip(&r.counts) {
                println!("{:>16}  {:?}", n, q);
            }
            if args.get("explain").is_some() {
                println!("alternative pattern set:");
                for p in &r.alt_set {
                    println!("    {p:?}");
                }
                for e in &r.equations {
                    println!("  {e}");
                }
            }
            print_profile(&r.profile);
        }
        "fsm" => {
            let c = coordinator_of(&args)?;
            let edges = args.parse_num("edges", 3usize)?;
            let support = args.parse_num("support", 100u64)?;
            println!("{}", c.describe());
            let t = crate::util::timer::Timer::start();
            let r = c.fsm(edges, support);
            println!("elapsed: {:.3}s", t.secs());
            println!(
                "frequent {}-edge patterns (support ≥ {support}): {}",
                edges,
                r.frequent.len()
            );
            for (p, s) in r.frequent.iter().take(20) {
                println!("{s:>12}  {p:?}");
            }
            print_profile(&r.profile);
        }
        "cliques" => {
            let c = coordinator_of(&args)?;
            let k = args.parse_num("k", 4usize)?;
            let t = crate::util::timer::Timer::start();
            let n = c.cliques(k);
            println!("{k}-cliques: {n}   ({:.3}s)", t.secs());
        }
        "census" => {
            let spec = args.get("graph").context("missing --graph")?;
            let graph = load_spec(spec)?;
            let dir = args.get_or("artifacts", "artifacts");
            let be = crate::runtime::CensusBackend::load(std::path::Path::new(&dir))?;
            println!("dense census via PJRT ({})", be.platform());
            let t = crate::util::timer::Timer::start();
            let r = be.census_graph(&graph)?;
            println!("elapsed: {:.3}s", t.secs());
            for (name, v) in crate::runtime::CENSUS_OUTPUTS.iter().zip(&r.values) {
                println!("{v:>16}  {name}");
            }
        }
        "gen" => {
            let d = args.get("dataset").context("missing --dataset")?;
            let out = args.get("out").context("missing --out <path>")?;
            let graph = load_spec(d)?;
            crate::graph::io::save_text(&graph, std::path::Path::new(out))?;
            println!(
                "wrote {} (|V|={} |E|={})",
                out,
                graph.num_vertices(),
                graph.num_edges()
            );
        }
        "bench" => {
            let exp = args.get_or("exp", "all");
            let scale = crate::graph::generators::Scale::parse(&args.get_or("scale", "tiny"))
                .context("bad --scale")?;
            let threads = args.parse_num("threads", crate::exec::parallel::default_threads())?;
            crate::bench::run_experiment(&exp, scale, threads)?;
        }
        "batch" => {
            let spec = args.get("queries").context("missing --queries q1;q2;…")?;
            let texts: Vec<&str> = spec
                .split(';')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .collect();
            ensure!(!texts.is_empty(), "--queries must name at least one query");
            let repeat = args.parse_num("repeat", 1usize)?.max(1);
            let trace = args.get("trace").is_some();
            let trace_tree = args.get("trace-tree").is_some();
            let slow_ms = slow_query_ms_of(&args)?;
            // --metrics-dump fails fast on an unwritable path, before any
            // matching work; the registry is written once after the last round
            let metrics_dump = match args.get("metrics-dump") {
                Some(p) => {
                    std::fs::File::create(p)
                        .with_context(|| format!("--metrics-dump {p}: path is not writable"))?;
                    Some(p.to_string())
                }
                None => None,
            };
            ensure!(
                args.get("cluster-stats").is_none() || args.get("shards").is_some(),
                "--cluster-stats needs --shards a1|a2,… (it sweeps shard-worker registries)"
            );
            let mut last = None;
            // either the in-process service or the sharded coordinator —
            // answers are identical, only who matches the bases differs
            let mut coord = match args.get("shards") {
                Some(addrs) => Some(shard_coordinator_of(&args, addrs)?),
                None => None,
            };
            let svc = match &coord {
                Some(_) => None,
                None => Some(service_of(&args)?),
            };
            for round in 1..=repeat {
                let t = crate::util::timer::Timer::start();
                let r = match (&mut coord, &svc) {
                    (Some(c), _) => c.call(&texts)?,
                    (None, Some(s)) => s.call(&texts)?,
                    (None, None) => unreachable!("one of the two paths is built"),
                };
                println!("batch {round}/{repeat}: elapsed {:.3}s", t.secs());
                print_batch(&r);
                if trace {
                    print_trace(&r, t.elapsed());
                }
                if trace_tree {
                    print!("{}", r.trace.render_tree());
                }
                maybe_log_slow(slow_ms, t.elapsed(), spec, &r);
                // the flight recorder always retains (pinning slow rounds),
                // so /trace.json and post-mortems work without render flags
                let slow = slow_ms.is_some_and(|th| t.elapsed().as_secs_f64() * 1e3 >= th as f64);
                crate::obs::trace::recorder().record(r.trace.clone(), slow);
                last = Some(r.stats);
            }
            let m = match (&coord, &svc) {
                (Some(c), _) => {
                    print_shard_metrics(c);
                    c.store_metrics()
                }
                (None, Some(s)) => s.store_metrics(),
                (None, None) => unreachable!(),
            };
            println!(
                "store: hits={} misses={} inserts={} evictions={} invalidations={} bytes={}",
                m.hits, m.misses, m.inserts, m.evictions, m.invalidations, m.bytes
            );
            if args.get("cluster-stats").is_some() {
                let c = coord.as_mut().expect("checked against --shards above");
                print_cluster_stats(c);
            }
            if args.get("assert-warm-hits").is_some() {
                let s = last.expect("at least one round ran");
                // with a single round the warmth must come from a store
                // recovered off disk (the CI warm-restart smoke); with
                // repeats, round 1 warms rounds 2+ in memory
                ensure!(
                    repeat >= 2 || args.get("persist").is_some(),
                    "--assert-warm-hits needs --repeat ≥ 2 (a warm round to check) or --persist (a recovered store to serve from)"
                );
                ensure!(
                    s.executed_bases == 0 && s.cached_bases + s.coalesced_bases > 0,
                    "warm batch was not cache-served: {s:?}"
                );
                ensure!(m.hits > 0, "store reported zero hits: {m:?}");
                println!("warm-cache assertion passed ({} hits)", m.hits);
            }
            if let Some(path) = metrics_dump {
                let doc = crate::obs::render_json(crate::obs::global());
                std::fs::write(&path, &doc)
                    .with_context(|| format!("writing --metrics-dump {path}"))?;
                println!("metrics-dump: wrote {} bytes to {path}", doc.len());
            }
        }
        "shard-worker" => {
            ensure_no_shard_timing_flags(&args)?;
            let spec = args
                .get("graph")
                .context("missing --graph <dataset[:scale] | path>")?;
            let graph = load_spec(spec)?;
            let listen = args
                .get("listen")
                .context("missing --listen <addr:port> (port 0 picks an ephemeral port)")?;
            let slice_pin = match args.get("slice") {
                None => None,
                Some(s) => {
                    let parts: Vec<&str> = s.split('/').collect();
                    let parsed = match parts.as_slice() {
                        [i, k] => i.parse::<usize>().ok().zip(k.parse::<usize>().ok()),
                        _ => None,
                    };
                    let (i, k) = parsed
                        .with_context(|| format!("bad --slice {s:?}: expected i/k, e.g. 0/2"))?;
                    ensure!(
                        k >= 1 && i < k,
                        "bad --slice {s:?}: the group index must be below the group count"
                    );
                    Some((i, k))
                }
            };
            let config = crate::shard::WorkerConfig {
                threads: args.parse_num("threads", crate::exec::parallel::default_threads())?,
                fused: fused_of(&args)?,
                cache_bytes: args.parse_num("cache-mb", 64usize)? << 20,
                persist: persist_of(&args)?,
                slice_pin,
            };
            let worker = crate::shard::ShardWorker::bind(graph, listen, config)?;
            spawn_metrics_of(&args)?;
            // killing the process skips the graceful-shutdown compaction
            // (no signal handler in a std-only crate): with --persist the
            // WAL is flushed per record, so the next start replays it
            // instead of loading one snapshot — slower, never colder, and
            // the dead owner's dir lock is reclaimed automatically on
            // Linux. `store compact --dir <dir>` folds the log offline.
            println!(
                "shard worker listening on {} ({}) — stop by killing the process \
                 (restart replays the WAL; `morphmine store compact` folds it offline)",
                worker.addr(),
                worker.fingerprint()
            );
            worker.wait();
        }
        "serve" => {
            let trace = args.get("trace").is_some();
            let trace_tree = args.get("trace-tree").is_some();
            let slow_ms = slow_query_ms_of(&args)?;
            // batches served below feed the flight recorder unconditionally
            // (slow ones pinned), so --metrics' /trace.json has evidence to
            // serve even when neither render flag is set
            let record = |r: &BatchResponse, elapsed: std::time::Duration| {
                let slow = slow_ms.is_some_and(|th| elapsed.as_secs_f64() * 1e3 >= th as f64);
                crate::obs::trace::recorder().record(r.trace.clone(), slow);
            };
            if let Some(addrs) = args.get("shards") {
                let cluster_stats = args.get("cluster-stats").is_some();
                let mut coord = shard_coordinator_of(&args, addrs)?;
                spawn_metrics_of(&args)?;
                println!(
                    "morphmine sharded service ready ({} workers, epoch {}). One batch per line, queries separated by ';'",
                    coord.num_shards(),
                    coord.epoch()
                );
                println!("  e.g. `motifs:4;match:cycle4,diamond-vi` — `+ u v` / `- u v` applies an edge update across the fabric, `quit` exits");
                let stdin = std::io::stdin();
                let mut line = String::new();
                loop {
                    line.clear();
                    if stdin.read_line(&mut line)? == 0 {
                        break; // EOF
                    }
                    let text = line.trim();
                    if text.is_empty() {
                        continue;
                    }
                    if text == "quit" || text == "exit" {
                        break;
                    }
                    if let Some(rest) = text.strip_prefix('+').or_else(|| text.strip_prefix('-')) {
                        let insert = text.starts_with('+');
                        let mut it = rest.split_whitespace();
                        match (
                            it.next().and_then(|s| s.parse::<u32>().ok()),
                            it.next().and_then(|s| s.parse::<u32>().ok()),
                        ) {
                            (Some(u), Some(v)) if u != v => {
                                let applied = if insert {
                                    coord.insert_edge(u, v)
                                } else {
                                    coord.remove_edge(u, v)
                                };
                                match applied {
                                    Ok(applied) => println!(
                                        "{} edge ({u},{v}): applied={applied} epoch={}",
                                        if insert { "insert" } else { "remove" },
                                        coord.epoch()
                                    ),
                                    Err(e) => eprintln!("error: {e:#}"),
                                }
                            }
                            _ => eprintln!("usage: +|- <u> <v> (two distinct vertex ids)"),
                        }
                        continue;
                    }
                    let texts: Vec<&str> = text
                        .split(';')
                        .map(str::trim)
                        .filter(|s| !s.is_empty())
                        .collect();
                    let t = crate::util::timer::Timer::start();
                    match coord.call(&texts) {
                        Ok(r) => {
                            print_batch(&r);
                            if trace {
                                print_trace(&r, t.elapsed());
                            }
                            if trace_tree {
                                print!("{}", r.trace.render_tree());
                            }
                            maybe_log_slow(slow_ms, t.elapsed(), text, &r);
                            record(&r, t.elapsed());
                            print_shard_metrics(&coord);
                            if cluster_stats {
                                print_cluster_stats(&mut coord);
                            }
                        }
                        Err(e) => eprintln!("error: {e:#}"),
                    }
                }
                return Ok(());
            }
            ensure!(
                args.get("cluster-stats").is_none(),
                "--cluster-stats needs --shards a1|a2,… (it sweeps shard-worker registries)"
            );
            let svc = service_of(&args)?;
            spawn_metrics_of(&args)?;
            println!(
                "morphmine service ready (epoch {}). One batch per line, queries separated by ';'",
                svc.epoch()
            );
            println!("  e.g. `motifs:4;match:cycle4,diamond-vi` — `+ u v` / `- u v` applies an edge update, `quit` exits");
            let stdin = std::io::stdin();
            let mut line = String::new();
            loop {
                line.clear();
                if stdin.read_line(&mut line)? == 0 {
                    break; // EOF
                }
                let text = line.trim();
                if text.is_empty() {
                    continue;
                }
                if text == "quit" || text == "exit" {
                    break;
                }
                if let Some(rest) = text.strip_prefix('+').or_else(|| text.strip_prefix('-')) {
                    let insert = text.starts_with('+');
                    let mut it = rest.split_whitespace();
                    match (
                        it.next().and_then(|s| s.parse::<u32>().ok()),
                        it.next().and_then(|s| s.parse::<u32>().ok()),
                    ) {
                        (Some(u), Some(v)) if u != v => {
                            let applied = if insert {
                                svc.insert_edge(u, v)
                            } else {
                                svc.remove_edge(u, v)
                            };
                            match applied {
                                Ok(applied) => println!(
                                    "{} edge ({u},{v}): applied={applied} epoch={}",
                                    if insert { "insert" } else { "remove" },
                                    svc.epoch()
                                ),
                                Err(e) => eprintln!("error: {e:#}"),
                            }
                        }
                        _ => eprintln!("usage: +|- <u> <v> (two distinct vertex ids)"),
                    }
                    continue;
                }
                let texts: Vec<&str> = text
                    .split(';')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .collect();
                let t = crate::util::timer::Timer::start();
                match svc.call(&texts) {
                    Ok(r) => {
                        print_batch(&r);
                        if trace {
                            print_trace(&r, t.elapsed());
                        }
                        if trace_tree {
                            print!("{}", r.trace.render_tree());
                        }
                        maybe_log_slow(slow_ms, t.elapsed(), text, &r);
                        record(&r, t.elapsed());
                    }
                    Err(e) => eprintln!("error: {e:#}"),
                }
            }
        }
        "store" => store_cmd(&args)?,
        "info" => {
            let c = coordinator_of(&args)?;
            println!("{}", c.describe());
            let s = c.stats();
            println!(
                "wedges={:.0} density={:.6} clustering={:.4} deg²Σ={:.0}",
                s.wedges, s.density, s.clustering, s.deg_sq_sum
            );
        }
        "help" | "--help" | "-h" => {
            println!("see module docs: motifs | match | fsm | cliques | census | gen | bench | info | batch | serve | shard-worker | store");
        }
        other => bail!("unknown command {other:?} — try `morphmine help`"),
    }
    Ok(())
}

/// `morphmine store <inspect|compact|purge|verify> --dir <path>` — offline
/// maintenance of a persist directory (no service; only `verify` loads a
/// graph, and only to fingerprint it).
fn store_cmd(args: &Args) -> Result<()> {
    let action = args
        .pos(0)
        .context("usage: morphmine store <inspect|compact|purge|verify> --dir <path>")?;
    if let Some(extra) = args.pos(1) {
        bail!("unexpected argument {extra:?} after store action {action:?}");
    }
    let dir = args.get("dir").context("missing --dir <persist directory>")?;
    let dir = std::path::PathBuf::from(dir);
    match action {
        "inspect" => {
            let i = persist::inspect::<i128>(&dir);
            match (i.snapshot, i.snapshot_bytes) {
                (Some((fp, n)), bytes) => {
                    println!("snapshot: {n} entries for {fp} ({} bytes)", bytes.unwrap_or(0))
                }
                (None, Some(b)) => {
                    println!("snapshot: unreadable ({b} bytes present, rejected by CRC/format)")
                }
                (None, None) => println!("snapshot: none"),
            }
            match i.wal_bytes {
                Some(b) => {
                    let tail = if i.wal_truncated {
                        ", torn/corrupt tail present"
                    } else {
                        ""
                    };
                    println!("wal: {} records ({b} bytes{tail})", i.wal_records);
                }
                None => println!("wal: none"),
            }
            match i.fingerprint {
                Some(fp) => println!("recoverable image: {} entries for {fp}", i.live_entries),
                None => println!("recoverable image: none"),
            }
        }
        "compact" => {
            let (entries, folded) = persist::compact_dir::<i128>(&dir)?;
            println!("compacted {}: {entries} entries, {folded} records folded", dir.display());
        }
        "purge" => {
            let removed = persist::purge_dir(&dir)?;
            println!("purged {}: {removed} files removed", dir.display());
        }
        "verify" => {
            // offline fingerprint check: would a service over this graph
            // recover the directory's state warm? No service is started.
            let spec = args
                .get("graph")
                .context("store verify needs --graph <spec> to fingerprint against")?;
            let graph = load_spec(spec)?;
            let fp = graph.fingerprint();
            let v = persist::verify_dir::<i128>(&dir, fp);
            match v.stored {
                Some(stored) => println!("stored:  {} entries for {stored}", v.entries),
                None => println!("stored:  no usable state"),
            }
            println!("graph:   {fp}");
            ensure!(
                v.matched,
                "MISMATCH: {} would recover COLD for this graph (state is for a different \
                 or mutated graph, or there is none)",
                dir.display()
            );
            println!("MATCH: a service over this graph recovers {} entries warm", v.entries);
        }
        other => bail!("unknown store action {other:?} (inspect|compact|purge|verify)"),
    }
    Ok(())
}

fn print_profile(p: &crate::util::timer::PhaseProfile) {
    let total = p.total().as_secs_f64();
    if total <= 0.0 {
        return;
    }
    print!("phases:");
    for (name, d) in p.entries() {
        print!("  {name}={:.3}s ({:.0}%)", d.as_secs_f64(), 100.0 * d.as_secs_f64() / total);
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn args_parse_flags() {
        let a = Args::parse(&argv("motifs --graph mico:tiny --size 4 --explain")).unwrap();
        assert_eq!(a.cmd, "motifs");
        assert_eq!(a.get("graph"), Some("mico:tiny"));
        assert_eq!(a.parse_num("size", 3usize).unwrap(), 4);
        assert_eq!(a.get("explain"), Some("true"));
        assert!(a.parse_num::<usize>("graph", 1).is_err());
    }

    #[test]
    fn run_motifs_smoke() {
        run(argv("motifs --graph mico:tiny --size 3 --pmr naive --threads 2")).unwrap();
    }

    #[test]
    fn run_motifs_fused_toggle() {
        run(argv("motifs --graph mico:tiny --size 3 --pmr naive --threads 2 --fused off")).unwrap();
        run(argv("motifs --graph mico:tiny --size 3 --pmr naive --threads 2 --fused on")).unwrap();
        assert!(run(argv("motifs --graph mico:tiny --fused maybe")).is_err());
    }

    #[test]
    fn run_match_smoke() {
        run(argv(
            "match --graph patents:tiny --patterns cycle4,diamond-vi --pmr cost --explain --threads 2",
        ))
        .unwrap();
    }

    #[test]
    fn run_info_and_gen() {
        run(argv("info --graph mico:tiny")).unwrap();
        let out = std::env::temp_dir().join("mm_cli_gen.txt");
        run(argv(&format!("gen --dataset mico:tiny --out {}", out.display()))).unwrap();
        assert!(out.exists());
    }

    #[test]
    fn run_rejects_unknown() {
        assert!(run(argv("frobnicate")).is_err());
        assert!(run(Vec::new()).is_err());
    }

    #[test]
    fn run_batch_smoke() {
        run(argv(
            "batch --graph mico:tiny --queries motifs:3;cliques:3 --repeat 2 --assert-warm-hits --pmr naive --threads 2 --workers 2",
        ))
        .unwrap();
    }

    #[test]
    fn run_batch_rejects_bad_usage() {
        assert!(run(argv("batch --graph mico:tiny")).is_err(), "no queries");
        let fsm = argv("batch --graph mico:tiny --queries fsm:3:10");
        assert!(run(fsm).is_err(), "fsm not servable");
        let warm = argv("batch --graph mico:tiny --queries motifs:3 --assert-warm-hits");
        assert!(run(warm).is_err(), "warm assertion needs a warm round or a recovered store");
    }

    #[test]
    fn args_parse_positionals() {
        let a = Args::parse(&argv("store inspect --dir /tmp/x")).unwrap();
        assert_eq!(a.cmd, "store");
        assert_eq!(a.pos(0), Some("inspect"));
        assert_eq!(a.pos(1), None);
        assert_eq!(a.get("dir"), Some("/tmp/x"));
        // every other command still rejects stray positionals fast
        assert!(Args::parse(&argv("bench persist")).is_err());
        assert!(Args::parse(&argv("motifs foo --graph mico:tiny")).is_err());
    }

    #[test]
    fn run_sharded_batch_matches_single_process() {
        // two in-process workers standing in for worker processes; the
        // sharded batch must produce identical counts to the plain one
        let load = || crate::graph::io::load_spec("mico:tiny").unwrap();
        let worker = |g| {
            crate::shard::ShardWorker::bind(
                g,
                "127.0.0.1:0",
                crate::shard::WorkerConfig {
                    threads: 2,
                    fused: true,
                    cache_bytes: 1 << 20,
                    persist: None,
                    slice_pin: None,
                },
            )
            .unwrap()
        };
        let (a, b) = (worker(load()), worker(load()));
        let shards = format!("{},{}", a.addr(), b.addr());
        run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3;cliques:3 --pmr naive --threads 2 \
             --shards {shards} --repeat 2 --assert-warm-hits --trace --trace-tree --cluster-stats"
        )))
        .unwrap();
        // --persist and --fsync-every belong on the workers in sharded mode
        assert!(run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --shards {shards} --persist /tmp/nope"
        )))
        .is_err());
        assert!(run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --shards {shards} --fsync-every 1"
        )))
        .is_err());
        a.shutdown();
        b.shutdown();
        // dead workers fail the batch loudly, not silently
        assert!(run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --shards {shards}"
        )))
        .is_err());
    }

    #[test]
    fn run_replicated_shards_and_verified_reads() {
        let load = || crate::graph::io::load_spec("mico:tiny").unwrap();
        let worker = |g| {
            crate::shard::ShardWorker::bind(
                g,
                "127.0.0.1:0",
                crate::shard::WorkerConfig {
                    threads: 2,
                    fused: true,
                    cache_bytes: 1 << 20,
                    persist: None,
                    slice_pin: None,
                },
            )
            .unwrap()
        };
        let ws: Vec<_> = (0..4).map(|_| worker(load())).collect();
        let shards = format!(
            "{}|{},{}|{}",
            ws[0].addr(),
            ws[1].addr(),
            ws[2].addr(),
            ws[3].addr()
        );
        // 2 groups × 2 replicas with every read verified: same answers as
        // the unreplicated smoke, zero mismatches expected
        run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3;cliques:3 --pmr naive --threads 2 \
             --shards {shards} --verify-reads 1.0"
        )))
        .unwrap();
        // bad fractions fail before any connection attempt
        for bad in ["--verify-reads 1.5", "--verify-reads -0.1", "--verify-reads nan"] {
            assert!(
                run(argv(&format!(
                    "batch --graph mico:tiny --queries motifs:3 --shards {shards} {bad}"
                )))
                .is_err(),
                "{bad}"
            );
        }
        // verified reads without a replica to compare against are refused
        let flat = format!("{},{}", ws[0].addr(), ws[1].addr());
        assert!(run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --shards {flat} --verify-reads 0.5"
        )))
        .is_err());
        // the replication fabric flags still require --shards
        assert!(run(argv("batch --graph mico:tiny --queries motifs:3 --hedge-timeout 5")).is_err());
        assert!(run(argv("batch --graph mico:tiny --queries motifs:3 --verify-reads 0.5")).is_err());
        // a duplicated address is refused at parse time
        assert!(run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --shards {0}|{0}",
            ws[0].addr()
        )))
        .is_err());
        for w in ws {
            w.shutdown();
        }
    }

    #[test]
    fn shard_worker_slice_flag_is_validated() {
        // malformed or out-of-range pins fail fast (a valid pin would
        // block in wait(), so only the rejections are testable here)
        for bad in ["--slice 2", "--slice a/b", "--slice 2/2", "--slice 3/2", "--slice 1/0"] {
            assert!(
                run(argv(&format!(
                    "shard-worker --graph mico:tiny --listen 127.0.0.1:0 {bad}"
                )))
                .is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn fabric_timing_flags_are_validated() {
        // the timing flags configure the shard fabric; without --shards
        // they would be silently ignored, so they are rejected instead
        for flag in ["--connect-timeout 5", "--shard-timeout 5", "--probe-interval 1"] {
            assert!(
                run(argv(&format!("batch --graph mico:tiny --queries motifs:3 {flag}"))).is_err(),
                "{flag} must require --shards"
            );
        }
        let w = crate::shard::ShardWorker::bind(
            crate::graph::io::load_spec("mico:tiny").unwrap(),
            "127.0.0.1:0",
            crate::shard::WorkerConfig {
                threads: 2,
                fused: true,
                cache_bytes: 1 << 20,
                persist: None,
                slice_pin: None,
            },
        )
        .unwrap();
        let shards = w.addr().to_string();
        // bad values fail before any connection attempt
        for bad in [
            "--connect-timeout 0",
            "--connect-timeout -1",
            "--connect-timeout nan",
            "--shard-timeout wat",
            "--probe-interval 0",
        ] {
            assert!(
                run(argv(&format!(
                    "batch --graph mico:tiny --queries motifs:3 --shards {shards} {bad}"
                )))
                .is_err(),
                "{bad}"
            );
        }
        // a wedge deadline shorter than the probe interval is unsatisfiable
        assert!(run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --shards {shards} \
             --shard-timeout 0.05 --probe-interval 1"
        )))
        .is_err());
        // valid settings serve the batch normally
        run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --pmr naive --threads 2 \
             --shards {shards} --connect-timeout 5 --shard-timeout 10 --probe-interval 0.5"
        )))
        .unwrap();
        w.shutdown();
    }

    #[test]
    fn obs_flags_are_validated() {
        // --metrics needs a long-lived serving process
        for cmd in [
            "motifs --graph mico:tiny --size 3 --metrics 127.0.0.1:0",
            "batch --graph mico:tiny --queries motifs:3 --metrics 127.0.0.1:0",
            "info --graph mico:tiny --metrics 127.0.0.1:0",
            "store inspect --dir /tmp/nope --metrics 127.0.0.1:0",
        ] {
            assert!(run(argv(cmd)).is_err(), "{cmd} must reject --metrics");
        }
        // --trace / --trace-tree / --slow-query-ms render batch timings:
        // batch/serve only
        assert!(run(argv("motifs --graph mico:tiny --size 3 --trace")).is_err());
        assert!(run(argv("motifs --graph mico:tiny --size 3 --trace-tree")).is_err());
        assert!(run(argv("info --graph mico:tiny --slow-query-ms 5")).is_err());
        assert!(run(argv("store inspect --dir /tmp/nope --trace")).is_err());
        assert!(run(argv("store inspect --dir /tmp/nope --trace-tree")).is_err());
        // --metrics-dump is the one-shot batch exporter: nowhere else (on
        // `serve` the rejection fires before the stdin loop is entered)
        assert!(
            run(argv("motifs --graph mico:tiny --size 3 --metrics-dump /tmp/x.json")).is_err()
        );
        assert!(run(argv("serve --graph mico:tiny --metrics-dump /tmp/x.json")).is_err());
        // bad threshold values fail fast, before any work
        assert!(run(argv(
            "batch --graph mico:tiny --queries motifs:3 --slow-query-ms wat"
        ))
        .is_err());
        // --cluster-stats needs a shard fabric to sweep
        assert!(run(argv(
            "batch --graph mico:tiny --queries motifs:3 --cluster-stats"
        ))
        .is_err());
        assert!(run(argv("motifs --graph mico:tiny --cluster-stats")).is_err());
        // accepted where they act: a traced batch with threshold 0 logs
        // every round, renders its span tree, and still answers
        run(argv(
            "batch --graph mico:tiny --queries motifs:3 --pmr naive --threads 2 \
             --trace --trace-tree --slow-query-ms 0",
        ))
        .unwrap();
    }

    #[test]
    fn metrics_dump_writes_registry_json() {
        // an unwritable path fails before any matching work happens
        assert!(run(argv(
            "batch --graph mico:tiny --queries motifs:3 --metrics-dump /nonexistent-dir/m.json"
        ))
        .is_err());
        let out = std::env::temp_dir().join("mm_cli_metrics_dump.json");
        let _ = std::fs::remove_file(&out);
        run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --pmr naive --threads 2 \
             --metrics-dump {}",
            out.display()
        )))
        .unwrap();
        let doc = std::fs::read_to_string(&out).unwrap();
        assert!(doc.trim_start().starts_with('{'), "{doc}");
        assert!(doc.contains("mm_build_info"), "the dump must identify its producer: {doc}");
    }

    #[test]
    fn run_store_verify_checks_fingerprint() {
        let dir = std::env::temp_dir().join("mm_cli_store_verify");
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.display();
        // nothing persisted yet: verify fails
        assert!(run(argv(&format!("store verify --dir {d} --graph mico:tiny"))).is_err());
        run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --pmr naive --threads 2 --workers 1 --persist {d}"
        )))
        .unwrap();
        // right graph matches, wrong graph (different scale = content) fails
        run(argv(&format!("store verify --dir {d} --graph mico:tiny"))).unwrap();
        assert!(run(argv(&format!("store verify --dir {d} --graph patents:tiny"))).is_err());
        assert!(run(argv(&format!("store verify --dir {d}"))).is_err(), "needs --graph");
    }

    #[test]
    fn fsync_every_flag_is_validated() {
        // --fsync-every without --persist is a usage error
        assert!(run(argv(
            "batch --graph mico:tiny --queries motifs:3 --fsync-every 1"
        ))
        .is_err());
        let dir = std::env::temp_dir().join("mm_cli_fsync");
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.display();
        assert!(run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --persist {d} --fsync-every 0"
        )))
        .is_err());
        run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --pmr naive --threads 2 --workers 1 \
             --persist {d} --fsync-every 1"
        )))
        .unwrap();
        // the synced store recovers warm like a flushed one
        run(argv(&format!(
            "batch --graph mico:tiny --queries motifs:3 --pmr naive --threads 2 --workers 1 \
             --persist {d} --assert-warm-hits"
        )))
        .unwrap();
    }

    #[test]
    fn run_batch_persist_roundtrip_and_store_ops() {
        // two separate "processes": the first persists its store, the
        // second must be served entirely from the recovered image
        let dir = std::env::temp_dir().join("mm_cli_persist_rt");
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.display();
        let common =
            "batch --graph mico:tiny --queries motifs:3;cliques:3 --pmr naive --threads 2 --workers 1";
        run(argv(&format!("{common} --persist {d}"))).unwrap();
        run(argv(&format!("{common} --persist {d} --assert-warm-hits"))).unwrap();
        // offline store maintenance on the same directory
        run(argv(&format!("store inspect --dir {d}"))).unwrap();
        run(argv(&format!("store compact --dir {d}"))).unwrap();
        run(argv(&format!("store purge --dir {d}"))).unwrap();
        // post-purge: nothing left, a restart is cold again → warm
        // assertion must now fail
        assert!(run(argv(&format!("{common} --persist {d} --assert-warm-hits"))).is_err());
        // bad store usage
        assert!(run(argv("store --dir /tmp/nope")).is_err(), "missing action");
        assert!(run(argv(&format!("store frobnicate --dir {d}"))).is_err());
        assert!(run(argv("store inspect")).is_err(), "missing --dir");
        assert!(
            run(argv(&format!("store purge inspect --dir {d}"))).is_err(),
            "extra positionals after the action must fail fast, not be dropped"
        );
    }
}
