//! Hand-rolled CLI (clap is not available offline).
//!
//! ```text
//! morphmine motifs  --graph <spec> [--size 4] [--pmr off|naive|cost] [--threads N] [--fused on|off]
//! morphmine match   --graph <spec> --patterns <p1,p2,…> [--pmr …] [--fused …] [--explain]
//! morphmine fsm     --graph <spec> [--edges 3] [--support 100] [--pmr …] [--fused …]
//! morphmine cliques --graph <spec> [--k 4]
//! morphmine census  --graph <spec> [--artifacts artifacts]
//! morphmine gen     --dataset mico[:scale] --out <path>
//! morphmine bench   [--exp all|table1|table2|table3|table4|fig2|fig5|fused|kernels|service|persist|ablations] [--scale tiny|small|medium]
//! morphmine info    --graph <spec>
//! morphmine batch   --graph <spec> --queries "motifs:4;match:cycle4,p3" [--repeat 2] [--workers 2] [--cache-mb 64] [--persist <dir>] [--assert-warm-hits]
//! morphmine serve   --graph <spec> [--workers 2] [--cache-mb 64] [--persist <dir>]
//! morphmine store   <inspect|compact|purge> --dir <dir>
//! ```
//!
//! Graph specs: dataset names (`mico`, `patents`, `youtube`, `orkut`,
//! optionally `:tiny|:small|:medium`) or a path to an edge-list file.
//!
//! `batch` runs one query batch (`;`-separated query texts) through the
//! result-cache service, `--repeat` re-submitting it to demonstrate warm
//! throughput; `--assert-warm-hits` exits nonzero unless the final repeat
//! was fully cache-served (the CI smoke leg; with `--repeat 1` it instead
//! requires the single batch to be served entirely from a store recovered
//! via `--persist` — the warm-restart smoke). `serve` is the interactive
//! loop: one batch per stdin line, `+ u v` / `- u v` applies an edge
//! update (bumping the cache epoch), `quit` exits.
//!
//! `--persist <dir>` makes the result store durable (WAL + snapshots, see
//! [`crate::service::persist`]): a restart against the same graph content
//! recovers warm; against different content it recovers cold. `store`
//! operates on such a directory offline: `inspect` prints what recovery
//! would find, `compact` folds the WAL into one snapshot, `purge` deletes
//! the persisted files.

use crate::coordinator::{Config, Coordinator};
use crate::graph::io::load_spec;
use crate::morph::Policy;
use crate::service::{persist, BatchResponse, PersistConfig, Service, ServiceConfig};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;

/// Parsed flags: the positional subcommand, optional positional
/// subactions immediately after it (e.g. `store inspect`), then
/// `--key value` pairs.
pub struct Args {
    pub cmd: String,
    pos: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            bail!("usage: morphmine <motifs|match|fsm|cliques|census|gen|bench|info|batch|serve|store> [--flags]\nsee `morphmine help`");
        }
        let cmd = argv[0].clone();
        let mut pos = Vec::new();
        let mut i = 1;
        while i < argv.len() && !argv[i].starts_with("--") {
            pos.push(argv[i].clone());
            i += 1;
        }
        // only `store` takes positional subactions; everywhere else a bare
        // word is a typo'd flag and must fail fast, not be ignored
        if cmd != "store" && !pos.is_empty() {
            bail!("expected --flag, got {:?}", pos[0]);
        }
        let mut flags = HashMap::new();
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("expected --flag, got {a:?}");
            };
            let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                i += 1;
                argv[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), val);
            i += 1;
        }
        Ok(Args { cmd, pos, flags })
    }

    /// Positional subaction after the command (`store inspect` → `pos(0)
    /// == Some("inspect")`).
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.pos.get(i).map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("bad --{key} {s:?}: {e}")),
        }
    }
}

fn policy_of(args: &Args) -> Result<Policy> {
    let s = args.get_or("pmr", "cost");
    Policy::parse(&s).with_context(|| format!("bad --pmr {s:?} (off|naive|cost)"))
}

fn fused_of(args: &Args) -> Result<bool> {
    match args.get("fused") {
        None | Some("on") | Some("true") => Ok(true),
        Some("off") | Some("false") => Ok(false),
        Some(other) => bail!("bad --fused {other:?} (on|off)"),
    }
}

fn service_of(args: &Args) -> Result<Service> {
    let spec = args
        .get("graph")
        .context("missing --graph <dataset[:scale] | path>")?;
    let graph = load_spec(spec)?;
    let config = ServiceConfig {
        workers: args.parse_num("workers", 2usize)?,
        threads: args.parse_num("threads", crate::exec::parallel::default_threads())?,
        policy: policy_of(args)?,
        fused: fused_of(args)?,
        cache_bytes: args.parse_num("cache-mb", 64usize)? << 20,
        persist: args.get("persist").map(PersistConfig::new),
    };
    let svc = Service::try_start(graph, config)?;
    if let Some(r) = svc.recovery_report() {
        println!(
            "persist: restored {} entries (snapshot {}, wal records {}, truncated tail: {}, fingerprint match: {})",
            r.restored, r.snapshot_entries, r.wal_records, r.wal_truncated, r.fingerprint_matched
        );
    }
    Ok(svc)
}

fn print_batch(r: &BatchResponse) {
    let s = &r.stats;
    println!(
        "epoch={}  bases: total={} cached={} executed={} coalesced={}",
        r.epoch, s.total_bases, s.cached_bases, s.executed_bases, s.coalesced_bases
    );
    for q in &r.results {
        for (p, n) in &q.counts {
            println!("{n:>16}  {p:?}   [{}]", q.query);
        }
    }
    print_profile(&r.profile);
}

fn coordinator_of(args: &Args) -> Result<Coordinator> {
    let spec = args
        .get("graph")
        .context("missing --graph <dataset[:scale] | path>")?;
    let graph = load_spec(spec)?;
    let mut config = Config {
        policy: policy_of(args)?,
        threads: args.parse_num("threads", crate::exec::parallel::default_threads())?,
        artifacts_dir: None,
        fused: fused_of(args)?,
        ..Config::default()
    };
    if let Some(dir) = args.get("artifacts") {
        config.artifacts_dir = Some(dir.into());
    }
    Coordinator::new(graph, config)
}

/// CLI entrypoint.
pub fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(&argv)?;
    match args.cmd.as_str() {
        "motifs" => {
            let c = coordinator_of(&args)?;
            let size = args.parse_num("size", 4usize)?;
            println!("{}", c.describe());
            let t = crate::util::timer::Timer::start();
            let (counts, backend) = c.motifs(size)?;
            println!("backend: {backend:?}   elapsed: {:.3}s", t.secs());
            for (p, n) in &counts.counts {
                println!("{:>16}  {:?}", n, p);
            }
            print_profile(&counts.profile);
        }
        "match" => {
            let c = coordinator_of(&args)?;
            let specs = args.get("patterns").context("missing --patterns p1,p2,…")?;
            let queries = specs
                .split(',')
                .map(crate::pattern::parse::parse)
                .collect::<Result<Vec<_>>>()?;
            println!("{}", c.describe());
            let t = crate::util::timer::Timer::start();
            let r = c.match_patterns(&queries);
            println!("elapsed: {:.3}s", t.secs());
            for (q, n) in queries.iter().zip(&r.counts) {
                println!("{:>16}  {:?}", n, q);
            }
            if args.get("explain").is_some() {
                println!("alternative pattern set:");
                for p in &r.alt_set {
                    println!("    {p:?}");
                }
                for e in &r.equations {
                    println!("  {e}");
                }
            }
            print_profile(&r.profile);
        }
        "fsm" => {
            let c = coordinator_of(&args)?;
            let edges = args.parse_num("edges", 3usize)?;
            let support = args.parse_num("support", 100u64)?;
            println!("{}", c.describe());
            let t = crate::util::timer::Timer::start();
            let r = c.fsm(edges, support);
            println!("elapsed: {:.3}s", t.secs());
            println!(
                "frequent {}-edge patterns (support ≥ {support}): {}",
                edges,
                r.frequent.len()
            );
            for (p, s) in r.frequent.iter().take(20) {
                println!("{s:>12}  {p:?}");
            }
            print_profile(&r.profile);
        }
        "cliques" => {
            let c = coordinator_of(&args)?;
            let k = args.parse_num("k", 4usize)?;
            let t = crate::util::timer::Timer::start();
            let n = c.cliques(k);
            println!("{k}-cliques: {n}   ({:.3}s)", t.secs());
        }
        "census" => {
            let spec = args.get("graph").context("missing --graph")?;
            let graph = load_spec(spec)?;
            let dir = args.get_or("artifacts", "artifacts");
            let be = crate::runtime::CensusBackend::load(std::path::Path::new(&dir))?;
            println!("dense census via PJRT ({})", be.platform());
            let t = crate::util::timer::Timer::start();
            let r = be.census_graph(&graph)?;
            println!("elapsed: {:.3}s", t.secs());
            for (name, v) in crate::runtime::CENSUS_OUTPUTS.iter().zip(&r.values) {
                println!("{v:>16}  {name}");
            }
        }
        "gen" => {
            let d = args.get("dataset").context("missing --dataset")?;
            let out = args.get("out").context("missing --out <path>")?;
            let graph = load_spec(d)?;
            crate::graph::io::save_text(&graph, std::path::Path::new(out))?;
            println!(
                "wrote {} (|V|={} |E|={})",
                out,
                graph.num_vertices(),
                graph.num_edges()
            );
        }
        "bench" => {
            let exp = args.get_or("exp", "all");
            let scale = crate::graph::generators::Scale::parse(&args.get_or("scale", "tiny"))
                .context("bad --scale")?;
            let threads = args.parse_num("threads", crate::exec::parallel::default_threads())?;
            crate::bench::run_experiment(&exp, scale, threads)?;
        }
        "batch" => {
            let svc = service_of(&args)?;
            let spec = args.get("queries").context("missing --queries q1;q2;…")?;
            let texts: Vec<&str> = spec
                .split(';')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .collect();
            ensure!(!texts.is_empty(), "--queries must name at least one query");
            let repeat = args.parse_num("repeat", 1usize)?.max(1);
            let mut last = None;
            for round in 1..=repeat {
                let t = crate::util::timer::Timer::start();
                let r = svc.call(&texts)?;
                println!("batch {round}/{repeat}: elapsed {:.3}s", t.secs());
                print_batch(&r);
                last = Some(r.stats);
            }
            let m = svc.store_metrics();
            println!(
                "store: hits={} misses={} inserts={} evictions={} invalidations={} bytes={}",
                m.hits, m.misses, m.inserts, m.evictions, m.invalidations, m.bytes
            );
            if args.get("assert-warm-hits").is_some() {
                let s = last.expect("at least one round ran");
                // with a single round the warmth must come from a store
                // recovered off disk (the CI warm-restart smoke); with
                // repeats, round 1 warms rounds 2+ in memory
                ensure!(
                    repeat >= 2 || args.get("persist").is_some(),
                    "--assert-warm-hits needs --repeat ≥ 2 (a warm round to check) or --persist (a recovered store to serve from)"
                );
                ensure!(
                    s.executed_bases == 0 && s.cached_bases + s.coalesced_bases > 0,
                    "warm batch was not cache-served: {s:?}"
                );
                ensure!(m.hits > 0, "store reported zero hits: {m:?}");
                println!("warm-cache assertion passed ({} hits)", m.hits);
            }
        }
        "serve" => {
            let svc = service_of(&args)?;
            println!(
                "morphmine service ready (epoch {}). One batch per line, queries separated by ';'",
                svc.epoch()
            );
            println!("  e.g. `motifs:4;match:cycle4,diamond-vi` — `+ u v` / `- u v` applies an edge update, `quit` exits");
            let stdin = std::io::stdin();
            let mut line = String::new();
            loop {
                line.clear();
                if stdin.read_line(&mut line)? == 0 {
                    break; // EOF
                }
                let text = line.trim();
                if text.is_empty() {
                    continue;
                }
                if text == "quit" || text == "exit" {
                    break;
                }
                if let Some(rest) = text.strip_prefix('+').or_else(|| text.strip_prefix('-')) {
                    let insert = text.starts_with('+');
                    let mut it = rest.split_whitespace();
                    match (
                        it.next().and_then(|s| s.parse::<u32>().ok()),
                        it.next().and_then(|s| s.parse::<u32>().ok()),
                    ) {
                        (Some(u), Some(v)) if u != v => {
                            let applied = if insert {
                                svc.insert_edge(u, v)
                            } else {
                                svc.remove_edge(u, v)
                            };
                            match applied {
                                Ok(applied) => println!(
                                    "{} edge ({u},{v}): applied={applied} epoch={}",
                                    if insert { "insert" } else { "remove" },
                                    svc.epoch()
                                ),
                                Err(e) => eprintln!("error: {e:#}"),
                            }
                        }
                        _ => eprintln!("usage: +|- <u> <v> (two distinct vertex ids)"),
                    }
                    continue;
                }
                let texts: Vec<&str> = text
                    .split(';')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .collect();
                match svc.call(&texts) {
                    Ok(r) => print_batch(&r),
                    Err(e) => eprintln!("error: {e:#}"),
                }
            }
        }
        "store" => store_cmd(&args)?,
        "info" => {
            let c = coordinator_of(&args)?;
            println!("{}", c.describe());
            let s = c.stats();
            println!(
                "wedges={:.0} density={:.6} clustering={:.4} deg²Σ={:.0}",
                s.wedges, s.density, s.clustering, s.deg_sq_sum
            );
        }
        "help" | "--help" | "-h" => {
            println!("see module docs: motifs | match | fsm | cliques | census | gen | bench | info | batch | serve | store");
        }
        other => bail!("unknown command {other:?} — try `morphmine help`"),
    }
    Ok(())
}

/// `morphmine store <inspect|compact|purge> --dir <path>` — offline
/// maintenance of a persist directory (no graph, no service).
fn store_cmd(args: &Args) -> Result<()> {
    let action = args
        .pos(0)
        .context("usage: morphmine store <inspect|compact|purge> --dir <path>")?;
    if let Some(extra) = args.pos(1) {
        bail!("unexpected argument {extra:?} after store action {action:?}");
    }
    let dir = args.get("dir").context("missing --dir <persist directory>")?;
    let dir = std::path::PathBuf::from(dir);
    match action {
        "inspect" => {
            let i = persist::inspect::<i128>(&dir);
            match (i.snapshot, i.snapshot_bytes) {
                (Some((fp, n)), bytes) => {
                    println!("snapshot: {n} entries for {fp} ({} bytes)", bytes.unwrap_or(0))
                }
                (None, Some(b)) => {
                    println!("snapshot: unreadable ({b} bytes present, rejected by CRC/format)")
                }
                (None, None) => println!("snapshot: none"),
            }
            match i.wal_bytes {
                Some(b) => {
                    let tail = if i.wal_truncated {
                        ", torn/corrupt tail present"
                    } else {
                        ""
                    };
                    println!("wal: {} records ({b} bytes{tail})", i.wal_records);
                }
                None => println!("wal: none"),
            }
            match i.fingerprint {
                Some(fp) => println!("recoverable image: {} entries for {fp}", i.live_entries),
                None => println!("recoverable image: none"),
            }
        }
        "compact" => {
            let (entries, folded) = persist::compact_dir::<i128>(&dir)?;
            println!("compacted {}: {entries} entries, {folded} records folded", dir.display());
        }
        "purge" => {
            let removed = persist::purge_dir(&dir)?;
            println!("purged {}: {removed} files removed", dir.display());
        }
        other => bail!("unknown store action {other:?} (inspect|compact|purge)"),
    }
    Ok(())
}

fn print_profile(p: &crate::util::timer::PhaseProfile) {
    let total = p.total().as_secs_f64();
    if total <= 0.0 {
        return;
    }
    print!("phases:");
    for (name, d) in p.entries() {
        print!("  {name}={:.3}s ({:.0}%)", d.as_secs_f64(), 100.0 * d.as_secs_f64() / total);
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn args_parse_flags() {
        let a = Args::parse(&argv("motifs --graph mico:tiny --size 4 --explain")).unwrap();
        assert_eq!(a.cmd, "motifs");
        assert_eq!(a.get("graph"), Some("mico:tiny"));
        assert_eq!(a.parse_num("size", 3usize).unwrap(), 4);
        assert_eq!(a.get("explain"), Some("true"));
        assert!(a.parse_num::<usize>("graph", 1).is_err());
    }

    #[test]
    fn run_motifs_smoke() {
        run(argv("motifs --graph mico:tiny --size 3 --pmr naive --threads 2")).unwrap();
    }

    #[test]
    fn run_motifs_fused_toggle() {
        run(argv("motifs --graph mico:tiny --size 3 --pmr naive --threads 2 --fused off")).unwrap();
        run(argv("motifs --graph mico:tiny --size 3 --pmr naive --threads 2 --fused on")).unwrap();
        assert!(run(argv("motifs --graph mico:tiny --fused maybe")).is_err());
    }

    #[test]
    fn run_match_smoke() {
        run(argv(
            "match --graph patents:tiny --patterns cycle4,diamond-vi --pmr cost --explain --threads 2",
        ))
        .unwrap();
    }

    #[test]
    fn run_info_and_gen() {
        run(argv("info --graph mico:tiny")).unwrap();
        let out = std::env::temp_dir().join("mm_cli_gen.txt");
        run(argv(&format!("gen --dataset mico:tiny --out {}", out.display()))).unwrap();
        assert!(out.exists());
    }

    #[test]
    fn run_rejects_unknown() {
        assert!(run(argv("frobnicate")).is_err());
        assert!(run(Vec::new()).is_err());
    }

    #[test]
    fn run_batch_smoke() {
        run(argv(
            "batch --graph mico:tiny --queries motifs:3;cliques:3 --repeat 2 --assert-warm-hits --pmr naive --threads 2 --workers 2",
        ))
        .unwrap();
    }

    #[test]
    fn run_batch_rejects_bad_usage() {
        assert!(run(argv("batch --graph mico:tiny")).is_err(), "no queries");
        let fsm = argv("batch --graph mico:tiny --queries fsm:3:10");
        assert!(run(fsm).is_err(), "fsm not servable");
        let warm = argv("batch --graph mico:tiny --queries motifs:3 --assert-warm-hits");
        assert!(run(warm).is_err(), "warm assertion needs a warm round or a recovered store");
    }

    #[test]
    fn args_parse_positionals() {
        let a = Args::parse(&argv("store inspect --dir /tmp/x")).unwrap();
        assert_eq!(a.cmd, "store");
        assert_eq!(a.pos(0), Some("inspect"));
        assert_eq!(a.pos(1), None);
        assert_eq!(a.get("dir"), Some("/tmp/x"));
        // every other command still rejects stray positionals fast
        assert!(Args::parse(&argv("bench persist")).is_err());
        assert!(Args::parse(&argv("motifs foo --graph mico:tiny")).is_err());
    }

    #[test]
    fn run_batch_persist_roundtrip_and_store_ops() {
        // two separate "processes": the first persists its store, the
        // second must be served entirely from the recovered image
        let dir = std::env::temp_dir().join("mm_cli_persist_rt");
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.display();
        let common =
            "batch --graph mico:tiny --queries motifs:3;cliques:3 --pmr naive --threads 2 --workers 1";
        run(argv(&format!("{common} --persist {d}"))).unwrap();
        run(argv(&format!("{common} --persist {d} --assert-warm-hits"))).unwrap();
        // offline store maintenance on the same directory
        run(argv(&format!("store inspect --dir {d}"))).unwrap();
        run(argv(&format!("store compact --dir {d}"))).unwrap();
        run(argv(&format!("store purge --dir {d}"))).unwrap();
        // post-purge: nothing left, a restart is cold again → warm
        // assertion must now fail
        assert!(run(argv(&format!("{common} --persist {d} --assert-warm-hits"))).is_err());
        // bad store usage
        assert!(run(argv("store --dir /tmp/nope")).is_err(), "missing action");
        assert!(run(argv(&format!("store frobnicate --dir {d}"))).is_err());
        assert!(run(argv("store inspect")).is_err(), "missing --dir");
        assert!(
            run(argv(&format!("store purge inspect --dir {d}"))).is_err(),
            "extra positionals after the action must fail fast, not be dropped"
        );
    }
}
