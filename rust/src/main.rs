//! `morphmine` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands cover the paper's applications and the reproduction harness:
//!
//! ```text
//! morphmine motifs   --graph <spec> --size 4 [--pmr naive|cost|off]
//! morphmine fsm      --graph <spec> --edges 3 --support 300 [--pmr ...]
//! morphmine match    --graph <spec> --pattern <pat> [--pmr ...]
//! morphmine bench    --exp table3 [--scale small]
//! morphmine census   --graph <spec> --artifacts artifacts/   # XLA dense backend
//! morphmine gen      --dataset mico-sim --out data/mico.txt  # synthesize datasets
//! ```
fn main() {
    if let Err(e) = morphmine::cli::run(std::env::args().skip(1).collect()) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
