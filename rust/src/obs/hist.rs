//! Log2-bucketed histograms with mergeable snapshots and percentile
//! extraction.
//!
//! Bucket `b` holds values whose bit length is `b`: bucket 0 is exactly
//! `{0}`, bucket `b ≥ 1` covers `[2^(b-1), 2^b - 1]`. 65 buckets span the
//! full `u64` domain, so recording never clamps and a microsecond latency
//! histogram resolves from sub-microsecond to half a million years within
//! a factor of two — the right trade for latency data, where percentile
//! *magnitude* matters and 2× resolution is plenty.
//!
//! Recording is two relaxed `fetch_add`s (bucket + running sum).
//! Percentiles are computed from snapshots at read time and are reported
//! as the **upper bound of the bucket holding the rank-q value** — a
//! conservative bound: at least a `q` fraction of recorded values are ≤
//! the reported pq (the property test pins this contract). Snapshots are
//! plain arrays, so cross-worker merging is element-wise addition —
//! commutative and associative, which is what lets the coordinator sum
//! worker histograms in any order and still report exact bucket counts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Bucket count: bit lengths 0..=64.
pub const NUM_BUCKETS: usize = 65;

/// Bucket index of a value: its bit length.
#[inline]
fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// Largest value bucket `b` can hold.
pub fn bucket_upper(b: usize) -> u64 {
    match b {
        0 => 0,
        1..=63 => (1u64 << b) - 1,
        _ => u64::MAX,
    }
}

/// Smallest value bucket `b` can hold.
pub fn bucket_lower(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        1u64 << (b - 1)
    }
}

/// Concurrent log2 histogram.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one value.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration in microseconds.
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Point-in-time copy. The count is *derived from the buckets*, so a
    /// snapshot is always internally consistent (every counted value is in
    /// exactly one bucket) even when taken mid-record.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// Mergeable, serializable histogram image.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Per-bucket counts, `NUM_BUCKETS` long (shorter vectors — e.g. built
    /// from a partial wire image — are treated as zero-extended).
    pub buckets: Vec<u64>,
    /// Sum of recorded values.
    pub sum: u64,
}

impl HistSnapshot {
    pub fn empty() -> HistSnapshot {
        HistSnapshot {
            buckets: vec![0; NUM_BUCKETS],
            sum: 0,
        }
    }

    /// Total recorded values.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Element-wise accumulate `other` into `self` — commutative and
    /// associative, the algebra cross-worker aggregation relies on.
    pub fn merge(&mut self, other: &HistSnapshot) {
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.sum += other.sum;
    }

    /// Upper bound of the bucket containing the rank-⌈q·n⌉ value
    /// (0 when empty). At least a `q` fraction of recorded values are ≤
    /// the returned bound.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper(b);
            }
        }
        bucket_upper(NUM_BUCKETS - 1)
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum as f64 / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn bucket_bounds_partition_u64() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), 64);
        for b in 0..NUM_BUCKETS {
            assert!(bucket_lower(b) <= bucket_upper(b));
            assert_eq!(bucket_of(bucket_lower(b)), b);
            assert_eq!(bucket_of(bucket_upper(b)), b);
            if b > 0 {
                assert_eq!(bucket_upper(b - 1) + 1, bucket_lower(b), "buckets meet");
            }
        }
    }

    #[test]
    fn quantiles_of_known_distribution() {
        let h = Histogram::new();
        // 100 values: 1..=100
        for v in 1..=100u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 100);
        assert_eq!(s.sum, 5050);
        // rank 50 value is 50 → bucket 6 ([32,63]) → upper bound 63
        assert_eq!(s.p50(), 63);
        // rank 99 value is 99 → bucket 7 ([64,127]) → upper bound 127
        assert_eq!(s.p99(), 127);
        assert_eq!(s.quantile(1.0), 127);
        assert_eq!(HistSnapshot::empty().p50(), 0);
    }

    /// Satellite property test: for every quantile, the reported bound is
    /// the upper edge of a bucket that (a) at least a q-fraction of the
    /// recorded values fall at or below, and (b) actually contains the
    /// rank-q value — i.e. the rank-q value lies within the reported
    /// bucket's bounds.
    #[test]
    fn prop_percentiles_bound_recorded_values() {
        proptest::check(0x0B5E, 120, |rng| {
            let n = 1 + rng.below_usize(400);
            let mut vals: Vec<u64> = (0..n)
                .map(|_| {
                    // mix magnitudes: uniform small, exponential large
                    let shift = rng.below(48) as u32;
                    rng.below(1 << 16) << shift
                })
                .collect();
            let h = Histogram::new();
            for &v in &vals {
                h.record(v);
            }
            vals.sort_unstable();
            let s = h.snapshot();
            assert_eq!(s.count(), n as u64);
            for &q in &[0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let bound = s.quantile(q);
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let exact = vals[rank - 1];
                // the rank-q value lies within the reported bucket
                assert!(
                    exact <= bound,
                    "q={q}: exact {exact} above reported bound {bound}"
                );
                assert!(
                    exact >= bucket_lower(bucket_of(bound)),
                    "q={q}: exact {exact} below reported bucket"
                );
                // at least a q fraction of values are ≤ the bound
                let at_or_below = vals.iter().filter(|&&v| v <= bound).count();
                assert!(
                    at_or_below >= rank,
                    "q={q}: only {at_or_below}/{n} values ≤ {bound}"
                );
            }
        });
    }

    /// Satellite property test: merge is associative (and commutative) —
    /// the coordinator may fold worker snapshots in any order.
    #[test]
    fn prop_merge_associative() {
        proptest::check(0x03E6, 100, |rng| {
            let mk = |rng: &mut crate::util::rng::Rng| {
                let h = Histogram::new();
                for _ in 0..rng.below(200) {
                    h.record(rng.below(1 << 30));
                }
                h.snapshot()
            };
            let (a, b, c) = (mk(rng), mk(rng), mk(rng));
            // (a ⊕ b) ⊕ c
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            // a ⊕ (b ⊕ c)
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            assert_eq!(left, right, "associativity");
            // b ⊕ a == a ⊕ b
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            assert_eq!(ab, ba, "commutativity");
            assert_eq!(left.count(), a.count() + b.count() + c.count());
            assert_eq!(left.sum, a.sum + b.sum + c.sum);
        });
    }

    #[test]
    fn merge_zero_extends_short_images() {
        let mut short = HistSnapshot {
            buckets: vec![3, 1],
            sum: 4,
        };
        let full = HistSnapshot::empty();
        short.merge(&full);
        assert_eq!(short.buckets.len(), NUM_BUCKETS);
        assert_eq!(short.count(), 4);
    }

    #[test]
    fn record_duration_uses_micros() {
        let h = Histogram::new();
        h.record_duration(Duration::from_millis(3));
        let s = h.snapshot();
        assert_eq!(s.sum, 3000);
        assert_eq!(s.count(), 1);
    }
}
