//! Atomic counters/gauges and the name-keyed metric registry.
//!
//! Counters and gauges are single relaxed `AtomicU64`s — increments from
//! any number of threads sum exactly (fetch-and-add is atomic; relaxed
//! ordering only relaxes *when* other threads see the value, never whether
//! an increment is counted). The registry maps series names to `Arc`ed
//! metrics; handles stay valid forever, so hot paths look a name up once
//! and then never touch the lock again.

use super::hist::Histogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous non-negative level (queue depths, in-flight requests).
/// Decrements saturate at zero rather than wrapping: a scrape racing a
/// transient imbalance should read a small number, never ~2^64.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn dec(&self) {
        self.sub(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn sub(&self, n: u64) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(n))
            });
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// One registered metric.
#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<Histogram>),
}

/// Point-in-time value of one registered metric.
#[derive(Clone, Debug)]
pub enum Sample {
    Counter(u64),
    Gauge(u64),
    Hist(super::hist::HistSnapshot),
}

/// Name → metric map. Lookup is get-or-create; re-registering a name
/// replaces the binding (the common case is a restarted in-process test
/// worker re-registering its store — last writer wins, and the old `Arc`
/// stays valid for whoever still holds it).
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create the counter named `name`. A name previously bound to
    /// a different metric kind is rebound to a fresh counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        if let Some(Metric::Counter(c)) = m.get(name) {
            return c.clone();
        }
        let c = Arc::new(Counter::new());
        m.insert(name.to_string(), Metric::Counter(c.clone()));
        c
    }

    /// Get or create the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        if let Some(Metric::Gauge(g)) = m.get(name) {
            return g.clone();
        }
        let g = Arc::new(Gauge::new());
        m.insert(name.to_string(), Metric::Gauge(g.clone()));
        g
    }

    /// Get or create the histogram named `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        if let Some(Metric::Hist(h)) = m.get(name) {
            return h.clone();
        }
        let h = Arc::new(Histogram::new());
        m.insert(name.to_string(), Metric::Hist(h.clone()));
        h
    }

    /// Register an existing counter under `name` — how a component that
    /// owns its counters privately (e.g. a `ResultStore`) exposes the very
    /// same atomics for scraping. Registration shares the `Arc`; the
    /// scrape view is live, not a copy.
    pub fn register_counter(&self, name: &str, c: Arc<Counter>) {
        self.metrics
            .lock()
            .unwrap()
            .insert(name.to_string(), Metric::Counter(c));
    }

    /// Register an existing gauge under `name` (see [`Registry::register_counter`]).
    pub fn register_gauge(&self, name: &str, g: Arc<Gauge>) {
        self.metrics
            .lock()
            .unwrap()
            .insert(name.to_string(), Metric::Gauge(g));
    }

    /// Register an existing histogram under `name` (see [`Registry::register_counter`]).
    pub fn register_histogram(&self, name: &str, h: Arc<Histogram>) {
        self.metrics
            .lock()
            .unwrap()
            .insert(name.to_string(), Metric::Hist(h));
    }

    /// Snapshot every registered metric, sorted by name.
    pub fn snapshot(&self) -> Vec<(String, Sample)> {
        let m = self.metrics.lock().unwrap();
        m.iter()
            .map(|(name, metric)| {
                let sample = match metric {
                    Metric::Counter(c) => Sample::Counter(c.get()),
                    Metric::Gauge(g) => Sample::Gauge(g.get()),
                    Metric::Hist(h) => Sample::Hist(h.snapshot()),
                };
                (name.clone(), sample)
            })
            .collect()
    }
}

/// The process-wide registry: what `--metrics` scrapes, what the proto v4
/// `STATS` reply snapshots, and what the bench harness embeds in JSON
/// rows.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        g.dec(); // saturates, never wraps
        assert_eq!(g.get(), 0);
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn registry_get_or_create_shares_handles() {
        let r = Registry::new();
        let a = r.counter("x_total");
        let b = r.counter("x_total");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3, "both handles hit the same atomic");
        // kind rebind: a gauge request on a counter name yields a fresh
        // gauge (the counter handle stays usable but unregistered)
        let g = r.gauge("x_total");
        g.set(9);
        assert_eq!(a.get(), 3);
        match &r.snapshot()[..] {
            [(name, Sample::Gauge(v))] => {
                assert_eq!(name, "x_total");
                assert_eq!(*v, 9);
            }
            other => panic!("unexpected snapshot {other:?}"),
        }
    }

    #[test]
    fn registered_external_counter_is_live() {
        let r = Registry::new();
        let c = Arc::new(Counter::new());
        r.register_counter("mm_store_hits_total", c.clone());
        c.add(11);
        match &r.snapshot()[..] {
            [(_, Sample::Counter(v))] => assert_eq!(*v, 11),
            other => panic!("unexpected snapshot {other:?}"),
        }
    }

    /// Satellite: concurrent updates from many threads sum exactly — the
    /// whole point of fetch-and-add counters.
    #[test]
    fn concurrent_updates_sum_exactly() {
        let r = Registry::new();
        let threads = 8usize;
        let per_thread = 10_000u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let r = &r;
                s.spawn(move || {
                    let c = r.counter("mm_concurrency_test_total");
                    let h = r.histogram("mm_concurrency_test_us");
                    for i in 0..per_thread {
                        c.inc();
                        h.record(t as u64 * per_thread + i);
                    }
                });
            }
        });
        assert_eq!(
            r.counter("mm_concurrency_test_total").get(),
            threads as u64 * per_thread
        );
        let snap = r.histogram("mm_concurrency_test_us").snapshot();
        assert_eq!(snap.count(), threads as u64 * per_thread);
        // sum of 0..(threads*per_thread) exactly
        let n = threads as u64 * per_thread;
        assert_eq!(snap.sum, n * (n - 1) / 2);
    }
}
