//! DISTRIBUTED TRACING — per-batch span trees and a slow-query flight
//! recorder.
//!
//! The metrics registry ([`crate::obs::registry`]) answers "how much /
//! how fast on average"; this module answers "*where did this batch's
//! time go*". Every served batch gets a [`Trace`]: a process-unique
//! trace id plus a tree of [`SpanRecord`]s — the batch root, one child
//! per pipeline stage (plan / probe / match / fuse / convert / persist,
//! from the same [`PhaseProfile`](crate::util::timer::PhaseProfile) the
//! legacy `trace:` line reads), and on a sharded coordinator one span
//! per remote sub-slice dispatch with the worker's own child spans
//! (store probe, match) grafted underneath. Hedges, failovers, and
//! retries appear as sibling spans with outcome tags, so the `fabric:`
//! counters become causally attributed events.
//!
//! Propagation: the shard protocol (proto v5) carries the trace context
//! downstream — EXEC holds `(trace_id, parent_span)` — and the worker's
//! child spans ride back in RESULT with *reply-relative* parent indices
//! ([`WIRE_PARENT_ROOT`] marks "attach to the dispatch span"). The
//! coordinator renumbers them into its own span-id space when grafting,
//! so span ids never need cross-process coordination.
//!
//! Tracing is **read-only**: spans observe timings that are measured
//! anyway, no control-flow decision ever consults them, and the sharded
//! fabric records them unconditionally (the worker already computes the
//! per-request profile it previously discarded). Enabling or disabling
//! the renderers therefore cannot change any count — CI re-asserts
//! sharded counts byte-identical with tracing on and off.
//!
//! Retention: the process-global [`FlightRecorder`] keeps the last
//! [`RING_CAPACITY`] complete traces in a ring and *pins* any trace
//! whose batch blew `--slow-query-ms` (up to [`PINNED_CAPACITY`],
//! oldest pin evicted first), so the evidence for a slow batch survives
//! until someone looks: `--metrics`' HTTP listener serves the whole
//! recorder as `/trace.json`, and `--trace-tree` renders the indented
//! tree with per-span wall/self times as batches complete.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Reply-relative parent sentinel in proto v5 RESULT spans: "my parent
/// is the coordinator's dispatch span for this sub-slice".
pub const WIRE_PARENT_ROOT: u32 = u32::MAX;

/// Complete traces kept in the flight-recorder ring (most recent wins).
pub const RING_CAPACITY: usize = 16;

/// Slow traces kept pinned (oldest pin evicted once full — a pin
/// protects evidence, it must not become an unbounded leak).
pub const PINNED_CAPACITY: usize = 32;

/// One timed event in a trace. `start_us` is microseconds since the
/// trace's root began (remote spans are offset by their dispatch time
/// when grafted, so the whole tree shares one clock origin); `parent`
/// is the parent span's id, `0` for the root. `tag` is a freeform
/// `key=value …` detail string (worker address, slice bounds, outcome).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    pub id: u64,
    pub parent: u64,
    pub name: String,
    pub start_us: u64,
    pub dur_us: u64,
    pub tag: String,
}

/// A finished batch's span tree. Spans are stored flat (parents before
/// children is typical but not required — the renderer resolves links
/// by id), which keeps the wire and JSON forms trivial.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Trace {
    pub trace_id: u64,
    pub spans: Vec<SpanRecord>,
}

impl Trace {
    /// The root span (parent id 0), if the trace is non-empty.
    pub fn root(&self) -> Option<&SpanRecord> {
        self.spans.iter().find(|s| s.parent == 0)
    }

    /// Total wall time of a span minus the wall time of its direct
    /// children — the time it spent working rather than delegating.
    /// Children can overlap the parent (remote spans run concurrently),
    /// so self time saturates at zero instead of going negative.
    pub fn self_us(&self, span: &SpanRecord) -> u64 {
        let children: u64 = self
            .spans
            .iter()
            .filter(|s| s.parent == span.id && s.id != span.id)
            .map(|s| s.dur_us)
            .sum();
        span.dur_us.saturating_sub(children)
    }

    /// Sum of the durations of a stage's direct children of the root by
    /// name — the single timing source the legacy `trace:` line derives
    /// its stage numbers from once a trace exists.
    pub fn stage_us(&self, name: &str) -> u64 {
        let Some(root) = self.root() else { return 0 };
        self.spans
            .iter()
            .filter(|s| s.parent == root.id && s.name == name)
            .map(|s| s.dur_us)
            .sum()
    }

    /// Render the indented span tree, one span per line:
    ///
    /// ```text
    /// trace 00000000000001a4 (2 spans)
    ///   batch  wall=12.345ms self=0.100ms
    ///     match  wall=12.245ms self=12.245ms  [outcome=ok]
    /// ```
    ///
    /// Orphan spans (parent id absent — possible if a reply raced a
    /// failure) are rendered at the end under an `orphans:` marker
    /// rather than dropped: a trace renderer must never hide evidence.
    pub fn render_tree(&self) -> String {
        let mut out = format!("trace {:016x} ({} spans)\n", self.trace_id, self.spans.len());
        let mut emitted = vec![false; self.spans.len()];
        // roots first (parent 0), then depth-first by parent link
        let mut stack: Vec<(usize, usize)> = Vec::new();
        for (i, s) in self.spans.iter().enumerate().rev() {
            if s.parent == 0 {
                stack.push((i, 1));
            }
        }
        while let Some((i, depth)) = stack.pop() {
            if emitted[i] {
                continue; // defensive: a span cycle must not hang the renderer
            }
            emitted[i] = true;
            self.render_line(&mut out, &self.spans[i], depth);
            let id = self.spans[i].id;
            for (j, s) in self.spans.iter().enumerate().rev() {
                if !emitted[j] && s.parent == id && s.id != id {
                    stack.push((j, depth + 1));
                }
            }
        }
        if emitted.iter().any(|&e| !e) {
            out.push_str("  orphans:\n");
            for (i, s) in self.spans.iter().enumerate() {
                if !emitted[i] {
                    self.render_line(&mut out, s, 2);
                }
            }
        }
        out
    }

    fn render_line(&self, out: &mut String, s: &SpanRecord, depth: usize) {
        use std::fmt::Write;
        let ms = |us: u64| us as f64 / 1e3;
        let _ = write!(
            out,
            "{:indent$}{}  wall={:.3}ms self={:.3}ms",
            "",
            s.name,
            ms(s.dur_us),
            ms(self.self_us(s)),
            indent = depth * 2
        );
        if !s.tag.is_empty() {
            let _ = write!(out, "  [{}]", s.tag);
        }
        out.push('\n');
    }

    /// JSON form of one trace (object with `trace_id` as a hex string
    /// and a flat `spans` array). Strings go through the same hardened
    /// escaping as the metrics exporter — worker addresses and outcome
    /// tags are data, not markup.
    pub fn to_json(&self) -> String {
        use std::fmt::Write;
        let mut out = format!(
            "{{\"trace_id\":\"{:016x}\",\"spans\":[",
            self.trace_id
        );
        for (i, s) in self.spans.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"id\":{},\"parent\":{},\"name\":",
                s.id, s.parent
            );
            super::export::json_escape_into(&mut out, &s.name);
            let _ = write!(out, ",\"start_us\":{},\"dur_us\":{},\"tag\":", s.start_us, s.dur_us);
            super::export::json_escape_into(&mut out, &s.tag);
            out.push('}');
        }
        out.push_str("]}");
        out
    }
}

/// Incrementally builds one trace: allocates span ids, records spans,
/// and grafts remote (reply-relative) spans into the local id space.
/// Single-threaded by design — the sharded coordinator already funnels
/// every reply through one batch mutex, and the service layer builds
/// its trace after the batch completes.
#[derive(Debug)]
pub struct TraceBuilder {
    trace: Trace,
    next_id: u64,
}

impl TraceBuilder {
    /// Start a trace with a fresh process-unique id.
    pub fn new() -> TraceBuilder {
        Self::with_id(next_trace_id())
    }

    /// Start a trace under an existing id (tests, resumed contexts).
    pub fn with_id(trace_id: u64) -> TraceBuilder {
        TraceBuilder {
            trace: Trace {
                trace_id,
                spans: Vec::new(),
            },
            next_id: 1,
        }
    }

    pub fn trace_id(&self) -> u64 {
        self.trace.trace_id
    }

    /// Record one span and return its id (parent `0` makes it a root).
    pub fn span(
        &mut self,
        parent: u64,
        name: &str,
        start_us: u64,
        dur_us: u64,
        tag: String,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.trace.spans.push(SpanRecord {
            id,
            parent,
            name: name.to_string(),
            start_us,
            dur_us,
            tag,
        });
        id
    }

    /// Graft a remote reply's spans under `parent`: reply-relative
    /// parent indices are renumbered into this trace's id space
    /// ([`WIRE_PARENT_ROOT`] or any out-of-range index attaches to
    /// `parent` — a malformed index degrades to a flatter tree, never
    /// a panic or a dropped span), and `offset_us` (the dispatch time
    /// of the sub-slice) shifts the remote clock onto the trace's.
    pub fn graft(
        &mut self,
        parent: u64,
        offset_us: u64,
        remote: &[(u32, u64, u64, String, String)],
    ) -> Vec<u64> {
        let ids: Vec<u64> = remote
            .iter()
            .enumerate()
            .map(|(i, _)| self.next_id + i as u64)
            .collect();
        self.next_id += remote.len() as u64;
        for (i, (rel_parent, start_us, dur_us, name, tag)) in remote.iter().enumerate() {
            let p = match ids.get(*rel_parent as usize) {
                Some(&id) if *rel_parent as usize != i => id,
                _ => parent,
            };
            self.trace.spans.push(SpanRecord {
                id: ids[i],
                parent: p,
                name: name.clone(),
                start_us: offset_us.saturating_add(*start_us),
                dur_us: *dur_us,
                tag: tag.clone(),
            });
        }
        ids
    }

    /// Absorb spans that were built elsewhere against this trace's id
    /// space (the shard pool collects its spans under the batch mutex
    /// with ids allocated from [`TraceBuilder::reserve`]d ranges).
    pub fn absorb(&mut self, spans: Vec<SpanRecord>) {
        self.trace.spans.extend(spans);
    }

    /// Reserve `n` span ids for an external collector and return the
    /// first — the collector owns `[first, first + n)`.
    pub fn reserve(&mut self, n: u64) -> u64 {
        let first = self.next_id;
        self.next_id += n;
        first
    }

    pub fn finish(self) -> Trace {
        self.trace
    }
}

impl Default for TraceBuilder {
    fn default() -> Self {
        Self::new()
    }
}

static NEXT_TRACE: AtomicU64 = AtomicU64::new(0);

/// Process-unique trace id: wall-clock seconds at first use in the high
/// bits (so ids from different processes almost never collide and sort
/// roughly by time), a process-local counter in the low bits (so ids
/// within a process never collide). Zero is reserved for "no trace".
pub fn next_trace_id() -> u64 {
    static SEED: OnceLock<u64> = OnceLock::new();
    let seed = *SEED.get_or_init(|| {
        let secs = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(1);
        (secs & 0xFFFF_FFFF) << 24
    });
    let n = NEXT_TRACE.fetch_add(1, Ordering::Relaxed) + 1;
    (seed | (n & 0xFF_FFFF)).max(1)
}

/// Lock-protected retention for finished traces: a ring of the most
/// recent [`RING_CAPACITY`] plus a pinned shelf for slow batches (see
/// module docs). `Arc`-shared so a snapshot never copies span vectors.
#[derive(Debug, Default)]
pub struct FlightRecorder {
    inner: Mutex<Shelves>,
}

#[derive(Debug, Default)]
struct Shelves {
    ring: VecDeque<Arc<Trace>>,
    pinned: VecDeque<Arc<Trace>>,
}

impl FlightRecorder {
    pub fn new() -> FlightRecorder {
        FlightRecorder::default()
    }

    /// Retain a finished trace; `pin` marks it slow (kept on the pinned
    /// shelf past ring eviction). Poisoned-lock recovery: a panicking
    /// recorder user must not take batch serving down with it.
    pub fn record(&self, trace: Trace, pin: bool) -> Arc<Trace> {
        let trace = Arc::new(trace);
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        g.ring.push_back(Arc::clone(&trace));
        while g.ring.len() > RING_CAPACITY {
            g.ring.pop_front();
        }
        if pin {
            g.pinned.push_back(Arc::clone(&trace));
            while g.pinned.len() > PINNED_CAPACITY {
                g.pinned.pop_front();
            }
        }
        trace
    }

    /// `(recent, pinned)`, oldest first in both.
    pub fn snapshot(&self) -> (Vec<Arc<Trace>>, Vec<Arc<Trace>>) {
        let g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        (g.ring.iter().cloned().collect(), g.pinned.iter().cloned().collect())
    }

    /// The `/trace.json` document: `{"recent": […], "pinned": […]}`.
    pub fn to_json(&self) -> String {
        let (recent, pinned) = self.snapshot();
        let join = |ts: &[Arc<Trace>]| {
            ts.iter().map(|t| t.to_json()).collect::<Vec<_>>().join(",")
        };
        format!(
            "{{\"recent\":[{}],\"pinned\":[{}]}}",
            join(&recent),
            join(&pinned)
        )
    }
}

/// The process-global flight recorder (`/trace.json` serves this one).
pub fn recorder() -> &'static FlightRecorder {
    static GLOBAL: OnceLock<FlightRecorder> = OnceLock::new();
    GLOBAL.get_or_init(FlightRecorder::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_trace(id: u64) -> Trace {
        let mut b = TraceBuilder::with_id(id);
        let root = b.span(0, "batch", 0, 1000, String::new());
        let m = b.span(root, "match", 100, 800, String::new());
        b.span(m, "slice", 120, 300, "worker=\"a:1\" outcome=ok".into());
        b.finish()
    }

    #[test]
    fn builder_allocates_unique_ids_and_links_parents() {
        let t = toy_trace(7);
        assert_eq!(t.trace_id, 7);
        assert_eq!(t.spans.len(), 3);
        let root = t.root().unwrap();
        assert_eq!(root.name, "batch");
        let ids: std::collections::HashSet<u64> = t.spans.iter().map(|s| s.id).collect();
        assert_eq!(ids.len(), 3, "span ids are unique");
        assert_eq!(t.stage_us("match"), 800);
        assert_eq!(t.stage_us("nope"), 0);
        // self time: batch delegated 800 of its 1000, match 300 of 800
        assert_eq!(t.self_us(root), 200);
        let m = t.spans.iter().find(|s| s.name == "match").unwrap();
        assert_eq!(t.self_us(m), 500);
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn render_tree_indents_and_keeps_orphans() {
        let mut t = toy_trace(0xAB);
        let out = t.render_tree();
        assert!(out.starts_with("trace 00000000000000ab (3 spans)\n"), "{out}");
        assert!(out.contains("\n  batch  wall=1.000ms self=0.200ms\n"), "{out}");
        assert!(out.contains("\n    match  wall=0.800ms"), "{out}");
        assert!(out.contains("\n      slice  wall=0.300ms"), "{out}");
        assert!(out.contains("[worker=\"a:1\" outcome=ok]"), "{out}");
        assert!(!out.contains("orphans"), "{out}");
        // a span whose parent id does not exist still renders
        t.spans.push(SpanRecord {
            id: 99,
            parent: 42,
            name: "lost".into(),
            start_us: 0,
            dur_us: 5,
            tag: String::new(),
        });
        let out = t.render_tree();
        assert!(out.contains("orphans:"), "{out}");
        assert!(out.contains("lost"), "{out}");
    }

    #[test]
    fn graft_renumbers_remote_parents_and_offsets_clocks() {
        let mut b = TraceBuilder::with_id(1);
        let root = b.span(0, "batch", 0, 100, String::new());
        let slice = b.span(root, "slice", 10, 80, String::new());
        // remote reply: span 0 is the worker's probe (parent = dispatch
        // span), span 1 is its match nested under span 0
        let remote = vec![
            (WIRE_PARENT_ROOT, 0u64, 30u64, "probe".to_string(), String::new()),
            (0u32, 5u64, 20u64, "match".to_string(), "tier=avx2".to_string()),
        ];
        let ids = b.graft(slice, 10, &remote);
        let t = b.finish();
        let probe = t.spans.iter().find(|s| s.name == "probe").unwrap();
        let mat = t.spans.iter().find(|s| s.name == "match").unwrap();
        assert_eq!(probe.parent, slice);
        assert_eq!(probe.start_us, 10, "offset by dispatch time");
        assert_eq!(mat.parent, ids[0], "reply-relative index renumbered");
        assert_eq!(mat.start_us, 15);
        assert_eq!(mat.tag, "tier=avx2");
        // out-of-range and self-referential parents degrade to `parent`
        let mut b = TraceBuilder::with_id(2);
        let root = b.span(0, "batch", 0, 1, String::new());
        let ids = b.graft(
            root,
            0,
            &[
                (7u32, 0, 1, "evil".to_string(), String::new()),
                (1u32, 0, 1, "selfish".to_string(), String::new()),
            ],
        );
        let t = b.finish();
        assert!(t
            .spans
            .iter()
            .all(|s| s.parent == root || s.parent == 0 || ids.contains(&s.parent)));
        assert_eq!(t.spans.iter().find(|s| s.name == "evil").unwrap().parent, root);
        assert_eq!(t.spans.iter().find(|s| s.name == "selfish").unwrap().parent, root);
    }

    #[test]
    fn json_escapes_hostile_tags() {
        let mut b = TraceBuilder::with_id(3);
        b.span(0, "na\"me\\", 0, 1, "tag\nwith {braces}".into());
        let json = b.finish().to_json();
        assert!(json.contains("\"trace_id\":\"0000000000000003\""), "{json}");
        assert!(json.contains("\"na\\\"me\\\\\""), "{json}");
        assert!(json.contains("\\n"), "{json}");
        assert!(!json.contains('\n'), "raw newline must never reach the document");
    }

    #[test]
    fn flight_recorder_rings_and_pins() {
        let rec = FlightRecorder::new();
        for i in 0..(RING_CAPACITY as u64 + 4) {
            rec.record(toy_trace(i + 1), false);
        }
        let (recent, pinned) = rec.snapshot();
        assert_eq!(recent.len(), RING_CAPACITY);
        assert!(pinned.is_empty());
        // oldest were evicted, newest survive
        assert_eq!(recent.last().unwrap().trace_id, RING_CAPACITY as u64 + 4);
        assert!(recent.iter().all(|t| t.trace_id > 4));
        // a pinned slow trace survives arbitrarily many later records
        let slow = rec.record(toy_trace(0xDEAD), true);
        for i in 0..(RING_CAPACITY as u64 + 4) {
            rec.record(toy_trace(1000 + i), false);
        }
        let (recent, pinned) = rec.snapshot();
        assert!(recent.iter().all(|t| t.trace_id != 0xDEAD));
        assert_eq!(pinned.len(), 1);
        assert_eq!(pinned[0].trace_id, slow.trace_id);
        // the pinned shelf is bounded too
        for i in 0..(PINNED_CAPACITY as u64 + 8) {
            rec.record(toy_trace(2000 + i), true);
        }
        let (_, pinned) = rec.snapshot();
        assert_eq!(pinned.len(), PINNED_CAPACITY);
        assert!(pinned.iter().all(|t| t.trace_id != 0xDEAD), "oldest pin evicted");
        let json = rec.to_json();
        assert!(json.starts_with("{\"recent\":["), "{json}");
        assert!(json.contains("\"pinned\":["), "{json}");
    }
}
